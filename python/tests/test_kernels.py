"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
hypothesis-swept over shapes and value scales."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.fused_linear import fused_linear
from compile.kernels.layernorm import layernorm
from compile.kernels.topk_mask import threshold_sparsify

SETTINGS = dict(max_examples=20, deadline=None)


def rnd(rng, *shape, scale=1.0):
    return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)


# ---------------------------------------------------------------------------
# threshold_sparsify (AdaTopK select pass)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 400),
    cols=st.integers(1, 200),
    tau=st.floats(0.0, 3.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_threshold_sparsify_matches_ref(rows, cols, tau, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, rows, cols)
    t = jnp.float32(tau)
    got = threshold_sparsify(x, t)
    want = ref.threshold_sparsify(x, t)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_sparsify_1d_and_3d():
    rng = np.random.default_rng(0)
    for shape in [(7,), (3, 5, 11), (1, 1)]:
        x = rnd(rng, *shape)
        got = threshold_sparsify(x, jnp.float32(0.5))
        want = ref.threshold_sparsify(x, jnp.float32(0.5))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_threshold_zero_keeps_everything():
    rng = np.random.default_rng(1)
    x = rnd(rng, 33, 9)
    got = threshold_sparsify(x, jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))


def test_threshold_huge_zeroes_everything():
    rng = np.random.default_rng(2)
    x = rnd(rng, 50, 3)
    got = threshold_sparsify(x, jnp.float32(1e9))
    assert np.all(np.asarray(got) == 0.0)


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    rows=st.integers(1, 300),
    d=st.integers(2, 256),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(rows, d, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, rows, d, scale=2.0)
    g = rnd(rng, d)
    b = rnd(rng, d)
    got = layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_layernorm_batched_3d():
    rng = np.random.default_rng(3)
    x = rnd(rng, 4, 17, 32)
    g = jnp.ones(32, jnp.float32)
    b = jnp.zeros(32, jnp.float32)
    got = layernorm(x, g, b)
    want = ref.layernorm(x, g, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)
    # Output rows should be ~zero-mean/unit-var.
    out = np.asarray(got).reshape(-1, 32)
    np.testing.assert_allclose(out.mean(axis=1), 0.0, atol=1e-4)


# ---------------------------------------------------------------------------
# fused_linear (matmul + bias + GELU)
# ---------------------------------------------------------------------------


@settings(**SETTINGS)
@given(
    m=st.integers(1, 200),
    k=st.integers(1, 160),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, m, k, scale=0.5)
    w = rnd(rng, k, n, scale=0.2)
    b = rnd(rng, n, scale=0.1)
    got = fused_linear(x, w, b)
    want = ref.fused_linear(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_fused_linear_exact_tile_multiple():
    rng = np.random.default_rng(4)
    x = rnd(rng, 256, 128, scale=0.3)
    w = rnd(rng, 128, 256, scale=0.2)
    b = rnd(rng, 256, scale=0.1)
    got = fused_linear(x, w, b)
    want = ref.fused_linear(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    t=st.integers(1, 160),
    h=st.sampled_from([1, 2, 4]),
    dh=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(t, h, dh, seed):
    rng = np.random.default_rng(seed)
    q = rnd(rng, t, h, dh, scale=0.5)
    k = rnd(rng, t, h, dh, scale=0.5)
    v = rnd(rng, t, h, dh, scale=0.5)
    got = attention(q, k, v)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_attention_is_causal():
    """Changing a future key/value must not change earlier outputs."""
    rng = np.random.default_rng(5)
    t, h, dh = 16, 2, 8
    q = rnd(rng, t, h, dh)
    k = rnd(rng, t, h, dh)
    v = rnd(rng, t, h, dh)
    base = np.asarray(attention(q, k, v))
    k2 = k.at[-1].set(k[-1] + 100.0)
    v2 = v.at[-1].set(v[-1] - 50.0)
    pert = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(base[: t - 1], pert[: t - 1], rtol=1e-6, atol=1e-6)
    assert not np.allclose(base[t - 1], pert[t - 1])
