"""L2 model correctness: stage composition, RAD legs vs whole-graph autodiff,
flat-param packing, optimizer updates, compression entry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import CONFIGS
from compile.kernels import ref

CFG = CONFIGS["tiny"]


def init_flat(segs, rng):
    flat = np.zeros(model.layout_size(segs), np.float32)
    off = 0
    for s in segs:
        if s.init == "zeros":
            vals = np.zeros(s.size, np.float32)
        elif s.init == "ones":
            vals = np.ones(s.size, np.float32)
        else:
            std = float(s.init.split(":")[1])
            vals = rng.standard_normal(s.size).astype(np.float32) * std
        flat[off : off + s.size] = vals
        off += s.size
    return jnp.asarray(flat)


@pytest.fixture(scope="module")
def stage_flats():
    rng = np.random.default_rng(42)
    flats = [init_flat(model.embed_segments(CFG), rng)]
    for _ in range(CFG.n_body_stages):
        flats.append(init_flat(model.body_segments(CFG), rng))
    flats.append(init_flat(model.head_segments(CFG), rng))
    return flats


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(7)
    tokens = jnp.asarray(
        rng.integers(0, CFG.vocab, (CFG.microbatch, CFG.seq_len)), jnp.int32
    )
    targets = jnp.asarray(
        rng.integers(0, CFG.vocab, (CFG.microbatch, CFG.seq_len)), jnp.int32
    )
    return tokens, targets


def test_layout_sizes_positive():
    for name, cfg in CONFIGS.items():
        if cfg.n_layers % cfg.n_body_stages != 0:
            continue
        assert model.layout_size(model.embed_segments(cfg)) > 0
        assert model.layout_size(model.body_segments(cfg)) > 0
        assert model.layout_size(model.head_segments(cfg)) > 0


def test_unpack_roundtrip():
    segs = model.embed_segments(CFG)
    rng = np.random.default_rng(0)
    flat = init_flat(segs, rng)
    p = model.unpack(flat, segs)
    assert p["tok_emb"].shape == (CFG.vocab, CFG.d_model)
    assert p["pos_emb"].shape == (CFG.seq_len, CFG.d_model)
    # Concatenating back reproduces the flat vector.
    recat = jnp.concatenate([p[s.name].reshape(-1) for s in segs])
    np.testing.assert_array_equal(np.asarray(recat), np.asarray(flat))


def test_stage_shapes(stage_flats, batch):
    tokens, targets = batch
    x = model.embed_fwd(CFG, stage_flats[0], tokens)
    assert x.shape == (CFG.microbatch, CFG.seq_len, CFG.d_model)
    y = model.body_fwd(CFG, stage_flats[1], x)
    assert y.shape == x.shape
    loss = model.head_loss(CFG, stage_flats[-1], y, targets)
    assert loss.shape == ()
    assert np.isfinite(float(loss))


def test_initial_loss_near_uniform(stage_flats, batch):
    """With random init the LM loss should sit near ln(vocab)."""
    tokens, targets = batch
    loss = float(model.full_forward_loss(CFG, stage_flats, tokens, targets))
    expected = np.log(CFG.vocab)
    assert abs(loss - expected) < 1.0, f"loss={loss} vs ln(V)={expected}"


def test_pipeline_rad_matches_whole_graph_autodiff(stage_flats, batch):
    """The paper's RAD: composing per-stage bwd legs must equal end-to-end
    autodiff of the full model. This is the core remote-autodiff invariant."""
    tokens, targets = batch

    # Whole-graph reference gradients.
    ref_loss, ref_grads = jax.value_and_grad(
        lambda fl: model.full_forward_loss(CFG, fl, tokens, targets)
    )(stage_flats)

    # Pipeline legs, exactly as the rust coordinator drives them.
    x0 = model.embed_fwd(CFG, stage_flats[0], tokens)
    acts = [x0]
    for s in range(CFG.n_body_stages):
        acts.append(model.body_fwd(CFG, stage_flats[1 + s], acts[-1]))
    loss, dx, dhead = model.head_fwd_loss(CFG, stage_flats[-1], acts[-1], targets)
    grads = [None] * len(stage_flats)
    grads[-1] = dhead
    for s in reversed(range(CFG.n_body_stages)):
        dx, dbody = model.body_bwd(CFG, stage_flats[1 + s], acts[s], dx)
        grads[1 + s] = dbody
    grads[0] = model.embed_bwd(CFG, stage_flats[0], tokens, dx)

    np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
    for i, (g, rg) in enumerate(zip(grads, ref_grads)):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(rg), rtol=2e-4, atol=2e-5,
            err_msg=f"stage {i} grads",
        )


def test_body_pallas_parity(stage_flats, batch):
    """body_fwd with Pallas kernels == pure-jnp body_fwd."""
    tokens, _ = batch
    x = model.embed_fwd(CFG, stage_flats[0], tokens)
    y_ref = model.body_fwd(CFG, stage_flats[1], x, use_pallas=False)
    y_pal = model.body_fwd(CFG, stage_flats[1], x, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(y_pal), np.asarray(y_ref), rtol=5e-5, atol=5e-5
    )


def test_sgd_update_math():
    p = jnp.asarray([1.0, 2.0], jnp.float32)
    g = jnp.asarray([0.5, -0.5], jnp.float32)
    m = jnp.asarray([0.1, 0.1], jnp.float32)
    p2, m2 = model.sgd_update(p, g, m, jnp.float32(0.1), jnp.float32(0.9))
    np.testing.assert_allclose(np.asarray(m2), [0.59, -0.41], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(p2), [1.0 - 0.059, 2.0 + 0.041], rtol=1e-6)


def test_adam_update_decreases_towards_gradient():
    p = jnp.zeros(4, jnp.float32)
    g = jnp.asarray([1.0, -1.0, 2.0, 0.0], jnp.float32)
    m = jnp.zeros(4, jnp.float32)
    v = jnp.zeros(4, jnp.float32)
    p2, m2, v2 = model.adam_update(p, g, m, v, jnp.float32(0.01), jnp.float32(1.0))
    # First Adam step moves ~lr in -sign(g) direction.
    assert p2[0] < 0 and p2[1] > 0 and p2[2] < 0 and p2[3] == 0
    assert np.all(np.asarray(v2) >= 0)


def test_topk_compress_matches_ref():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.standard_normal((4, 8, 16)).astype(np.float32))
    k = 32
    got = model.topk_compress(x, k)
    want = ref.topk_sparsify(x, k)
    got_nz = int(np.count_nonzero(np.asarray(got)))
    assert got_nz >= k  # ties at the threshold may keep a few extra
    assert got_nz <= k + 4
    # The support of the reference is preserved.
    w = np.asarray(want)
    gmask = np.asarray(got) != 0
    assert np.all((w != 0) <= gmask)
    np.testing.assert_allclose(np.asarray(got)[w != 0], w[w != 0])


def test_gradients_flow_through_every_segment(stage_flats, batch):
    """No dead parameters: every segment receives nonzero gradient signal."""
    tokens, targets = batch
    _, grads = jax.value_and_grad(
        lambda fl: model.full_forward_loss(CFG, fl, tokens, targets)
    )(stage_flats)
    layouts = (
        [model.embed_segments(CFG)]
        + [model.body_segments(CFG)] * CFG.n_body_stages
        + [model.head_segments(CFG)]
    )
    for si, (g, segs) in enumerate(zip(grads, layouts)):
        g = np.asarray(g)
        off = 0
        for s in segs:
            seg_g = g[off : off + s.size]
            off += s.size
            assert np.any(seg_g != 0.0), f"dead segment stage{si}:{s.name}"
