"""AOT emission: manifest schema, HLO text sanity, entry shapes."""

import json
import os

import pytest

from compile import aot, model
from compile.configs import CONFIGS


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    root = tmp_path_factory.mktemp("artifacts")
    manifest = aot.emit_config(CONFIGS["tiny"], str(root), with_pallas_parity=True)
    return str(root), manifest


def test_manifest_schema(emitted):
    root, m = emitted
    assert m["format"] == 1
    cfg = m["config"]
    assert cfg["name"] == "tiny"
    assert cfg["n_stages"] == len(m["stages"])
    assert m["stages"][0]["kind"] == "embed"
    assert m["stages"][-1]["kind"] == "head"
    for st in m["stages"]:
        assert st["param_size"] == sum(s["size"] for s in st["segments"])
        # Offsets are contiguous.
        off = 0
        for seg in st["segments"]:
            assert seg["offset"] == off
            off += seg["size"]


def test_hlo_files_exist_and_parse_as_text(emitted):
    root, m = emitted
    out_dir = os.path.join(root, "tiny")
    for name, e in m["entries"].items():
        path = os.path.join(out_dir, e["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.lstrip().startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text


def test_entry_io_shapes(emitted):
    _, m = emitted
    cfg = CONFIGS["tiny"]
    e = m["entries"]["body_fwd"]
    act = [cfg.microbatch, cfg.seq_len, cfg.d_model]
    assert e["inputs"][1]["shape"] == act
    assert e["outputs"][0]["shape"] == act
    assert e["inputs"][0]["shape"] == [model.layout_size(model.body_segments(cfg))]

    h = m["entries"]["head_fwd_loss"]
    assert h["outputs"][0]["shape"] == []  # scalar loss
    assert h["outputs"][1]["shape"] == act

    for tag in ("embed", "body", "head"):
        assert f"sgd_{tag}" in m["entries"]
        assert f"adam_{tag}" in m["entries"]
    assert "topk_compress_act" in m["entries"]
    assert "body_fwd_pallas" in m["entries"]


def test_manifest_json_roundtrip(emitted):
    root, m = emitted
    with open(os.path.join(root, "tiny", "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == m
