"""AOT lowering: jax stage functions -> HLO text + manifest.json.

Run once by `make artifacts`; python never runs on the training path.

Interchange format is HLO *text*, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 rust crate links) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly. All entries are lowered with return_tuple=True, so the rust side
always receives a tuple literal (even for single outputs).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .configs import CONFIGS, ModelConfig


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io_desc(avals):
    out = []
    for name, a in avals:
        out.append({"name": name, "dtype": str(a.dtype), "shape": list(a.shape)})
    return out


class Emitter:
    def __init__(self, out_dir: str, cfg: ModelConfig):
        self.out_dir = out_dir
        self.cfg = cfg
        self.entries = {}

    def emit(self, name, fn, inputs, outputs_desc):
        """Lower fn at the given input specs and write <name>.hlo.txt."""
        specs = [a for (_, a) in inputs]
        # keep_unused: gradients of gather-like ops don't read the params
        # values; without this jax prunes the argument and the rust side's
        # positional buffer count no longer matches the manifest.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries[name] = {
            "file": fname,
            "inputs": _io_desc(inputs),
            "outputs": outputs_desc,
        }
        print(f"  {name}: {len(text) / 1024:.0f} KiB HLO")


def _segments_desc(segs):
    out = []
    off = 0
    for s in segs:
        out.append(
            {
                "name": s.name,
                "shape": list(s.shape),
                "size": s.size,
                "offset": off,
                "init": s.init,
            }
        )
        off += s.size
    return out


def emit_config(cfg: ModelConfig, root: str, with_pallas_parity: bool):
    out_dir = os.path.join(root, cfg.name)
    os.makedirs(out_dir, exist_ok=True)
    print(f"[aot] config={cfg.name} -> {out_dir}")
    em = Emitter(out_dir, cfg)

    B, T, D, V = cfg.microbatch, cfg.seq_len, cfg.d_model, cfg.vocab
    e_segs = model.embed_segments(cfg)
    b_segs = model.body_segments(cfg)
    h_segs = model.head_segments(cfg)
    Pe, Pb, Ph = (model.layout_size(s) for s in (e_segs, b_segs, h_segs))

    f32, i32 = jnp.float32, jnp.int32
    act = _spec((B, T, D))
    tok = _spec((B, T), i32)

    # ---- forward/backward stage legs -------------------------------------
    em.emit(
        "embed_fwd",
        lambda p, t: (model.embed_fwd(cfg, p, t),),
        [("params", _spec((Pe,))), ("tokens", tok)],
        _io_desc([("x", act)]),
    )
    em.emit(
        "embed_bwd",
        lambda p, t, dx: (model.embed_bwd(cfg, p, t, dx),),
        [("params", _spec((Pe,))), ("tokens", tok), ("dx", act)],
        _io_desc([("dparams", _spec((Pe,)))]),
    )
    em.emit(
        "body_fwd",
        lambda p, x: (model.body_fwd(cfg, p, x, use_pallas=False),),
        [("params", _spec((Pb,))), ("x", act)],
        _io_desc([("y", act)]),
    )
    em.emit(
        "body_bwd",
        lambda p, x, dy: model.body_bwd(cfg, p, x, dy),
        [("params", _spec((Pb,))), ("x", act), ("dy", act)],
        _io_desc([("dx", act), ("dparams", _spec((Pb,)))]),
    )
    em.emit(
        "head_fwd_loss",
        lambda p, x, t: model.head_fwd_loss(cfg, p, x, t),
        [("params", _spec((Ph,))), ("x", act), ("targets", tok)],
        _io_desc(
            [("loss", _spec(())), ("dx", act), ("dparams", _spec((Ph,)))]
        ),
    )

    # ---- optimizer updates (one artifact per distinct flat size) ---------
    for tag, P in (("embed", Pe), ("body", Pb), ("head", Ph)):
        flat = _spec((P,))
        scalar = _spec(())
        em.emit(
            f"sgd_{tag}",
            lambda p, g, m, lr, mu: model.sgd_update(p, g, m, lr, mu),
            [
                ("params", flat),
                ("grads", flat),
                ("momentum", flat),
                ("lr", scalar),
                ("mu", scalar),
            ],
            _io_desc([("params2", flat), ("momentum2", flat)]),
        )
        em.emit(
            f"adam_{tag}",
            lambda p, g, m, v, lr, t: model.adam_update(p, g, m, v, lr, t),
            [
                ("params", flat),
                ("grads", flat),
                ("m", flat),
                ("v", flat),
                ("lr", scalar),
                ("t", scalar),
            ],
            _io_desc([("params2", flat), ("m2", flat), ("v2", flat)]),
        )

    # ---- compression entry (L1 Pallas kernel on the compute path) --------
    k = max(1, cfg.act_elems // cfg.compress_ratio)
    em.emit(
        "topk_compress_act",
        lambda x: (model.topk_compress(x, k),),
        [("x", act)],
        _io_desc([("x_sparse", act)]),
    )

    # ---- pallas-parity body stage (proves L1 lowers into the same HLO) ---
    if with_pallas_parity:
        em.emit(
            "body_fwd_pallas",
            lambda p, x: (model.body_fwd(cfg, p, x, use_pallas=True),),
            [("params", _spec((Pb,))), ("x", act)],
            _io_desc([("y", act)]),
        )

    # ---- manifest ---------------------------------------------------------
    stages = [
        {
            "kind": "embed",
            "param_size": Pe,
            "fwd": "embed_fwd",
            "bwd": "embed_bwd",
            "segments": _segments_desc(e_segs),
        }
    ]
    for _ in range(cfg.n_body_stages):
        stages.append(
            {
                "kind": "body",
                "param_size": Pb,
                "fwd": "body_fwd",
                "bwd": "body_bwd",
                "segments": _segments_desc(b_segs),
            }
        )
    stages.append(
        {
            "kind": "head",
            "param_size": Ph,
            "fwd": "head_fwd_loss",
            "bwd": "head_fwd_loss",
            "segments": _segments_desc(h_segs),
        }
    )

    manifest = {
        "format": 1,
        "config": {
            "name": cfg.name,
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers,
            "seq_len": cfg.seq_len,
            "microbatch": cfg.microbatch,
            "n_stages": cfg.n_stages,
            "compress_ratio": cfg.compress_ratio,
            "topk_k": k,
        },
        "stages": stages,
        "entries": em.entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--configs",
        default="tiny,fig8,small",
        help="comma-separated config names (see configs.py)",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    names = [n for n in args.configs.split(",") if n]
    for name in names:
        cfg = CONFIGS[name]
        # Pallas-parity artifact only for the test config: interpret-mode
        # lowering expands to while-loop HLO, which gets large for big stages.
        emit_config(cfg, args.out, with_pallas_parity=(name == "tiny"))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump({"format": 1, "configs": names}, f, indent=2)
    print(f"[aot] wrote top-level manifest for {names}")


if __name__ == "__main__":
    main()
