"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: each kernel in this package must match
its oracle here to float32 tolerance across the hypothesis shape/dtype sweep
in python/tests/test_kernels.py.
"""

import jax
import jax.numpy as jnp


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def gelu(x):
    """tanh-approximation GELU (GPT-2 style)."""
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * jnp.power(x, 3))))


def fused_linear(x, w, b):
    """y = gelu(x @ w + b)."""
    return gelu(jnp.dot(x, w) + b)


def threshold_sparsify(x, tau):
    """Zero out entries with |x| < tau (the AdaTopK streaming-select pass)."""
    return jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))


def topk_sparsify(x, k):
    """Exact dense Top-K-by-magnitude sparsification of a flat vector.

    Returns the dense decoded vector (zeros off-support), matching Fig. 6 of
    the paper: keep the k largest |x|, zero the rest.
    """
    flat = x.reshape(-1)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def attention(q, k, v, scale=None):
    """Plain causal self-attention. q,k,v: [T, H, Dh] (single sequence)."""
    t = q.shape[0]
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    # [H, T, T]
    logits = jnp.einsum("qhd,khd->hqk", q, k) * scale
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.finfo(logits.dtype).min)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", probs, v)
