"""L1 Pallas kernel: fused y = gelu(x @ w + b), MXU-tiled.

The MLP up-projection is the FLOPs hot spot of the transformer stage. The
kernel tiles (M, K) x (K, N) into (BM, BK) x (BK, BN) blocks with a K-loop
accumulating into a VMEM scratch accumulator; the bias add + tanh-GELU run in
the epilogue of the final K step, so the pre-activation never round-trips
HBM. Block sizes are multiples of the 128x128 MXU systolic tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

BM, BK, BN = 128, 128, 128


def _gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * jnp.power(x, 3))))


def _kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros((BM, BN), jnp.float32)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        o_ref[...] = _gelu(acc_ref[...] + b_ref[...]).astype(o_ref.dtype)


@jax.jit
def fused_linear(x, w, b):
    """gelu(x @ w + b). x: [M, K]; w: [K, N]; b: [N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    pad_m, pad_k, pad_n = (-m) % BM, (-k) % BK, (-n) % BN
    xp = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    wp = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    bp = jnp.pad(b, (0, pad_n))
    gm, gk, gn = xp.shape[0] // BM, xp.shape[1] // BK, wp.shape[1] // BN

    out = pl.pallas_call(
        functools.partial(_kernel, k_steps=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((BM, BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((BK, BN), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((BN,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((BM, BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), x.dtype),
        scratch_shapes=[pltpu.VMEM((BM, BN), jnp.float32)],
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]
