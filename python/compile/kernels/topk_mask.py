"""L1 Pallas kernel: magnitude-threshold sparsification (AdaTopK hot path).

Hardware adaptation of the paper's CUDA Top-K (§6 "Compression"): instead of
a device-wide sort (poor fit for the TPU VPU), the k-th-largest |x| threshold
``tau`` is computed once at L2 (see model.topk_compress) and this kernel does
a single streaming select over VMEM tiles: ``out = |x| >= tau ? x : 0``.
One HBM read + one HBM write per element, embarrassingly block-parallel.

Lowered with interpret=True so the op becomes plain HLO executable on the
CPU PJRT client (real-TPU Mosaic lowering is compile-only in this repo).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sized for VMEM: 8x128 is the fp32 VPU native tile; 256x128 (=128 KiB
# in fp32) keeps in+out double-buffered tiles well under the ~16 MiB VMEM.
BLOCK_ROWS = 256
BLOCK_COLS = 128


def _kernel(x_ref, tau_ref, o_ref):
    x = x_ref[...]
    tau = tau_ref[0]
    o_ref[...] = jnp.where(jnp.abs(x) >= tau, x, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=())
def threshold_sparsify(x, tau):
    """Zero entries with |x| < tau. x: any shape; tau: scalar array."""
    orig_shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    # Pad to a whole number of (BLOCK_ROWS*BLOCK_COLS) tiles.
    tile = BLOCK_ROWS * BLOCK_COLS
    pad = (-n) % tile
    padded = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK_COLS)
    rows = padded.shape[0]
    grid = rows // BLOCK_ROWS

    out = pl.pallas_call(
        _kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
            # The scalar threshold is broadcast to every grid step.
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, x.dtype),
        interpret=True,
    )(padded, tau.reshape(1).astype(x.dtype))
    return out.reshape(-1)[:n].reshape(orig_shape)
