"""L1 Pallas kernel: LayerNorm over the last axis.

Row-tiled for VMEM: each grid step normalizes a (BLOCK_ROWS, D) tile. The
mean/variance reduction happens entirely in VMEM (single pass, Welford not
needed at these tile sizes), gamma/beta are broadcast per step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 128


def _kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    o_ref[...] = (x - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis. x: [..., D]; gamma/beta: [D]."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    pad = (-rows) % BLOCK_ROWS
    x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = x2.shape[0] // BLOCK_ROWS

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=True,
    )(x2, gamma, beta)
    return out[:rows].reshape(orig_shape)
