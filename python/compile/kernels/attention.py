"""L1 Pallas kernel: causal self-attention, query-tiled per head.

Grid = (heads, q_tiles). Each step loads one (BQ, Dh) query tile plus the
full (T, Dh) key/value panels for that head into VMEM (sequences in this repo
are <= 1k, so K/V panels fit comfortably), computes the causally-masked
softmax(QK^T)V for the tile, and writes one output tile. This is the
"keep K/V resident, stream Q" schedule — the TPU analogue of the paper's
GPU threadblock tiling, chosen because VMEM (~16 MiB) fits whole K/V panels
where an SM's shared memory cannot.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BQ = 128  # query rows per grid step


def _kernel(q_ref, k_ref, v_ref, o_ref, *, scale, seq_len):
    qi = pl.program_id(1)
    q = q_ref[0]  # [BQ, Dh]
    k = k_ref[0]  # [T, Dh]
    v = v_ref[0]  # [T, Dh]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # Causal mask: query row (global) qi*BQ + r attends keys <= that index.
    rows = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = (cols <= rows) & (cols < seq_len)
    logits = jnp.where(valid, logits, jnp.float32(-1e30))
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p.astype(v.dtype), v, preferred_element_type=jnp.float32).astype(
        o_ref.dtype
    )


@jax.jit
def attention(q, k, v):
    """Causal self-attention. q,k,v: [T, H, Dh] -> [T, H, Dh]."""
    t, h, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5
    # [H, T, Dh] layout so the head axis is the outer grid dimension.
    qh = jnp.moveaxis(q, 1, 0)
    kh = jnp.moveaxis(k, 1, 0)
    vh = jnp.moveaxis(v, 1, 0)
    pad_q = (-t) % BQ
    qp = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    tq = qp.shape[1]

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, seq_len=t),
        grid=(h, tq // BQ),
        in_specs=[
            pl.BlockSpec((1, BQ, dh), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((1, t, dh), lambda hh, qi: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, dh), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, dh), q.dtype),
        interpret=True,
    )(qp, kh, vh)
    return jnp.moveaxis(out[:, :t, :], 0, 1)
