"""L2: GPT-2-style transformer as pipeline-stage functions over FLAT params.

Every stage function takes a single flat f32[P] parameter vector (plus
activations / tokens) so the rust coordinator can hold, update, and
communicate per-stage parameters as opaque buffers. The segment layout
(name, shape, offset, init) is exported in the manifest so rust can
initialize parameters without python.

Stage functions (lowered to HLO by aot.py):
  embed_fwd(flat, tokens)            -> x
  embed_bwd(flat, tokens, dx)        -> dflat
  body_fwd(flat, x)                  -> y            (layers_per_stage blocks)
  body_bwd(flat, x, dy)              -> (dx, dflat)  (recompute-based)
  head_fwd_loss(flat, x, targets)    -> (loss, dx, dflat)
  sgd_update(p, g, m, lr, mom)       -> (p', m')
  adam_update(p, g, m, v, lr, t)     -> (p', m', v')
  topk_compress(x)                   -> dense sparsified x (Pallas threshold)
"""

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .kernels import ref
from .kernels.attention import attention as pallas_attention
from .kernels.fused_linear import fused_linear as pallas_fused_linear
from .kernels.layernorm import layernorm as pallas_layernorm
from .kernels.topk_mask import threshold_sparsify as pallas_threshold


# ---------------------------------------------------------------------------
# Flat parameter layout
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Segment:
    name: str
    shape: tuple
    init: str  # "normal:<std>" | "zeros" | "ones"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))


def embed_segments(cfg: ModelConfig):
    return [
        Segment("tok_emb", (cfg.vocab, cfg.d_model), "normal:0.02"),
        Segment("pos_emb", (cfg.seq_len, cfg.d_model), "normal:0.01"),
    ]


def block_segments(cfg: ModelConfig, li: int):
    d = cfg.d_model
    # GPT-2 init: residual-out projections scaled by 1/sqrt(2*n_layers).
    res_std = 0.02 / math.sqrt(2.0 * cfg.n_layers)
    return [
        Segment(f"b{li}.ln1_g", (d,), "ones"),
        Segment(f"b{li}.ln1_b", (d,), "zeros"),
        Segment(f"b{li}.qkv_w", (d, 3 * d), "normal:0.02"),
        Segment(f"b{li}.qkv_b", (3 * d,), "zeros"),
        Segment(f"b{li}.proj_w", (d, d), f"normal:{res_std:.6g}"),
        Segment(f"b{li}.proj_b", (d,), "zeros"),
        Segment(f"b{li}.ln2_g", (d,), "ones"),
        Segment(f"b{li}.ln2_b", (d,), "zeros"),
        Segment(f"b{li}.fc1_w", (d, 4 * d), "normal:0.02"),
        Segment(f"b{li}.fc1_b", (4 * d,), "zeros"),
        Segment(f"b{li}.fc2_w", (4 * d, d), f"normal:{res_std:.6g}"),
        Segment(f"b{li}.fc2_b", (d,), "zeros"),
    ]


def body_segments(cfg: ModelConfig):
    segs = []
    for li in range(cfg.layers_per_stage):
        segs.extend(block_segments(cfg, li))
    return segs


def head_segments(cfg: ModelConfig):
    return [
        Segment("lnf_g", (cfg.d_model,), "ones"),
        Segment("lnf_b", (cfg.d_model,), "zeros"),
        Segment("out_w", (cfg.d_model, cfg.vocab), "normal:0.02"),
        Segment("out_b", (cfg.vocab,), "zeros"),
    ]


def layout_size(segs) -> int:
    return sum(s.size for s in segs)


def unpack(flat, segs):
    """Slice the flat vector into named arrays (static offsets)."""
    out = {}
    off = 0
    for s in segs:
        out[s.name] = jax.lax.dynamic_slice_in_dim(flat, off, s.size).reshape(s.shape)
        off += s.size
    return out


# ---------------------------------------------------------------------------
# Stage forward functions
# ---------------------------------------------------------------------------


def embed_fwd(cfg: ModelConfig, flat, tokens):
    """tokens i32[B,T] -> activations f32[B,T,D]."""
    p = unpack(flat, embed_segments(cfg))
    return p["tok_emb"][tokens] + p["pos_emb"][None, :, :]


def _block_fwd(cfg: ModelConfig, p, li, x, use_pallas):
    """One pre-LN transformer block. x: [B,T,D]."""
    d, h = cfg.d_model, cfg.n_heads
    ln = pallas_layernorm if use_pallas else ref.layernorm
    attn = pallas_attention if use_pallas else ref.attention

    def g(name):
        return p[f"b{li}.{name}"]

    # Attention sublayer.
    a_in = ln(x, g("ln1_g"), g("ln1_b"))
    qkv = jnp.dot(a_in, g("qkv_w")) + g("qkv_b")  # [B,T,3D]
    b, t, _ = qkv.shape
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, d // h)
    k = k.reshape(b, t, h, d // h)
    v = v.reshape(b, t, h, d // h)
    o = jax.vmap(attn)(q, k, v)  # [B,T,H,Dh]
    o = o.reshape(b, t, d)
    x = x + jnp.dot(o, g("proj_w")) + g("proj_b")

    # MLP sublayer.
    m_in = ln(x, g("ln2_g"), g("ln2_b"))
    if use_pallas:
        hmid = pallas_fused_linear(m_in.reshape(b * t, d), g("fc1_w"), g("fc1_b"))
        hmid = hmid.reshape(b, t, 4 * d)
    else:
        hmid = ref.gelu(jnp.dot(m_in, g("fc1_w")) + g("fc1_b"))
    x = x + jnp.dot(hmid, g("fc2_w")) + g("fc2_b")
    return x


def body_fwd(cfg: ModelConfig, flat, x, use_pallas=None):
    """layers_per_stage blocks. x: [B,T,D] -> [B,T,D]."""
    if use_pallas is None:
        use_pallas = cfg.use_pallas
    p = unpack(flat, body_segments(cfg))
    for li in range(cfg.layers_per_stage):
        x = _block_fwd(cfg, p, li, x, use_pallas)
    return x


def head_loss(cfg: ModelConfig, flat, x, targets):
    """Final LN + LM head + mean token cross-entropy. targets: i32[B,T]."""
    p = unpack(flat, head_segments(cfg))
    xn = ref.layernorm(x, p["lnf_g"], p["lnf_b"])
    logits = jnp.dot(xn, p["out_w"]) + p["out_b"]  # [B,T,V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Stage backward functions (recompute-based RAD legs)
# ---------------------------------------------------------------------------


def embed_bwd(cfg: ModelConfig, flat, tokens, dx):
    _, vjp = jax.vjp(lambda f: embed_fwd(cfg, f, tokens), flat)
    (dflat,) = vjp(dx)
    return dflat


def body_bwd(cfg: ModelConfig, flat, x, dy):
    _, vjp = jax.vjp(lambda f, xx: body_fwd(cfg, f, xx), flat, x)
    dflat, dx = vjp(dy)
    return dx, dflat


def head_fwd_loss(cfg: ModelConfig, flat, x, targets):
    (loss, (dflat, dx)) = jax.value_and_grad(
        lambda f, xx: head_loss(cfg, f, xx, targets), argnums=(0, 1)
    )(flat, x)
    return loss, dx, dflat


# ---------------------------------------------------------------------------
# Optimizers (flat-vector updates; donated in AOT lowering)
# ---------------------------------------------------------------------------


def sgd_update(p, g, m, lr, momentum):
    """Heavy-ball SGD: m' = mu*m + g; p' = p - lr*m'."""
    m2 = momentum * m + g
    return p - lr * m2, m2


def adam_update(p, g, m, v, lr, t, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0):
    """AdamW with bias correction; t is the 1-based step as f32 scalar."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2


# ---------------------------------------------------------------------------
# Compression entry (L1 kernel on the compute path)
# ---------------------------------------------------------------------------


def topk_compress(x, k: int):
    """Dense Top-K sparsification via the Pallas threshold kernel.

    tau = k-th largest |x| (exact, lax.top_k at L2); the Pallas kernel then
    streams the select. Returns the dense decoded tensor (Fig. 6).
    """
    flat = x.reshape(-1)
    # k-th largest |x| via full sort: lax.top_k lowers to an HLO `topk`
    # custom attribute (largest=true) that the xla_extension 0.5.1 text
    # parser rejects, while `sort` round-trips fine.
    tau = jnp.sort(jnp.abs(flat))[flat.shape[0] - k]
    return pallas_threshold(x, tau)


# ---------------------------------------------------------------------------
# Whole-model reference (used by tests to check stage composition)
# ---------------------------------------------------------------------------


def full_forward_loss(cfg: ModelConfig, stage_flats, tokens, targets):
    """Compose embed -> body stages -> head, as the pipeline would."""
    x = embed_fwd(cfg, stage_flats[0], tokens)
    for s in range(cfg.n_body_stages):
        x = body_fwd(cfg, stage_flats[1 + s], x)
    return head_loss(cfg, stage_flats[-1], x, targets)
