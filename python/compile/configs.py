"""Model / pipeline configurations for AOT lowering.

Each config fixes the shapes the HLO artifacts are compiled for. The rust
coordinator reads these back from artifacts/manifest.json — python never
runs at training time.

Stage plan: stage 0 = embedding, stages 1..S-2 = transformer-block stages
(n_layers split evenly), stage S-1 = LM head (+final LN + loss).
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int
    microbatch: int
    n_stages: int  # embed + body stages + head; >= 3
    compress_ratio: int = 100  # default Top-K ratio for the compress artifact
    use_pallas: bool = False  # lower body stages through the Pallas kernels

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_body_stages(self) -> int:
        assert self.n_stages >= 3, "need embed + >=1 body + head"
        return self.n_stages - 2

    @property
    def layers_per_stage(self) -> int:
        assert self.n_layers % self.n_body_stages == 0, (
            f"n_layers={self.n_layers} not divisible by "
            f"body stages={self.n_body_stages}"
        )
        return self.n_layers // self.n_body_stages

    @property
    def act_elems(self) -> int:
        """Elements in one inter-stage activation message."""
        return self.microbatch * self.seq_len * self.d_model


CONFIGS = {
    # CI/test config: small enough that pytest + cargo test stay fast.
    "tiny": ModelConfig(
        name="tiny", vocab=256, d_model=64, n_heads=4, n_layers=2,
        seq_len=32, microbatch=2, n_stages=4,
    ),
    # Fig. 8 convergence config (~0.9M params) — hundreds of steps in minutes.
    "fig8": ModelConfig(
        name="fig8", vocab=256, d_model=128, n_heads=4, n_layers=4,
        seq_len=64, microbatch=4, n_stages=4,
    ),
    # E2E driver config (~6.5M params), byte-level LM.
    "small": ModelConfig(
        name="small", vocab=256, d_model=256, n_heads=8, n_layers=8,
        seq_len=128, microbatch=8, n_stages=6,
    ),
    # ~100M-parameter configuration (compiled on demand; see EXPERIMENTS.md).
    "gpt2-100m": ModelConfig(
        name="gpt2-100m", vocab=8192, d_model=768, n_heads=12, n_layers=12,
        seq_len=256, microbatch=4, n_stages=6,
    ),
}
