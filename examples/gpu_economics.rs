//! Table 1 reproduction: GPU-days and #GPUs to pre-train / load GPT-3.
//!
//! Run: cargo run --release --example gpu_economics

use fusionllm::cmd;
use fusionllm::util::cli::Args;

fn main() -> anyhow::Result<()> {
    cmd::economics(&Args::default())
}
