//! Fig. 8 reproduction: training-loss curves for dense vs uniform Top-K vs
//! AdaTopK (ratio 100) on the transformer-LM workload.
//!
//! The paper's finding: uniform Top-K hurts convergence on the vision
//! models and is neutral-to-helpful on GPT-2; AdaTopK tracks dense closely
//! everywhere. At LM scale we reproduce the transformer row of Fig. 8;
//! higher `--ratio` values sharpen the separation.
//!
//! Run: cargo run --release --example convergence_fig8 -- [--steps 150]
//! Output: fig8_<compressor>.csv per variant + a summary table.

use fusionllm::broker::{self, Job};
use fusionllm::compress::CompressKind;
use fusionllm::util::cli::Args;
use fusionllm::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 150);
    let ratio = args.f64("ratio", 100.0);
    let config = args.str("config", "fig8");

    let mut table = Table::new(vec![
        "compressor",
        "first-5 loss",
        "last-5 loss",
        "Δ",
        "wire/iter (MiB)",
    ]);
    for kind in [CompressKind::None, CompressKind::TopK, CompressKind::AdaTopK] {
        let job = Job {
            config: config.clone(),
            iters: steps,
            lr: 0.1,
            n_micro: 2,
            compress: kind,
            ratio,
            ..Job::default()
        };
        eprintln!("running {} ({steps} steps)...", kind.name());
        let r = broker::run(&job)?;
        let first: f32 = r.losses.iter().take(5).sum::<f32>() / 5.0;
        let last: f32 = r.losses.iter().rev().take(5).sum::<f32>() / 5.0;
        table.row(vec![
            kind.name().to_string(),
            format!("{first:.4}"),
            format!("{last:.4}"),
            format!("{:+.4}", last - first),
            format!("{:.2}", r.wire_bytes[0] / 1048576.0),
        ]);
        let path = format!("fig8_{}.csv", kind.name());
        std::fs::write(&path, r.to_csv())?;
        eprintln!("wrote {path}");
    }
    println!("\nFig. 8 (transformer-LM row), ratio {ratio}, {steps} steps:");
    table.print();
    println!("\nExpected shape: dense and adatopk track closely; uniform topk");
    println!("lags (or, per the paper's GPT-2 observation, may act as a mild");
    println!("regularizer at moderate ratios).");
    Ok(())
}
