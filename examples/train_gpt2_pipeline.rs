//! END-TO-END DRIVER: real pipeline training over PJRT artifacts on the
//! simulated geo-distributed testbed.
//!
//! Trains the GPT-2-style byte-LM (config `small`, ~6.6M params by default;
//! pass --config gpt2-100m after emitting those artifacts for the ~100M
//! variant) for a few hundred steps with OP-Fence placement and AdaTopK
//! compression, logging the loss curve and the simulated geo-iteration
//! latency. Results are recorded in EXPERIMENTS.md.
//!
//! Run:
//!   make artifacts
//!   cargo run --release --example train_gpt2_pipeline -- \
//!       --config small --steps 200 --compress adatopk --ratio 100
//!
//! Output: train_<config>_<compressor>.csv (iter, loss, wall, sim-geo).

use fusionllm::broker::{self, Job};
use fusionllm::util::cli::Args;
use fusionllm::util::math::fmt_secs;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let mut job = Job::from_args(&args)?;
    // E2E defaults: small config, AdaTopK, a few hundred steps.
    if args.opt_str("config").is_none() {
        job.config = "small".into();
    }
    if args.opt_str("steps").is_none() {
        job.iters = 200;
    }
    if args.opt_str("compress").is_none() {
        job.compress = fusionllm::compress::CompressKind::AdaTopK;
    }
    if args.opt_str("lr").is_none() {
        job.lr = 0.15;
    }
    if args.opt_str("micro").is_none() {
        job.n_micro = 4;
    }

    println!(
        "e2e: config={} testbed={} scheduler={} compress={} ratio={} \
         n_micro={} steps={} lr={}",
        job.config,
        job.testbed,
        job.scheduler,
        job.compress.name(),
        job.ratio,
        job.n_micro,
        job.iters,
        job.lr
    );
    let t0 = std::time::Instant::now();
    let report = broker::run(&job)?;
    let total = t0.elapsed().as_secs_f64();

    println!("\nstage placement (stage -> CompNode): {:?}", report.placement);
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!("step {i:4}  loss {loss:.4}");
        }
    }
    println!(
        "\nfirst-10 mean loss {:.4} -> last-10 mean loss {:.4}",
        report.losses.iter().take(10).sum::<f32>() / 10f32.min(report.losses.len() as f32),
        report.losses.iter().rev().take(10).sum::<f32>()
            / 10f32.min(report.losses.len() as f32),
    );
    println!(
        "wall total {}  |  simulated geo-iteration {}  |  wire/iter {}",
        fmt_secs(total),
        fmt_secs(report.mean_sim_latency()),
        fusionllm::util::math::fmt_bytes(report.wire_bytes[0]),
    );

    let path = format!("train_{}_{}.csv", report.config, report.compressor);
    std::fs::write(&path, report.to_csv())?;
    println!("wrote {path}");
    Ok(())
}
