//! Quickstart: the FusionLLM pipeline end to end in simulation.
//!
//! 1. Synthesize a geo-distributed testbed (Fig. 9).
//! 2. Build the GPT2-XL OP-DAG (Table 6 workload).
//! 3. Schedule it with OP-Fence vs. the baselines.
//! 4. Attach the AdaTopK compression plan (Eq. 7).
//! 5. Simulate an iteration and compare latencies.
//!
//! Run: cargo run --release --example quickstart

use fusionllm::cluster::testbed;
use fusionllm::compress::{CompressKind, CompressPlan};
use fusionllm::cost::throughput::PipelineParams;
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler;
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::util::math::fmt_secs;
use fusionllm::util::table::Table;

fn main() -> anyhow::Result<()> {
    // 1. 24 heterogeneous GPUs across two clusters, 8 Mbps – 10 Gbps links.
    let tb = testbed::testbed1(1);
    println!("{}\n", tb.summary());

    // 2. The model as an OP-DAG: each node is a layer with FLOPs/output
    //    size/param attributes the workload estimator uses (§3.5).
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    println!(
        "GPT2-XL OP-DAG: {} ops, {:.2} GFLOPs fwd/microbatch, max degree {}",
        dag.len(),
        dag.total_flops_fwd() / 1e9,
        dag.max_degree()
    );

    // 3–5. Schedule, compress, simulate.
    let n_micro = 2;
    let params = PipelineParams { n_micro, micro_size: 3, include_bwd: true };
    let mut table = Table::new(vec!["scheduler", "compression", "iter latency", "speedup"]);
    let mut baseline = None;
    for sched_name in ["equal-number", "equal-compute", "opfence"] {
        for comp in [CompressKind::None, CompressKind::TopK, CompressKind::AdaTopK] {
            let part = scheduler::by_name(sched_name)?.schedule(&dag, &tb)?;
            let plan = match comp {
                CompressKind::None => CompressPlan::dense(tb.nodes.len()),
                CompressKind::AdaTopK => {
                    CompressPlan::adatopk(&dag, &part, &tb, params, 100.0)
                }
                k => CompressPlan::uniform(k, 100.0, tb.nodes.len()),
            };
            let sp = StagePlan::from_partition(&dag, &part, &tb);
            let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), n_micro);
            let sim = simulate_iteration(&sp, &tb, &sched, &plan);
            let base = *baseline.get_or_insert(sim.iter_s);
            table.row(vec![
                sched_name.to_string(),
                comp.name().to_string(),
                fmt_secs(sim.iter_s),
                format!("{:.2}x", base / sim.iter_s),
            ]);
        }
    }
    table.print();
    println!("\nNext: `cargo run --release --example train_gpt2_pipeline` for");
    println!("real PJRT training over the artifacts (`make artifacts` first).");
    Ok(())
}
