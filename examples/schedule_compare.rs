//! Fig. 10 companion: iteration latency for every scheduler × compressor
//! combination, on both testbeds, via the discrete-event simulator.
//!
//! Run: cargo run --release --example schedule_compare -- [--micro 2]

use fusionllm::cluster::testbed;
use fusionllm::compress::{CompressKind, CompressPlan};
use fusionllm::cost::throughput::PipelineParams;
use fusionllm::opdag::builders::{transformer_chain, TransformerSpec};
use fusionllm::pipeline::{PipelineSchedule, ScheduleKind};
use fusionllm::scheduler;
use fusionllm::simnet::{simulate_iteration, StagePlan};
use fusionllm::util::cli::Args;
use fusionllm::util::math::fmt_secs;
use fusionllm::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_micro = args.usize("micro", 2);
    let ratio = args.f64("ratio", 100.0);

    for tb_id in [1, 2] {
        let tb = testbed::by_id(tb_id, 1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let params = PipelineParams { n_micro, micro_size: 3, include_bwd: true };
        println!("\n=== {} — GPT2-XL, ratio {ratio}, n_micro {n_micro} ===", tb.summary());
        let mut t = Table::new(vec!["scheduler", "dense", "topk", "adatopk", "best speedup"]);
        let mut worst_dense: f64 = 0.0;
        let mut rows = Vec::new();
        for s in ["equal-number", "equal-compute", "opfence", "opfence-dp"] {
            let part = scheduler::by_name(s)?.schedule(&dag, &tb)?;
            let sp = StagePlan::from_partition(&dag, &part, &tb);
            let sched = PipelineSchedule::new(ScheduleKind::GPipe, sp.n_stages(), n_micro);
            let mut lat = Vec::new();
            for kind in [CompressKind::None, CompressKind::TopK, CompressKind::AdaTopK] {
                let plan = match kind {
                    CompressKind::None => CompressPlan::dense(tb.nodes.len()),
                    CompressKind::AdaTopK => {
                        CompressPlan::adatopk(&dag, &part, &tb, params, ratio)
                    }
                    k => CompressPlan::uniform(k, ratio, tb.nodes.len()),
                };
                lat.push(simulate_iteration(&sp, &tb, &sched, &plan).iter_s);
            }
            worst_dense = worst_dense.max(lat[0]);
            rows.push((s.to_string(), lat));
        }
        for (s, lat) in rows {
            let best = lat.iter().cloned().fold(f64::MAX, f64::min);
            t.row(vec![
                s,
                fmt_secs(lat[0]),
                fmt_secs(lat[1]),
                fmt_secs(lat[2]),
                format!("{:.2}x", worst_dense / best),
            ]);
        }
        t.print();
    }
    println!("\n(speedup = worst dense baseline / this row's best combination;");
    println!(" the paper reports 1.45–9.39x across testbeds and workloads)");
    Ok(())
}
