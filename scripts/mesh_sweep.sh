#!/usr/bin/env bash
# Mesh flow-control sweep: --mesh-window x FUSIONLLM_CREDIT_DIV.
#
# Runs the 4-stage Null-backend mesh demo (broker + 4 worker processes
# on localhost) over every (window, credit divisor) pair and reports
# per-run wall time, so MESH_WINDOW and CREDIT_BATCH_DIV defaults in
# rust/src/transport/mesh.rs are tuned from measurements instead of
# folklore. Results feed the sweep table in EXPERIMENTS.md §Mesh data
# plane — re-run after transport changes (e.g. the vectored frame
# writer) and update the table if the optimum moves.
#
# Usage:
#   scripts/mesh_sweep.sh [steps]
#
# Requires a rust toolchain (cargo). The CI container currently ships
# none — run this on a dev machine.

set -euo pipefail
cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found -- this sweep needs a rust toolchain" >&2
    exit 1
fi

STEPS="${1:-30}"
WINDOWS=(4 8 16 32 64)
DIVS=(2 4 8)
PORT=4971
TOKEN=sweep

cargo build --release --quiet
BIN=target/release/fusionllm

run_one() {
    local window=$1 div=$2
    local pids=()
    FUSIONLLM_CREDIT_DIV="$div" "$BIN" train \
        --backend null --transport tcp --data-plane mesh \
        --listen "127.0.0.1:$PORT" --token "$TOKEN" \
        --workers 4 --placement 0,1,2,3 --micro 8 \
        --mesh-window "$window" --steps "$STEPS" >/dev/null &
    local broker=$!
    sleep 0.3
    for d in 0 1 2 3; do
        FUSIONLLM_CREDIT_DIV="$div" "$BIN" worker \
            --connect "127.0.0.1:$PORT" --token "$TOKEN" --device "$d" \
            --peer-listen 127.0.0.1:0 >/dev/null &
        pids+=($!)
    done
    wait "$broker"
    wait "${pids[@]}" 2>/dev/null || true
}

printf '%-8s %-6s %-10s\n' window div wall_s
for w in "${WINDOWS[@]}"; do
    for d in "${DIVS[@]}"; do
        t0=$(date +%s.%N)
        run_one "$w" "$d"
        t1=$(date +%s.%N)
        printf '%-8s %-6s %-10s\n' "$w" "$d" \
            "$(awk -v a="$t1" -v b="$t0" 'BEGIN{printf "%.3f", a-b}')"
        PORT=$((PORT + 1))
    done
done
