//! FusionLLM — a decentralized LLM training system on geo-distributed GPUs
//! with adaptive compression (reproduction of Tang et al., 2024).
//!
//! Layer 3 of the three-layer stack: the rust coordinator. See DESIGN.md.

// Manual `(n + t - 1) / t` stays portable to toolchains without
// `usize::div_ceil`; guard the allow for clippy versions predating the lint.
#![allow(unknown_lints)]
#![allow(clippy::manual_div_ceil)]

pub mod broker;
pub mod checkpoint;
pub mod cluster;
pub mod cmd;
pub mod compress;
pub mod cost;
pub mod opdag;
pub mod pipeline;
pub mod runtime;
pub mod scheduler;
pub mod simnet;
pub mod trainer;
pub mod transport;
pub mod util;
pub mod worker;
