//! Combined int8-quantized sparse wire encoding (ISSUE 2 tentpole).
//!
//! Top-K/Random-K select *which* values cross a link; this module shrinks
//! *how wide* each value is: instead of an f32 per kept element, values are
//! transmitted as linear int8 codes plus a scale — one per message
//! (`CompressCfg::QSparse`) or one per feature row for the chunked hot path
//! (`CompressCfg::QSparseRows`, scales ride in the `values` array). A kept
//! element then costs 4 B (u32 index) + 1 B (code) ≈ 5 B on the wire vs
//! 8 B for f32-sparse, and a dense fallback costs ~1 B/value vs 4.
//!
//! Quantization is lossy, but the loss is *bounded* (≤ scale/2 per value)
//! and — when wrapped in `ErrorFeedback` — the dropped fraction re-enters
//! the next message's residual exactly like the sparsification error, so
//! convergence degrades gracefully (EF-SGD argument; paper §10).
//!
//! Determinism contract: quantization is a sequential post-pass over the
//! (already thread-count-deterministic) compressed pairs, so the combined
//! encoding is bit-identical for every worker thread count.

use super::sparsify::{Compressed, CompressScratch, Compressor};
use crate::opdag::data::CompressCfg;
use crate::util::simd;

/// Per-value wire representation for compressed payloads, negotiated per
/// link by the broker (`CompressPlan::codec_for_kind`). This is the
/// "ValueCodec" knob: the support selection (Top-K etc.) is orthogonal.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ValueCodec {
    /// Values travel as f32 (the seed wire format).
    #[default]
    F32,
    /// Values travel as int8 codes + f32 scale(s).
    Int8,
    /// Int8 codes with delta-coded u24 sparse indices (`QSparseRowsDelta`):
    /// 3 B/index instead of 4 for payloads under 16M elements, falling
    /// back to the plain int8 layout when the payload is too large or the
    /// support is not ascending (Random-K).
    Int8Delta,
}

impl ValueCodec {
    pub fn parse(s: &str) -> anyhow::Result<ValueCodec> {
        Ok(match s {
            "f32" | "fp32" => ValueCodec::F32,
            "int8" | "q8" => ValueCodec::Int8,
            "int8-u24" | "q8u24" => ValueCodec::Int8Delta,
            other => anyhow::bail!("unknown wire codec `{other}` (f32|int8|int8-u24)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ValueCodec::F32 => "f32",
            ValueCodec::Int8 => "int8",
            ValueCodec::Int8Delta => "int8-u24",
        }
    }

    /// Wire bytes per *kept sparse* element (value + index). f32 keeps the
    /// paper's Fig. 6 accounting (f32 value + int64 index = 12 B); int8 is
    /// the actual packed layout (1 B code + u32 index = 5 B, per-message
    /// scale amortized away); int8-u24 packs delta-coded u24 indices
    /// (1 B code + 3 B index = 4 B). Feeds Eq. 7 and the cost model, so
    /// the scheduler sees the real link cost of each encoding.
    pub fn sparse_bytes_per_value(self) -> f64 {
        match self {
            ValueCodec::F32 => 12.0,
            ValueCodec::Int8 => 5.0,
            ValueCodec::Int8Delta => 4.0,
        }
    }

    /// Wire bytes per element of a *dense* payload under this codec.
    pub fn dense_bytes_per_value(self) -> f64 {
        match self {
            ValueCodec::F32 => 4.0,
            ValueCodec::Int8 | ValueCodec::Int8Delta => 1.0,
        }
    }
}

/// Wraps any sparsifying compressor and quantizes its kept values to int8
/// on the way out. `row = Some(chunk)` emits one scale per `chunk`-wide
/// feature row (`QSparseRows`, matching `ChunkedTopK`); `row = None` emits
/// a single per-message scale (`QSparse`). A dense inner result
/// (`CompressCfg::None`) quantizes to the existing `Int8` encoding.
#[derive(Debug, Clone, Copy)]
pub struct Quantized<C: Compressor> {
    pub inner: C,
    /// Scale granularity: `Some(chunk)` = per-row scales, `None` = one
    /// per-message scale.
    pub row: Option<usize>,
}

impl<C: Compressor> Quantized<C> {
    /// Per-message scale (whole-tensor Top-K / Random-K).
    pub fn per_message(inner: C) -> Self {
        Quantized { inner, row: None }
    }

    /// Per-row scales of width `chunk` (pair with `ChunkedTopK { chunk }`).
    pub fn per_row(inner: C, chunk: usize) -> Self {
        Quantized { inner, row: Some(chunk.max(1)) }
    }
}

impl<C: Compressor> Compressor for Quantized<C> {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, scratch: &mut CompressScratch) {
        self.inner.compress_with(data, out, scratch);
        quantize_compressed(out, self.row, &mut scratch.scales);
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        match &c.cfg {
            CompressCfg::Int8 { scale, .. } => {
                out.fill(0.0);
                simd::dequant_into(&c.bytes, *scale, out);
            }
            CompressCfg::QSparse { scale, .. } => {
                out.fill(0.0);
                simd::scatter_int8(&c.indices, &c.bytes, *scale, out);
            }
            CompressCfg::QSparseRows { chunk, .. }
            | CompressCfg::QSparseRowsDelta { chunk, .. } => {
                out.fill(0.0);
                let chunk = (*chunk as usize).max(1);
                simd::scatter_int8_rows(&c.indices, &c.bytes, &c.values, chunk, out);
            }
            // An unquantized payload (shouldn't occur on this path, but the
            // trait allows mixing): defer to the inner decoder.
            _ => self.inner.decompress(c, out),
        }
    }

    fn name(&self) -> &'static str {
        "int8+sparse"
    }
}

/// Absmax linear scale: full int8 range for the largest magnitude, 1.0 for
/// all-zero payloads (every code is then 0). THE int8 quantization formula
/// — `Int8Quantizer` and every `Quantized` encoding share these two
/// helpers so the dense and sparse int8 wire formats cannot drift apart.
pub(crate) fn absmax_scale(values: &[f32]) -> f32 {
    let absmax = simd::max_abs(values);
    if absmax > 0.0 {
        // The max() guards subnormal absmax (÷127 could underflow to 0 and
        // poison every code with v/0 = inf); normal payloads never hit it.
        (absmax / 127.0).max(f32::MIN_POSITIVE)
    } else {
        1.0
    }
}

/// Encode one value against a scale (round-to-nearest, saturating ±127).
#[inline]
pub(crate) fn code(v: f32, scale: f32) -> u8 {
    simd::quant_code(v, scale)
}

/// Quantize a compressed payload in place: `values` → int8 `bytes` (+ scale
/// in the cfg, or per-row scales left *in* `values`). Already-quantized
/// payloads pass through untouched. `scales` is scratch for the per-row
/// absmax pass (reused per link — no steady-state allocation).
pub(crate) fn quantize_compressed(
    out: &mut Compressed,
    row: Option<usize>,
    scales: &mut Vec<f32>,
) {
    let (ratio, total_len) = match out.cfg {
        CompressCfg::None => {
            let scale = absmax_scale(&out.values);
            out.bytes.clear();
            simd::quantize_codes(&out.values, scale, &mut out.bytes);
            out.cfg = CompressCfg::Int8 { scale, total_len: out.values.len() as u32 };
            out.values.clear();
            return;
        }
        CompressCfg::TopK { ratio, total_len } => (ratio, total_len),
        CompressCfg::RandomK { ratio, total_len, .. } => (ratio, total_len),
        // Int8 / QSparse / QSparseRows(Delta): already quantized.
        _ => return,
    };
    match row {
        None => {
            let scale = absmax_scale(&out.values);
            out.bytes.clear();
            simd::quantize_codes(&out.values, scale, &mut out.bytes);
            out.cfg = CompressCfg::QSparse { ratio, total_len, scale };
            out.values.clear();
        }
        Some(chunk) => {
            let chunk = chunk.max(1);
            let n_rows = (total_len as usize + chunk - 1) / chunk;
            scales.clear();
            scales.resize(n_rows, 0.0);
            for (&i, &v) in out.indices.iter().zip(&out.values) {
                let r = &mut scales[i as usize / chunk];
                *r = r.max(v.abs());
            }
            for s in scales.iter_mut() {
                // Same subnormal guard as `absmax_scale`.
                *s = if *s > 0.0 { (*s / 127.0).max(f32::MIN_POSITIVE) } else { 1.0 };
            }
            out.bytes.clear();
            out.bytes.reserve(out.values.len());
            {
                // Quantize runs of same-row elements SIMD-wide with their
                // scale splatted; codes append in input order, so the
                // byte stream is identical to the per-element map.
                let (indices, values, bytes) = (&out.indices, &out.values, &mut out.bytes);
                let n = indices.len().min(values.len());
                let mut s = 0usize;
                while s < n {
                    let row = indices[s] as usize / chunk;
                    let mut e = s + 1;
                    while e < n && indices[e] as usize / chunk == row {
                        e += 1;
                    }
                    simd::quantize_codes(&values[s..e], scales[row], bytes);
                    s = e;
                }
            }
            // Row scales ride in `values` (f32 region of the wire format).
            out.values.clear();
            out.values.extend_from_slice(scales);
            out.cfg = CompressCfg::QSparseRows { ratio, total_len, chunk: chunk as u32 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparsify::{ChunkedTopK, Int8Quantizer, NoCompress, RandomK, TopK};
    use crate::util::rng::Rng;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect()
    }

    /// Kept support identical to the f32 compressor; each kept value within
    /// half a scale step of the original.
    #[test]
    fn qsparse_roundtrip_within_half_scale() {
        let xs = data(2000, 1);
        let plain = TopK { ratio: 20.0 };
        let quant = Quantized::per_message(plain);
        let c = quant.compress(&xs);
        let scale = match c.cfg {
            CompressCfg::QSparse { scale, .. } => scale,
            ref other => panic!("expected QSparse, got {other:?}"),
        };
        assert_eq!(c.indices, plain.compress(&xs).indices, "same support");
        assert!(c.values.is_empty(), "values moved to int8 codes");
        let mut out = vec![0.0f32; xs.len()];
        quant.decompress(&c, &mut out);
        for (&i, &b) in c.indices.iter().zip(&c.bytes) {
            let orig = xs[i as usize];
            let deq = (b as i8) as f32 * scale;
            assert!(
                (orig - deq).abs() <= scale * 0.5 + scale * 1e-4,
                "idx {i}: {orig} vs {deq} (scale {scale})"
            );
            assert_eq!(out[i as usize], deq);
        }
    }

    #[test]
    fn qsparse_rows_scales_per_row() {
        // Rows with wildly different magnitudes: a shared scale would crush
        // the small rows to zero codes; per-row scales keep them.
        let d = 64usize;
        let rows = 8usize;
        let mut rng = Rng::new(2);
        let mut xs: Vec<f32> = (0..rows * d).map(|_| (rng.f32() - 0.5) * 0.01).collect();
        for v in &mut xs[..d] {
            *v *= 1e4; // row 0 is 10^4 larger
        }
        let inner = ChunkedTopK { ratio: 8.0, chunk: d };
        let per_row = Quantized::per_row(inner, d);
        let per_msg = Quantized::per_message(TopK { ratio: 8.0 });
        let c = per_row.compress(&xs);
        match c.cfg {
            CompressCfg::QSparseRows { chunk, total_len, .. } => {
                assert_eq!(chunk as usize, d);
                assert_eq!(total_len as usize, xs.len());
            }
            ref other => panic!("expected QSparseRows, got {other:?}"),
        }
        assert_eq!(c.values.len(), rows, "one scale per row");
        let mut out_row = vec![0.0f32; xs.len()];
        per_row.decompress(&c, &mut out_row);
        let mut out_msg = vec![0.0f32; xs.len()];
        per_msg.decompress(&per_msg.compress(&xs), &mut out_msg);
        let err = |out: &[f32]| -> f64 {
            xs.iter().zip(out).map(|(a, b)| ((a - b) * (a - b)) as f64).sum()
        };
        assert!(
            err(&out_row) < err(&out_msg) / 10.0,
            "per-row {} vs per-message {}",
            err(&out_row),
            err(&out_msg)
        );
        // Small rows still deliver nonzero mass under per-row scales.
        assert!(out_row[d..].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn dense_fallback_matches_int8_quantizer() {
        let xs = data(256, 3);
        let a = Quantized::per_message(NoCompress).compress(&xs);
        let b = Int8Quantizer.compress(&xs);
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.bytes, b.bytes);
        let mut out = vec![0.0f32; xs.len()];
        Quantized::per_message(NoCompress).decompress(&a, &mut out);
        let mut want = vec![0.0f32; xs.len()];
        Int8Quantizer.decompress(&b, &mut want);
        assert_eq!(out, want);
    }

    #[test]
    fn randomk_support_survives_quantization() {
        let xs = data(1000, 4);
        let plain = RandomK { ratio: 50.0, seed: 9 };
        let quant = Quantized::per_message(plain);
        let c = quant.compress(&xs);
        assert_eq!(c.indices, plain.compress(&xs).indices);
        assert_eq!(c.bytes.len(), c.indices.len());
    }

    #[test]
    fn all_zero_payload_quantizes_to_zero_codes() {
        let xs = vec![0.0f32; 128];
        let quant = Quantized::per_row(ChunkedTopK { ratio: 8.0, chunk: 32 }, 32);
        let c = quant.compress(&xs);
        assert!(c.bytes.iter().all(|&b| b == 0));
        assert!(c.values.iter().all(|&s| s == 1.0), "empty rows scale = 1");
        let mut out = vec![7.0f32; 128];
        quant.decompress(&c, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn wire_bytes_five_per_value() {
        // 4 B index + 1 B code (+ 4 B scale per message) — the tentpole
        // byte budget: ≤ 5 B/value + O(1), vs 8 actual / 12 accounted for
        // f32-sparse.
        let xs = data(10_000, 5);
        let c = Quantized::per_message(TopK { ratio: 100.0 }).compress(&xs);
        let k = c.indices.len() as f64;
        assert_eq!(c.bytes.len(), c.indices.len());
        assert!((c.wire_bytes() - (5.0 * k + 4.0)).abs() < 1e-9, "{}", c.wire_bytes());
        // Dense fallback: ~1 B/value.
        let d = Quantized::per_message(NoCompress).compress(&xs);
        assert!((d.wire_bytes() - (xs.len() as f64 + 4.0)).abs() < 1e-9);
    }
}
