//! AdaTopK (§5.2): adaptive per-node compression ratios.
//!
//! Given the user ratio r and the estimated per-node communication times
//! R_p from the dense cost model, Eq. 7 assigns
//!
//! ```text
//! r_i = max(1, 3r * R_i / max_p(R_p))
//! ```
//!
//! so the slowest links get (up to) the full 3r ratio while fast links are
//! compressed little or not at all — preserving convergence (Fig. 8) at
//! nearly uniform-Top-K latency (Fig. 10).

use crate::cluster::Testbed;
use crate::compress::CompressKind;
use crate::cost::throughput::{dense_bytes, evaluate, PipelineParams};
use crate::opdag::{Dag, Partition};

/// Which message direction gets compressed. The paper compresses both
/// activations and gradients; at small model scale forward-activation
/// sparsification can dominate the convergence gap, so the direction is a
/// first-class knob (ablated in EXPERIMENTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressDirection {
    Both,
    /// Gradients only (backward messages).
    BwdOnly,
    /// Activations only (forward messages).
    FwdOnly,
}

impl CompressDirection {
    pub fn parse(s: &str) -> anyhow::Result<CompressDirection> {
        Ok(match s {
            "both" => CompressDirection::Both,
            "bwd" | "grad" => CompressDirection::BwdOnly,
            "fwd" | "act" => CompressDirection::FwdOnly,
            other => anyhow::bail!("unknown direction `{other}` (both|bwd|fwd)"),
        })
    }
}

/// Per-node compression ratios; messages *received by* node i are
/// compressed at `node_ratio[i]` (R_i is node i's retrieval time, §3.5).
#[derive(Debug, Clone)]
pub struct CompressPlan {
    pub kind: CompressKind,
    /// Base user-facing ratio r.
    pub base_ratio: f64,
    /// Effective ratio per CompNode (indexed by node id); 1.0 = dense.
    pub node_ratio: Vec<f64>,
    /// Which direction is compressed (default Both, per the paper).
    pub direction: CompressDirection,
}

impl CompressPlan {
    /// Dense plan (no compression anywhere).
    pub fn dense(n_nodes: usize) -> CompressPlan {
        CompressPlan {
            kind: CompressKind::None,
            base_ratio: 1.0,
            node_ratio: vec![1.0; n_nodes],
            direction: CompressDirection::Both,
        }
    }

    /// Uniform plan: every node compresses at r.
    pub fn uniform(kind: CompressKind, ratio: f64, n_nodes: usize) -> CompressPlan {
        CompressPlan {
            kind,
            base_ratio: ratio,
            node_ratio: vec![ratio; n_nodes],
            direction: CompressDirection::Both,
        }
    }

    /// AdaTopK plan (Eq. 7) from the dense cost model.
    pub fn adatopk(
        dag: &Dag,
        part: &Partition,
        testbed: &Testbed,
        params: PipelineParams,
        base_ratio: f64,
    ) -> CompressPlan {
        let est = evaluate(dag, part, testbed, params, &dense_bytes);
        let mut r_by_node = vec![0.0f64; testbed.nodes.len()];
        for c in &est.per_node {
            r_by_node[c.node] = c.comm_s;
        }
        let rmax = r_by_node.iter().cloned().fold(0.0f64, f64::max);
        let node_ratio = r_by_node
            .iter()
            .map(|&ri| {
                if rmax <= 0.0 {
                    1.0
                } else {
                    (3.0 * base_ratio * ri / rmax).max(1.0)
                }
            })
            .collect();
        CompressPlan {
            kind: CompressKind::AdaTopK,
            base_ratio,
            node_ratio,
            direction: CompressDirection::Both,
        }
    }

    /// Effective ratio for a message delivered to `dst`.
    pub fn ratio_for(&self, dst: usize) -> f64 {
        self.node_ratio.get(dst).copied().unwrap_or(1.0)
    }

    /// Effective ratio for a message of `kind` delivered to `dst`, honoring
    /// the direction gate (activations travel forward, gradients backward).
    /// This is what the per-link wire codecs are built from.
    pub fn ratio_for_kind(&self, dst: usize, kind: crate::opdag::data::OpDataKind) -> f64 {
        use crate::opdag::data::OpDataKind;
        let gated = matches!(
            (self.direction, kind),
            (CompressDirection::BwdOnly, OpDataKind::Activation)
                | (CompressDirection::FwdOnly, OpDataKind::Gradient)
        );
        if gated {
            1.0
        } else {
            self.ratio_for(dst)
        }
    }

    /// Wire-byte scaling for the latency models: dense bytes -> effective.
    /// Top-K style encodings pay 3× per kept element (f32 value + i64 idx).
    pub fn scale_bytes(&self, dst: usize, bytes: f64) -> f64 {
        let r = self.ratio_for(dst);
        match self.kind {
            CompressKind::None => bytes,
            CompressKind::Int8 => bytes / 4.0 + 4.0,
            CompressKind::TopK | CompressKind::AdaTopK | CompressKind::RandomK => {
                if r <= 1.0 {
                    bytes
                } else {
                    3.0 * bytes / r
                }
            }
        }
    }

    /// Closure adapter for `cost::throughput::evaluate`.
    pub fn msg_scale(&self) -> impl Fn(usize, usize, f64) -> f64 + '_ {
        move |_src, dst, bytes| self.scale_bytes(dst, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::opdag::OpKind;

    fn cross_cluster_partition(dag: &Dag) -> Partition {
        // Half the chain on node 0 (cluster A), half on node 23 (cluster B),
        // with one segment on node 1 to create a fast link too.
        let chain = dag.compute_chain();
        let mut assign = vec![usize::MAX; dag.len()];
        for (i, &op) in chain.iter().enumerate() {
            assign[op] = if i < chain.len() / 3 {
                0
            } else if i < 2 * chain.len() / 3 {
                1
            } else {
                23
            };
        }
        for op in &dag.ops {
            if op.kind == OpKind::Placeholder {
                assign[op.id] = assign[op.users[0]];
            }
        }
        Partition::new(assign)
    }

    #[test]
    fn eq7_slowest_node_gets_3r() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let plan =
            CompressPlan::adatopk(&dag, &part, &tb, PipelineParams::default(), 100.0);
        let max_r = plan.node_ratio.iter().cloned().fold(0.0f64, f64::max);
        assert!((max_r - 300.0).abs() < 1e-6, "max ratio {max_r} != 3r");
        // Nodes receiving nothing stay dense.
        assert_eq!(plan.ratio_for(5), 1.0);
    }

    #[test]
    fn fast_links_less_compressed_than_slow() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let plan =
            CompressPlan::adatopk(&dag, &part, &tb, PipelineParams::default(), 100.0);
        // Node 0 only receives the gradient from node 1 over a fast
        // intra-machine link; node 23 receives the activation over the slow
        // cross-cluster link. (Node 1 also sees the slow link in BP, so it
        // is NOT a fast-only receiver.)
        assert!(
            plan.ratio_for(0) < plan.ratio_for(23) / 10.0,
            "fast {} vs slow {}",
            plan.ratio_for(0),
            plan.ratio_for(23)
        );
    }

    #[test]
    fn ratio_for_kind_honors_direction_gate() {
        use crate::opdag::data::OpDataKind;
        let mut plan = CompressPlan::uniform(CompressKind::TopK, 50.0, 2);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 50.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 50.0);
        plan.direction = CompressDirection::BwdOnly;
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 1.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 50.0);
        plan.direction = CompressDirection::FwdOnly;
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 50.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 1.0);
    }

    #[test]
    fn scale_bytes_semantics() {
        let mut plan = CompressPlan::uniform(CompressKind::TopK, 100.0, 4);
        assert!((plan.scale_bytes(0, 1e6) - 3e4).abs() < 1.0);
        plan.kind = CompressKind::None;
        assert_eq!(plan.scale_bytes(0, 1e6), 1e6);
        plan.kind = CompressKind::Int8;
        assert!((plan.scale_bytes(0, 1e6) - 250004.0).abs() < 1.0);
        // Ratio 1 in TopK mode = dense bytes.
        let p = CompressPlan::dense(2);
        assert_eq!(p.scale_bytes(1, 777.0), 777.0);
    }

    #[test]
    fn adatopk_latency_close_to_uniform_and_far_below_dense() {
        // Fig. 10: both compressed variants beat dense by a wide margin;
        // uniform and AdaTopK land close to each other ("uniform TopK
        // cannot obtain lower latency than adaptive TopK with a large
        // gap", §7.4) — AdaTopK may even win since it compresses the
        // bottleneck link at 3r.
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let params = PipelineParams::default();
        let dense = evaluate(&dag, &part, &tb, params, &dense_bytes).t_pipe;
        let uni = CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len());
        let t_uni = evaluate(&dag, &part, &tb, params, &uni.msg_scale()).t_pipe;
        let ada = CompressPlan::adatopk(&dag, &part, &tb, params, 100.0);
        let t_ada = evaluate(&dag, &part, &tb, params, &ada.msg_scale()).t_pipe;
        assert!(t_ada < dense / 2.0, "ada={t_ada} dense={dense}");
        assert!(t_uni < dense / 2.0, "uni={t_uni} dense={dense}");
        let gap = t_ada.max(t_uni) / t_ada.min(t_uni);
        assert!(gap < 2.0, "uniform/adaptive gap {gap} too large");
    }
}
