//! AdaTopK (§5.2): adaptive per-node compression ratios.
//!
//! Given the user ratio r and the estimated per-node communication times
//! R_p from the dense cost model, Eq. 7 assigns
//!
//! ```text
//! r_i = max(1, 3r * R_i / max_p(R_p))
//! ```
//!
//! so the slowest links get (up to) the full 3r ratio while fast links are
//! compressed little or not at all — preserving convergence (Fig. 8) at
//! nearly uniform-Top-K latency (Fig. 10).

use crate::cluster::Testbed;
use crate::compress::{CompressKind, ValueCodec};
use crate::cost::throughput::{dense_bytes, evaluate, PipelineParams};
use crate::opdag::{Dag, Partition};

/// Which message direction gets compressed. The paper compresses both
/// activations and gradients; at small model scale forward-activation
/// sparsification can dominate the convergence gap, so the direction is a
/// first-class knob (ablated in EXPERIMENTS.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressDirection {
    Both,
    /// Gradients only (backward messages).
    BwdOnly,
    /// Activations only (forward messages).
    FwdOnly,
}

impl CompressDirection {
    pub fn parse(s: &str) -> anyhow::Result<CompressDirection> {
        Ok(match s {
            "both" => CompressDirection::Both,
            "bwd" | "grad" => CompressDirection::BwdOnly,
            "fwd" | "act" => CompressDirection::FwdOnly,
            other => anyhow::bail!("unknown direction `{other}` (both|bwd|fwd)"),
        })
    }
}

/// Per-node compression ratios; messages *received by* node i are
/// compressed at `node_ratio[i]` (R_i is node i's retrieval time, §3.5).
#[derive(Debug, Clone)]
pub struct CompressPlan {
    pub kind: CompressKind,
    /// Base user-facing ratio r.
    pub base_ratio: f64,
    /// Effective ratio per CompNode (indexed by node id); 1.0 = dense.
    pub node_ratio: Vec<f64>,
    /// Which direction is compressed (default Both, per the paper).
    pub direction: CompressDirection,
    /// Per-value wire representation for compressed links (f32 or int8).
    /// Int8 cuts sparse payloads to ~5 B/value and dense fallbacks to
    /// ~1 B/value; Eq. 7 and `scale_bytes` account for it.
    pub value_codec: ValueCodec,
}

impl CompressPlan {
    /// Dense plan (no compression anywhere).
    pub fn dense(n_nodes: usize) -> CompressPlan {
        CompressPlan {
            kind: CompressKind::None,
            base_ratio: 1.0,
            node_ratio: vec![1.0; n_nodes],
            direction: CompressDirection::Both,
            value_codec: ValueCodec::F32,
        }
    }

    /// Uniform plan: every node compresses at r.
    pub fn uniform(kind: CompressKind, ratio: f64, n_nodes: usize) -> CompressPlan {
        CompressPlan {
            kind,
            base_ratio: ratio,
            node_ratio: vec![ratio; n_nodes],
            direction: CompressDirection::Both,
            value_codec: ValueCodec::F32,
        }
    }

    /// Builder-style codec override (keeps the constructor call sites
    /// stable while the codec is negotiated per job).
    pub fn with_value_codec(mut self, codec: ValueCodec) -> CompressPlan {
        self.value_codec = codec;
        self
    }

    /// AdaTopK plan (Eq. 7) from the dense cost model, f32 value codec.
    pub fn adatopk(
        dag: &Dag,
        part: &Partition,
        testbed: &Testbed,
        params: PipelineParams,
        base_ratio: f64,
    ) -> CompressPlan {
        CompressPlan::adatopk_with_codec(dag, part, testbed, params, base_ratio, ValueCodec::F32)
    }

    /// AdaTopK plan (Eq. 7), bytes-per-value-aware: to actually shrink a
    /// link's bytes by the user ratio r, the selection ratio must also pay
    /// for the per-element wire overhead — 12 B/4 B = 3× under f32-sparse
    /// (the paper's 3r), but only 5 B/4 B = 1.25× under int8-sparse, so
    /// the same wire budget drops far fewer values.
    pub fn adatopk_with_codec(
        dag: &Dag,
        part: &Partition,
        testbed: &Testbed,
        params: PipelineParams,
        base_ratio: f64,
        codec: ValueCodec,
    ) -> CompressPlan {
        let est = evaluate(dag, part, testbed, params, &dense_bytes);
        let mut r_by_node = vec![0.0f64; testbed.nodes.len()];
        for c in &est.per_node {
            r_by_node[c.node] = c.comm_s;
        }
        let overhead = codec.sparse_bytes_per_value() / 4.0;
        let rmax = r_by_node.iter().cloned().fold(0.0f64, f64::max);
        let node_ratio = r_by_node
            .iter()
            .map(|&ri| {
                if rmax <= 0.0 {
                    1.0
                } else {
                    (overhead * base_ratio * ri / rmax).max(1.0)
                }
            })
            .collect();
        CompressPlan {
            kind: CompressKind::AdaTopK,
            base_ratio,
            node_ratio,
            direction: CompressDirection::Both,
            value_codec: codec,
        }
    }

    /// Effective ratio for a message delivered to `dst`.
    pub fn ratio_for(&self, dst: usize) -> f64 {
        self.node_ratio.get(dst).copied().unwrap_or(1.0)
    }

    /// Does the direction gate turn compression off for this message kind?
    /// (Activations travel forward, gradients backward.)
    fn gated(&self, kind: crate::opdag::data::OpDataKind) -> bool {
        use crate::opdag::data::OpDataKind;
        matches!(
            (self.direction, kind),
            (CompressDirection::BwdOnly, OpDataKind::Activation)
                | (CompressDirection::FwdOnly, OpDataKind::Gradient)
        )
    }

    /// Effective ratio for a message of `kind` delivered to `dst`, honoring
    /// the direction gate. This is what the per-link wire codecs are built
    /// from.
    pub fn ratio_for_kind(&self, dst: usize, kind: crate::opdag::data::OpDataKind) -> f64 {
        if self.gated(kind) {
            1.0
        } else {
            self.ratio_for(dst)
        }
    }

    /// Per-link value codec for a message of `kind` delivered to `dst`: a
    /// direction-gated link stays lossless f32 (the gate exists to protect
    /// convergence in that direction — int8 would quietly re-lossify it);
    /// every other link uses the plan's negotiated codec.
    pub fn codec_for_kind(&self, _dst: usize, kind: crate::opdag::data::OpDataKind) -> ValueCodec {
        if self.gated(kind) {
            ValueCodec::F32
        } else {
            self.value_codec
        }
    }

    /// Wire-byte scaling for the latency models: dense bytes -> effective.
    /// Per-kept-element cost comes from the value codec: f32-sparse pays
    /// 12 B (3× dense, paper accounting), int8-sparse 5 B (1.25×); int8
    /// dense fallbacks pay 1 B/value + scale.
    pub fn scale_bytes(&self, dst: usize, bytes: f64) -> f64 {
        let r = self.ratio_for(dst);
        match self.kind {
            // A dense plan under the int8 codecs still quantizes (1 B/value).
            CompressKind::None => match self.value_codec {
                ValueCodec::F32 => bytes,
                ValueCodec::Int8 | ValueCodec::Int8Delta => bytes / 4.0 + 4.0,
            },
            CompressKind::Int8 => bytes / 4.0 + 4.0,
            CompressKind::TopK | CompressKind::AdaTopK | CompressKind::RandomK => {
                if r <= 1.0 {
                    match self.value_codec {
                        ValueCodec::F32 => bytes,
                        ValueCodec::Int8 | ValueCodec::Int8Delta => bytes / 4.0 + 4.0,
                    }
                } else {
                    // Random-K support is unsorted, so the u24 delta index
                    // packing never applies there: it pays the plain int8
                    // 5 B/value, keeping this model equal to the measured
                    // wire bytes.
                    let bpv = match (self.kind, self.value_codec) {
                        (CompressKind::RandomK, ValueCodec::Int8Delta) => 5.0,
                        _ => self.value_codec.sparse_bytes_per_value(),
                    };
                    bpv / 4.0 * bytes / r
                }
            }
        }
    }

    /// Closure adapter for `cost::throughput::evaluate`.
    pub fn msg_scale(&self) -> impl Fn(usize, usize, f64) -> f64 + '_ {
        move |_src, dst, bytes| self.scale_bytes(dst, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::opdag::OpKind;

    fn cross_cluster_partition(dag: &Dag) -> Partition {
        // Half the chain on node 0 (cluster A), half on node 23 (cluster B),
        // with one segment on node 1 to create a fast link too.
        let chain = dag.compute_chain();
        let mut assign = vec![usize::MAX; dag.len()];
        for (i, &op) in chain.iter().enumerate() {
            assign[op] = if i < chain.len() / 3 {
                0
            } else if i < 2 * chain.len() / 3 {
                1
            } else {
                23
            };
        }
        for op in &dag.ops {
            if op.kind == OpKind::Placeholder {
                assign[op.id] = assign[op.users[0]];
            }
        }
        Partition::new(assign)
    }

    #[test]
    fn eq7_slowest_node_gets_3r() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let plan =
            CompressPlan::adatopk(&dag, &part, &tb, PipelineParams::default(), 100.0);
        let max_r = plan.node_ratio.iter().cloned().fold(0.0f64, f64::max);
        assert!((max_r - 300.0).abs() < 1e-6, "max ratio {max_r} != 3r");
        // Nodes receiving nothing stay dense.
        assert_eq!(plan.ratio_for(5), 1.0);
    }

    #[test]
    fn fast_links_less_compressed_than_slow() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let plan =
            CompressPlan::adatopk(&dag, &part, &tb, PipelineParams::default(), 100.0);
        // Node 0 only receives the gradient from node 1 over a fast
        // intra-machine link; node 23 receives the activation over the slow
        // cross-cluster link. (Node 1 also sees the slow link in BP, so it
        // is NOT a fast-only receiver.)
        assert!(
            plan.ratio_for(0) < plan.ratio_for(23) / 10.0,
            "fast {} vs slow {}",
            plan.ratio_for(0),
            plan.ratio_for(23)
        );
    }

    #[test]
    fn ratio_for_kind_honors_direction_gate() {
        use crate::opdag::data::OpDataKind;
        let mut plan = CompressPlan::uniform(CompressKind::TopK, 50.0, 2);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 50.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 50.0);
        plan.direction = CompressDirection::BwdOnly;
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 1.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 50.0);
        plan.direction = CompressDirection::FwdOnly;
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Activation), 50.0);
        assert_eq!(plan.ratio_for_kind(0, OpDataKind::Gradient), 1.0);
    }

    #[test]
    fn scale_bytes_semantics() {
        let mut plan = CompressPlan::uniform(CompressKind::TopK, 100.0, 4);
        assert!((plan.scale_bytes(0, 1e6) - 3e4).abs() < 1.0);
        plan.kind = CompressKind::None;
        assert_eq!(plan.scale_bytes(0, 1e6), 1e6);
        plan.kind = CompressKind::Int8;
        assert!((plan.scale_bytes(0, 1e6) - 250004.0).abs() < 1.0);
        // Ratio 1 in TopK mode = dense bytes.
        let p = CompressPlan::dense(2);
        assert_eq!(p.scale_bytes(1, 777.0), 777.0);
    }

    #[test]
    fn eq7_int8_codec_needs_only_fraction_of_3r() {
        // Same wire budget under 5 B/value costs 1.25r instead of 3r.
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let plan = CompressPlan::adatopk_with_codec(
            &dag,
            &part,
            &tb,
            PipelineParams::default(),
            100.0,
            ValueCodec::Int8,
        );
        let max_r = plan.node_ratio.iter().cloned().fold(0.0f64, f64::max);
        assert!((max_r - 125.0).abs() < 1e-6, "max ratio {max_r} != 1.25r");
        assert_eq!(plan.value_codec, ValueCodec::Int8);
    }

    #[test]
    fn scale_bytes_int8_codec() {
        let mut plan =
            CompressPlan::uniform(CompressKind::TopK, 100.0, 4).with_value_codec(ValueCodec::Int8);
        // 5 B/value instead of 12: 1.25 * 1e6 / 100.
        assert!((plan.scale_bytes(0, 1e6) - 1.25e4).abs() < 1.0);
        // Dense fallback (ratio 1) quantizes dense: ~1 B/value.
        plan.node_ratio[1] = 1.0;
        assert!((plan.scale_bytes(1, 1e6) - 250004.0).abs() < 1.0);
        // A fully dense plan under int8 (`--compress none --wire-codec
        // int8`) also quantizes; the f32 dense plan stays pass-through.
        let dense_q = CompressPlan::dense(2).with_value_codec(ValueCodec::Int8);
        assert!((dense_q.scale_bytes(0, 1e6) - 250004.0).abs() < 1.0);
        assert_eq!(CompressPlan::dense(2).scale_bytes(0, 1e6), 1e6);
    }

    #[test]
    fn scale_bytes_u24_delta_codec() {
        // 4 B/value instead of 5: 1.0 * 1e6 / 100 on Top-K links.
        let plan = CompressPlan::uniform(CompressKind::TopK, 100.0, 4)
            .with_value_codec(ValueCodec::Int8Delta);
        assert!((plan.scale_bytes(0, 1e6) - 1.0e4).abs() < 1.0);
        // Random-K support is unsorted: no delta packing, plain 5 B/value.
        let rk = CompressPlan::uniform(CompressKind::RandomK, 100.0, 4)
            .with_value_codec(ValueCodec::Int8Delta);
        assert!((rk.scale_bytes(0, 1e6) - 1.25e4).abs() < 1.0);
    }

    #[test]
    fn codec_for_kind_keeps_gated_direction_lossless() {
        use crate::opdag::data::OpDataKind;
        let mut plan =
            CompressPlan::uniform(CompressKind::TopK, 50.0, 2).with_value_codec(ValueCodec::Int8);
        assert_eq!(plan.codec_for_kind(0, OpDataKind::Activation), ValueCodec::Int8);
        plan.direction = CompressDirection::BwdOnly;
        assert_eq!(plan.codec_for_kind(0, OpDataKind::Activation), ValueCodec::F32);
        assert_eq!(plan.codec_for_kind(0, OpDataKind::Gradient), ValueCodec::Int8);
    }

    #[test]
    fn adatopk_latency_close_to_uniform_and_far_below_dense() {
        // Fig. 10: both compressed variants beat dense by a wide margin;
        // uniform and AdaTopK land close to each other ("uniform TopK
        // cannot obtain lower latency than adaptive TopK with a large
        // gap", §7.4) — AdaTopK may even win since it compresses the
        // bottleneck link at 3r.
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = cross_cluster_partition(&dag);
        let params = PipelineParams::default();
        let dense = evaluate(&dag, &part, &tb, params, &dense_bytes).t_pipe;
        let uni = CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len());
        let t_uni = evaluate(&dag, &part, &tb, params, &uni.msg_scale()).t_pipe;
        let ada = CompressPlan::adatopk(&dag, &part, &tb, params, 100.0);
        let t_ada = evaluate(&dag, &part, &tb, params, &ada.msg_scale()).t_pipe;
        assert!(t_ada < dense / 2.0, "ada={t_ada} dense={dense}");
        assert!(t_uni < dense / 2.0, "uni={t_uni} dense={dense}");
        let gap = t_ada.max(t_uni) / t_ada.min(t_uni);
        assert!(gap < 2.0, "uniform/adaptive gap {gap} too large");
    }
}
