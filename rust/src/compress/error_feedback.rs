//! Error-feedback residual accumulation (EF-SGD style [65]) — one of the
//! paper's §10 "advanced compression algorithms" extensions.
//!
//! Each compressed edge keeps the residual e_t = x_t + e_{t-1} - C(x_t +
//! e_{t-1}); the dropped mass re-enters the next message instead of being
//! lost, which tightens convergence at high ratios.

use super::sparsify::{Compressed, CompressScratch, Compressor};
use std::collections::HashMap;

/// Wraps a compressor with per-edge residual memory.
pub struct ErrorFeedback<C: Compressor> {
    inner: C,
    residuals: HashMap<(usize, usize), Vec<f32>>,
    scratch: Vec<f32>,
    comp_scratch: CompressScratch,
    decoded: Vec<f32>,
}

impl<C: Compressor> ErrorFeedback<C> {
    pub fn new(inner: C) -> Self {
        ErrorFeedback {
            inner,
            residuals: HashMap::new(),
            scratch: Vec::new(),
            comp_scratch: CompressScratch::default(),
            decoded: Vec::new(),
        }
    }

    /// Compress `data` for the edge key, folding in and updating residuals.
    pub fn compress_edge(&mut self, edge: (usize, usize), data: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_edge_into(edge, data, &mut out);
        out
    }

    /// `compress_edge` into a caller-owned `Compressed` — together with the
    /// internal residual/decode buffers this keeps the steady-state EF path
    /// allocation-free.
    pub fn compress_edge_into(
        &mut self,
        edge: (usize, usize),
        data: &[f32],
        out: &mut Compressed,
    ) {
        let res = self
            .residuals
            .entry(edge)
            .or_insert_with(|| vec![0.0; data.len()]);
        if res.len() != data.len() {
            res.clear();
            res.resize(data.len(), 0.0);
        }
        // corrected = data + residual
        self.scratch.clear();
        self.scratch.extend(data.iter().zip(res.iter()).map(|(d, r)| d + r));
        self.inner.compress_with(&self.scratch, out, &mut self.comp_scratch);
        // residual = corrected - decompress(c)
        self.decoded.clear();
        self.decoded.resize(data.len(), 0.0);
        self.inner.decompress(out, &mut self.decoded);
        for ((r, s), d) in res.iter_mut().zip(&self.scratch).zip(&self.decoded) {
            *r = s - d;
        }
    }

    pub fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        self.inner.decompress(c, out);
    }

    /// Total residual mass (for diagnostics/tests).
    pub fn residual_l2(&self, edge: (usize, usize)) -> f32 {
        self.residuals
            .get(&edge)
            .map(|r| r.iter().map(|v| v * v).sum::<f32>().sqrt())
            .unwrap_or(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sparsify::TopK;
    use crate::util::rng::Rng;

    #[test]
    fn residual_reinjects_dropped_mass() {
        let mut ef = ErrorFeedback::new(TopK { ratio: 10.0 });
        let mut rng = Rng::new(1);
        let n = 50; // k = 5 per round
        // Constant signal: without EF the small entries are NEVER sent;
        // with EF they accumulate and eventually cross the threshold.
        let data: Vec<f32> = (0..n).map(|_| rng.f32() * 0.1 + 0.05).collect();
        let rounds = 300usize;
        let mut delivered = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        for _ in 0..rounds {
            let c = ef.compress_edge((0, 1), &data);
            ef.decompress(&c, &mut out);
            for (d, o) in delivered.iter_mut().zip(&out) {
                *d += o;
            }
        }
        // delivered_i = x_i·rounds − residual_i, residual bounded by the
        // steady-state send threshold (≈ Σx/k ≈ 1), so every coordinate
        // converges to its true cumulative mass.
        for (i, (&d, &x)) in delivered.iter().zip(&data).enumerate() {
            let want = x * rounds as f32;
            assert!(
                (d - want).abs() / want < 0.25,
                "coord {i}: delivered {d} vs want {want}"
            );
        }
        // Contrast: plain Top-K never delivers the smallest coordinates.
        let plain = TopK { ratio: 10.0 };
        let c = plain.compress(&data);
        let mut once = vec![0.0f32; n];
        plain.decompress(&c, &mut once);
        assert!(once.iter().filter(|v| **v == 0.0).count() >= n - 6);
    }

    /// With the int8-quantized sparse codec the residual absorbs BOTH the
    /// sparsification error and the quantization error: cumulative
    /// delivered mass still converges to the true mass, so the cheaper
    /// wire format costs no systematic bias.
    #[test]
    fn residual_absorbs_quantization_error() {
        use crate::compress::quant::Quantized;
        let mut ef = ErrorFeedback::new(Quantized::per_message(TopK { ratio: 10.0 }));
        let mut rng = Rng::new(21);
        let n = 50;
        let data: Vec<f32> = (0..n).map(|_| rng.f32() * 0.1 + 0.05).collect();
        let rounds = 300usize;
        let mut delivered = vec![0.0f32; n];
        let mut out = vec![0.0f32; n];
        for _ in 0..rounds {
            let c = ef.compress_edge((0, 1), &data);
            assert!(c.values.is_empty(), "values must travel as int8 codes");
            ef.decompress(&c, &mut out);
            for (d, o) in delivered.iter_mut().zip(&out) {
                *d += o;
            }
        }
        for (i, (&d, &x)) in delivered.iter().zip(&data).enumerate() {
            let want = x * rounds as f32;
            assert!(
                (d - want).abs() / want < 0.25,
                "coord {i}: delivered {d} vs want {want}"
            );
        }
        // One round's residual is bounded by send threshold + half a scale
        // step (not accumulating): matches the f32 bound up to quant noise.
        let r = ef.residual_l2((0, 1));
        assert!(r.is_finite() && r < 10.0, "residual l2 {r}");
    }

    #[test]
    fn residual_bounded() {
        let mut ef = ErrorFeedback::new(TopK { ratio: 10.0 });
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..100).map(|_| rng.f32() - 0.5).collect();
        let mut prev = f32::MAX;
        for step in 0..50 {
            ef.compress_edge((3, 4), &data);
            let r = ef.residual_l2((3, 4));
            if step > 10 {
                // Residual settles (doesn't blow up).
                assert!(r <= prev * 2.0 + 1.0);
            }
            prev = r;
        }
    }

    #[test]
    fn payload_length_changes_reset_residual() {
        let mut ef = ErrorFeedback::new(TopK { ratio: 5.0 });
        ef.compress_edge((0, 0), &[1.0; 64]);
        // Different length on the same edge must not panic.
        let c = ef.compress_edge((0, 0), &[1.0; 32]);
        assert_eq!(c.cfg, crate::opdag::data::CompressCfg::TopK { ratio: 5.0, total_len: 32 });
    }
}
