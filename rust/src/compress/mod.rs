//! Communication compression (§5): Top-K sparsification, the AdaTopK
//! adaptive per-link ratio plan (Eq. 7), and baselines (Random-K, int8
//! quantization), plus error-feedback residuals (paper §10 future work).
//!
//! These operate on real f32 payloads in the e2e training path AND provide
//! the message-scaling closures the analytic/simulated latency models use.

pub mod adatopk;
pub mod error_feedback;
pub mod quant;
pub mod sparsify;

pub use adatopk::{CompressDirection, CompressPlan};
pub use error_feedback::ErrorFeedback;
pub use quant::{Quantized, ValueCodec};
pub use sparsify::{
    ChunkedTopK, CompressScratch, Compressed, Compressor, Int8Quantizer, NoCompress, RandomK,
    TopK,
};

/// User-facing compressor selection (CLI / configs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressKind {
    None,
    /// Uniform Top-K at the given ratio on every cross-node link.
    TopK,
    /// AdaTopK: per-link ratios from Eq. 7.
    AdaTopK,
    /// Random-K baseline.
    RandomK,
    /// Int8 linear quantization baseline.
    Int8,
}

impl CompressKind {
    pub fn parse(s: &str) -> anyhow::Result<CompressKind> {
        Ok(match s {
            "none" | "dense" => CompressKind::None,
            "topk" => CompressKind::TopK,
            "adatopk" => CompressKind::AdaTopK,
            "randomk" => CompressKind::RandomK,
            "int8" => CompressKind::Int8,
            other => anyhow::bail!("unknown compressor `{other}`"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            CompressKind::None => "none",
            CompressKind::TopK => "topk",
            CompressKind::AdaTopK => "adatopk",
            CompressKind::RandomK => "randomk",
            CompressKind::Int8 => "int8",
        }
    }
}
