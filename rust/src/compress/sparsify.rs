//! Wire compressors over f32 payloads (Fig. 6).
//!
//! Top-K is the hot path (every cross-node message in the AdaTopK runs):
//! a radix-select threshold (O(n), no sort) followed by a gather pass —
//! the same streaming-select shape as the L1 Pallas kernel. Both passes
//! run on `compress_threads()` workers with per-thread partitions stitched
//! in index order, so results are bit-identical for every thread count.
//!
//! Steady-state entry point is `Compressor::compress_with`, which reuses
//! the caller's `Compressed` buffers and a per-link `CompressScratch` —
//! zero heap allocation per message once warm (EXPERIMENTS.md §Perf). The
//! allocating `compress` remains as a thin wrapper so every pre-existing
//! test doubles as a differential oracle for the `_into` forms.

use crate::opdag::data::CompressCfg;
use crate::util::math::{compress_threads, kth_largest_abs_with, SelectScratch, PAR_MIN};
use crate::util::rng::Rng;
use crate::util::simd;
use std::collections::HashSet;

/// A sparse/quantized wire message.
#[derive(Debug, Clone, Default)]
pub struct Compressed {
    pub cfg: CompressCfg,
    pub values: Vec<f32>,
    pub indices: Vec<u32>,
    pub bytes: Vec<u8>,
}

impl Compressed {
    /// Bytes on the wire. f32-sparse keeps the paper accounting (f32
    /// values + int64 indices); the int8 encodings are counted at their
    /// actual packed layout (1 B code + u32 index + f32 scale(s)) so the
    /// cost model sees the real link cost.
    pub fn wire_bytes(&self) -> f64 {
        match self.cfg {
            CompressCfg::None => 4.0 * self.values.len() as f64,
            CompressCfg::TopK { .. } | CompressCfg::RandomK { .. } => {
                4.0 * self.values.len() as f64 + 8.0 * self.indices.len() as f64
            }
            CompressCfg::Int8 { .. } => self.bytes.len() as f64 + 4.0,
            CompressCfg::QSparse { .. } => {
                self.bytes.len() as f64 + 4.0 * self.indices.len() as f64 + 4.0
            }
            // Per-row scales ride in `values`.
            CompressCfg::QSparseRows { .. } => {
                self.bytes.len() as f64
                    + 4.0 * self.indices.len() as f64
                    + 4.0 * self.values.len() as f64
            }
            // Delta-coded u24 indices: 3 B each on the wire.
            CompressCfg::QSparseRowsDelta { .. } => {
                self.bytes.len() as f64
                    + 3.0 * self.indices.len() as f64
                    + 4.0 * self.values.len() as f64
            }
        }
    }

    fn reset(&mut self, cfg: CompressCfg) {
        self.cfg = cfg;
        self.values.clear();
        self.indices.clear();
        self.bytes.clear();
    }
}

/// One thread's stitch partition for the parallel gather: chunk-local
/// strictly-above and at-threshold entries, concatenated in chunk (= index)
/// order by the caller — deterministic for every thread count.
#[derive(Debug, Default)]
struct PartBuf {
    values: Vec<f32>,
    indices: Vec<u32>,
    tie_values: Vec<f32>,
    tie_indices: Vec<u32>,
    /// Per-thread select scratch for the row-parallel ChunkedTopK path.
    select: SelectScratch,
}

/// Reusable per-link scratch for `Compressor::compress_with`: radix-select
/// buffers, per-thread gather partitions, and the Random-K sample set. One
/// of these per link keeps the steady-state wire path allocation-free.
#[derive(Debug)]
pub struct CompressScratch {
    threads: usize,
    select: SelectScratch,
    parts: Vec<PartBuf>,
    sample: HashSet<u32>,
    /// Per-row absmax buffer for the int8 quantization post-pass.
    pub(crate) scales: Vec<f32>,
}

impl Default for CompressScratch {
    fn default() -> Self {
        CompressScratch::with_threads(compress_threads())
    }
}

impl CompressScratch {
    /// Scratch pinned to an explicit worker count (tests use 1/2/8 to prove
    /// determinism; production uses `Default` = `compress_threads()`).
    pub fn with_threads(threads: usize) -> Self {
        CompressScratch {
            threads: threads.max(1),
            select: SelectScratch::default(),
            parts: Vec::new(),
            sample: HashSet::new(),
            scales: Vec::new(),
        }
    }
}

/// Compressor interface: compress a dense payload, decompress to dense.
///
/// `compress_with` is the steady-state form; `compress_into` and `compress`
/// are provided wrappers (the latter is the differential oracle used by the
/// seed tests).
pub trait Compressor: Send + Sync {
    /// Compress `data` into `out`, reusing its buffers and `scratch`.
    fn compress_with(&self, data: &[f32], out: &mut Compressed, scratch: &mut CompressScratch);

    /// Compress into `out`, reusing its buffers (fresh scratch).
    fn compress_into(&self, data: &[f32], out: &mut Compressed) {
        let mut scratch = CompressScratch::default();
        self.compress_with(data, out, &mut scratch);
    }

    /// Allocating wrapper around `compress_into`.
    fn compress(&self, data: &[f32]) -> Compressed {
        let mut out = Compressed::default();
        self.compress_into(data, &mut out);
        out
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]);
    fn name(&self) -> &'static str;
}

/// Identity (dense) — the paper's "no compression" baseline.
#[derive(Debug, Clone, Copy)]
pub struct NoCompress;

impl Compressor for NoCompress {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, _scratch: &mut CompressScratch) {
        out.reset(CompressCfg::None);
        out.values.extend_from_slice(data);
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.copy_from_slice(&c.values);
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Top-K by magnitude at compression ratio r (keep k = ceil(n/r)).
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    pub fn k_for(&self, n: usize) -> usize {
        if n == 0 {
            return 0;
        }
        ((n as f64 / self.ratio).ceil() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, scratch: &mut CompressScratch) {
        let n = data.len();
        let k = self.k_for(n);
        out.reset(CompressCfg::TopK { ratio: self.ratio, total_len: n as u32 });
        if k >= n {
            out.values.extend_from_slice(data);
            out.indices.extend(0..n as u32);
            return;
        }
        let threads = scratch.threads;
        let tau = kth_largest_abs_with(data, k, threads, &mut scratch.select);
        topk_gather(
            data,
            tau,
            k,
            threads,
            &mut scratch.parts,
            &mut out.values,
            &mut out.indices,
        );
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        simd::scatter_f32(&c.indices, &c.values, out);
    }

    fn name(&self) -> &'static str {
        "topk"
    }
}

/// Gather the k top-|v| entries given threshold `tau`, index-sorted: every
/// strictly-above entry plus the first at-threshold ties in index order.
/// Parallel chunks stitch in index order, so the output is identical for
/// every thread count (and to the sequential seed implementation).
fn topk_gather(
    data: &[f32],
    tau: f32,
    k: usize,
    threads: usize,
    parts: &mut Vec<PartBuf>,
    values: &mut Vec<f32>,
    indices: &mut Vec<u32>,
) {
    let n = data.len();
    let threads = threads.max(1).min(n / PAR_MIN + 1);
    if threads <= 1 {
        gather_seq(data, tau, k, 0, values, indices);
        return;
    }
    let chunk = (n + threads - 1) / threads;
    let n_parts = data.chunks(chunk).len();
    if parts.len() < n_parts {
        parts.resize_with(n_parts, PartBuf::default);
    }
    std::thread::scope(|s| {
        for (t, (slice, part)) in data.chunks(chunk).zip(parts.iter_mut()).enumerate() {
            let base = (t * chunk) as u32;
            s.spawn(move || {
                part.values.clear();
                part.indices.clear();
                part.tie_values.clear();
                part.tie_indices.clear();
                for (i, &v) in slice.iter().enumerate() {
                    let a = v.abs();
                    if a > tau {
                        part.values.push(v);
                        part.indices.push(base + i as u32);
                    } else if a == tau {
                        part.tie_values.push(v);
                        part.tie_indices.push(base + i as u32);
                    }
                }
            });
        }
    });
    let mut above = 0usize;
    for part in parts.iter().take(n_parts) {
        above += part.values.len();
        values.extend_from_slice(&part.values);
        indices.extend_from_slice(&part.indices);
    }
    let split = values.len();
    let mut need = k.saturating_sub(above);
    'ties: for part in parts.iter().take(n_parts) {
        for (&i, &v) in part.tie_indices.iter().zip(&part.tie_values) {
            if need == 0 {
                break 'ties;
            }
            values.push(v);
            indices.push(i);
            need -= 1;
        }
    }
    merge_tail_by_index(values, indices, split);
}

/// Sequential gather of the k top-|v| entries of one region: strictly-above
/// pass, then at-threshold ties until k, then an index-order tail merge.
/// Appends to (values, indices) with `base` added to every index. Shared by
/// the single-thread whole-tensor path and the per-row ChunkedTopK path.
fn gather_seq(
    data: &[f32],
    tau: f32,
    k: usize,
    base: u32,
    values: &mut Vec<f32>,
    indices: &mut Vec<u32>,
) {
    let start = values.len();
    // First pass: strictly-above-threshold entries (always kept).
    for (i, &v) in data.iter().enumerate() {
        if v.abs() > tau {
            values.push(v);
            indices.push(base + i as u32);
        }
    }
    let split = values.len() - start;
    if split < k {
        // Second pass: fill remaining slots with at-threshold ties.
        for (i, &v) in data.iter().enumerate() {
            if v.abs() == tau {
                values.push(v);
                indices.push(base + i as u32);
                if values.len() - start == k {
                    break;
                }
            }
        }
        // Keep indices sorted for cache-friendly decode.
        merge_tail_by_index(&mut values[start..], &mut indices[start..], split);
    }
}

/// Merge the two index-sorted runs `[..split]` and `[split..]` in place
/// (the tail holds the threshold ties, which is almost always tiny, so
/// binary-search + rotate beats re-sorting all k pairs).
fn merge_tail_by_index(values: &mut [f32], indices: &mut [u32], split: usize) {
    let len = indices.len();
    if split == 0 || split == len || indices[split - 1] < indices[split] {
        return;
    }
    let mut lo = 0usize;
    for t in split..len {
        let idx = indices[t];
        let pos = lo + indices[lo..t].partition_point(|&x| x < idx);
        indices[pos..=t].rotate_right(1);
        values[pos..=t].rotate_right(1);
        lo = pos + 1;
    }
}

/// Row-chunked Top-K (Fig. 6 applied per vector): the payload is treated
/// as rows of `chunk` elements (one token's feature vector) and Top-K is
/// selected within each row, so every token keeps its strongest features.
/// Whole-tensor Top-K concentrates the budget on a few high-norm tokens and
/// zeroes the rest entirely — much worse for convergence (EXPERIMENTS.md).
/// Rows are independent, so they parallelize across `compress_threads()`
/// workers in contiguous row ranges (stitched in row order: deterministic).
#[derive(Debug, Clone, Copy)]
pub struct ChunkedTopK {
    pub ratio: f64,
    pub chunk: usize,
}

impl Compressor for ChunkedTopK {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, scratch: &mut CompressScratch) {
        let n = data.len();
        out.reset(CompressCfg::TopK { ratio: self.ratio, total_len: n as u32 });
        if n == 0 {
            return;
        }
        let chunk = self.chunk.max(1);
        let inner = TopK { ratio: self.ratio };
        let n_rows = (n + chunk - 1) / chunk;
        let threads = scratch.threads.min(n_rows).max(1);
        if threads <= 1 || n < PAR_MIN {
            compress_rows(
                data,
                chunk,
                inner,
                0,
                n_rows,
                &mut scratch.select,
                &mut out.values,
                &mut out.indices,
            );
            return;
        }
        let rows_per = (n_rows + threads - 1) / threads;
        let active = (n_rows + rows_per - 1) / rows_per;
        if scratch.parts.len() < active {
            scratch.parts.resize_with(active, PartBuf::default);
        }
        let parts = &mut scratch.parts[..active];
        std::thread::scope(|s| {
            for (t, part) in parts.iter_mut().enumerate() {
                let row0 = t * rows_per;
                let row1 = ((t + 1) * rows_per).min(n_rows);
                s.spawn(move || {
                    part.values.clear();
                    part.indices.clear();
                    compress_rows(
                        data,
                        chunk,
                        inner,
                        row0,
                        row1,
                        &mut part.select,
                        &mut part.values,
                        &mut part.indices,
                    );
                });
            }
        });
        for part in parts.iter() {
            out.values.extend_from_slice(&part.values);
            out.indices.extend_from_slice(&part.indices);
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        simd::scatter_f32(&c.indices, &c.values, out);
    }

    fn name(&self) -> &'static str {
        "chunked-topk"
    }
}

/// Sequentially compress rows `[row0, row1)` of the chunked layout,
/// appending (values, indices) per row in index order.
fn compress_rows(
    data: &[f32],
    chunk: usize,
    inner: TopK,
    row0: usize,
    row1: usize,
    select: &mut SelectScratch,
    values: &mut Vec<f32>,
    indices: &mut Vec<u32>,
) {
    for r in row0..row1 {
        let off = r * chunk;
        let end = (off + chunk).min(data.len());
        let row = &data[off..end];
        let k = inner.k_for(row.len());
        if k >= row.len() {
            values.extend_from_slice(row);
            indices.extend((off as u32)..(end as u32));
            continue;
        }
        let tau = kth_largest_abs_with(row, k, 1, select);
        gather_seq(row, tau, k, off as u32, values, indices);
    }
}

/// Random-K baseline: uniformly sampled support, deterministic by seed.
#[derive(Debug, Clone, Copy)]
pub struct RandomK {
    pub ratio: f64,
    pub seed: u64,
}

impl Compressor for RandomK {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, scratch: &mut CompressScratch) {
        let n = data.len();
        out.reset(CompressCfg::RandomK {
            ratio: self.ratio,
            total_len: n as u32,
            seed: self.seed,
        });
        if n == 0 {
            return;
        }
        let k = ((n as f64 / self.ratio).ceil() as usize).clamp(1, n);
        if k >= n {
            out.indices.extend(0..n as u32);
        } else {
            // Floyd's sampling: k distinct indices in O(k) time and memory.
            // (The seed implementation materialized a full 0..n index vector
            // per message — 7.8 MB of throwaway churn for a 19.66 MB payload.)
            let mut rng = Rng::new(self.seed);
            let set = &mut scratch.sample;
            set.clear();
            for j in (n - k)..n {
                let t = rng.below((j + 1) as u64) as u32;
                if !set.insert(t) {
                    set.insert(j as u32);
                }
            }
            out.indices.extend(set.iter().copied());
            out.indices.sort_unstable();
        }
        let (values, indices) = (&mut out.values, &out.indices);
        simd::gather_f32(data, indices, values);
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        simd::scatter_f32(&c.indices, &c.values, out);
    }

    fn name(&self) -> &'static str {
        "randomk"
    }
}

/// Linear int8 quantization with per-message absmax scale.
#[derive(Debug, Clone, Copy)]
pub struct Int8Quantizer;

impl Compressor for Int8Quantizer {
    fn compress_with(&self, data: &[f32], out: &mut Compressed, _scratch: &mut CompressScratch) {
        // Shared formula with the sparse int8 encodings (compress::quant).
        let scale = crate::compress::quant::absmax_scale(data);
        out.reset(CompressCfg::Int8 { scale, total_len: data.len() as u32 });
        simd::quantize_codes(data, scale, &mut out.bytes);
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        let scale = match c.cfg {
            CompressCfg::Int8 { scale, .. } => scale,
            _ => panic!("int8 decompress on non-int8 payload"),
        };
        simd::dequant_into(&c.bytes, scale, out);
    }

    fn name(&self) -> &'static str {
        "int8"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect()
    }

    #[test]
    fn topk_keeps_exactly_k_largest() {
        let xs = data(1000, 1);
        let c = TopK { ratio: 100.0 }.compress(&xs);
        assert_eq!(c.values.len(), 10);
        assert_eq!(c.indices.len(), 10);
        // Every kept |v| >= every dropped |v|.
        let kept_min = c.values.iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
        let kept: std::collections::BTreeSet<u32> = c.indices.iter().copied().collect();
        for (i, &v) in xs.iter().enumerate() {
            if !kept.contains(&(i as u32)) {
                assert!(v.abs() <= kept_min + 1e-7);
            }
        }
    }

    #[test]
    fn topk_roundtrip_preserves_support() {
        let xs = data(512, 2);
        let comp = TopK { ratio: 8.0 };
        let c = comp.compress(&xs);
        let mut out = vec![0f32; xs.len()];
        comp.decompress(&c, &mut out);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            assert_eq!(out[i as usize], v);
            assert_eq!(xs[i as usize], v);
        }
        let nz = out.iter().filter(|v| **v != 0.0).count();
        assert!(nz <= comp.k_for(xs.len()));
    }

    #[test]
    fn topk_with_duplicates_respects_k() {
        let xs = vec![1.0f32; 100];
        let c = TopK { ratio: 10.0 }.compress(&xs);
        assert_eq!(c.values.len(), 10);
    }

    #[test]
    fn topk_ratio_one_is_dense() {
        let xs = data(64, 3);
        let c = TopK { ratio: 1.0 }.compress(&xs);
        assert_eq!(c.values.len(), 64);
        let mut out = vec![0f32; 64];
        TopK { ratio: 1.0 }.decompress(&c, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn randomk_deterministic_and_correct_size() {
        let xs = data(1000, 4);
        let comp = RandomK { ratio: 50.0, seed: 99 };
        let c1 = comp.compress(&xs);
        let c2 = comp.compress(&xs);
        assert_eq!(c1.indices, c2.indices);
        assert_eq!(c1.values.len(), 20);
        // Indices unique.
        let set: std::collections::BTreeSet<u32> = c1.indices.iter().copied().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn int8_roundtrip_within_quant_error() {
        let xs = data(256, 5);
        let comp = Int8Quantizer;
        let c = comp.compress(&xs);
        let mut out = vec![0f32; 256];
        comp.decompress(&c, &mut out);
        let absmax = xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in xs.iter().zip(&out) {
            assert!((a - b).abs() <= absmax / 127.0 + 1e-6);
        }
    }

    #[test]
    fn wire_bytes_ratio100_is_33x_smaller_than_dense() {
        // Paper Fig. 10 caption: ratio 100 gives 33.3× smaller payloads
        // (4B values + 8B indices per kept element = 12B vs 4B dense).
        let xs = data(10_000, 6);
        let dense = NoCompress.compress(&xs);
        let sparse = TopK { ratio: 100.0 }.compress(&xs);
        let shrink = dense.wire_bytes() / sparse.wire_bytes();
        assert!((shrink - 33.33).abs() < 0.5, "shrink={shrink}");
    }

    #[test]
    fn topk_compression_error_smaller_than_randomk() {
        let xs = data(2000, 7);
        let t = TopK { ratio: 20.0 };
        let r = RandomK { ratio: 20.0, seed: 1 };
        let mut out_t = vec![0f32; 2000];
        let mut out_r = vec![0f32; 2000];
        t.decompress(&t.compress(&xs), &mut out_t);
        r.decompress(&r.compress(&xs), &mut out_r);
        let err = |out: &[f32]| -> f32 {
            xs.iter().zip(out).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(err(&out_t) < err(&out_r));
    }

    #[test]
    fn compress_into_reuses_buffers_steady_state() {
        // Zero per-message heap growth on the steady-state Top-K path:
        // after warm-up, the Compressed buffer capacities must be stable
        // across 100 messages of the same shape.
        let comp = ChunkedTopK { ratio: 100.0, chunk: 256 };
        let mut scratch = CompressScratch::with_threads(4);
        let mut out = Compressed::default();
        let mut rng = Rng::new(9);
        let n = 64 * 1024;
        let mut data = vec![0.0f32; n];
        let mut caps = Vec::new();
        for msg in 0..100 {
            for v in data.iter_mut() {
                *v = rng.f32() - 0.5;
            }
            comp.compress_with(&data, &mut out, &mut scratch);
            assert_eq!(out.values.len(), (n / 256) * 3); // ceil(256/100) = 3 kept per row
            if msg >= 2 {
                caps.push((out.values.capacity(), out.indices.capacity()));
            }
        }
        assert!(
            caps.windows(2).all(|w| w[0] == w[1]),
            "steady-state capacity drifted: {caps:?}"
        );
    }

    #[test]
    fn empty_payload_is_handled_by_all_compressors() {
        let mut out = Compressed::default();
        let comps: [&dyn Compressor; 5] = [
            &NoCompress,
            &TopK { ratio: 8.0 },
            &ChunkedTopK { ratio: 8.0, chunk: 64 },
            &RandomK { ratio: 8.0, seed: 3 },
            &Int8Quantizer,
        ];
        for comp in comps {
            comp.compress_into(&[], &mut out);
            assert!(out.values.is_empty(), "{}", comp.name());
            assert!(out.indices.is_empty(), "{}", comp.name());
            assert!(out.bytes.is_empty(), "{}", comp.name());
            let c = comp.compress(&[]);
            comp.decompress(&c, &mut []);
        }
    }
}

#[cfg(test)]
mod chunked_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunked_topk_keeps_k_per_row() {
        let mut rng = Rng::new(11);
        let d = 64usize;
        let rows = 10usize;
        // One row has huge values; whole-tensor TopK would spend the whole
        // budget there, chunked keeps k in EVERY row.
        let mut data: Vec<f32> = (0..rows * d).map(|_| rng.f32() * 0.1).collect();
        for v in &mut data[..d] {
            *v += 100.0;
        }
        let comp = ChunkedTopK { ratio: 8.0, chunk: d };
        let c = comp.compress(&data);
        let per_row = (d as f64 / 8.0).ceil() as usize;
        assert_eq!(c.values.len(), per_row * rows);
        for r in 0..rows {
            let cnt = c
                .indices
                .iter()
                .filter(|&&i| (i as usize) / d == r)
                .count();
            assert_eq!(cnt, per_row, "row {r}");
        }
        // Contrast: whole-tensor TopK starves the small rows.
        let whole = TopK { ratio: 8.0 }.compress(&data);
        let row0 = whole.indices.iter().filter(|&&i| (i as usize) < d).count();
        assert_eq!(row0, d.min(whole.indices.len()), "whole-tensor concentrates");
    }

    #[test]
    fn chunked_topk_roundtrip_and_ragged_tail() {
        let mut rng = Rng::new(12);
        let data: Vec<f32> = (0..150).map(|_| rng.f32() - 0.5).collect();
        let comp = ChunkedTopK { ratio: 4.0, chunk: 64 }; // 64+64+22 tail
        let c = comp.compress(&data);
        let mut out = vec![0.0f32; 150];
        comp.decompress(&c, &mut out);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            assert_eq!(out[i as usize], v);
            assert_eq!(data[i as usize], v);
        }
        assert!(c.indices.iter().all(|&i| (i as usize) < 150));
    }
}
