//! Wire compressors over f32 payloads (Fig. 6).
//!
//! Top-K is the hot path (every cross-node message in the AdaTopK runs):
//! a quickselect threshold (O(n), no sort) followed by a single gather
//! pass — the same streaming-select shape as the L1 Pallas kernel.

use crate::opdag::data::CompressCfg;
use crate::util::math::kth_largest_abs;
use crate::util::rng::Rng;

/// A sparse/quantized wire message.
#[derive(Debug, Clone)]
pub struct Compressed {
    pub cfg: CompressCfg,
    pub values: Vec<f32>,
    pub indices: Vec<u32>,
    pub bytes: Vec<u8>,
}

impl Compressed {
    /// Bytes on the wire (paper accounting: f32 values + int64 indices).
    pub fn wire_bytes(&self) -> f64 {
        match self.cfg {
            CompressCfg::None => 4.0 * self.values.len() as f64,
            CompressCfg::TopK { .. } | CompressCfg::RandomK { .. } => {
                4.0 * self.values.len() as f64 + 8.0 * self.indices.len() as f64
            }
            CompressCfg::Int8 { .. } => self.bytes.len() as f64 + 4.0,
        }
    }
}

/// Compressor interface: compress a dense payload, decompress to dense.
pub trait Compressor: Send + Sync {
    fn compress(&self, data: &[f32]) -> Compressed;
    fn decompress(&self, c: &Compressed, out: &mut [f32]);
    fn name(&self) -> &'static str;
}

/// Identity (dense) — the paper's "no compression" baseline.
#[derive(Debug, Clone, Copy)]
pub struct NoCompress;

impl Compressor for NoCompress {
    fn compress(&self, data: &[f32]) -> Compressed {
        Compressed {
            cfg: CompressCfg::None,
            values: data.to_vec(),
            indices: Vec::new(),
            bytes: Vec::new(),
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.copy_from_slice(&c.values);
    }

    fn name(&self) -> &'static str {
        "none"
    }
}

/// Top-K by magnitude at compression ratio r (keep k = ceil(n/r)).
#[derive(Debug, Clone, Copy)]
pub struct TopK {
    pub ratio: f64,
}

impl TopK {
    pub fn k_for(&self, n: usize) -> usize {
        ((n as f64 / self.ratio).ceil() as usize).clamp(1, n)
    }
}

impl Compressor for TopK {
    fn compress(&self, data: &[f32]) -> Compressed {
        let n = data.len();
        let k = self.k_for(n);
        let mut values = Vec::with_capacity(k);
        let mut indices = Vec::with_capacity(k);
        if k >= n {
            values.extend_from_slice(data);
            indices.extend(0..n as u32);
        } else {
            let tau = kth_largest_abs(data, k);
            // First pass: strictly-above-threshold entries (always kept).
            for (i, &v) in data.iter().enumerate() {
                if v.abs() > tau {
                    values.push(v);
                    indices.push(i as u32);
                }
            }
            // Second pass: fill remaining slots with at-threshold ties.
            if values.len() < k {
                for (i, &v) in data.iter().enumerate() {
                    if v.abs() == tau {
                        values.push(v);
                        indices.push(i as u32);
                        if values.len() == k {
                            break;
                        }
                    }
                }
                // Keep indices sorted for cache-friendly decode.
                let mut pairs: Vec<(u32, f32)> =
                    indices.iter().copied().zip(values.iter().copied()).collect();
                pairs.sort_unstable_by_key(|p| p.0);
                indices = pairs.iter().map(|p| p.0).collect();
                values = pairs.iter().map(|p| p.1).collect();
            }
        }
        Compressed {
            cfg: CompressCfg::TopK { ratio: self.ratio, total_len: n as u32 },
            values,
            indices,
            bytes: Vec::new(),
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            out[i as usize] = v;
        }
    }

    fn name(&self) -> &'static str {
        "topk"
    }
}

/// Row-chunked Top-K (Fig. 6 applied per vector): the payload is treated
/// as rows of `chunk` elements (one token's feature vector) and Top-K is
/// selected within each row, so every token keeps its strongest features.
/// Whole-tensor Top-K concentrates the budget on a few high-norm tokens and
/// zeroes the rest entirely — much worse for convergence (EXPERIMENTS.md).
#[derive(Debug, Clone, Copy)]
pub struct ChunkedTopK {
    pub ratio: f64,
    pub chunk: usize,
}

impl Compressor for ChunkedTopK {
    fn compress(&self, data: &[f32]) -> Compressed {
        let n = data.len();
        let inner = TopK { ratio: self.ratio };
        let mut values = Vec::new();
        let mut indices = Vec::new();
        let mut off = 0usize;
        while off < n {
            let end = (off + self.chunk).min(n);
            let c = inner.compress(&data[off..end]);
            values.extend_from_slice(&c.values);
            indices.extend(c.indices.iter().map(|&i| i + off as u32));
            off = end;
        }
        Compressed {
            cfg: CompressCfg::TopK { ratio: self.ratio, total_len: n as u32 },
            values,
            indices,
            bytes: Vec::new(),
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            out[i as usize] = v;
        }
    }

    fn name(&self) -> &'static str {
        "chunked-topk"
    }
}

/// Random-K baseline: uniformly sampled support, deterministic by seed.
#[derive(Debug, Clone, Copy)]
pub struct RandomK {
    pub ratio: f64,
    pub seed: u64,
}

impl Compressor for RandomK {
    fn compress(&self, data: &[f32]) -> Compressed {
        let n = data.len();
        let k = ((n as f64 / self.ratio).ceil() as usize).clamp(1, n);
        let mut rng = Rng::new(self.seed);
        // Partial Fisher–Yates over indices: first k of a shuffle.
        let mut idx: Vec<u32> = (0..n as u32).collect();
        for i in 0..k {
            let j = i + rng.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        let mut indices: Vec<u32> = idx[..k].to_vec();
        indices.sort_unstable();
        let values = indices.iter().map(|&i| data[i as usize]).collect();
        Compressed {
            cfg: CompressCfg::RandomK {
                ratio: self.ratio,
                total_len: n as u32,
                seed: self.seed,
            },
            values,
            indices,
            bytes: Vec::new(),
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        out.fill(0.0);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            out[i as usize] = v;
        }
    }

    fn name(&self) -> &'static str {
        "randomk"
    }
}

/// Linear int8 quantization with per-message absmax scale.
#[derive(Debug, Clone, Copy)]
pub struct Int8Quantizer;

impl Compressor for Int8Quantizer {
    fn compress(&self, data: &[f32]) -> Compressed {
        let absmax = data.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        let bytes = data
            .iter()
            .map(|&v| (v / scale).round().clamp(-127.0, 127.0) as i8 as u8)
            .collect();
        Compressed {
            cfg: CompressCfg::Int8 { scale, total_len: data.len() as u32 },
            values: Vec::new(),
            indices: Vec::new(),
            bytes,
        }
    }

    fn decompress(&self, c: &Compressed, out: &mut [f32]) {
        let scale = match c.cfg {
            CompressCfg::Int8 { scale, .. } => scale,
            _ => panic!("int8 decompress on non-int8 payload"),
        };
        for (o, &b) in out.iter_mut().zip(&c.bytes) {
            *o = (b as i8) as f32 * scale;
        }
    }

    fn name(&self) -> &'static str {
        "int8"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (rng.f32() - 0.5) * 4.0).collect()
    }

    #[test]
    fn topk_keeps_exactly_k_largest() {
        let xs = data(1000, 1);
        let c = TopK { ratio: 100.0 }.compress(&xs);
        assert_eq!(c.values.len(), 10);
        assert_eq!(c.indices.len(), 10);
        // Every kept |v| >= every dropped |v|.
        let kept_min = c.values.iter().map(|v| v.abs()).fold(f32::MAX, f32::min);
        let kept: std::collections::BTreeSet<u32> = c.indices.iter().copied().collect();
        for (i, &v) in xs.iter().enumerate() {
            if !kept.contains(&(i as u32)) {
                assert!(v.abs() <= kept_min + 1e-7);
            }
        }
    }

    #[test]
    fn topk_roundtrip_preserves_support() {
        let xs = data(512, 2);
        let comp = TopK { ratio: 8.0 };
        let c = comp.compress(&xs);
        let mut out = vec![0f32; xs.len()];
        comp.decompress(&c, &mut out);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            assert_eq!(out[i as usize], v);
            assert_eq!(xs[i as usize], v);
        }
        let nz = out.iter().filter(|v| **v != 0.0).count();
        assert!(nz <= comp.k_for(xs.len()));
    }

    #[test]
    fn topk_with_duplicates_respects_k() {
        let xs = vec![1.0f32; 100];
        let c = TopK { ratio: 10.0 }.compress(&xs);
        assert_eq!(c.values.len(), 10);
    }

    #[test]
    fn topk_ratio_one_is_dense() {
        let xs = data(64, 3);
        let c = TopK { ratio: 1.0 }.compress(&xs);
        assert_eq!(c.values.len(), 64);
        let mut out = vec![0f32; 64];
        TopK { ratio: 1.0 }.decompress(&c, &mut out);
        assert_eq!(out, xs);
    }

    #[test]
    fn randomk_deterministic_and_correct_size() {
        let xs = data(1000, 4);
        let comp = RandomK { ratio: 50.0, seed: 99 };
        let c1 = comp.compress(&xs);
        let c2 = comp.compress(&xs);
        assert_eq!(c1.indices, c2.indices);
        assert_eq!(c1.values.len(), 20);
        // Indices unique.
        let set: std::collections::BTreeSet<u32> = c1.indices.iter().copied().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn int8_roundtrip_within_quant_error() {
        let xs = data(256, 5);
        let comp = Int8Quantizer;
        let c = comp.compress(&xs);
        let mut out = vec![0f32; 256];
        comp.decompress(&c, &mut out);
        let absmax = xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        for (a, b) in xs.iter().zip(&out) {
            assert!((a - b).abs() <= absmax / 127.0 + 1e-6);
        }
    }

    #[test]
    fn wire_bytes_ratio_is_3x_smaller_than_nominal() {
        // Paper Fig. 10 caption: ratio 100 gives 33.3× smaller payloads
        // (4B values + 8B indices per kept element = 12B vs 4B dense).
        let xs = data(10_000, 6);
        let dense = NoCompress.compress(&xs);
        let sparse = TopK { ratio: 100.0 }.compress(&xs);
        let shrink = dense.wire_bytes() / sparse.wire_bytes();
        assert!((shrink - 33.33).abs() < 0.5, "shrink={shrink}");
    }

    #[test]
    fn topk_compression_error_smaller_than_randomk() {
        let xs = data(2000, 7);
        let t = TopK { ratio: 20.0 };
        let r = RandomK { ratio: 20.0, seed: 1 };
        let mut out_t = vec![0f32; 2000];
        let mut out_r = vec![0f32; 2000];
        t.decompress(&t.compress(&xs), &mut out_t);
        r.decompress(&r.compress(&xs), &mut out_r);
        let err = |out: &[f32]| -> f32 {
            xs.iter().zip(out).map(|(a, b)| (a - b) * (a - b)).sum()
        };
        assert!(err(&out_t) < err(&out_r));
    }
}

#[cfg(test)]
mod chunked_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunked_topk_keeps_k_per_row() {
        let mut rng = Rng::new(11);
        let d = 64usize;
        let rows = 10usize;
        // One row has huge values; whole-tensor TopK would spend the whole
        // budget there, chunked keeps k in EVERY row.
        let mut data: Vec<f32> = (0..rows * d).map(|_| rng.f32() * 0.1).collect();
        for v in &mut data[..d] {
            *v += 100.0;
        }
        let comp = ChunkedTopK { ratio: 8.0, chunk: d };
        let c = comp.compress(&data);
        let per_row = (d as f64 / 8.0).ceil() as usize;
        assert_eq!(c.values.len(), per_row * rows);
        for r in 0..rows {
            let cnt = c
                .indices
                .iter()
                .filter(|&&i| (i as usize) / d == r)
                .count();
            assert_eq!(cnt, per_row, "row {r}");
        }
        // Contrast: whole-tensor TopK starves the small rows.
        let whole = TopK { ratio: 8.0 }.compress(&data);
        let row0 = whole.indices.iter().filter(|&&i| (i as usize) < d).count();
        assert_eq!(row0, d.min(whole.indices.len()), "whole-tensor concentrates");
    }

    #[test]
    fn chunked_topk_roundtrip_and_ragged_tail() {
        let mut rng = Rng::new(12);
        let data: Vec<f32> = (0..150).map(|_| rng.f32() - 0.5).collect();
        let comp = ChunkedTopK { ratio: 4.0, chunk: 64 }; // 64+64+22 tail
        let c = comp.compress(&data);
        let mut out = vec![0.0f32; 150];
        comp.decompress(&c, &mut out);
        for (&i, &v) in c.indices.iter().zip(&c.values) {
            assert_eq!(out[i as usize], v);
            assert_eq!(data[i as usize], v);
        }
        assert!(c.indices.iter().all(|&i| (i as usize) < 150));
    }
}
