//! `TcpTransport`: the socket implementation of the transport traits.
//!
//! Topology is a star routed through the broker: each worker process
//! holds exactly one TCP connection, multiplexing its lanes (fwd / bwd /
//! labels / driver / ctl) with a lane byte in the frame header. The
//! broker relays inter-stage `Packet` frames between worker connections
//! without re-encoding the OP-Data body — the bytes produced by the
//! sending `LinkEncoder` are the bytes the receiving stage decodes.
//!
//! Liveness is a *socket read deadline*, not channel-poll heuristics:
//! every broker-side connection reader runs with `SO_RCVTIMEO`-style read
//! timeouts, tracks the instant of the last received byte, and — while
//! the connection hosts a stage of a running generation — declares the
//! worker dead once the silence exceeds the heartbeat deadline (with the
//! `--heartbeat-grace` multiplier before first contact, covering slow
//! backend init). A `kill -9`'d worker process surfaces even earlier as
//! EOF/ECONNRESET on the same path. Either way the reader synthesizes a
//! `Wire::Fatal` into the driver plane and the existing checkpoint /
//! re-plan machinery recovers the run.
//!
//! Deadlock freedom: every endpoint has a dedicated, always-draining
//! reader thread pushing into unbounded local queues, so a blocked
//! `write_all` on one side always finds a reader on the other.

use crate::transport::codec::{self, Hello, StageAssign};
use crate::transport::frame::{
    encode_frame_header, write_all_vectored, write_frame_to, Frame, FrameKind, Framer, Lane,
    FRAME_OVERHEAD,
};
use crate::transport::{Link, LinkClosed, PacketPool};
use crate::worker::messages::Wire;
use std::collections::BTreeMap;
use std::io::Read;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long the broker waits for the full worker pool to connect.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(180);
/// Socket read timeout tick (granularity of the deadline monitor).
const READ_TICK: Duration = Duration::from_millis(50);

// ---- shared write half -------------------------------------------------

/// Serialized write half of one connection. Frames go out with
/// `write_vectored` (header / body / checksum as one iovec batch), so
/// there is no frame staging buffer and a packet's pooled body is never
/// memcpy'd on the send path; `body` only stages the compact encodings
/// of non-`Packet` control messages.
pub(crate) struct ConnWriter {
    stream: TcpStream,
    body: Vec<u8>,
}

/// Refuse to put an oversized body on the wire: the peer's `Framer`
/// would reject it (> `MAX_BODY`) — or, past 4 GiB, the u32 length field
/// would wrap and desync the stream — and either way a *healthy* peer
/// gets torn down. Failing the send keeps the error at the sender.
fn check_body(len: usize) -> std::io::Result<()> {
    if len > crate::transport::frame::MAX_BODY {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("frame body of {len} bytes exceeds cap {}", crate::transport::frame::MAX_BODY),
        ));
    }
    Ok(())
}

impl ConnWriter {
    pub(crate) fn new(stream: TcpStream) -> ConnWriter {
        ConnWriter { stream, body: Vec::new() }
    }

    pub(crate) fn write_frame(
        &mut self,
        lane: Lane,
        kind: FrameKind,
        body: &[u8],
    ) -> std::io::Result<()> {
        check_body(body.len())?;
        write_frame_to(&mut self.stream, lane, kind, body)
    }

    pub(crate) fn write_wire(&mut self, lane: Lane, w: &Wire) -> std::io::Result<()> {
        // Packet bodies are already wire bytes: frame them straight from
        // the caller's (pooled) buffer — `codec::encode_wire` would only
        // memcpy them into the staging vec.
        if let Wire::Packet(buf) = w {
            return self.write_frame(lane, FrameKind::Packet, buf);
        }
        self.body.clear();
        let kind = codec::encode_wire(w, &mut self.body);
        check_body(self.body.len())?;
        let Self { stream, body } = self;
        write_frame_to(stream, lane, kind, body)
    }

    /// Forward a validated frame unchanged, reusing its checksum: the
    /// header this rebuilds is byte-identical to the one the checksum
    /// already covers, so the relay path skips the FNV pass over the
    /// (potentially multi-MiB) body — and the vectored write skips the
    /// body copy too.
    fn relay_frame(&mut self, f: &Frame) -> std::io::Result<()> {
        check_body(f.body.len())?;
        let head = encode_frame_header(f.lane, f.kind, f.body.len());
        let sum = f.sum.to_le_bytes();
        write_all_vectored(&mut self.stream, [&head, &f.body, &sum])
    }
}

pub(crate) type SharedWriter = Arc<Mutex<ConnWriter>>;

/// `Link` over one lane of a TCP connection. Packet buffers are returned
/// to `pool` right after the socket write — the sender-side half of the
/// zero-allocation send path.
pub struct TcpLink {
    w: SharedWriter,
    lane: Lane,
    pool: Option<PacketPool>,
}

impl Link for TcpLink {
    fn send(&self, w: Wire) -> Result<(), LinkClosed> {
        let mut g = self.w.lock().map_err(|_| LinkClosed)?;
        let r = g.write_wire(self.lane, &w);
        drop(g);
        if let (Some(p), Wire::Packet(buf)) = (&self.pool, w) {
            p.give(buf);
        }
        r.map_err(|_| LinkClosed)
    }

    fn clone_link(&self) -> Box<dyn Link> {
        Box::new(TcpLink { w: self.w.clone(), lane: self.lane, pool: self.pool.clone() })
    }
}

// ---- worker side -------------------------------------------------------

/// Control events the worker main loop consumes.
#[derive(Debug)]
pub enum WorkerCtl {
    /// Run one stage of one generation.
    Assign(Box<StageAssign>),
    /// Broker is done; exit the process cleanly.
    Exit,
    /// The broker connection died (EOF, error, or corrupt stream).
    Lost(String),
}

/// Per-generation lane sinks the demux reader delivers into. Cleared
/// between generations so stale frames from a torn-down run are dropped.
#[derive(Default)]
struct LaneSinks {
    fwd: Option<Sender<Wire>>,
    bwd: Option<Sender<Wire>>,
    labels: Option<Sender<Wire>>,
}

/// A worker process's connection to the broker: demux reader thread,
/// shared write half, and the control-event queue.
pub struct WorkerSession {
    writer: SharedWriter,
    sinks: Arc<Mutex<LaneSinks>>,
    ctl_rx: Receiver<WorkerCtl>,
    rx_pool: PacketPool,
    peer: SocketAddr,
}

impl Drop for WorkerSession {
    /// Shut the socket down (not just this handle's fd — the demux
    /// reader holds a duplicate): the broker observes EOF immediately,
    /// and the reader thread unblocks and exits. A dropped session
    /// therefore looks exactly like a killed process from outside.
    fn drop(&mut self) {
        if let Ok(g) = self.writer.lock() {
            let _ = g.stream.shutdown(Shutdown::Both);
        }
    }
}

impl WorkerSession {
    /// Connect (retrying `retry` long — the broker may not be up yet),
    /// send `Hello{token, device, peer_listen}` and start the demux
    /// reader. `peer_listen` is the advertised mesh peer-listener
    /// address (None = this worker only serves the relay data plane).
    pub fn connect(
        addr: &str,
        token: &str,
        device: Option<usize>,
        peer_listen: Option<String>,
        retry: Duration,
    ) -> anyhow::Result<WorkerSession> {
        let t0 = Instant::now();
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if t0.elapsed() >= retry {
                        anyhow::bail!("could not connect to broker at {addr}: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        };
        let _ = stream.set_nodelay(true);
        let peer = stream.peer_addr()?;
        let reader = stream.try_clone()?;
        let writer: SharedWriter = Arc::new(Mutex::new(ConnWriter::new(stream)));
        let sinks: Arc<Mutex<LaneSinks>> = Arc::new(Mutex::new(LaneSinks::default()));
        let rx_pool = PacketPool::new();
        let (ctl_tx, ctl_rx) = mpsc::channel();
        {
            let sinks = sinks.clone();
            let pool = rx_pool.clone();
            std::thread::Builder::new()
                .name("tcp-demux".into())
                .spawn(move || worker_reader(reader, sinks, ctl_tx, pool))
                .expect("spawn worker demux reader");
        }
        let mut body = Vec::new();
        Hello { token: token.to_string(), device, peer_listen }.encode(&mut body);
        writer
            .lock()
            .unwrap()
            .write_frame(Lane::Ctl, FrameKind::Hello, &body)
            .map_err(|e| anyhow::anyhow!("hello to broker failed: {e}"))?;
        Ok(WorkerSession { writer, sinks, ctl_rx, rx_pool, peer })
    }

    pub fn peer(&self) -> SocketAddr {
        self.peer
    }

    /// Control events (Assign / Exit / Lost).
    pub fn ctl(&self) -> &Receiver<WorkerCtl> {
        &self.ctl_rx
    }

    /// Install this generation's lane queues (done before `send_ready`, so
    /// ordered delivery guarantees no post-Ready frame is dropped).
    pub fn install_lanes(
        &self,
        fwd: Sender<Wire>,
        bwd: Option<Sender<Wire>>,
        labels: Option<Sender<Wire>>,
    ) {
        if let Ok(mut g) = self.sinks.lock() {
            g.fwd = Some(fwd);
            g.bwd = bwd;
            g.labels = labels;
        }
    }

    /// Drop the generation's lane queues (stale frames then fall on the
    /// floor instead of leaking into the next generation).
    pub fn clear_lanes(&self) {
        if let Ok(mut g) = self.sinks.lock() {
            *g = LaneSinks::default();
        }
    }

    /// A send half over one lane of this connection.
    pub fn link(&self, lane: Lane, pool: Option<PacketPool>) -> Box<dyn Link> {
        Box::new(TcpLink { w: self.writer.clone(), lane, pool })
    }

    /// The pool incoming frame bodies are drawn from; the interpreter
    /// returns drained packet buffers here.
    pub fn rx_pool(&self) -> PacketPool {
        self.rx_pool.clone()
    }

    pub fn send_ready(&self, stage: usize) -> anyhow::Result<()> {
        let mut body = Vec::new();
        codec::encode_ready(stage, &mut body);
        self.writer
            .lock()
            .unwrap()
            .write_frame(Lane::Ctl, FrameKind::Ready, &body)
            .map_err(|e| anyhow::anyhow!("ready to broker failed: {e}"))
    }
}

/// Worker-side demux: every frame from the broker lands in the matching
/// lane queue (or the ctl queue). Exits — dropping all sinks so blocked
/// receives observe `Closed` — when the connection dies.
fn worker_reader(
    mut stream: TcpStream,
    sinks: Arc<Mutex<LaneSinks>>,
    ctl: Sender<WorkerCtl>,
    pool: PacketPool,
) {
    let mut framer = Framer::with_pool(pool.clone());
    let mut chunk = vec![0u8; 64 * 1024];
    let lost = |sinks: &Arc<Mutex<LaneSinks>>, ctl: &Sender<WorkerCtl>, why: String| {
        if let Ok(mut g) = sinks.lock() {
            *g = LaneSinks::default();
        }
        let _ = ctl.send(WorkerCtl::Lost(why));
    };
    loop {
        let n = match stream.read(&mut chunk) {
            Ok(0) => return lost(&sinks, &ctl, "broker closed the connection".into()),
            Ok(n) => n,
            Err(e) => return lost(&sinks, &ctl, format!("read error: {e}")),
        };
        framer.push(&chunk[..n]);
        loop {
            let f = match framer.next() {
                Ok(Some(f)) => f,
                Ok(None) => break,
                Err(e) => return lost(&sinks, &ctl, format!("corrupt stream: {e:#}")),
            };
            match (f.lane, f.kind) {
                (Lane::Ctl, FrameKind::Assign) => match StageAssign::decode(&f.body) {
                    Ok(a) => {
                        pool.give(f.body);
                        let _ = ctl.send(WorkerCtl::Assign(Box::new(a)));
                    }
                    Err(e) => return lost(&sinks, &ctl, format!("bad assign: {e:#}")),
                },
                (Lane::Ctl, FrameKind::Exit) => {
                    let _ = ctl.send(WorkerCtl::Exit);
                    return;
                }
                // Handshake rejection (bad token, duplicate device claim):
                // surface the broker's reason instead of a generic EOF.
                (Lane::Ctl, FrameKind::Fatal) => {
                    let why = match codec::decode_wire(FrameKind::Fatal, &f.body) {
                        Ok(Wire::Fatal { error, .. }) => format!("rejected by broker: {error}"),
                        _ => "rejected by broker".to_string(),
                    };
                    return lost(&sinks, &ctl, why);
                }
                (lane, kind) => {
                    // Packets hand the frame body over zero-copy (the
                    // interpreter returns it to `pool` after decode);
                    // control messages decode then recycle immediately.
                    let w = if kind == FrameKind::Packet {
                        Wire::Packet(f.body)
                    } else {
                        let w = match codec::decode_wire(kind, &f.body) {
                            Ok(w) => w,
                            Err(e) => return lost(&sinks, &ctl, format!("bad frame: {e:#}")),
                        };
                        pool.give(f.body);
                        w
                    };
                    let g = match sinks.lock() {
                        Ok(g) => g,
                        Err(_) => return,
                    };
                    let sink = match lane {
                        Lane::Fwd => g.fwd.as_ref(),
                        Lane::Bwd => g.bwd.as_ref(),
                        Lane::Labels => g.labels.as_ref(),
                        // No driver/ctl wire traffic flows toward workers.
                        Lane::Driver | Lane::Ctl => None,
                    };
                    if let Some(tx) = sink {
                        let _ = tx.send(w);
                    }
                    // No sink installed (between generations): drop.
                }
            }
        }
    }
}

// ---- broker side -------------------------------------------------------

/// Socket deadline configuration (mirrors the channel-plane monitor).
#[derive(Debug, Clone, Copy)]
pub struct MonitorCfg {
    /// Silence past this and a stage-hosting connection is dead.
    pub deadline: Duration,
    /// Deadline multiplier before a worker's first driver-plane frame of
    /// a generation (`--heartbeat-grace`): backend init may be slow.
    pub grace: u32,
}

struct Route {
    stage_of_conn: Vec<Option<usize>>,
    conn_of_stage: Vec<Option<usize>>,
    monitored: Vec<bool>,
    heard: Vec<bool>,
    alive: Vec<bool>,
    /// Bumped whenever monitoring is reconfigured; readers reset their
    /// silence clock on epoch change.
    epoch: u64,
}

struct Shared {
    route: Mutex<Route>,
    /// Driver-plane sink of the *current* generation.
    driver: Mutex<Option<Sender<Wire>>>,
    writers: Mutex<Vec<SharedWriter>>,
    monitor: MonitorCfg,
    /// Total `Packet` frame bytes (body + overhead) relayed through the
    /// broker. The mesh data plane's win condition: ≈ 0 when packet
    /// lanes travel worker↔worker.
    relayed: AtomicU64,
}

impl Shared {
    fn writer(&self, conn: usize) -> Option<SharedWriter> {
        self.writers.lock().ok()?.get(conn).cloned()
    }
}

enum HsEvent {
    Hello { conn: usize, hello: Hello },
    Ready { conn: usize, stage: usize },
}

/// The broker's TCP plane: the accepted worker pool, the routing table
/// mapping stages onto connections, and the per-connection deadline
/// monitors feeding the driver event loop. The listener stays open for
/// the whole run: workers may arrive (join) or come back (rejoin) long
/// after the initial pool formed, and `admit_pending` folds them in.
pub struct TcpPlane {
    shared: Arc<Shared>,
    hs_rx: Receiver<HsEvent>,
    hs_tx: Sender<HsEvent>,
    listener: TcpListener,
    token: String,
    device_cap: usize,
    /// Peer address per connection index (diagnostics).
    peers: Vec<String>,
    /// Hello claims observed while another routine owned `hs_rx` (e.g.
    /// a generation's ready barrier); processed on the next admission
    /// sweep instead of being dropped.
    pending_hellos: Vec<(usize, Hello)>,
    /// device id -> connection index (most recent claim wins; a dead
    /// device's id can be reclaimed by a fresh connection — a rejoin).
    device_conn: BTreeMap<usize, usize>,
    /// device id -> advertised mesh peer-listener address (from the most
    /// recent Hello claiming the device; rejoins overwrite).
    peer_addrs: BTreeMap<usize, String>,
    /// Monotonic mesh-generation counter (stamped into `StageAssign`s so
    /// peer listeners can drop stale dials).
    mesh_gen: u64,
    local_addr: SocketAddr,
}

impl TcpPlane {
    /// Bind (or adopt `listener`), accept `n_workers` authenticated
    /// workers and assign their device ids (claims must be below
    /// `device_cap`, the testbed size — out-of-range claims are turned
    /// away per-connection, they do not kill the pool). Blocks until the
    /// pool is complete or `ACCEPT_TIMEOUT` passes.
    pub fn start(
        listen: &str,
        listener: Option<TcpListener>,
        token: &str,
        n_workers: usize,
        device_cap: usize,
        monitor: MonitorCfg,
    ) -> anyhow::Result<TcpPlane> {
        anyhow::ensure!(n_workers > 0, "tcp transport needs at least one worker");
        let listener = match listener {
            Some(l) => l,
            None => TcpListener::bind(listen)
                .map_err(|e| anyhow::anyhow!("cannot listen on {listen}: {e}"))?,
        };
        let local_addr = listener.local_addr()?;
        eprintln!(
            "broker: listening on {local_addr}, waiting for {n_workers} worker(s) \
             (`fusionllm worker --connect {local_addr}`)"
        );
        listener.set_nonblocking(true)?;
        let (hs_tx, hs_rx) = mpsc::channel();
        let shared = Arc::new(Shared {
            route: Mutex::new(Route {
                stage_of_conn: Vec::new(),
                conn_of_stage: Vec::new(),
                monitored: Vec::new(),
                heard: Vec::new(),
                alive: Vec::new(),
                epoch: 0,
            }),
            driver: Mutex::new(None),
            writers: Mutex::new(Vec::new()),
            monitor,
            relayed: AtomicU64::new(0),
        });
        let mut plane = TcpPlane {
            shared,
            hs_rx,
            hs_tx,
            listener,
            token: token.to_string(),
            device_cap,
            peers: Vec::new(),
            pending_hellos: Vec::new(),
            device_conn: BTreeMap::new(),
            peer_addrs: BTreeMap::new(),
            mesh_gen: 0,
            local_addr,
        };
        let t0 = Instant::now();
        while plane.device_conn.len() < n_workers {
            anyhow::ensure!(
                t0.elapsed() < ACCEPT_TIMEOUT,
                "only {}/{n_workers} workers connected within {}s",
                plane.device_conn.len(),
                ACCEPT_TIMEOUT.as_secs()
            );
            match plane.listener.accept() {
                Ok((stream, peer)) => {
                    // Some platforms make accepted sockets inherit the
                    // listener's nonblocking flag; the reader relies on
                    // blocking reads with SO_RCVTIMEO.
                    stream.set_nonblocking(false)?;
                    let _ = stream.set_nodelay(true);
                    plane.register(stream)?;
                    plane.peers.push(peer.to_string());
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
                Err(e) => anyhow::bail!("accept failed: {e}"),
            }
            match plane.hs_rx.recv_timeout(Duration::from_millis(50)) {
                Ok(HsEvent::Hello { conn, hello }) => {
                    if let Some(dev) = plane.admit_hello(conn, hello) {
                        let peer = plane.peers.get(conn).cloned().unwrap_or_default();
                        eprintln!(
                            "broker: worker {peer} joined as device {dev} ({}/{n_workers})",
                            plane.device_conn.len()
                        );
                    }
                }
                Ok(HsEvent::Ready { .. }) => {} // cannot happen before assigns
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => anyhow::bail!("handshake plane lost"),
            }
        }
        Ok(plane)
    }

    /// Process one Hello claim: authenticate, resolve the device id (an
    /// explicit claim or the lowest never-claimed id) and bind it to
    /// `conn`. A claim on a device whose previous connection has *died*
    /// reclaims the id — that is a rejoin; the fresh connection starts
    /// with `heard = false`, so it re-earns liveness under the
    /// first-contact grace when the next generation monitors it. A claim
    /// on a live device, a bad token, or an out-of-range id turns that
    /// connection away without touching the rest of the pool. Returns the
    /// admitted device id.
    fn admit_hello(&mut self, conn: usize, hello: Hello) -> Option<usize> {
        let peer = self.peers.get(conn).cloned().unwrap_or_default();
        if hello.token != self.token {
            self.reject(conn, &peer, "bad token");
            return None;
        }
        let dev = match hello.device {
            Some(d) => d,
            None => {
                let mut d = 0usize;
                while self.device_conn.contains_key(&d) {
                    d += 1;
                }
                d
            }
        };
        if dev >= self.device_cap {
            self.reject(
                conn,
                &peer,
                &format!("device {dev} out of range (testbed has {})", self.device_cap),
            );
            return None;
        }
        if let Some(&old) = self.device_conn.get(&dev) {
            let old_alive = {
                let rt = self.shared.route.lock().unwrap();
                rt.alive.get(old).copied().unwrap_or(false)
            };
            if old_alive {
                self.reject(conn, &peer, &format!("device {dev} already claimed"));
                return None;
            }
            // Previous worker for this device is gone: reclaim (rejoin).
        }
        self.device_conn.insert(dev, conn);
        // The mesh route table always reflects the device's *current*
        // worker: a rejoin overwrites, a relay-only claim clears.
        match hello.peer_listen {
            Some(addr) => {
                self.peer_addrs.insert(dev, addr);
            }
            None => {
                self.peer_addrs.remove(&dev);
            }
        }
        Some(dev)
    }

    /// The mesh peer-listener address device `dev`'s worker advertised in
    /// its Hello (None = relay-only worker).
    pub fn peer_addr(&self, dev: usize) -> Option<String> {
        self.peer_addrs.get(&dev).cloned()
    }

    /// Next mesh generation id (monotonic per broker run).
    pub fn next_mesh_gen(&mut self) -> u64 {
        self.mesh_gen += 1;
        self.mesh_gen
    }

    /// Total `Packet` bytes (frame overhead included) the broker has
    /// relayed between worker connections so far.
    pub fn relayed_packet_bytes(&self) -> u64 {
        self.shared.relayed.load(Ordering::Relaxed)
    }

    /// Accept and authenticate any workers that connected after the pool
    /// formed (elastic membership). Non-blocking: sweeps the listener's
    /// accept queue, then the buffered + freshly arrived Hello claims.
    /// Returns the device ids admitted by this sweep.
    pub fn admit_pending(&mut self) -> Vec<usize> {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let p = peer.to_string();
                    if self.register(stream).is_ok() {
                        self.peers.push(p);
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
        let mut claims = std::mem::take(&mut self.pending_hellos);
        while let Ok(ev) = self.hs_rx.try_recv() {
            if let HsEvent::Hello { conn, hello } = ev {
                claims.push((conn, hello));
            }
            // Stray Ready from a torn-down generation: drop.
        }
        let mut admitted = Vec::new();
        for (conn, hello) in claims {
            if let Some(dev) = self.admit_hello(conn, hello) {
                let peer = self.peers.get(conn).cloned().unwrap_or_default();
                eprintln!("broker: worker {peer} admitted mid-run as device {dev}");
                admitted.push(dev);
            }
        }
        admitted
    }

    /// Block until a live worker connection claims `dev` (a scripted join
    /// or rejoin boundary), sweeping the accept queue while waiting.
    pub fn await_device(&mut self, dev: usize, timeout: Duration) -> anyhow::Result<()> {
        let t0 = Instant::now();
        loop {
            self.admit_pending();
            if let Some(&conn) = self.device_conn.get(&dev) {
                let alive = {
                    let rt = self.shared.route.lock().unwrap();
                    rt.alive.get(conn).copied().unwrap_or(false)
                };
                if alive {
                    return Ok(());
                }
            }
            anyhow::ensure!(
                t0.elapsed() < timeout,
                "no worker claimed device {dev} within {:.0}s",
                timeout.as_secs_f64()
            );
            std::thread::sleep(Duration::from_millis(25));
        }
    }

    /// Turn a connection away during the handshake: tell it why (a Ctl
    /// `Fatal` frame the worker surfaces in its error), close the socket
    /// and mark the conn dead. The rest of the pool is unaffected.
    fn reject(&self, conn: usize, peer: &str, why: &str) {
        eprintln!("broker: rejecting worker {peer} ({why})");
        if let Some(w) = self.shared.writer(conn) {
            let mut body = Vec::new();
            let k = codec::encode_wire(
                &Wire::Fatal { stage: usize::MAX, error: why.to_string() },
                &mut body,
            );
            let mut g = w.lock().unwrap();
            let _ = g.write_frame(Lane::Ctl, k, &body);
            let _ = g.stream.shutdown(Shutdown::Both);
        }
        mark_dead(&self.shared, conn);
    }

    fn register(&mut self, stream: TcpStream) -> anyhow::Result<usize> {
        let reader = stream.try_clone()?;
        let writer: SharedWriter = Arc::new(Mutex::new(ConnWriter::new(stream)));
        let conn = {
            let mut ws = self.shared.writers.lock().unwrap();
            ws.push(writer);
            ws.len() - 1
        };
        {
            let mut rt = self.shared.route.lock().unwrap();
            rt.stage_of_conn.push(None);
            rt.monitored.push(false);
            rt.heard.push(false);
            rt.alive.push(true);
        }
        let shared = self.shared.clone();
        let hs = self.hs_tx.clone();
        std::thread::Builder::new()
            .name(format!("tcp-conn{conn}"))
            .spawn(move || broker_reader(conn, reader, shared, hs))
            .expect("spawn broker connection reader");
        Ok(conn)
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Device ids with a live worker connection.
    pub fn live_devices(&self) -> Vec<usize> {
        let rt = self.shared.route.lock().unwrap();
        self.device_conn
            .iter()
            .filter(|(_, &c)| rt.alive[c])
            .map(|(&d, _)| d)
            .collect()
    }

    /// Device ids whose worker connection has died.
    pub fn dead_devices(&self) -> Vec<usize> {
        let rt = self.shared.route.lock().unwrap();
        self.device_conn
            .iter()
            .filter(|(_, &c)| !rt.alive[c])
            .map(|(&d, _)| d)
            .collect()
    }

    fn conn_of_device(&self, dev: usize) -> anyhow::Result<usize> {
        let conn = *self
            .device_conn
            .get(&dev)
            .ok_or_else(|| anyhow::anyhow!("no worker connected for device {dev}"))?;
        let rt = self.shared.route.lock().unwrap();
        anyhow::ensure!(rt.alive[conn], "worker for device {dev} is gone");
        Ok(conn)
    }

    /// A broker-side send half toward the worker hosting `stage`'s conn.
    fn conn_link(&self, conn: usize, lane: Lane) -> Box<dyn Link> {
        let w = self.shared.writer(conn).expect("registered conn");
        Box::new(TcpLink { w, lane, pool: None })
    }

    /// Start one generation: route stages onto device connections, ship
    /// the `StageAssign`s, wait for every Ready, arm the deadline
    /// monitors, and hand back the driver receive queue plus the
    /// per-stage fwd links and the head's label link.
    #[allow(clippy::type_complexity)]
    pub fn begin_generation(
        &mut self,
        devices: &[usize],
        assigns: Vec<StageAssign>,
        ready_timeout: Duration,
    ) -> anyhow::Result<(Receiver<Wire>, Vec<Box<dyn Link>>, Box<dyn Link>)> {
        let s_n = devices.len();
        anyhow::ensure!(s_n == assigns.len() && s_n > 0, "assignment shape mismatch");
        {
            let mut seen = std::collections::BTreeSet::new();
            for &d in devices {
                anyhow::ensure!(
                    seen.insert(d),
                    "device {d} would host two stages — a worker process runs one stage; \
                     start a spare worker so failover has a free device"
                );
            }
        }
        let stage_conns: Vec<usize> = devices
            .iter()
            .map(|&d| self.conn_of_device(d))
            .collect::<anyhow::Result<_>>()?;
        // No driver sink until the ready barrier passes: anything a
        // straggling previous generation still sends falls on the floor
        // instead of leaking into the new generation's queue.
        self.clear_driver();
        {
            let mut rt = self.shared.route.lock().unwrap();
            for v in rt.stage_of_conn.iter_mut() {
                *v = None;
            }
            rt.conn_of_stage = vec![None; s_n];
            for (s, &c) in stage_conns.iter().enumerate() {
                rt.stage_of_conn[c] = Some(s);
                rt.conn_of_stage[s] = Some(c);
            }
            for i in 0..rt.monitored.len() {
                rt.monitored[i] = rt.stage_of_conn[i].is_some();
                rt.heard[i] = false;
            }
            rt.epoch += 1;
        }
        // Drop stale Readys from a previous generation, but KEEP Hello
        // claims: a joiner that connected during the last generation must
        // not be silently discarded — it is admitted at the next
        // `admit_pending` sweep.
        while let Ok(ev) = self.hs_rx.try_recv() {
            if let HsEvent::Hello { conn, hello } = ev {
                self.pending_hellos.push((conn, hello));
            }
        }
        let mut body = Vec::new();
        for (s, a) in assigns.iter().enumerate() {
            body.clear();
            a.encode(&mut body);
            let w = self.shared.writer(stage_conns[s]).expect("registered conn");
            w.lock()
                .unwrap()
                .write_frame(Lane::Ctl, FrameKind::Assign, &body)
                .map_err(|e| {
                    anyhow::anyhow!("assign to stage {s} (device {}) failed: {e}", devices[s])
                })?;
        }
        // Ready barrier.
        let mut ready = vec![false; s_n];
        let mut got = 0usize;
        let t0 = Instant::now();
        while got < s_n {
            let left = ready_timeout
                .checked_sub(t0.elapsed())
                .filter(|d| !d.is_zero())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "ready barrier timed out: {got}/{s_n} stages ready after {:.1}s",
                        t0.elapsed().as_secs_f64()
                    )
                })?;
            match self.hs_rx.recv_timeout(left) {
                Ok(HsEvent::Ready { conn, stage }) => {
                    if stage < s_n && stage_conns[stage] == conn && !ready[stage] {
                        ready[stage] = true;
                        got += 1;
                    }
                }
                // A joiner arriving during the barrier: buffer its claim.
                Ok(HsEvent::Hello { conn, hello }) => {
                    self.pending_hellos.push((conn, hello))
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => anyhow::bail!("handshake plane lost"),
            }
        }
        // Barrier passed: per-connection frame ordering guarantees every
        // driver-lane message from here on belongs to this generation.
        let (tx, rx) = mpsc::channel();
        *self.shared.driver.lock().unwrap() = Some(tx);
        let fwd_tx: Vec<Box<dyn Link>> = stage_conns
            .iter()
            .map(|&c| self.conn_link(c, Lane::Fwd))
            .collect();
        let label_tx = self.conn_link(stage_conns[s_n - 1], Lane::Labels);
        Ok((rx, fwd_tx, label_tx))
    }

    /// Best-effort abort of a generation start that failed partway (some
    /// workers may have accepted their Assign and be waiting for data):
    /// send Stop on the data lanes of every live connection so they park,
    /// disarm the monitors, and drop any driver sink. Whatever Snapshot /
    /// Stats they emit in response falls on the floor.
    pub fn abort_generation(&self) {
        self.monitor_off();
        self.clear_driver();
        let alive: Vec<bool> = {
            let rt = self.shared.route.lock().unwrap();
            rt.alive.clone()
        };
        for &conn in self.device_conn.values() {
            if !alive.get(conn).copied().unwrap_or(false) {
                continue;
            }
            if let Some(w) = self.shared.writer(conn) {
                let mut g = w.lock().unwrap();
                let _ = g.write_wire(Lane::Fwd, &Wire::Stop);
                let _ = g.write_wire(Lane::Labels, &Wire::Stop);
            }
        }
    }

    /// Drop the driver-plane sink: subsequent driver-lane frames are
    /// discarded until the next generation installs a fresh one. Called
    /// at teardown so a slow straggler cannot pollute the next queue.
    pub fn clear_driver(&self) {
        *self.shared.driver.lock().unwrap() = None;
    }

    /// Disarm every connection's deadline monitor (teardown windows are
    /// legitimately silent — workers idle between generations).
    pub fn monitor_off(&self) {
        let mut rt = self.shared.route.lock().unwrap();
        for m in rt.monitored.iter_mut() {
            *m = false;
        }
        rt.epoch += 1;
    }

    /// End of run: tell every surviving worker process to exit.
    pub fn shutdown(&self) {
        self.monitor_off();
        let rt_alive: Vec<bool> = {
            let rt = self.shared.route.lock().unwrap();
            rt.alive.clone()
        };
        for &conn in self.device_conn.values() {
            if !rt_alive.get(conn).copied().unwrap_or(false) {
                continue;
            }
            if let Some(w) = self.shared.writer(conn) {
                let _ = w.lock().unwrap().write_frame(Lane::Ctl, FrameKind::Exit, &[]);
            }
        }
    }
}

fn mark_dead(shared: &Arc<Shared>, conn: usize) -> (Option<usize>, bool) {
    let mut rt = shared.route.lock().unwrap();
    if !rt.alive[conn] {
        return (None, false);
    }
    rt.alive[conn] = false;
    let stage = rt.stage_of_conn[conn].take();
    if let Some(s) = stage {
        rt.conn_of_stage[s] = None;
    }
    let monitored = rt.monitored[conn];
    rt.monitored[conn] = false;
    (stage, monitored)
}

/// A connection died (EOF, socket error, protocol corruption, or read
/// deadline). If it hosted a monitored stage, synthesize the death into
/// the driver plane so the existing recovery machinery reacts.
fn declare_dead(shared: &Arc<Shared>, conn: usize, cause: &str) {
    let (stage, monitored) = mark_dead(shared, conn);
    if stage.is_none() && !monitored {
        return; // idle spare or already-dead conn: nothing to report
    }
    if let (Some(s), true) = (stage, monitored) {
        if let Ok(g) = shared.driver.lock() {
            if let Some(tx) = g.as_ref() {
                let _ = tx.send(Wire::Fatal { stage: s, error: cause.to_string() });
            }
        }
    }
}

/// Broker-side per-connection reader: demux + relay + deadline monitor.
fn broker_reader(
    conn: usize,
    mut stream: TcpStream,
    shared: Arc<Shared>,
    hs: Sender<HsEvent>,
) {
    let pool = PacketPool::new();
    let mut framer = Framer::with_pool(pool.clone());
    let mut chunk = vec![0u8; 64 * 1024];
    let _ = stream.set_read_timeout(Some(READ_TICK));
    let mut last_rx = Instant::now();
    let mut last_epoch = 0u64;
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return declare_dead(&shared, conn, "worker connection closed (EOF)"),
            Ok(n) => {
                last_rx = Instant::now();
                framer.push(&chunk[..n]);
                loop {
                    match framer.next() {
                        Ok(Some(f)) => {
                            if let Err(e) = handle_frame(conn, f, &shared, &hs, &pool) {
                                let _ = stream.shutdown(Shutdown::Both);
                                return declare_dead(
                                    &shared,
                                    conn,
                                    &format!("protocol error: {e:#}"),
                                );
                            }
                        }
                        Ok(None) => break,
                        Err(e) => {
                            let _ = stream.shutdown(Shutdown::Both);
                            return declare_dead(&shared, conn, &format!("corrupt stream: {e:#}"));
                        }
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // The socket read deadline: the transport-level liveness
                // plane replacing the channel-poll heuristics.
                let (monitored, heard, epoch) = {
                    let rt = shared.route.lock().unwrap();
                    (rt.monitored[conn], rt.heard[conn], rt.epoch)
                };
                if epoch != last_epoch {
                    last_epoch = epoch;
                    last_rx = Instant::now();
                    continue;
                }
                if monitored {
                    let limit = if heard {
                        shared.monitor.deadline
                    } else {
                        shared.monitor.deadline * shared.monitor.grace.max(1)
                    };
                    let silent = last_rx.elapsed();
                    if silent > limit {
                        let _ = stream.shutdown(Shutdown::Both);
                        return declare_dead(
                            &shared,
                            conn,
                            &format!(
                                "socket read deadline: no bytes for {:.2}s (limit {:.2}s)",
                                silent.as_secs_f64(),
                                limit.as_secs_f64()
                            ),
                        );
                    }
                }
            }
            Err(e) => return declare_dead(&shared, conn, &format!("socket error: {e}")),
        }
    }
}

fn handle_frame(
    conn: usize,
    f: Frame,
    shared: &Arc<Shared>,
    hs: &Sender<HsEvent>,
    pool: &PacketPool,
) -> anyhow::Result<()> {
    match (f.lane, f.kind) {
        (Lane::Ctl, FrameKind::Hello) => {
            let hello = Hello::decode(&f.body)?;
            pool.give(f.body);
            let _ = hs.send(HsEvent::Hello { conn, hello });
        }
        (Lane::Ctl, FrameKind::Ready) => {
            let stage = codec::decode_ready(&f.body)?;
            pool.give(f.body);
            let _ = hs.send(HsEvent::Ready { conn, stage });
        }
        (Lane::Driver, kind) => {
            let w = codec::decode_wire(kind, &f.body)?;
            pool.give(f.body);
            {
                let mut rt = shared.route.lock().unwrap();
                rt.heard[conn] = true;
            }
            if let Ok(g) = shared.driver.lock() {
                if let Some(tx) = g.as_ref() {
                    let _ = tx.send(w);
                }
            }
        }
        // Inter-stage packets: relay the frame body verbatim (the OP-Data
        // bytes the sender's LinkEncoder produced) to the neighbor.
        (Lane::Fwd, FrameKind::Packet) => relay(conn, 1, f, shared, pool),
        (Lane::Bwd, FrameKind::Packet) => relay(conn, -1, f, shared, pool),
        (lane, kind) => anyhow::bail!("unexpected {kind:?} on {lane:?} lane from worker"),
    }
    Ok(())
}

fn relay(conn: usize, dir: i64, f: Frame, shared: &Arc<Shared>, pool: &PacketPool) {
    let dst = {
        let rt = shared.route.lock().unwrap();
        match rt.stage_of_conn[conn] {
            None => None, // stale frame from a torn-down generation
            Some(s) => {
                let d = s as i64 + dir;
                if d < 0 {
                    None
                } else {
                    rt.conn_of_stage
                        .get(d as usize)
                        .and_then(|c| *c)
                        .filter(|&c| rt.alive[c])
                }
            }
        }
    };
    if let Some(dst) = dst {
        shared
            .relayed
            .fetch_add((f.body.len() + FRAME_OVERHEAD) as u64, Ordering::Relaxed);
        if let Some(w) = shared.writer(dst) {
            // A failed write is the destination's problem; its own reader
            // declares the death.
            let _ = w.lock().unwrap().relay_frame(&f);
        }
    }
    pool.give(f.body);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::{encode_frame, frame_checksum};

    /// Loopback capture: the vectored `ConnWriter` paths (direct frame,
    /// Packet fast path, control-message staging, relay) must put bytes
    /// on a real socket identical to the old encode-into-a-staging-buffer
    /// + `write_all` path.
    #[test]
    fn conn_writer_bytes_match_copy_path() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let capture = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).unwrap();
            buf
        });

        let body: Vec<u8> = (0..1000u32).map(|i| (i.wrapping_mul(13)) as u8).collect();
        let mut w = ConnWriter::new(TcpStream::connect(addr).unwrap());
        w.write_frame(Lane::Fwd, FrameKind::Packet, &body).unwrap();
        w.write_wire(Lane::Bwd, &Wire::Packet(body.clone())).unwrap();
        w.write_wire(Lane::Fwd, &Wire::Stop).unwrap();
        let head = encode_frame_header(Lane::Bwd, FrameKind::Packet, body.len());
        let relay = Frame {
            lane: Lane::Bwd,
            kind: FrameKind::Packet,
            body: body.clone(),
            sum: frame_checksum(&head, &body),
        };
        w.relay_frame(&relay).unwrap();
        drop(w); // closes the socket; capture thread sees EOF

        let got = capture.join().unwrap();
        let mut want = Vec::new();
        let mut tmp = Vec::new();
        encode_frame(Lane::Fwd, FrameKind::Packet, &body, &mut tmp);
        want.extend_from_slice(&tmp);
        encode_frame(Lane::Bwd, FrameKind::Packet, &body, &mut tmp);
        want.extend_from_slice(&tmp);
        let mut stop = Vec::new();
        let kind = codec::encode_wire(&Wire::Stop, &mut stop);
        encode_frame(Lane::Fwd, kind, &stop, &mut tmp);
        want.extend_from_slice(&tmp);
        encode_frame(Lane::Bwd, FrameKind::Packet, &body, &mut tmp);
        want.extend_from_slice(&tmp);
        assert_eq!(got, want);

        // And the byte stream decodes back into the four frames.
        let mut fr = Framer::new();
        fr.push(&got);
        let mut n = 0;
        while let Some(f) = fr.next().unwrap() {
            n += 1;
            if f.kind == FrameKind::Packet {
                assert_eq!(f.body, body);
            }
        }
        assert_eq!(n, 4);
    }
}
