//! Low-level frame codec for the socket wire: every message travels as
//!
//! ```text
//! [magic 0xF5][version][lane][kind][body_len u32 LE][body ...][fnv1a64 LE]
//! ```
//!
//! The checksum covers header + body, so a flipped bit anywhere in the
//! frame is caught before the body is interpreted. `Packet` frames carry
//! the existing OP-Data wire encoding verbatim as their body (the OP-Data
//! codec is not re-invented at this layer); control messages get the
//! compact binary bodies of `transport::codec`.
//!
//! Decoding is incremental: a `Framer` accumulates raw socket reads and
//! yields complete frames, so `SO_RCVTIMEO`-interrupted partial reads can
//! never lose frame sync. Every malformed input — truncated frame, bad
//! magic, version mismatch, oversized length, checksum failure, unknown
//! lane/kind — surfaces as a clean `Err`, never a panic.

use crate::transport::PacketPool;
use crate::util::fnv::{fnv1a64, Fnv};

/// First byte of every frame.
pub const FRAME_MAGIC: u8 = 0xF5;
/// Protocol version; bumped on any incompatible frame/body change.
pub const FRAME_VERSION: u8 = 1;
/// Fixed bytes around the body: 8 header + 8 checksum.
pub const FRAME_OVERHEAD: usize = 16;
/// Upper bound on one frame body (a corrupt length field must not drive
/// a multi-gigabyte allocation).
pub const MAX_BODY: usize = 1 << 30;

const HEADER: usize = 8;

/// Which logical channel a frame belongs to. The star topology routes
/// everything through the broker, so the lane — not a per-link socket —
/// is what separates forward data, backward gradients, the label stream,
/// the driver plane and the control/handshake plane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// Forward-direction traffic: Data/Packet toward the next stage, plus
    /// broadcast control (Stop / Checkpoint) from the driver.
    Fwd,
    /// Backward-direction gradient packets toward the previous stage.
    Bwd,
    /// Driver -> head stage label stream.
    Labels,
    /// Worker -> driver reporting (Loss / IterProfile / Heartbeat / ...).
    Driver,
    /// Connection control: Hello / Assign / Ready / Exit.
    Ctl,
}

impl Lane {
    pub fn to_u8(self) -> u8 {
        match self {
            Lane::Fwd => 0,
            Lane::Bwd => 1,
            Lane::Labels => 2,
            Lane::Driver => 3,
            Lane::Ctl => 4,
        }
    }

    pub fn from_u8(b: u8) -> anyhow::Result<Lane> {
        Ok(match b {
            0 => Lane::Fwd,
            1 => Lane::Bwd,
            2 => Lane::Labels,
            3 => Lane::Driver,
            4 => Lane::Ctl,
            other => anyhow::bail!("unknown frame lane {other}"),
        })
    }
}

/// Frame payload type. One tag per `Wire` variant plus the handshake
/// messages that never appear on in-process channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    Hello,
    Assign,
    Ready,
    Exit,
    Data,
    Labels,
    Packet,
    Loss,
    IterProfile,
    Snapshot,
    Heartbeat,
    Checkpoint,
    Stats,
    Fatal,
    Stop,
    /// Peer-link flow control: the receiver returns `n` credits for the
    /// frame's lane (body = u32 LE count). Never crosses the broker and
    /// never surfaces as a `Wire` message — the mesh demux consumes it.
    Credit,
    /// Incremental checkpoint reply: a stage's lossless delta against the
    /// last acknowledged checkpoint version instead of a full `Snapshot`.
    SnapshotDelta,
}

impl FrameKind {
    pub fn to_u8(self) -> u8 {
        match self {
            FrameKind::Hello => 1,
            FrameKind::Assign => 2,
            FrameKind::Ready => 3,
            FrameKind::Exit => 4,
            FrameKind::Data => 5,
            FrameKind::Labels => 6,
            FrameKind::Packet => 7,
            FrameKind::Loss => 8,
            FrameKind::IterProfile => 9,
            FrameKind::Snapshot => 10,
            FrameKind::Heartbeat => 11,
            FrameKind::Checkpoint => 12,
            FrameKind::Stats => 13,
            FrameKind::Fatal => 14,
            FrameKind::Stop => 15,
            FrameKind::Credit => 16,
            FrameKind::SnapshotDelta => 17,
        }
    }

    pub fn from_u8(b: u8) -> anyhow::Result<FrameKind> {
        Ok(match b {
            1 => FrameKind::Hello,
            2 => FrameKind::Assign,
            3 => FrameKind::Ready,
            4 => FrameKind::Exit,
            5 => FrameKind::Data,
            6 => FrameKind::Labels,
            7 => FrameKind::Packet,
            8 => FrameKind::Loss,
            9 => FrameKind::IterProfile,
            10 => FrameKind::Snapshot,
            11 => FrameKind::Heartbeat,
            12 => FrameKind::Checkpoint,
            13 => FrameKind::Stats,
            14 => FrameKind::Fatal,
            15 => FrameKind::Stop,
            16 => FrameKind::Credit,
            17 => FrameKind::SnapshotDelta,
            other => anyhow::bail!("unknown frame kind {other}"),
        })
    }
}

/// The fixed 8-byte frame header for a body of `body_len` bytes.
pub fn encode_frame_header(lane: Lane, kind: FrameKind, body_len: usize) -> [u8; 8] {
    let len = (body_len as u32).to_le_bytes();
    [FRAME_MAGIC, FRAME_VERSION, lane.to_u8(), kind.to_u8(), len[0], len[1], len[2], len[3]]
}

/// Frame checksum over header + body without requiring them to be
/// contiguous — the vectored send path hashes the two regions in place
/// instead of staging them into one buffer first.
pub fn frame_checksum(head: &[u8; 8], body: &[u8]) -> u64 {
    Fnv::new().update(head).update(body).finish()
}

/// Serialize one frame into `out` (cleared first, capacity reused).
pub fn encode_frame(lane: Lane, kind: FrameKind, body: &[u8], out: &mut Vec<u8>) {
    out.clear();
    out.reserve(FRAME_OVERHEAD + body.len());
    out.extend_from_slice(&encode_frame_header(lane, kind, body.len()));
    out.extend_from_slice(body);
    let sum = fnv1a64(out);
    out.extend_from_slice(&sum.to_le_bytes());
}

/// Write one frame to `w` with `write_vectored` — header, borrowed body
/// and checksum go out as one iovec batch, so the body is never copied
/// into a staging frame buffer. Byte-identical on the wire to
/// `encode_frame` + `write_all`.
pub fn write_frame_to<W: std::io::Write>(
    w: &mut W,
    lane: Lane,
    kind: FrameKind,
    body: &[u8],
) -> std::io::Result<()> {
    let head = encode_frame_header(lane, kind, body.len());
    let sum = frame_checksum(&head, body).to_le_bytes();
    write_all_vectored(w, [&head, body, &sum])
}

/// `write_all` over three logically-concatenated buffers via
/// `write_vectored`, resuming correctly after short writes anywhere in
/// the batch. (`IoSlice::advance_slices` is past our MSRV, so the
/// remaining sub-slices are rebuilt per iteration — three slice offsets,
/// no byte copies.)
pub fn write_all_vectored<W: std::io::Write>(w: &mut W, bufs: [&[u8]; 3]) -> std::io::Result<()> {
    use std::io::{ErrorKind, IoSlice};
    let total: usize = bufs.iter().map(|b| b.len()).sum();
    let mut written = 0usize;
    while written < total {
        let mut rem = [&[][..]; 3];
        let mut skip = written;
        for (r, b) in rem.iter_mut().zip(bufs.iter()) {
            let take = skip.min(b.len());
            skip -= take;
            *r = &b[take..];
        }
        let io = [IoSlice::new(rem[0]), IoSlice::new(rem[1]), IoSlice::new(rem[2])];
        match w.write_vectored(&io) {
            Ok(0) => {
                return Err(std::io::Error::new(
                    ErrorKind::WriteZero,
                    "failed to write whole frame",
                ))
            }
            Ok(n) => written += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// One decoded frame. The body `Vec` comes from the framer's pool (if
/// any); give it back once drained to keep the receive path off malloc.
#[derive(Debug)]
pub struct Frame {
    pub lane: Lane,
    pub kind: FrameKind,
    pub body: Vec<u8>,
    /// The (already validated) checksum of this frame. A relay that
    /// forwards lane/kind/body unchanged re-emits it verbatim — the
    /// header bytes it covers are identical — instead of re-hashing a
    /// multi-KiB body on the hottest broker path.
    pub sum: u64,
}

/// Incremental frame decoder over an untrusted byte stream.
#[derive(Default)]
pub struct Framer {
    buf: Vec<u8>,
    pos: usize,
    pool: Option<PacketPool>,
}

impl Framer {
    pub fn new() -> Framer {
        Framer::default()
    }

    /// A framer whose frame bodies are allocated from (and returnable to)
    /// `pool`.
    pub fn with_pool(pool: PacketPool) -> Framer {
        Framer { pool: Some(pool), ..Framer::default() }
    }

    /// Feed raw bytes from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        // Compact consumed prefix before growing (amortized O(1)/byte).
        if self.pos > 0 && (self.pos >= self.buf.len() || self.pos > 64 * 1024) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Next complete frame, `None` if more bytes are needed, `Err` on a
    /// corrupt stream (the connection must be dropped — sync is lost).
    pub fn next(&mut self) -> anyhow::Result<Option<Frame>> {
        let avail = &self.buf[self.pos..];
        if avail.len() < HEADER {
            return Ok(None);
        }
        anyhow::ensure!(
            avail[0] == FRAME_MAGIC,
            "bad frame magic {:#04x} (expected {FRAME_MAGIC:#04x})",
            avail[0]
        );
        anyhow::ensure!(
            avail[1] == FRAME_VERSION,
            "frame version mismatch: peer speaks v{}, this build v{FRAME_VERSION}",
            avail[1]
        );
        let len = u32::from_le_bytes(avail[4..8].try_into().unwrap()) as usize;
        anyhow::ensure!(len <= MAX_BODY, "frame body of {len} bytes exceeds cap {MAX_BODY}");
        let total = HEADER + len + 8;
        if avail.len() < total {
            return Ok(None);
        }
        let want = u64::from_le_bytes(avail[HEADER + len..total].try_into().unwrap());
        let got = fnv1a64(&avail[..HEADER + len]);
        anyhow::ensure!(got == want, "frame checksum mismatch ({got:#x} != {want:#x})");
        let lane = Lane::from_u8(avail[2])?;
        let kind = FrameKind::from_u8(avail[3])?;
        let mut body = match &self.pool {
            Some(p) => p.take(),
            None => Vec::new(),
        };
        body.extend_from_slice(&avail[HEADER..HEADER + len]);
        self.pos += total;
        Ok(Some(Frame { lane, kind, body, sum: want }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(lane: Lane, kind: FrameKind, body: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        encode_frame(lane, kind, body, &mut out);
        out
    }

    #[test]
    fn roundtrip_across_chunk_boundaries() {
        let frames = [
            one(Lane::Fwd, FrameKind::Packet, &[1, 2, 3]),
            one(Lane::Ctl, FrameKind::Ready, &[]),
            one(Lane::Driver, FrameKind::Heartbeat, &(0..255u8).collect::<Vec<_>>()),
        ];
        let stream: Vec<u8> = frames.concat();
        // Feed 1 byte at a time: the framer must resync partial reads.
        let mut fr = Framer::new();
        let mut got = Vec::new();
        for b in &stream {
            fr.push(std::slice::from_ref(b));
            while let Some(f) = fr.next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got.len(), 3);
        assert_eq!((got[0].lane, got[0].kind), (Lane::Fwd, FrameKind::Packet));
        assert_eq!(got[0].body, vec![1, 2, 3]);
        assert_eq!(got[1].body, Vec::<u8>::new());
        assert_eq!(got[2].body.len(), 255);
    }

    #[test]
    fn truncated_frame_is_incomplete_not_error() {
        let f = one(Lane::Bwd, FrameKind::Packet, &[9; 64]);
        let mut fr = Framer::new();
        fr.push(&f[..f.len() - 1]);
        assert!(fr.next().unwrap().is_none());
        fr.push(&f[f.len() - 1..]);
        assert!(fr.next().unwrap().is_some());
    }

    #[test]
    fn corruption_errors_cleanly() {
        // Flipped body byte -> checksum error.
        let mut f = one(Lane::Fwd, FrameKind::Data, &[7; 32]);
        f[HEADER + 4] ^= 0x40;
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("checksum"));

        // Version mismatch.
        let mut f = one(Lane::Fwd, FrameKind::Data, &[7; 8]);
        f[1] = FRAME_VERSION + 1;
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("version"));

        // Bad magic (stream out of sync).
        let mut f = one(Lane::Fwd, FrameKind::Data, &[7; 8]);
        f[0] = 0x00;
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("magic"));

        // Oversized length field must not allocate.
        let mut f = one(Lane::Fwd, FrameKind::Data, &[]);
        f[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("exceeds cap"));
    }

    /// Accepts at most `cap` bytes per call (the default `write_vectored`
    /// additionally only ever sees the first non-empty buffer — worst-case
    /// scatter behavior), with periodic spurious `Interrupted` errors.
    struct ShortWriter {
        out: Vec<u8>,
        cap: usize,
        calls: usize,
    }

    impl std::io::Write for ShortWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.calls += 1;
            if self.calls % 3 == 0 {
                return Err(std::io::Error::new(std::io::ErrorKind::Interrupted, "signal"));
            }
            let n = buf.len().min(self.cap);
            self.out.extend_from_slice(&buf[..n]);
            Ok(n)
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn vectored_write_matches_encode_frame() {
        let body: Vec<u8> = (0..300u32).map(|i| (i.wrapping_mul(7)) as u8).collect();
        for body in [&body[..0], &body[..1], &body[..]] {
            let mut copied = Vec::new();
            encode_frame(Lane::Bwd, FrameKind::Packet, body, &mut copied);
            let mut vectored = Vec::new();
            write_frame_to(&mut vectored, Lane::Bwd, FrameKind::Packet, body).unwrap();
            assert_eq!(vectored, copied, "len={}", body.len());
        }
    }

    #[test]
    fn vectored_write_survives_short_writes() {
        let body: Vec<u8> = (0..257u32).map(|i| (i.wrapping_mul(31)) as u8).collect();
        let mut want = Vec::new();
        encode_frame(Lane::Fwd, FrameKind::Packet, &body, &mut want);
        for cap in [1, 2, 3, 7, 16, 64, 1024] {
            let mut w = ShortWriter { out: Vec::new(), cap, calls: 0 };
            write_frame_to(&mut w, Lane::Fwd, FrameKind::Packet, &body).unwrap();
            assert_eq!(w.out, want, "cap={cap}");
            // And the reassembled stream still decodes.
            let mut fr = Framer::new();
            fr.push(&w.out);
            let f = fr.next().unwrap().unwrap();
            assert_eq!(f.body, body);
        }
    }

    #[test]
    fn vectored_write_zero_is_error() {
        let mut w = ShortWriter { out: Vec::new(), cap: 0, calls: 0 };
        let err = write_frame_to(&mut w, Lane::Ctl, FrameKind::Ready, &[]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::WriteZero);
    }

    #[test]
    fn unknown_lane_and_kind_rejected() {
        let mut f = one(Lane::Fwd, FrameKind::Data, &[1]);
        f[2] = 99;
        let sum = fnv1a64(&f[..f.len() - 8]);
        let n = f.len();
        f[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("lane"));

        let mut f = one(Lane::Fwd, FrameKind::Data, &[1]);
        f[3] = 200;
        let sum = fnv1a64(&f[..f.len() - 8]);
        let n = f.len();
        f[n - 8..].copy_from_slice(&sum.to_le_bytes());
        let mut fr = Framer::new();
        fr.push(&f);
        assert!(fr.next().unwrap_err().to_string().contains("kind"));
    }
}
