//! `ChanTransport`: the in-process mpsc implementation of the transport
//! traits — today's default path, kept verbatim as the differential
//! oracle for `TcpTransport` (the e2e tests require bitwise-identical
//! loss trajectories across the two).

use crate::transport::{Endpoint, Link, LinkClosed, RecvError};
use crate::worker::messages::Wire;
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::time::Duration;

/// Sending half of an in-process lane.
pub struct ChanLink(pub Sender<Wire>);

impl Link for ChanLink {
    fn send(&self, w: Wire) -> Result<(), LinkClosed> {
        self.0.send(w).map_err(|_| LinkClosed)
    }

    fn clone_link(&self) -> Box<dyn Link> {
        Box::new(ChanLink(self.0.clone()))
    }
}

/// Receiving half of an in-process lane.
pub struct ChanEndpoint(pub Receiver<Wire>);

impl Endpoint for ChanEndpoint {
    fn recv(&self) -> Result<Wire, RecvError> {
        self.0.recv().map_err(|_| RecvError::Closed)
    }

    fn recv_deadline(&self, d: Duration) -> Result<Wire, RecvError> {
        self.0.recv_timeout(d).map_err(|e| match e {
            RecvTimeoutError::Timeout => RecvError::Timeout,
            RecvTimeoutError::Disconnected => RecvError::Closed,
        })
    }

    fn try_recv(&self) -> Result<Wire, RecvError> {
        self.0.try_recv().map_err(|e| match e {
            TryRecvError::Empty => RecvError::Timeout,
            TryRecvError::Disconnected => RecvError::Closed,
        })
    }
}

/// Box an mpsc sender as a transport link.
pub fn link(tx: Sender<Wire>) -> Box<dyn Link> {
    Box::new(ChanLink(tx))
}

/// Box an mpsc receiver as a transport endpoint.
pub fn endpoint(rx: Receiver<Wire>) -> Box<dyn Endpoint> {
    Box::new(ChanEndpoint(rx))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn chan_semantics_map_onto_the_traits() {
        let (tx, rx) = channel::<Wire>();
        let l = link(tx);
        let e = endpoint(rx);
        l.send(Wire::Stop).unwrap();
        assert_eq!(e.recv().unwrap(), Wire::Stop);
        assert_eq!(e.try_recv().unwrap_err(), RecvError::Timeout);
        assert_eq!(
            e.recv_deadline(Duration::from_millis(1)).unwrap_err(),
            RecvError::Timeout
        );
        let l2 = l.clone_link();
        drop((l, l2));
        assert_eq!(e.recv().unwrap_err(), RecvError::Closed);
    }
}
