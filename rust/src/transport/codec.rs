//! Compact binary bodies for control-plane frames: every `Wire` variant
//! plus the connection handshake (`Hello` → `StageAssign` → `Ready`).
//!
//! `Wire::Packet` bodies are the existing OP-Data wire encoding verbatim
//! (this layer adds nothing on top of the payload hot path); everything
//! else is flat little-endian fields behind the frame checksum. Decoding
//! never panics: a truncated or trailing-garbage body is a clean error,
//! and the property tests in `rust/tests/transport.rs` fuzz exactly that.

use crate::compress::{CompressKind, ValueCodec};
use crate::pipeline::{Task, TaskKind};
use crate::transport::frame::FrameKind;
use crate::worker::messages::{LinkSpec, StageState, Wire, WorkerStats};
use crate::worker::BackendKind;

// ---- primitive writers -------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// usize as u64 (wire-portable across word sizes).
fn put_usize(out: &mut Vec<u8>, v: usize) {
    put_u64(out, v as u64);
}

/// Option<usize> as i64 (-1 = None).
fn put_opt_usize(out: &mut Vec<u8>, v: Option<usize>) {
    let enc: i64 = v.map(|x| x as i64).unwrap_or(-1);
    out.extend_from_slice(&enc.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u64(out, xs.len() as u64);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_i32s(out: &mut Vec<u8>, xs: &[i32]) {
    put_u64(out, xs.len() as u64);
    for v in xs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

// ---- primitive reader --------------------------------------------------

/// Cursor over an untrusted body; every read is bounds-checked.
pub struct Rd<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Rd<'a> {
    pub fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.b.len())
            .ok_or_else(|| anyhow::anyhow!("frame body truncated"))?;
        let s = &self.b[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usize(&mut self) -> anyhow::Result<usize> {
        Ok(self.u64()? as usize)
    }

    fn opt_usize(&mut self) -> anyhow::Result<Option<usize>> {
        let v = i64::from_le_bytes(self.take(8)?.try_into().unwrap());
        Ok(if v < 0 { None } else { Some(v as usize) })
    }

    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn str(&mut self) -> anyhow::Result<String> {
        let n = self.u32()? as usize;
        Ok(std::str::from_utf8(self.take(n)?)?.to_string())
    }

    fn f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i32s(&mut self) -> anyhow::Result<Vec<i32>> {
        let n = self.u64()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("length overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// The whole body must be consumed — trailing bytes mean the peer and
    /// this build disagree about the message layout.
    fn finish(self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.pos == self.b.len(),
            "{} trailing bytes after message body",
            self.b.len() - self.pos
        );
        Ok(())
    }
}

// ---- small enum tags ---------------------------------------------------

fn compress_kind_u8(k: CompressKind) -> u8 {
    match k {
        CompressKind::None => 0,
        CompressKind::TopK => 1,
        CompressKind::AdaTopK => 2,
        CompressKind::RandomK => 3,
        CompressKind::Int8 => 4,
    }
}

fn compress_kind_from(b: u8) -> anyhow::Result<CompressKind> {
    Ok(match b {
        0 => CompressKind::None,
        1 => CompressKind::TopK,
        2 => CompressKind::AdaTopK,
        3 => CompressKind::RandomK,
        4 => CompressKind::Int8,
        other => anyhow::bail!("unknown compress kind tag {other}"),
    })
}

fn value_codec_u8(c: ValueCodec) -> u8 {
    match c {
        ValueCodec::F32 => 0,
        ValueCodec::Int8 => 1,
        ValueCodec::Int8Delta => 2,
    }
}

fn value_codec_from(b: u8) -> anyhow::Result<ValueCodec> {
    Ok(match b {
        0 => ValueCodec::F32,
        1 => ValueCodec::Int8,
        2 => ValueCodec::Int8Delta,
        other => anyhow::bail!("unknown value codec tag {other}"),
    })
}

fn backend_u8(b: BackendKind) -> u8 {
    match b {
        BackendKind::Pjrt => 0,
        BackendKind::Null => 1,
    }
}

fn backend_from(b: u8) -> anyhow::Result<BackendKind> {
    Ok(match b {
        0 => BackendKind::Pjrt,
        1 => BackendKind::Null,
        other => anyhow::bail!("unknown backend tag {other}"),
    })
}

fn task_kind_u8(k: TaskKind) -> u8 {
    match k {
        TaskKind::Forward => 0,
        TaskKind::Backward => 1,
        TaskKind::Update => 2,
    }
}

fn task_kind_from(b: u8) -> anyhow::Result<TaskKind> {
    Ok(match b {
        0 => TaskKind::Forward,
        1 => TaskKind::Backward,
        2 => TaskKind::Update,
        other => anyhow::bail!("unknown task kind tag {other}"),
    })
}

// ---- StageState --------------------------------------------------------

fn put_state(out: &mut Vec<u8>, st: &StageState) {
    put_f32s(out, &st.params);
    put_f32s(out, &st.momentum);
    put_f32s(out, &st.second);
}

fn read_state(rd: &mut Rd) -> anyhow::Result<StageState> {
    Ok(StageState { params: rd.f32s()?, momentum: rd.f32s()?, second: rd.f32s()? })
}

// ---- Wire --------------------------------------------------------------

/// Serialize one `Wire` into a frame body (appended to `out`, which the
/// caller clears) and return the frame kind tag it travels under.
pub fn encode_wire(w: &Wire, out: &mut Vec<u8>) -> FrameKind {
    match w {
        Wire::Data { iter, micro, tokens } => {
            put_u32(out, *iter);
            put_u32(out, *micro);
            put_i32s(out, tokens);
            FrameKind::Data
        }
        Wire::Labels { iter, micro, targets } => {
            put_u32(out, *iter);
            put_u32(out, *micro);
            put_i32s(out, targets);
            FrameKind::Labels
        }
        Wire::Packet(buf) => {
            out.extend_from_slice(buf);
            FrameKind::Packet
        }
        Wire::Loss { iter, micro, loss } => {
            put_u32(out, *iter);
            put_u32(out, *micro);
            put_f32(out, *loss);
            FrameKind::Loss
        }
        Wire::IterProfile { stage, iter, fwd_s, bwd_s, update_s, bytes, msgs } => {
            put_usize(out, *stage);
            put_u32(out, *iter);
            put_f64(out, *fwd_s);
            put_f64(out, *bwd_s);
            put_f64(out, *update_s);
            put_f64(out, *bytes);
            put_u64(out, *msgs);
            FrameKind::IterProfile
        }
        Wire::Snapshot { stage, state } => {
            put_usize(out, *stage);
            put_state(out, state);
            FrameKind::Snapshot
        }
        Wire::Heartbeat { stage, iter } => {
            put_usize(out, *stage);
            put_u32(out, *iter);
            FrameKind::Heartbeat
        }
        Wire::Checkpoint { iter, base } => {
            put_u32(out, *iter);
            put_opt_usize(out, base.map(|b| b as usize));
            FrameKind::Checkpoint
        }
        Wire::SnapshotDelta { stage, base_iter, blob } => {
            put_usize(out, *stage);
            put_u32(out, *base_iter);
            put_u64(out, blob.len() as u64);
            out.extend_from_slice(blob);
            FrameKind::SnapshotDelta
        }
        Wire::Stats(st) => {
            put_usize(out, st.stage);
            put_usize(out, st.device);
            put_f64(out, st.fwd_s);
            put_f64(out, st.bwd_s);
            put_f64(out, st.update_s);
            put_f64(out, st.wait_s);
            put_f64(out, st.bytes_sent);
            put_f64(out, st.dense_bytes);
            put_u64(out, st.msgs_sent);
            put_f64(out, st.flops);
            FrameKind::Stats
        }
        Wire::Fatal { stage, error } => {
            put_usize(out, *stage);
            put_str(out, error);
            FrameKind::Fatal
        }
        Wire::Stop => FrameKind::Stop,
    }
}

/// Decode a frame body back into a `Wire`. Handshake kinds (Hello /
/// Assign / Ready / Exit) are not `Wire` messages and error here.
pub fn decode_wire(kind: FrameKind, body: &[u8]) -> anyhow::Result<Wire> {
    let mut rd = Rd::new(body);
    let w = match kind {
        FrameKind::Data => Wire::Data {
            iter: rd.u32()?,
            micro: rd.u32()?,
            tokens: rd.i32s()?,
        },
        FrameKind::Labels => Wire::Labels {
            iter: rd.u32()?,
            micro: rd.u32()?,
            targets: rd.i32s()?,
        },
        FrameKind::Packet => {
            return Ok(Wire::Packet(body.to_vec()));
        }
        FrameKind::Loss => Wire::Loss {
            iter: rd.u32()?,
            micro: rd.u32()?,
            loss: rd.f32()?,
        },
        FrameKind::IterProfile => Wire::IterProfile {
            stage: rd.usize()?,
            iter: rd.u32()?,
            fwd_s: rd.f64()?,
            bwd_s: rd.f64()?,
            update_s: rd.f64()?,
            bytes: rd.f64()?,
            msgs: rd.u64()?,
        },
        FrameKind::Snapshot => Wire::Snapshot {
            stage: rd.usize()?,
            state: read_state(&mut rd)?,
        },
        FrameKind::Heartbeat => Wire::Heartbeat { stage: rd.usize()?, iter: rd.u32()? },
        FrameKind::Checkpoint => Wire::Checkpoint {
            iter: rd.u32()?,
            base: rd.opt_usize()?.map(|b| b as u32),
        },
        FrameKind::SnapshotDelta => Wire::SnapshotDelta {
            stage: rd.usize()?,
            base_iter: rd.u32()?,
            blob: {
                let n = rd.u64()? as usize;
                rd.take(n)?.to_vec()
            },
        },
        FrameKind::Stats => Wire::Stats(WorkerStats {
            stage: rd.usize()?,
            device: rd.usize()?,
            fwd_s: rd.f64()?,
            bwd_s: rd.f64()?,
            update_s: rd.f64()?,
            wait_s: rd.f64()?,
            bytes_sent: rd.f64()?,
            dense_bytes: rd.f64()?,
            msgs_sent: rd.u64()?,
            flops: rd.f64()?,
        }),
        FrameKind::Fatal => Wire::Fatal { stage: rd.usize()?, error: rd.str()? },
        FrameKind::Stop => Wire::Stop,
        FrameKind::Hello
        | FrameKind::Assign
        | FrameKind::Ready
        | FrameKind::Exit
        | FrameKind::Credit => {
            anyhow::bail!("handshake frame {kind:?} is not a Wire message")
        }
    };
    rd.finish()?;
    Ok(w)
}

// ---- handshake ---------------------------------------------------------

/// Worker -> broker on connect.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    /// Shared-secret token; mismatches are rejected before any assignment.
    pub token: String,
    /// Requested device id (None = broker assigns the next free one).
    pub device: Option<usize>,
    /// Address (`host:port`) where this worker's peer listener accepts
    /// direct mesh connections from pipeline neighbors (None = relay-only
    /// worker; the broker excludes it from mesh route tables).
    pub peer_listen: Option<String>,
}

impl Hello {
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_str(out, &self.token);
        put_opt_usize(out, self.device);
        match &self.peer_listen {
            None => put_u8(out, 0),
            Some(addr) => {
                put_u8(out, 1);
                put_str(out, addr);
            }
        }
    }

    pub fn decode(body: &[u8]) -> anyhow::Result<Hello> {
        let mut rd = Rd::new(body);
        let h = Hello {
            token: rd.str()?,
            device: rd.opt_usize()?,
            peer_listen: match rd.u8()? {
                0 => None,
                1 => Some(rd.str()?),
                other => anyhow::bail!("bad peer-listen presence tag {other}"),
            },
        };
        rd.finish()?;
        Ok(h)
    }
}

// ---- peer handshake (mesh data plane) ----------------------------------

/// Dialer -> acceptor on a fresh peer connection: authenticate and bind
/// the socket to (stage, generation). The acceptor validates the token,
/// that the dialer is its pipeline predecessor, and that the generation
/// matches — stale dials from a torn-down generation are dropped.
pub(crate) fn encode_peer_hello(token: &str, stage: usize, gen: u64, out: &mut Vec<u8>) {
    put_str(out, token);
    put_usize(out, stage);
    put_u64(out, gen);
}

/// Decode a peer hello body into (token, dialer stage, mesh generation).
pub(crate) fn decode_peer_hello(body: &[u8]) -> anyhow::Result<(String, usize, u64)> {
    let mut rd = Rd::new(body);
    let token = rd.str()?;
    let stage = rd.usize()?;
    let gen = rd.u64()?;
    rd.finish()?;
    Ok((token, stage, gen))
}

/// Broker -> worker: everything a remote process needs to run one stage
/// of one worker generation — the serialized `StagePlan`/`StageCodec`
/// configuration of the ISSUE handshake. Mirrors the in-process
/// `StageCtx` minus the channel endpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct StageAssign {
    pub stage: usize,
    pub n_stages: usize,
    pub device: usize,
    pub next_device: Option<usize>,
    pub prev_device: Option<usize>,
    /// Model/artifact config name; PJRT workers load it from their local
    /// artifacts root, Null workers synthesize it.
    pub config: String,
    pub backend: BackendKind,
    pub optimizer: String,
    /// Top-K row chunk (d_model) for the link encoders.
    pub chunk: usize,
    pub fwd: Option<LinkSpec>,
    pub bwd: Option<LinkSpec>,
    pub tasks: Vec<Task>,
    pub iter0: u32,
    pub iters: usize,
    pub n_micro: usize,
    pub lr: f32,
    pub momentum: f32,
    pub param_seed: u64,
    pub slow_factor: f64,
    /// Artificial per-forward pacing (Null backend demos/CI), seconds.
    pub pace_s: f64,
    pub heartbeat_s: f64,
    pub kill_at_iter: Option<u32>,
    /// Migrated/restored state (checkpoint recovery, live migration).
    pub init_state: Option<StageState>,
    /// Mesh generation this assignment belongs to: a broker-monotonic
    /// counter peer hellos carry, so a listener can drop stale dials left
    /// over from a torn-down generation. Meaningful only when `peers` is
    /// non-empty.
    pub mesh_gen: u64,
    /// Mesh route table: (stage, peer-listener `host:port`) for every
    /// stage of this generation. Empty = relay data plane (all packets
    /// through the broker, the pre-mesh wire behavior).
    pub peers: Vec<(usize, String)>,
    /// Overlapped wire pipeline: encode/send on dedicated threads and
    /// prefetch inbound activations while the backend runs.
    pub overlap: bool,
    /// Artificial per-send delay (seconds) modelling a slow link; used by
    /// the paced overlap smoke so the hidden latency is measurable.
    pub link_delay_s: f64,
    /// Mesh credit window depth (in-flight packets per directed peer link).
    pub mesh_window: usize,
}

fn put_link_spec(out: &mut Vec<u8>, spec: &Option<LinkSpec>) {
    match spec {
        None => put_u8(out, 0),
        Some(s) => {
            put_u8(out, 1);
            put_u8(out, compress_kind_u8(s.kind));
            put_f64(out, s.ratio);
            put_u8(out, value_codec_u8(s.codec));
        }
    }
}

fn read_link_spec(rd: &mut Rd) -> anyhow::Result<Option<LinkSpec>> {
    Ok(match rd.u8()? {
        0 => None,
        1 => Some(LinkSpec {
            kind: compress_kind_from(rd.u8()?)?,
            ratio: rd.f64()?,
            codec: value_codec_from(rd.u8()?)?,
        }),
        other => anyhow::bail!("bad link-spec presence tag {other}"),
    })
}

impl StageAssign {
    pub fn encode(&self, out: &mut Vec<u8>) {
        put_usize(out, self.stage);
        put_usize(out, self.n_stages);
        put_usize(out, self.device);
        put_opt_usize(out, self.next_device);
        put_opt_usize(out, self.prev_device);
        put_str(out, &self.config);
        put_u8(out, backend_u8(self.backend));
        put_str(out, &self.optimizer);
        put_usize(out, self.chunk);
        put_link_spec(out, &self.fwd);
        put_link_spec(out, &self.bwd);
        put_u32(out, self.tasks.len() as u32);
        for t in &self.tasks {
            put_usize(out, t.stage);
            put_usize(out, t.micro);
            put_u8(out, task_kind_u8(t.kind));
        }
        put_u32(out, self.iter0);
        put_usize(out, self.iters);
        put_usize(out, self.n_micro);
        put_f32(out, self.lr);
        put_f32(out, self.momentum);
        put_u64(out, self.param_seed);
        put_f64(out, self.slow_factor);
        put_f64(out, self.pace_s);
        put_f64(out, self.heartbeat_s);
        put_opt_usize(out, self.kill_at_iter.map(|k| k as usize));
        match &self.init_state {
            None => put_u8(out, 0),
            Some(st) => {
                put_u8(out, 1);
                put_state(out, st);
            }
        }
        put_u64(out, self.mesh_gen);
        put_u32(out, self.peers.len() as u32);
        for (stage, addr) in &self.peers {
            put_usize(out, *stage);
            put_str(out, addr);
        }
        put_u8(out, self.overlap as u8);
        put_f64(out, self.link_delay_s);
        put_usize(out, self.mesh_window);
    }

    pub fn decode(body: &[u8]) -> anyhow::Result<StageAssign> {
        let mut rd = Rd::new(body);
        let stage = rd.usize()?;
        let n_stages = rd.usize()?;
        let device = rd.usize()?;
        let next_device = rd.opt_usize()?;
        let prev_device = rd.opt_usize()?;
        let config = rd.str()?;
        let backend = backend_from(rd.u8()?)?;
        let optimizer = rd.str()?;
        let chunk = rd.usize()?;
        let fwd = read_link_spec(&mut rd)?;
        let bwd = read_link_spec(&mut rd)?;
        let n_tasks = rd.u32()? as usize;
        let mut tasks = Vec::with_capacity(n_tasks.min(4096));
        for _ in 0..n_tasks {
            tasks.push(Task {
                stage: rd.usize()?,
                micro: rd.usize()?,
                kind: task_kind_from(rd.u8()?)?,
            });
        }
        let a = StageAssign {
            stage,
            n_stages,
            device,
            next_device,
            prev_device,
            config,
            backend,
            optimizer,
            chunk,
            fwd,
            bwd,
            tasks,
            iter0: rd.u32()?,
            iters: rd.usize()?,
            n_micro: rd.usize()?,
            lr: rd.f32()?,
            momentum: rd.f32()?,
            param_seed: rd.u64()?,
            slow_factor: rd.f64()?,
            pace_s: rd.f64()?,
            heartbeat_s: rd.f64()?,
            kill_at_iter: rd.opt_usize()?.map(|k| k as u32),
            init_state: match rd.u8()? {
                0 => None,
                1 => Some(read_state(&mut rd)?),
                other => anyhow::bail!("bad init-state presence tag {other}"),
            },
            mesh_gen: rd.u64()?,
            peers: {
                let n = rd.u32()? as usize;
                let mut peers = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    peers.push((rd.usize()?, rd.str()?));
                }
                peers
            },
            overlap: match rd.u8()? {
                0 => false,
                1 => true,
                other => anyhow::bail!("bad overlap flag {other}"),
            },
            link_delay_s: rd.f64()?,
            mesh_window: rd.usize()?,
        };
        rd.finish()?;
        Ok(a)
    }
}

/// Worker -> broker: assignment accepted, lanes installed, about to
/// initialize the backend (the first heartbeat marks init complete).
pub fn encode_ready(stage: usize, out: &mut Vec<u8>) {
    put_usize(out, stage);
}

pub fn decode_ready(body: &[u8]) -> anyhow::Result<usize> {
    let mut rd = Rd::new(body);
    let s = rd.usize()?;
    rd.finish()?;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_wire_variant_roundtrips() {
        let msgs = vec![
            Wire::Data { iter: 3, micro: 1, tokens: vec![1, -2, 60] },
            Wire::Labels { iter: 3, micro: 0, targets: vec![5, 6] },
            Wire::Packet(vec![0xAA; 17]),
            Wire::Loss { iter: 9, micro: 2, loss: -0.125 },
            Wire::IterProfile {
                stage: 2,
                iter: 7,
                fwd_s: 0.25,
                bwd_s: 0.5,
                update_s: 0.0625,
                bytes: 1024.0,
                msgs: 6,
            },
            Wire::Snapshot {
                stage: 1,
                state: StageState {
                    params: vec![1.0, -2.5],
                    momentum: vec![0.5],
                    second: vec![],
                },
            },
            Wire::Heartbeat { stage: 3, iter: 11 },
            Wire::Checkpoint { iter: 4, base: None },
            Wire::Checkpoint { iter: 6, base: Some(4) },
            Wire::SnapshotDelta { stage: 2, base_iter: 4, blob: vec![0x5A; 23] },
            Wire::Stats(WorkerStats {
                stage: 1,
                device: 9,
                fwd_s: 1.0,
                bwd_s: 2.0,
                update_s: 0.5,
                wait_s: 0.25,
                bytes_sent: 4096.0,
                dense_bytes: 8192.0,
                msgs_sent: 12,
                flops: 1e9,
            }),
            Wire::Fatal { stage: 0, error: "boom: device lost".into() },
            Wire::Stop,
        ];
        for m in msgs {
            let mut body = Vec::new();
            let kind = encode_wire(&m, &mut body);
            let back = decode_wire(kind, &body).unwrap();
            assert_eq!(back, m);
        }
    }

    #[test]
    fn truncated_bodies_error_cleanly() {
        let m = Wire::IterProfile {
            stage: 2,
            iter: 7,
            fwd_s: 0.25,
            bwd_s: 0.5,
            update_s: 0.0625,
            bytes: 1024.0,
            msgs: 6,
        };
        let mut body = Vec::new();
        let kind = encode_wire(&m, &mut body);
        for cut in 0..body.len() {
            assert!(decode_wire(kind, &body[..cut]).is_err(), "cut at {cut}");
        }
        // Trailing garbage is rejected too.
        body.push(0);
        assert!(decode_wire(kind, &body).is_err());
    }

    #[test]
    fn stage_assign_roundtrips() {
        let a = StageAssign {
            stage: 1,
            n_stages: 4,
            device: 7,
            next_device: Some(2),
            prev_device: None,
            config: "tiny".into(),
            backend: BackendKind::Null,
            optimizer: "adam".into(),
            chunk: 128,
            fwd: Some(LinkSpec {
                kind: CompressKind::AdaTopK,
                ratio: 50.0,
                codec: ValueCodec::Int8,
            }),
            bwd: Some(LinkSpec {
                kind: CompressKind::TopK,
                ratio: 20.0,
                codec: ValueCodec::Int8Delta,
            }),
            tasks: vec![
                Task { stage: 1, micro: 0, kind: TaskKind::Forward },
                Task { stage: 1, micro: 0, kind: TaskKind::Backward },
                Task { stage: 1, micro: 0, kind: TaskKind::Update },
            ],
            iter0: 5,
            iters: 3,
            n_micro: 2,
            lr: 0.05,
            momentum: 0.9,
            param_seed: 0xDEAD_BEEF,
            slow_factor: 1.0,
            pace_s: 0.0,
            heartbeat_s: 0.25,
            kill_at_iter: Some(6),
            init_state: Some(StageState {
                params: vec![0.5; 3],
                momentum: vec![],
                second: vec![1.0],
            }),
            mesh_gen: 9,
            peers: vec![(0, "10.0.0.1:4501".into()), (1, "10.0.0.2:4501".into())],
            overlap: false,
            link_delay_s: 0.015,
            mesh_window: 16,
        };
        let mut body = Vec::new();
        a.encode(&mut body);
        assert_eq!(StageAssign::decode(&body).unwrap(), a);
        for cut in 0..body.len() {
            assert!(StageAssign::decode(&body[..cut]).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn hello_and_ready_roundtrip() {
        for h in [
            Hello {
                token: "secret".into(),
                device: Some(4),
                peer_listen: Some("127.0.0.1:4501".into()),
            },
            Hello { token: String::new(), device: None, peer_listen: None },
        ] {
            let mut b = Vec::new();
            h.encode(&mut b);
            assert_eq!(Hello::decode(&b).unwrap(), h);
        }
        let mut b = Vec::new();
        encode_ready(3, &mut b);
        assert_eq!(decode_ready(&b).unwrap(), 3);
        assert!(decode_ready(&b[..4]).is_err());
    }

    #[test]
    fn peer_hello_roundtrips_and_rejects_cuts() {
        let mut b = Vec::new();
        encode_peer_hello("mesh-token", 2, 17, &mut b);
        assert_eq!(decode_peer_hello(&b).unwrap(), ("mesh-token".to_string(), 2, 17));
        for cut in 0..b.len() {
            assert!(decode_peer_hello(&b[..cut]).is_err(), "cut at {cut}");
        }
        b.push(0);
        assert!(decode_peer_hello(&b).is_err());
    }

    #[test]
    fn credit_is_not_a_wire_message() {
        assert!(decode_wire(FrameKind::Credit, &4u32.to_le_bytes()).is_err());
    }
}
