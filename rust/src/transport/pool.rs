//! Pooled packet buffers (ROADMAP §Perf): drained `Packet` byte `Vec`s
//! return to the sending `LinkEncoder` through a shared free-list, so the
//! steady-state send path performs zero allocations — the packet buffer
//! cycles encoder → channel/socket → decode → back to the encoder.
//!
//! The pool is deliberately tiny: a mutex around a shelf of `Vec`s. The
//! hot path takes the lock twice per message, which is orders of
//! magnitude cheaper than the allocator round trip for multi-megabyte
//! activation packets. A capacity cap keeps a burst (e.g. a deep 1F1B
//! warmup) from pinning unbounded memory.

use std::sync::{Arc, Mutex};

/// Buffers retained per pool; beyond this, `give` lets the Vec drop.
const POOL_CAP: usize = 32;

/// A shared free-list of byte buffers. Clones share the same shelf.
#[derive(Clone, Default)]
pub struct PacketPool {
    shelf: Arc<Mutex<Vec<Vec<u8>>>>,
}

impl PacketPool {
    pub fn new() -> PacketPool {
        PacketPool::default()
    }

    /// Pop a cleared buffer (empty `Vec` if the shelf is dry).
    pub fn take(&self) -> Vec<u8> {
        let mut b = self
            .shelf
            .lock()
            .map(|mut g| g.pop().unwrap_or_default())
            .unwrap_or_default();
        b.clear();
        b
    }

    /// Return a drained buffer for reuse (dropped when the shelf is full).
    pub fn give(&self, mut b: Vec<u8>) {
        if b.capacity() == 0 {
            return;
        }
        b.clear();
        if let Ok(mut g) = self.shelf.lock() {
            if g.len() < POOL_CAP {
                g.push(b);
            }
        }
    }

    /// Buffers currently shelved (tests).
    pub fn len(&self) -> usize {
        self.shelf.lock().map(|g| g.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_cycle_without_reallocating() {
        let pool = PacketPool::new();
        let mut b = pool.take();
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        let ptr = b.as_ptr();
        pool.give(b);
        assert_eq!(pool.len(), 1);
        let b2 = pool.take();
        assert!(b2.is_empty(), "pooled buffer must come back cleared");
        assert_eq!(b2.capacity(), cap);
        assert_eq!(b2.as_ptr(), ptr, "same allocation must be reused");
    }

    #[test]
    fn clones_share_one_shelf_and_cap_holds() {
        let a = PacketPool::new();
        let b = a.clone();
        for _ in 0..POOL_CAP + 10 {
            b.give(Vec::with_capacity(8));
        }
        assert_eq!(a.len(), POOL_CAP, "cap must bound the shelf");
    }
}
