//! Mesh data plane: direct worker↔worker `Packet` lanes.
//!
//! The star topology (`tcp.rs`) relays every fwd/bwd packet through the
//! broker, so broker NIC bandwidth caps the cluster. Under
//! `--data-plane mesh` the broker stays control-only (hello / assign /
//! heartbeat / checkpoint / replan) and each adjacent stage pair holds
//! one direct TCP connection carrying the high-volume packet lanes:
//!
//! * Stage `s` **dials** stage `s+1`'s peer listener (every worker binds
//!   one at process startup and advertises it in its broker `Hello`; the
//!   broker snapshots the addresses into each generation's `StageAssign`
//!   route table). Fwd packets flow dialer→acceptor and bwd packets
//!   acceptor→dialer on the *same* socket, so per-lane FIFO order — the
//!   property the chan/tcp bitwise differential rests on — is preserved.
//! * A dialed connection opens with a `(Ctl, Hello)` frame carrying
//!   `(token, dialer stage, mesh generation)`. The acceptor drops
//!   anything with a bad token, the wrong predecessor stage, or a stale
//!   generation (a dial left in the backlog by a torn-down generation)
//!   and keeps accepting — replan/join/rejoin boundaries simply re-issue
//!   route tables with a fresh generation id.
//!
//! **Backpressure** is credit-based: each direction of a peer connection
//! has a window of `MESH_WINDOW` in-flight packets. A sender takes one
//! credit per packet and blocks at the cap; the receiver returns a
//! `(lane, Credit)` frame — the lane byte in the frame header names
//! which window — after delivering each packet into its stage queue. So
//! a slow consumer stalls its producer at a bounded number of in-flight
//! packets instead of filling unbounded socket buffers.
//!
//! **Deadlock freedom**: each connection end has an always-draining
//! reader thread and a dedicated writer thread fed by an in-process
//! queue. Senders never block on the socket (only on the credit window),
//! credit returns never block behind a half-written multi-MiB packet,
//! and queue memory is bounded by the credit windows.
//!
//! **Death**: a dying neighbor surfaces here as EOF/write failure; the
//! windows close so every subsequent send fails with `LinkClosed` and
//! the interpreter quiesces (ticking heartbeats, exactly as on a dead
//! chan lane). Death *authority* stays with the broker — the dead
//! worker's own broker connection trips EOF or the socket read deadline
//! there, which synthesizes the one `Wire::Fatal` recovery event.

use crate::transport::codec::{self, StageAssign};
use crate::transport::frame::{FrameKind, Framer, Lane};
use crate::transport::tcp::ConnWriter;
use crate::transport::{Link, LinkClosed, PacketPool};
use crate::worker::messages::Wire;
use std::io::Read;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Default per-lane in-flight packet cap on a peer connection (override
/// with `--mesh-window N`). Small enough to bound memory on both ends,
/// large enough to keep the pipe busy while credits are in flight.
/// Raised 8 → 16 with the vectored send path: cheaper per-frame sends
/// drain the window faster, and at depth 8 the overlap smokes showed the
/// sender parking on `acquire` while credits were still on the reverse
/// path (EXPERIMENTS §Mesh sweep — 16 keeps the pipe busy at the same
/// worst-case buffering, 16 × one packet per lane, on localhost and adds
/// nothing past 16).
pub const MESH_WINDOW: usize = 16;

/// Credits are returned in batches of `window / CREDIT_BATCH_DIV`
/// (minimum 1): the reader withholds at most one partial batch, so the
/// effective window never drops below `window - batch + 1 >= 1` and the
/// reverse path carries one Credit frame per batch instead of one per
/// packet. Any partial batch is flushed before the reader blocks on the
/// socket, so credits are never withheld across an idle period. Divisor
/// 4 held up in the sweep (2 halves credit traffic again but widens the
/// withheld band to window/2; 8 doubles credit frames for no measured
/// gain) — `FUSIONLLM_CREDIT_DIV` overrides it for sweep runs.
const CREDIT_BATCH_DIV: usize = 4;

fn credit_div() -> usize {
    static D: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *D.get_or_init(|| {
        std::env::var("FUSIONLLM_CREDIT_DIV")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .filter(|&d| d >= 1)
            .unwrap_or(CREDIT_BATCH_DIV)
    })
}

/// Batch size for credit returns on a window of depth `window`.
pub(crate) fn credit_batch(window: usize) -> usize {
    (window / credit_div()).max(1)
}

/// How long a dialer retries connecting to a neighbor's peer listener
/// (the listener is bound at worker startup, so this only covers slow
/// process scheduling, not a worker that is still booting).
const PEER_DIAL_TIMEOUT: Duration = Duration::from_secs(30);

/// How long an acceptor waits for its predecessor's (validated) dial
/// before giving up — the safety valve that turns a vanished neighbor
/// into a normal `Fatal` → recovery instead of a hang.
const PEER_ACCEPT_TIMEOUT: Duration = Duration::from_secs(120);

/// Per-connection read timeout while validating a freshly accepted
/// dial's hello frame (garbage connections must not stall the sweep).
const PEER_HELLO_TIMEOUT: Duration = Duration::from_secs(5);

// ---- credit window -----------------------------------------------------

/// A bounded in-flight window: `acquire` takes one credit (blocking at
/// zero), `release` returns credits as the receiver drains, `close`
/// fails all current and future acquires (peer gone).
pub struct CreditWindow {
    state: Mutex<WindowState>,
    cv: Condvar,
    cap: usize,
}

struct WindowState {
    available: usize,
    closed: bool,
}

impl CreditWindow {
    pub fn new(cap: usize) -> Arc<CreditWindow> {
        Arc::new(CreditWindow {
            state: Mutex::new(WindowState { available: cap.max(1), closed: false }),
            cv: Condvar::new(),
            cap: cap.max(1),
        })
    }

    /// Take one credit, blocking while the window is exhausted. Errors
    /// once the window is closed (the connection died).
    pub fn acquire(&self) -> Result<(), LinkClosed> {
        let mut g = self.state.lock().map_err(|_| LinkClosed)?;
        loop {
            if g.closed {
                return Err(LinkClosed);
            }
            if g.available > 0 {
                g.available -= 1;
                return Ok(());
            }
            g = self.cv.wait(g).map_err(|_| LinkClosed)?;
        }
    }

    /// Return `n` credits (clamped at the cap: a buggy or malicious peer
    /// cannot inflate the window past its bound).
    pub fn release(&self, n: usize) {
        if let Ok(mut g) = self.state.lock() {
            g.available = (g.available + n).min(self.cap);
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Fail every blocked and future `acquire` (the peer is gone).
    pub fn close(&self) {
        if let Ok(mut g) = self.state.lock() {
            g.closed = true;
            drop(g);
            self.cv.notify_all();
        }
    }

    /// Packets currently in flight (sent but not yet credited back).
    pub fn in_flight(&self) -> usize {
        self.state.lock().map(|g| self.cap - g.available).unwrap_or(0)
    }
}

// ---- outbound queue ----------------------------------------------------

/// One message for a peer connection's writer thread.
enum PeerOut {
    /// OP-Data packet body for the connection's outbound packet lane
    /// (a credit was already taken).
    Packet(Vec<u8>),
    /// Credit return for `lane` (the reader delivered a packet).
    Credit(Lane, u32),
    /// Defensive escape hatch: a non-packet `Wire` sent down a peer
    /// link (none flow today — the broker keeps the control plane).
    Control(FrameKind, Vec<u8>),
}

/// `Link` over the outbound packet lane of one peer connection.
pub struct PeerLink {
    q: Sender<PeerOut>,
    window: Arc<CreditWindow>,
}

impl Link for PeerLink {
    fn send(&self, w: Wire) -> Result<(), LinkClosed> {
        match w {
            Wire::Packet(buf) => {
                self.window.acquire()?;
                self.q.send(PeerOut::Packet(buf)).map_err(|_| LinkClosed)
            }
            other => {
                let mut body = Vec::new();
                let kind = codec::encode_wire(&other, &mut body);
                self.q.send(PeerOut::Control(kind, body)).map_err(|_| LinkClosed)
            }
        }
    }

    fn clone_link(&self) -> Box<dyn Link> {
        Box::new(PeerLink { q: self.q.clone(), window: self.window.clone() })
    }
}

// ---- connection threads ------------------------------------------------

/// Writer half: drains the outbound queue onto the socket. Packet buffers
/// recycle into `give_pool` (the sending `LinkEncoder`'s free-list) after
/// the write, exactly like the star path. Exits — closing the send window
/// so blocked senders observe `LinkClosed` — on any write failure or once
/// every queue sender is gone.
fn peer_writer(
    mut w: ConnWriter,
    rx: Receiver<PeerOut>,
    out_lane: Lane,
    window: Arc<CreditWindow>,
    give_pool: Option<PacketPool>,
) {
    for msg in rx {
        let r = match msg {
            PeerOut::Packet(buf) => {
                let r = w.write_frame(out_lane, FrameKind::Packet, &buf);
                if let Some(p) = &give_pool {
                    p.give(buf);
                }
                r
            }
            PeerOut::Credit(lane, n) => {
                w.write_frame(lane, FrameKind::Credit, &n.to_le_bytes())
            }
            PeerOut::Control(kind, body) => w.write_frame(out_lane, kind, &body),
        };
        if r.is_err() {
            break;
        }
    }
    window.close();
}

/// Reader half: incoming packets on `in_lane` land in `sink` (the same
/// per-generation stage queue the broker demux feeds) and credits go
/// back in batches of `credit_batch(window)` — any partial batch is
/// flushed before the reader blocks, so the sender's effective window
/// only ever shrinks by the in-progress batch. Incoming credits on
/// `out_lane` release the local send window. Exits on EOF, socket
/// error, or stream corruption — closing the send window, but *not*
/// tearing down `sink`: the broker session holds the other sender, and
/// death authority stays with the broker's deadline monitor.
#[allow(clippy::too_many_arguments)]
fn peer_reader(
    mut stream: TcpStream,
    mut framer: Framer,
    q: Sender<PeerOut>,
    window: Arc<CreditWindow>,
    in_lane: Lane,
    out_lane: Lane,
    sink: Sender<Wire>,
    pool: PacketPool,
    batch: u32,
) {
    let mut chunk = vec![0u8; 64 * 1024];
    let mut pending: u32 = 0;
    loop {
        // Drain buffered frames first: the accept-side framer may hold
        // bytes that arrived with the hello.
        loop {
            let f = match framer.next() {
                Ok(Some(f)) => f,
                Ok(None) => break,
                Err(_) => {
                    window.close();
                    return;
                }
            };
            match (f.lane, f.kind) {
                (lane, FrameKind::Packet) if lane == in_lane => {
                    // Zero-copy handoff; the interpreter recycles the
                    // body into `pool` after decoding.
                    let _ = sink.send(Wire::Packet(f.body));
                    pending += 1;
                    if pending >= batch {
                        if q.send(PeerOut::Credit(in_lane, pending)).is_err() {
                            window.close();
                            return;
                        }
                        pending = 0;
                    }
                }
                (lane, FrameKind::Credit) if lane == out_lane => {
                    let Ok(raw) = <[u8; 4]>::try_from(&f.body[..]) else {
                        window.close();
                        return;
                    };
                    window.release(u32::from_le_bytes(raw) as usize);
                    pool.give(f.body);
                }
                _ => {
                    // Protocol violation: drop the connection.
                    window.close();
                    return;
                }
            }
        }
        // About to block: flush the partial batch so an idle producer
        // gets its credits back promptly.
        if pending > 0 {
            if q.send(PeerOut::Credit(in_lane, pending)).is_err() {
                window.close();
                return;
            }
            pending = 0;
        }
        match stream.read(&mut chunk) {
            Ok(0) | Err(_) => {
                window.close();
                return;
            }
            Ok(n) => framer.push(&chunk[..n]),
        }
    }
}

/// One live peer connection: the outbound queue + send window the links
/// use, the thread handles, and a socket clone for teardown.
struct PeerConn {
    q: Sender<PeerOut>,
    window: Arc<CreditWindow>,
    stream: TcpStream,
    threads: Vec<JoinHandle<()>>,
}

impl PeerConn {
    #[allow(clippy::too_many_arguments)]
    fn spawn(
        stream: TcpStream,
        framer: Framer,
        out_lane: Lane,
        in_lane: Lane,
        sink: Sender<Wire>,
        rx_pool: PacketPool,
        give_pool: Option<PacketPool>,
        win: usize,
        label: &str,
    ) -> anyhow::Result<PeerConn> {
        let (q_tx, q_rx) = mpsc::channel();
        let window = CreditWindow::new(win);
        let batch = credit_batch(win.max(1)) as u32;
        let writer = ConnWriter::new(stream.try_clone()?);
        let reader_stream = stream.try_clone()?;
        let mut threads = Vec::with_capacity(2);
        {
            let window = window.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("mesh-tx-{label}"))
                    .spawn(move || peer_writer(writer, q_rx, out_lane, window, give_pool))?,
            );
        }
        {
            let window = window.clone();
            let q = q_tx.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("mesh-rx-{label}"))
                    .spawn(move || {
                        peer_reader(
                            reader_stream,
                            framer,
                            q,
                            window,
                            in_lane,
                            out_lane,
                            sink,
                            rx_pool,
                            batch,
                        )
                    })?,
            );
        }
        Ok(PeerConn { q: q_tx, window, stream, threads })
    }

    fn link(&self) -> Box<dyn Link> {
        Box::new(PeerLink { q: self.q.clone(), window: self.window.clone() })
    }
}

/// One generation's peer connections for one stage. Dropping it tears
/// the mesh down: windows close (failing any straggling send), sockets
/// shut (unblocking the readers), threads join.
pub struct MeshGen {
    /// Connection to stage `s+1` (we dialed): fwd packets out, bwd in.
    next: Option<PeerConn>,
    /// Connection from stage `s-1` (we accepted): fwd in, bwd out.
    prev: Option<PeerConn>,
}

impl MeshGen {
    /// Send half toward the successor stage (None on the last stage).
    pub fn fwd_link(&self) -> Option<Box<dyn Link>> {
        self.next.as_ref().map(|c| c.link())
    }

    /// Send half toward the predecessor stage (None on stage 0).
    pub fn bwd_link(&self) -> Option<Box<dyn Link>> {
        self.prev.as_ref().map(|c| c.link())
    }
}

impl Drop for MeshGen {
    fn drop(&mut self) {
        for conn in [self.next.take(), self.prev.take()].into_iter().flatten() {
            conn.window.close();
            let _ = conn.stream.shutdown(Shutdown::Both);
            drop(conn.q);
            for t in conn.threads {
                let _ = t.join();
            }
        }
    }
}

// ---- the per-worker peer node ------------------------------------------

/// A worker process's persistent mesh endpoint: the listener neighbors
/// dial, bound once at startup, its advertised address carried in the
/// broker `Hello`. Each generation calls `establish` with that
/// generation's `StageAssign` route table.
pub struct PeerNode {
    listener: TcpListener,
    advert: String,
    token: String,
}

impl PeerNode {
    /// Bind the peer listener (`--peer-listen`; port 0 picks an
    /// ephemeral port, and the bound address is what gets advertised —
    /// use an externally reachable host for multi-machine runs).
    pub fn bind(spec: &str, token: &str) -> anyhow::Result<PeerNode> {
        let listener = TcpListener::bind(spec)
            .map_err(|e| anyhow::anyhow!("cannot bind peer listener on {spec}: {e}"))?;
        listener.set_nonblocking(true)?;
        let advert = listener.local_addr()?.to_string();
        Ok(PeerNode { listener, advert, token: token.to_string() })
    }

    /// The address neighbors dial (sent to the broker in the Hello).
    pub fn advert(&self) -> &str {
        &self.advert
    }

    /// Build this stage's peer connections for one generation: dial the
    /// successor's listener (never blocks on the successor's accept —
    /// its listener backlog holds the connection), then accept and
    /// validate the predecessor's dial. Packets received from peers
    /// land in `fwd_sink` / `bwd_sink`, the same queues the broker
    /// demux feeds, so the interpreter sees one identical stream.
    pub fn establish(
        &self,
        a: &StageAssign,
        fwd_sink: Sender<Wire>,
        bwd_sink: Option<Sender<Wire>>,
        rx_pool: PacketPool,
        fwd_give: Option<PacketPool>,
        bwd_give: Option<PacketPool>,
    ) -> anyhow::Result<MeshGen> {
        anyhow::ensure!(!a.peers.is_empty(), "establish called without a mesh route table");
        let addr_of = |stage: usize| {
            a.peers
                .iter()
                .find(|(s, _)| *s == stage)
                .map(|(_, addr)| addr.clone())
                .ok_or_else(|| {
                    anyhow::anyhow!("mesh route table has no peer address for stage {stage}")
                })
        };
        let next = if a.stage + 1 < a.n_stages {
            let addr = addr_of(a.stage + 1)?;
            let stream = dial_peer(&addr)?;
            let mut w = ConnWriter::new(stream.try_clone()?);
            let mut body = Vec::new();
            codec::encode_peer_hello(&self.token, a.stage, a.mesh_gen, &mut body);
            w.write_frame(Lane::Ctl, FrameKind::Hello, &body)
                .map_err(|e| anyhow::anyhow!("peer hello to {addr} failed: {e}"))?;
            let sink = bwd_sink
                .clone()
                .ok_or_else(|| anyhow::anyhow!("stage below head has no bwd sink"))?;
            Some(PeerConn::spawn(
                stream,
                Framer::with_pool(rx_pool.clone()),
                Lane::Fwd,
                Lane::Bwd,
                sink,
                rx_pool.clone(),
                fwd_give,
                a.mesh_window.max(1),
                &format!("next{}", a.stage + 1),
            )?)
        } else {
            None
        };
        let prev = if a.stage > 0 {
            let (stream, framer) = self.accept_predecessor(a.stage, a.mesh_gen, &rx_pool)?;
            Some(PeerConn::spawn(
                stream,
                framer,
                Lane::Bwd,
                Lane::Fwd,
                fwd_sink,
                rx_pool,
                bwd_give,
                a.mesh_window.max(1),
                &format!("prev{}", a.stage - 1),
            )?)
        } else {
            None
        };
        Ok(MeshGen { next, prev })
    }

    /// Accept connections until one presents a valid hello for this
    /// (stage, generation). Invalid or stale dials — wrong token, wrong
    /// stage, a backlog leftover from a torn-down generation — are
    /// dropped and the sweep continues.
    fn accept_predecessor(
        &self,
        my_stage: usize,
        gen: u64,
        pool: &PacketPool,
    ) -> anyhow::Result<(TcpStream, Framer)> {
        let t0 = Instant::now();
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    match validate_peer_hello(stream, &self.token, my_stage, gen, pool) {
                        Ok(accepted) => return Ok(accepted),
                        Err(e) => {
                            eprintln!(
                                "worker: dropping peer dial at stage {my_stage}: {e:#}"
                            );
                        }
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    anyhow::ensure!(
                        t0.elapsed() < PEER_ACCEPT_TIMEOUT,
                        "no valid peer dial for stage {my_stage} within {:.0}s",
                        PEER_ACCEPT_TIMEOUT.as_secs_f64()
                    );
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(e) => anyhow::bail!("peer accept failed: {e}"),
            }
        }
    }
}

fn dial_peer(addr: &str) -> anyhow::Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                let _ = s.set_nodelay(true);
                return Ok(s);
            }
            Err(e) => {
                anyhow::ensure!(
                    t0.elapsed() < PEER_DIAL_TIMEOUT,
                    "could not dial peer {addr}: {e}"
                );
                std::thread::sleep(Duration::from_millis(50));
            }
        }
    }
}

/// Read and validate the opening hello of an accepted dial. Returns the
/// stream plus the framer (it may already hold bytes that arrived after
/// the hello — the reader thread picks them up, losing nothing).
fn validate_peer_hello(
    mut stream: TcpStream,
    token: &str,
    my_stage: usize,
    gen: u64,
    pool: &PacketPool,
) -> anyhow::Result<(TcpStream, Framer)> {
    stream.set_read_timeout(Some(PEER_HELLO_TIMEOUT))?;
    let mut framer = Framer::with_pool(pool.clone());
    let mut chunk = [0u8; 4096];
    loop {
        if let Some(f) = framer.next()? {
            anyhow::ensure!(
                f.lane == Lane::Ctl && f.kind == FrameKind::Hello,
                "peer sent {:?}/{:?} before hello",
                f.lane,
                f.kind
            );
            let (tok, stage, g) = codec::decode_peer_hello(&f.body)?;
            anyhow::ensure!(tok == token, "bad peer token");
            anyhow::ensure!(
                stage + 1 == my_stage,
                "peer claims stage {stage}, expected predecessor {}",
                my_stage - 1
            );
            anyhow::ensure!(g == gen, "stale peer generation {g} (current {gen})");
            pool.give(f.body);
            stream.set_read_timeout(None)?;
            return Ok((stream, framer));
        }
        let n = stream.read(&mut chunk)?;
        anyhow::ensure!(n > 0, "peer closed before hello");
        framer.push(&chunk[..n]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::frame::encode_frame;
    use std::io::Write;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn credit_window_blocks_at_cap_and_resumes_on_release() {
        let w = CreditWindow::new(2);
        w.acquire().unwrap();
        w.acquire().unwrap();
        assert_eq!(w.in_flight(), 2);
        // Third acquire must block until a credit returns.
        let acquired = Arc::new(AtomicBool::new(false));
        let h = {
            let w = w.clone();
            let acquired = acquired.clone();
            std::thread::spawn(move || {
                w.acquire().unwrap();
                acquired.store(true, Ordering::SeqCst);
            })
        };
        std::thread::sleep(Duration::from_millis(50));
        assert!(!acquired.load(Ordering::SeqCst), "sender ran past the in-flight cap");
        w.release(1);
        h.join().unwrap();
        assert!(acquired.load(Ordering::SeqCst));
        assert_eq!(w.in_flight(), 2);
    }

    #[test]
    fn credit_window_close_fails_blocked_and_future_acquires() {
        let w = CreditWindow::new(1);
        w.acquire().unwrap();
        let h = {
            let w = w.clone();
            std::thread::spawn(move || w.acquire())
        };
        std::thread::sleep(Duration::from_millis(20));
        w.close();
        assert_eq!(h.join().unwrap(), Err(LinkClosed));
        assert_eq!(w.acquire(), Err(LinkClosed));
    }

    #[test]
    fn credit_batch_floors_at_one_and_scales_with_window() {
        assert_eq!(credit_batch(1), 1);
        assert_eq!(credit_batch(3), 1);
        assert_eq!(credit_batch(8), 2);
        assert_eq!(credit_batch(32), 8);
    }

    #[test]
    fn credit_release_clamps_at_cap() {
        let w = CreditWindow::new(3);
        w.release(100);
        assert_eq!(w.in_flight(), 0);
        w.acquire().unwrap();
        w.release(100);
        assert_eq!(w.in_flight(), 0);
    }

    /// End-to-end over a loopback socket pair: packets flow dialer →
    /// acceptor, credits flow back, and the window returns to empty.
    #[test]
    fn peer_conn_roundtrip_returns_credits() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dialer = TcpStream::connect(addr).unwrap();
        let (acceptor, _) = listener.accept().unwrap();

        let (fwd_tx, fwd_rx) = mpsc::channel::<Wire>();
        let (bwd_tx, _bwd_rx) = mpsc::channel::<Wire>();
        // Dialer end: fwd out / bwd in. Acceptor end: bwd out / fwd in.
        let d = PeerConn::spawn(
            dialer,
            Framer::new(),
            Lane::Fwd,
            Lane::Bwd,
            bwd_tx,
            PacketPool::new(),
            None,
            MESH_WINDOW,
            "t-dial",
        )
        .unwrap();
        let a = PeerConn::spawn(
            acceptor,
            Framer::new(),
            Lane::Bwd,
            Lane::Fwd,
            fwd_tx,
            PacketPool::new(),
            None,
            MESH_WINDOW,
            "t-accept",
        )
        .unwrap();

        let link = d.link();
        for i in 0..(MESH_WINDOW * 3) {
            link.send(Wire::Packet(vec![i as u8; 100])).unwrap();
        }
        for i in 0..(MESH_WINDOW * 3) {
            match fwd_rx.recv_timeout(Duration::from_secs(5)).unwrap() {
                Wire::Packet(b) => assert_eq!(b, vec![i as u8; 100]),
                other => panic!("unexpected {other:?}"),
            }
        }
        // All credits come home once the receiver has drained.
        let t0 = Instant::now();
        while d.window.in_flight() > 0 {
            assert!(t0.elapsed() < Duration::from_secs(5), "credits never returned");
            std::thread::sleep(Duration::from_millis(5));
        }
        let gen = MeshGen { next: Some(d), prev: None };
        drop(gen);
        let gen = MeshGen { next: None, prev: Some(a) };
        drop(gen);
    }

    /// A dead neighbor closes the window: blocked senders fail with
    /// `LinkClosed` instead of hanging (the interpreter's quiesce path).
    #[test]
    fn peer_socket_death_closes_window() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let dialer = TcpStream::connect(addr).unwrap();
        let (victim, _) = listener.accept().unwrap();

        let (bwd_tx, _bwd_rx) = mpsc::channel::<Wire>();
        let d = PeerConn::spawn(
            dialer,
            Framer::new(),
            Lane::Fwd,
            Lane::Bwd,
            bwd_tx,
            PacketPool::new(),
            None,
            MESH_WINDOW,
            "t-death",
        )
        .unwrap();
        // Neighbor dies without a word.
        victim.shutdown(Shutdown::Both).unwrap();
        drop(victim);
        let link = d.link();
        // No credits ever return, so at most MESH_WINDOW sends can pass
        // before acquire blocks — and the closed window must fail it.
        let t0 = Instant::now();
        loop {
            if link.send(Wire::Packet(vec![0u8; 64])).is_err() {
                break; // LinkClosed observed
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "send never failed after peer death"
            );
        }
        drop(MeshGen { next: Some(d), prev: None });
    }

    /// A stale dial (wrong generation) is rejected; the matching one is
    /// accepted with its post-hello bytes preserved in the framer.
    #[test]
    fn stale_peer_dials_are_dropped_fresh_ones_accepted() {
        let node = PeerNode::bind("127.0.0.1:0", "tok").unwrap();
        let addr = node.advert().to_string();

        // Stale: generation 1 (current is 2).
        let mut stale = TcpStream::connect(&addr).unwrap();
        let mut body = Vec::new();
        codec::encode_peer_hello("tok", 0, 1, &mut body);
        let mut frame = Vec::new();
        encode_frame(Lane::Ctl, FrameKind::Hello, &body, &mut frame);
        stale.write_all(&frame).unwrap();

        // Fresh: generation 2, with a packet right behind the hello.
        let mut fresh = TcpStream::connect(&addr).unwrap();
        body.clear();
        codec::encode_peer_hello("tok", 0, 2, &mut body);
        encode_frame(Lane::Ctl, FrameKind::Hello, &body, &mut frame);
        fresh.write_all(&frame).unwrap();
        encode_frame(Lane::Fwd, FrameKind::Packet, &[7; 16], &mut frame);
        fresh.write_all(&frame).unwrap();

        let pool = PacketPool::new();
        let (_stream, mut framer) = node.accept_predecessor(1, 2, &pool).unwrap();
        let f = framer.next().unwrap().expect("post-hello packet survives the handoff");
        assert_eq!((f.lane, f.kind), (Lane::Fwd, FrameKind::Packet));
        assert_eq!(f.body, vec![7; 16]);
    }
}
