//! Pluggable broker↔worker transport (the socket plane the paper's
//! geo-distributed deployment implies).
//!
//! Every channel the broker/worker runtime uses is a directed lane behind
//! the `Link` (send half) / `Endpoint` (receive half) trait pair. Two
//! implementations exist:
//!
//! * `ChanTransport` (`chan`) — in-process mpsc, the default and the
//!   differential oracle. Zero behavior change from the pre-transport
//!   code: `ChanLink`/`ChanEndpoint` are transparent wrappers.
//! * `TcpTransport` (`tcp`) — length-framed binary serialization of every
//!   `Wire` variant over real sockets (`frame` + `codec`), a star
//!   topology routed through the broker, `fusionllm worker --connect`
//!   multi-process workers, and socket read-deadline liveness: a
//!   `kill -9`'d worker process is declared dead by its connection's
//!   deadline (or EOF) and recovered through the existing checkpoint /
//!   re-plan machinery.
//!
//! `Packet` payloads reuse the existing zero-copy OP-Data wire format as
//! the frame body; control messages get the compact codec in `codec`.

pub mod chan;
pub mod codec;
pub mod frame;
pub mod mesh;
pub mod pool;
pub mod tcp;

pub use pool::PacketPool;

use crate::worker::messages::Wire;
use std::time::Duration;

/// The send failed because the peer (or its process/socket) is gone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkClosed;

impl std::fmt::Display for LinkClosed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "transport link closed (peer gone)")
    }
}

impl std::error::Error for LinkClosed {}

/// Why a receive returned without a message.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// Deadline elapsed / nothing pending; the lane is still alive.
    Timeout,
    /// Every sender is gone — no more messages will ever arrive.
    Closed,
}

impl std::fmt::Display for RecvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecvError::Timeout => write!(f, "transport receive timed out"),
            RecvError::Closed => write!(f, "transport endpoint closed (all senders gone)"),
        }
    }
}

impl std::error::Error for RecvError {}

/// Sending half of one directed lane. Cheap to clone; `send` transfers
/// ownership of the message (packet buffers are recycled through
/// `PacketPool` by the receiving side or the transport itself).
pub trait Link: Send {
    fn send(&self, w: Wire) -> Result<(), LinkClosed>;
    fn clone_link(&self) -> Box<dyn Link>;
}

/// Receiving half of one directed lane.
pub trait Endpoint: Send {
    /// Block until a message arrives or the lane closes.
    fn recv(&self) -> Result<Wire, RecvError>;
    /// Block at most `d`.
    fn recv_deadline(&self, d: Duration) -> Result<Wire, RecvError>;
    /// Non-blocking poll.
    fn try_recv(&self) -> Result<Wire, RecvError>;
}

/// Which transport a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process mpsc channels (single OS process; the default).
    Chan,
    /// TCP sockets: the broker listens, `fusionllm worker --connect`
    /// processes run the stages.
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        Ok(match s {
            "chan" => TransportKind::Chan,
            "tcp" => TransportKind::Tcp,
            other => anyhow::bail!("unknown transport `{other}` (chan|tcp)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Chan => "chan",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// Where high-volume `Packet` lanes travel under the tcp transport.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPlane {
    /// Star topology: every fwd/bwd packet is relayed through the broker
    /// (the pre-mesh wire behavior, and the only option under chan).
    Relay,
    /// Direct worker↔worker peer connections carry the packet lanes; the
    /// broker keeps only control (hello/assign/heartbeat/checkpoint).
    Mesh,
}

impl DataPlane {
    pub fn parse(s: &str) -> anyhow::Result<DataPlane> {
        Ok(match s {
            "relay" => DataPlane::Relay,
            "mesh" => DataPlane::Mesh,
            other => anyhow::bail!("unknown data plane `{other}` (relay|mesh)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DataPlane::Relay => "relay",
            DataPlane::Mesh => "mesh",
        }
    }
}
