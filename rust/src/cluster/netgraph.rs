//! The bidirectional communication graph `P` (§3.5): per-link alpha–beta
//! model `T_comm(M) = α + β·M` [60, 70]. α in seconds, β in seconds/byte.

/// Dense symmetric link matrix over n CompNodes.
#[derive(Debug, Clone)]
pub struct NetGraph {
    n: usize,
    /// Latency component α (seconds), row-major n×n. 0 on the diagonal.
    alpha: Vec<f64>,
    /// Inverse bandwidth β (seconds/byte), row-major n×n. 0 on the diagonal.
    beta: Vec<f64>,
    /// Nodes declared dead by the liveness monitor (churn, §fault
    /// tolerance): their links carry no community weight and the
    /// re-planner must route stages around them.
    failed: Vec<bool>,
}

impl NetGraph {
    pub fn new(n: usize) -> NetGraph {
        NetGraph { n, alpha: vec![0.0; n * n], beta: vec![0.0; n * n], failed: vec![false; n] }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Set a symmetric link: latency (s) and bandwidth (bits/sec).
    pub fn set_link(&mut self, i: usize, j: usize, alpha_s: f64, bw_bps: f64) {
        assert!(i != j, "no self links");
        assert!(bw_bps > 0.0);
        let beta = 8.0 / bw_bps; // seconds per BYTE
        self.alpha[i * self.n + j] = alpha_s;
        self.alpha[j * self.n + i] = alpha_s;
        self.beta[i * self.n + j] = beta;
        self.beta[j * self.n + i] = beta;
    }

    pub fn alpha(&self, i: usize, j: usize) -> f64 {
        self.alpha[i * self.n + j]
    }

    pub fn beta(&self, i: usize, j: usize) -> f64 {
        self.beta[i * self.n + j]
    }

    /// Link bandwidth in bits/sec (∞-free: returns f64::INFINITY for i==j).
    pub fn bandwidth_bps(&self, i: usize, j: usize) -> f64 {
        let b = self.beta(i, j);
        if b == 0.0 {
            f64::INFINITY
        } else {
            8.0 / b
        }
    }

    /// T_comm^{ij}(M) = α^{ij} + β^{ij}·M, M in bytes. Free if i == j.
    pub fn comm_time(&self, i: usize, j: usize, bytes: f64) -> f64 {
        if i == j {
            return 0.0;
        }
        self.alpha(i, j) + self.beta(i, j) * bytes
    }

    /// Mark a node dead (device churn). Links stay recorded for post-hoc
    /// accounting, but community detection and the re-planner ignore them.
    pub fn set_failed(&mut self, i: usize) {
        if i < self.n {
            self.failed[i] = true;
        }
    }

    pub fn is_failed(&self, i: usize) -> bool {
        self.failed.get(i).copied().unwrap_or(false)
    }

    /// Clear a node's failed mark (elastic membership: the device came
    /// back). Its pre-failure links are still recorded and become live
    /// again immediately.
    pub fn clear_failed(&mut self, i: usize) {
        if i < self.n {
            self.failed[i] = false;
        }
    }

    /// Append one node with no links (callers wire it up via `set_link`).
    /// Returns the new node's id.
    pub fn grow(&mut self) -> usize {
        let n = self.n;
        let m = n + 1;
        let mut alpha = vec![0.0; m * m];
        let mut beta = vec![0.0; m * m];
        for i in 0..n {
            for j in 0..n {
                alpha[i * m + j] = self.alpha[i * n + j];
                beta[i * m + j] = self.beta[i * n + j];
            }
        }
        self.alpha = alpha;
        self.beta = beta;
        self.failed.push(false);
        self.n = m;
        n
    }

    /// Nodes not declared dead.
    pub fn n_alive(&self) -> usize {
        self.failed.iter().filter(|&&f| !f).count()
    }

    /// Symmetric weight for community detection: bandwidth in Mbps.
    /// (Louvain clusters "high-bandwidth islands", §4 Observation 2.)
    pub fn louvain_weight(&self, i: usize, j: usize) -> f64 {
        // beta == 0 off the diagonal means "no link" — weight 0, not ∞.
        // Dead nodes are islands: no weight to or from them.
        if i == j || self.beta(i, j) == 0.0 || self.failed[i] || self.failed[j] {
            return 0.0;
        }
        self.bandwidth_bps(i, j) / 1e6
    }

    /// Fit α/β for a link from (message size, measured time) samples via
    /// least squares — the warm-up profiling path (§3.5).
    pub fn fit_link(
        &mut self,
        i: usize,
        j: usize,
        sizes_bytes: &[f64],
        times_s: &[f64],
    ) {
        let (a, b) = crate::util::math::linfit(sizes_bytes, times_s);
        let a = a.max(0.0);
        let b = b.max(1e-12);
        self.alpha[i * self.n + j] = a;
        self.alpha[j * self.n + i] = a;
        self.beta[i * self.n + j] = b;
        self.beta[j * self.n + i] = b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_time_is_alpha_beta() {
        let mut g = NetGraph::new(3);
        g.set_link(0, 1, 0.01, 8e6); // 8 Mbps -> 1 MB/s
        // 1 MB at 1 MB/s + 10ms latency = ~1.01 s
        let t = g.comm_time(0, 1, 1e6);
        assert!((t - 1.01).abs() < 1e-9, "t={t}");
        assert_eq!(g.comm_time(1, 1, 1e9), 0.0);
        // symmetric
        assert_eq!(g.comm_time(1, 0, 1e6), t);
    }

    #[test]
    fn bandwidth_roundtrip() {
        let mut g = NetGraph::new(2);
        g.set_link(0, 1, 0.0, 1e9);
        assert!((g.bandwidth_bps(0, 1) - 1e9).abs() < 1.0);
    }

    #[test]
    fn failed_nodes_drop_out_of_community_weights() {
        let mut g = NetGraph::new(3);
        g.set_link(0, 1, 0.01, 1e9);
        g.set_link(1, 2, 0.01, 1e9);
        assert!(g.louvain_weight(0, 1) > 0.0);
        assert_eq!(g.n_alive(), 3);
        g.set_failed(1);
        assert!(g.is_failed(1));
        assert!(!g.is_failed(0));
        assert_eq!(g.n_alive(), 2);
        assert_eq!(g.louvain_weight(0, 1), 0.0);
        assert_eq!(g.louvain_weight(1, 2), 0.0);
        // The raw α–β record survives for accounting.
        assert!(g.comm_time(0, 1, 1e6) > 0.0);
    }

    #[test]
    fn clear_failed_restores_membership_and_links() {
        let mut g = NetGraph::new(3);
        g.set_link(0, 1, 0.01, 1e9);
        g.set_failed(1);
        assert_eq!(g.n_alive(), 2);
        assert_eq!(g.louvain_weight(0, 1), 0.0);
        g.clear_failed(1);
        assert!(!g.is_failed(1));
        assert_eq!(g.n_alive(), 3);
        // Pre-failure links are live again, untouched.
        assert!(g.louvain_weight(0, 1) > 0.0);
        assert!((g.bandwidth_bps(0, 1) - 1e9).abs() < 1.0);
        // Out-of-range clear is a no-op, not a panic.
        g.clear_failed(99);
    }

    #[test]
    fn grow_appends_a_node_and_keeps_old_links() {
        let mut g = NetGraph::new(2);
        g.set_link(0, 1, 0.02, 1e8);
        let id = g.grow();
        assert_eq!(id, 2);
        assert_eq!(g.len(), 3);
        assert!(!g.is_failed(2));
        // Existing links survive the matrix reshape exactly.
        assert_eq!(g.alpha(0, 1), 0.02);
        assert!((g.bandwidth_bps(0, 1) - 1e8).abs() < 1.0);
        // The new node starts unlinked until set_link wires it.
        assert_eq!(g.beta(0, 2), 0.0);
        g.set_link(0, 2, 0.01, 1e7);
        assert!((g.bandwidth_bps(0, 2) - 1e7).abs() < 1.0);
    }

    #[test]
    fn fit_link_recovers_params() {
        let mut g = NetGraph::new(2);
        let alpha = 0.02;
        let beta = 1e-6;
        let sizes: Vec<f64> = (1..=10).map(|k| k as f64 * 1e5).collect();
        let times: Vec<f64> = sizes.iter().map(|m| alpha + beta * m).collect();
        g.fit_link(0, 1, &sizes, &times);
        assert!((g.alpha(0, 1) - alpha).abs() < 1e-9);
        assert!((g.beta(0, 1) - beta).abs() < 1e-12);
    }
}
