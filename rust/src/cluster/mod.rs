//! The decentralized computing substrate: CompNodes, the bidirectional
//! network graph `P` with alpha–beta links (§3.5), Louvain community
//! detection over bandwidth (§4 Observation 2), and the Fig. 9 testbed
//! generators (Table 5).

pub mod compnode;
pub mod louvain;
pub mod netgraph;
pub mod testbed;

pub use compnode::{CompNode, GpuModel};
pub use netgraph::NetGraph;
pub use testbed::Testbed;
