//! Fig. 9 / Table 5 testbed synthesis.
//!
//! The paper's physical testbeds: cluster A machines carry 8× RTX 4090,
//! cluster B machines carry 4× RTX 2080, links span 8 Mbps – 10 Gbps.
//! We synthesize the same topology deterministically (seeded jitter):
//!   - same machine:   ~10 Gbps, α ≈ 0.05 ms   (no NCCL, loopback/PCIe)
//!   - same cluster:   ~1 Gbps,  α ≈ 0.2 ms    (datacenter Ethernet)
//!   - cross cluster:  8–100 Mbps, α ≈ 10–50 ms (Internet / N2N relay)

use super::compnode::{CompNode, GpuModel};
use super::netgraph::NetGraph;
use crate::util::rng::Rng;

/// A synthesized testbed: nodes + link matrix.
#[derive(Debug, Clone)]
pub struct Testbed {
    pub name: String,
    pub nodes: Vec<CompNode>,
    pub net: NetGraph,
}

/// Table 5, testbed 1: A 1×8 + B 4×4 = 24 GPUs.
pub fn testbed1(seed: u64) -> Testbed {
    build("testbed1", 1, 4, seed)
}

/// Table 5, testbed 2: A 2×8 + B 8×4 = 48 GPUs.
pub fn testbed2(seed: u64) -> Testbed {
    build("testbed2", 2, 8, seed)
}

pub fn by_id(id: usize, seed: u64) -> Testbed {
    match id {
        1 => testbed1(seed),
        2 => testbed2(seed),
        other => panic!("unknown testbed {other} (expected 1 or 2)"),
    }
}

fn build(name: &str, a_machines: usize, b_machines: usize, seed: u64) -> Testbed {
    let mut rng = Rng::new(seed);
    let mut nodes = Vec::new();
    // Cluster A: 8× RTX 4090 per machine.
    for m in 0..a_machines {
        for g in 0..8 {
            nodes.push(CompNode {
                id: nodes.len(),
                name: format!("A/node{}/gpu{}", m + 1, g),
                gpu: GpuModel::Rtx4090,
                // λ drawn near the literature's 0.35–0.55 sustained/peak
                // band for consumer GPUs under mixed workloads [54].
                lambda: rng.uniform(0.40, 0.55),
                cluster: "A".into(),
                machine: m,
            });
        }
    }
    // Cluster B: 4× RTX 2080 per machine.
    for m in 0..b_machines {
        for g in 0..4 {
            nodes.push(CompNode {
                id: nodes.len(),
                name: format!("B/node{}/gpu{}", m + 3, g),
                gpu: GpuModel::Rtx2080,
                lambda: rng.uniform(0.35, 0.50),
                cluster: "B".into(),
                machine: m,
            });
        }
    }

    let n = nodes.len();
    let mut net = NetGraph::new(n);
    for i in 0..n {
        for j in (i + 1)..n {
            let (a, b) = (&nodes[i], &nodes[j]);
            let (alpha, bw) = if a.cluster == b.cluster && a.machine == b.machine {
                // Intra-machine (paper disables NCCL to emulate WAN-ish
                // conditions, but the loopback path is still ~10 Gbps).
                (5e-5 * rng.uniform(0.8, 1.2), 10e9 * rng.uniform(0.9, 1.1))
            } else if a.cluster == b.cluster {
                (2e-4 * rng.uniform(0.8, 1.5), 1e9 * rng.uniform(0.8, 1.1))
            } else {
                // Cross-cluster Internet: 8–100 Mbps, 10–50 ms RTT/2.
                (rng.uniform(0.010, 0.050), rng.uniform(8e6, 100e6))
            };
            net.set_link(i, j, alpha, bw);
        }
    }
    Testbed { name: name.into(), nodes, net }
}

impl Testbed {
    /// Ground-truth machine groups (for tests: Louvain should rediscover
    /// at least the cluster boundary without reading labels).
    pub fn machine_key(&self, i: usize) -> (String, usize) {
        (self.nodes[i].cluster.clone(), self.nodes[i].machine)
    }

    /// Declare a device dead (liveness monitor verdict). Schedulers reach
    /// survivors through `surviving()`; Louvain weights to the node are 0.
    pub fn fail_node(&mut self, dev: usize) {
        self.net.set_failed(dev);
    }

    pub fn is_failed(&self, dev: usize) -> bool {
        self.net.is_failed(dev)
    }

    /// Re-admit a previously failed device (elastic membership: a killed
    /// or partitioned machine rejoined the pool). Its pre-failure links
    /// come back as recorded; its *profile* must not — the broker resets
    /// the device's EWMA entries so it re-earns its speed reputation.
    pub fn unfail_node(&mut self, dev: usize) {
        self.net.clear_failed(dev);
    }

    /// Add a brand-new device mid-run (elastic membership: join). The
    /// broker only knows coarse reachability for a fresh volunteer, so
    /// every link to the existing pool starts in one uniform class;
    /// warm-up profiling refines α/β afterwards. Returns the new id.
    pub fn add_node(&mut self, mut node: CompNode, alpha_s: f64, bw_bps: f64) -> usize {
        let id = self.net.grow();
        node.id = id;
        self.nodes.push(node);
        for i in 0..id {
            self.net.set_link(i, id, alpha_s, bw_bps);
        }
        id
    }

    /// Device ids not declared dead.
    pub fn alive_nodes(&self) -> Vec<usize> {
        (0..self.nodes.len()).filter(|&i| !self.net.is_failed(i)).collect()
    }

    /// Compacted view of the surviving devices: a testbed containing only
    /// alive nodes (renumbered 0..) plus the new-id -> original-id map, so
    /// any scheduler can re-partition across survivors unchanged and the
    /// result can be mapped back onto original device ids.
    pub fn surviving(&self) -> (Testbed, Vec<usize>) {
        let keep = self.alive_nodes();
        let mut nodes = Vec::with_capacity(keep.len());
        for (new_id, &old) in keep.iter().enumerate() {
            let mut n = self.nodes[old].clone();
            n.id = new_id;
            nodes.push(n);
        }
        let mut net = NetGraph::new(keep.len());
        for a in 0..keep.len() {
            for b in (a + 1)..keep.len() {
                net.set_link(
                    a,
                    b,
                    self.net.alpha(keep[a], keep[b]),
                    self.net.bandwidth_bps(keep[a], keep[b]),
                );
            }
        }
        (
            Testbed { name: format!("{}-degraded", self.name), nodes, net },
            keep,
        )
    }

    /// Aggregate description used by the `testbed` CLI subcommand.
    pub fn summary(&self) -> String {
        let a = self.nodes.iter().filter(|n| n.cluster == "A").count();
        let b = self.nodes.iter().filter(|n| n.cluster == "B").count();
        format!(
            "{}: {} CompNodes (cluster A: {} × RTX 4090, cluster B: {} × RTX 2080)",
            self.name,
            self.nodes.len(),
            a,
            b
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::louvain::louvain;

    #[test]
    fn testbed_sizes_match_table5() {
        assert_eq!(testbed1(1).nodes.len(), 24);
        assert_eq!(testbed2(1).nodes.len(), 48);
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = testbed2(7);
        let t2 = testbed2(7);
        for i in 0..t1.nodes.len() {
            assert_eq!(t1.nodes[i].lambda, t2.nodes[i].lambda);
            for j in 0..t1.nodes.len() {
                assert_eq!(t1.net.alpha(i, j), t2.net.alpha(i, j));
            }
        }
    }

    #[test]
    fn link_classes_ordered() {
        let t = testbed1(3);
        // intra-machine (0,1) >> intra-cluster... find B pairs.
        let bw_mach = t.net.bandwidth_bps(0, 1); // A machine 0
        let bw_x = t.net.bandwidth_bps(0, 8); // A -> B cross-cluster
        assert!(bw_mach > 5e9);
        assert!(bw_x < 110e6);
        assert!(t.net.alpha(0, 8) >= 0.010);
    }

    #[test]
    fn louvain_rediscovers_clusters() {
        let t = testbed2(11);
        let comm = louvain(&t.net);
        // All of cluster A in one community, all of B in another (machine-
        // level sub-communities are allowed; cluster must not be split
        // across *the other* cluster).
        let a_set: std::collections::BTreeSet<usize> =
            (0..16).map(|i| comm[i]).collect();
        let b_set: std::collections::BTreeSet<usize> =
            (16..48).map(|i| comm[i]).collect();
        assert!(a_set.is_disjoint(&b_set), "A={a_set:?} B={b_set:?}");
    }

    #[test]
    fn surviving_view_compacts_and_maps_back() {
        let mut t = testbed1(2);
        t.fail_node(1);
        t.fail_node(9);
        assert!(t.is_failed(1) && !t.is_failed(2));
        assert_eq!(t.alive_nodes().len(), 22);
        let (sub, map) = t.surviving();
        assert_eq!(sub.nodes.len(), 22);
        assert_eq!(map.len(), 22);
        assert!(!map.contains(&1) && !map.contains(&9));
        for (new_id, &old) in map.iter().enumerate() {
            assert_eq!(sub.nodes[new_id].id, new_id);
            assert_eq!(sub.nodes[new_id].lambda, t.nodes[old].lambda);
            assert_eq!(sub.nodes[new_id].gpu.name(), t.nodes[old].gpu.name());
        }
        // Links survive the renumbering exactly.
        let (a, b) = (3usize, 15usize);
        let (na, nb) = (
            map.iter().position(|&o| o == a).unwrap(),
            map.iter().position(|&o| o == b).unwrap(),
        );
        assert_eq!(sub.net.alpha(na, nb), t.net.alpha(a, b));
        assert!((sub.net.bandwidth_bps(na, nb) - t.net.bandwidth_bps(a, b)).abs() < 1.0);
    }

    #[test]
    fn unfail_node_round_trips_membership() {
        let mut t = testbed1(4);
        t.fail_node(2);
        t.fail_node(5);
        assert_eq!(t.alive_nodes().len(), 22);
        t.unfail_node(2);
        assert!(!t.is_failed(2) && t.is_failed(5));
        assert_eq!(t.alive_nodes().len(), 23);
        // The rejoined node's links are exactly the pre-failure record.
        let fresh = testbed1(4);
        assert_eq!(t.net.alpha(0, 2), fresh.net.alpha(0, 2));
        assert_eq!(t.net.beta(0, 2), fresh.net.beta(0, 2));
    }

    #[test]
    fn add_node_joins_with_uniform_links() {
        let mut t = testbed1(6);
        let before = t.nodes.len();
        let id = t.add_node(
            CompNode {
                id: 0, // overwritten by add_node
                name: "B/joiner/gpu0".into(),
                gpu: GpuModel::Rtx2080,
                lambda: 0.45,
                cluster: "B".into(),
                machine: 99,
            },
            0.020,
            50e6,
        );
        assert_eq!(id, before);
        assert_eq!(t.nodes.len(), before + 1);
        assert_eq!(t.nodes[id].id, id);
        assert!(!t.is_failed(id));
        assert!(t.alive_nodes().contains(&id));
        for i in 0..id {
            assert_eq!(t.net.alpha(i, id), 0.020);
            assert!((t.net.bandwidth_bps(i, id) - 50e6).abs() < 1.0);
        }
        // Survivor compaction includes the newcomer and maps back.
        let (sub, map) = t.surviving();
        assert_eq!(sub.nodes.len(), before + 1);
        assert_eq!(map[map.len() - 1], id);
    }

    #[test]
    fn paper_bandwidth_envelope() {
        // Paper: 8 Mbps ≤ bw ≤ 10 Gbps across all testbeds.
        let t = testbed2(5);
        for i in 0..48 {
            for j in (i + 1)..48 {
                let bw = t.net.bandwidth_bps(i, j);
                assert!(bw >= 8e6 * 0.99, "bw({i},{j})={bw}");
                assert!(bw <= 11.1e9, "bw({i},{j})={bw}");
            }
        }
    }
}
