//! Louvain community detection [4] over the bandwidth graph (§4).
//!
//! OP-Fence's first step: discover "high-bandwidth islands" among
//! CompNodes without being told cluster boundaries. Standard two-phase
//! Louvain maximizing weighted modularity, iterated until no gain.

use super::netgraph::NetGraph;

/// Sparse weighted undirected graph for the aggregation phases.
#[derive(Debug, Clone)]
struct WGraph {
    n: usize,
    /// adjacency: for each node, (neighbor, weight); includes self loops.
    adj: Vec<Vec<(usize, f64)>>,
    total_weight: f64, // m = sum of all edge weights (each edge once)
}

impl WGraph {
    fn from_netgraph(g: &NetGraph) -> WGraph {
        let n = g.len();
        let mut adj = vec![Vec::new(); n];
        let mut total = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                let w = g.louvain_weight(i, j);
                if w > 0.0 {
                    adj[i].push((j, w));
                    adj[j].push((i, w));
                    total += w;
                }
            }
        }
        WGraph { n, adj, total_weight: total }
    }

    fn degree(&self, i: usize) -> f64 {
        // Weighted degree; self-loops count twice per convention.
        self.adj[i]
            .iter()
            .map(|&(j, w)| if j == i { 2.0 * w } else { w })
            .sum()
    }
}

/// Run Louvain; returns community id per node (0..k, densely renumbered).
pub fn louvain(g: &NetGraph) -> Vec<usize> {
    let mut graph = WGraph::from_netgraph(g);
    // node -> community at the current level; membership[orig] composes.
    let mut membership: Vec<usize> = (0..g.len()).collect();

    loop {
        let (comm_raw, improved) = one_level(&graph);
        // Dense labels so membership composition and aggregation agree.
        let comm = renumber(&comm_raw);
        // Compose: original node -> new community.
        for m in membership.iter_mut() {
            *m = comm[*m];
        }
        if !improved {
            break;
        }
        graph = aggregate(&graph, &comm);
        if graph.n <= 1 {
            break;
        }
    }
    renumber(&membership)
}

/// Phase 1: greedy local moves until no modularity gain.
fn one_level(g: &WGraph) -> (Vec<usize>, bool) {
    let m = g.total_weight.max(1e-12);
    let mut comm: Vec<usize> = (0..g.n).collect();
    // Sum of weighted degrees per community.
    let mut sigma_tot: Vec<f64> = (0..g.n).map(|i| g.degree(i)).collect();
    let degrees: Vec<f64> = (0..g.n).map(|i| g.degree(i)).collect();
    let mut improved_any = false;

    loop {
        let mut moved = false;
        for i in 0..g.n {
            let ci = comm[i];
            // Weights from i to each neighboring community.
            let mut to_comm: Vec<(usize, f64)> = Vec::new();
            for &(j, w) in &g.adj[i] {
                if j == i {
                    continue;
                }
                let cj = comm[j];
                match to_comm.iter_mut().find(|(c, _)| *c == cj) {
                    Some((_, acc)) => *acc += w,
                    None => to_comm.push((cj, w)),
                }
            }
            // Remove i from its community.
            sigma_tot[ci] -= degrees[i];
            let w_own = to_comm
                .iter()
                .find(|(c, _)| *c == ci)
                .map(|(_, w)| *w)
                .unwrap_or(0.0);
            // Best gain: ΔQ = k_{i,in}/m - Σ_tot·k_i/(2m²) relative terms.
            let mut best_c = ci;
            let mut best_gain = w_own - sigma_tot[ci] * degrees[i] / (2.0 * m);
            for &(c, w) in &to_comm {
                if c == ci {
                    continue;
                }
                let gain = w - sigma_tot[c] * degrees[i] / (2.0 * m);
                if gain > best_gain + 1e-12 {
                    best_gain = gain;
                    best_c = c;
                }
            }
            sigma_tot[best_c] += degrees[i];
            if best_c != ci {
                comm[i] = best_c;
                moved = true;
                improved_any = true;
            }
        }
        if !moved {
            break;
        }
    }
    (comm, improved_any)
}

/// Phase 2: collapse communities into super-nodes. `comm` must already be
/// densely renumbered (0..k).
fn aggregate(g: &WGraph, comm: &[usize]) -> WGraph {
    let ids = comm;
    let k = ids.iter().max().map(|&m| m + 1).unwrap_or(0);
    let mut acc: std::collections::BTreeMap<(usize, usize), f64> =
        std::collections::BTreeMap::new();
    for i in 0..g.n {
        for &(j, w) in &g.adj[i] {
            if j < i {
                continue; // count each undirected edge once (self loops i==j kept)
            }
            let (a, b) = (ids[i].min(ids[j]), ids[i].max(ids[j]));
            *acc.entry((a, b)).or_insert(0.0) += w;
        }
    }
    let mut adj = vec![Vec::new(); k];
    let mut total = 0.0;
    for (&(a, b), &w) in &acc {
        if a == b {
            adj[a].push((a, w));
        } else {
            adj[a].push((b, w));
            adj[b].push((a, w));
        }
        total += w;
    }
    WGraph { n: k, adj, total_weight: total }
}

/// Densely renumber community labels to 0..k in first-appearance order.
fn renumber(comm: &[usize]) -> Vec<usize> {
    let mut map: std::collections::BTreeMap<usize, usize> = Default::default();
    let mut out = Vec::with_capacity(comm.len());
    for &c in comm {
        let next = map.len();
        out.push(*map.entry(c).or_insert(next));
    }
    out
}

/// Weighted modularity Q of a partition (for tests/diagnostics).
pub fn modularity(g: &NetGraph, comm: &[usize]) -> f64 {
    let n = g.len();
    let mut m = 0.0;
    for i in 0..n {
        for j in (i + 1)..n {
            m += g.louvain_weight(i, j);
        }
    }
    if m <= 0.0 {
        return 0.0;
    }
    let deg: Vec<f64> = (0..n)
        .map(|i| (0..n).map(|j| g.louvain_weight(i, j)).sum())
        .collect();
    let mut q = 0.0;
    for i in 0..n {
        for j in 0..n {
            if comm[i] == comm[j] {
                let a = g.louvain_weight(i, j);
                q += a - deg[i] * deg[j] / (2.0 * m);
            }
        }
    }
    q / (2.0 * m)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two dense 1 Gbps islands bridged by an 8 Mbps link must split in two.
    fn two_island_graph() -> NetGraph {
        let mut g = NetGraph::new(8);
        for i in 0..4 {
            for j in (i + 1)..4 {
                g.set_link(i, j, 1e-4, 1e9);
                g.set_link(i + 4, j + 4, 1e-4, 1e9);
            }
        }
        g.set_link(0, 4, 0.02, 8e6);
        g
    }

    #[test]
    fn separates_two_islands() {
        let g = two_island_graph();
        let comm = louvain(&g);
        assert_eq!(comm.len(), 8);
        for i in 1..4 {
            assert_eq!(comm[i], comm[0], "island A node {i}");
            assert_eq!(comm[i + 4], comm[4], "island B node {i}");
        }
        assert_ne!(comm[0], comm[4]);
    }

    #[test]
    fn louvain_beats_trivial_partition() {
        let g = two_island_graph();
        let comm = louvain(&g);
        let all_one = vec![0usize; 8];
        assert!(modularity(&g, &comm) > modularity(&g, &all_one));
    }

    #[test]
    fn fully_connected_uniform_is_one_community() {
        let mut g = NetGraph::new(5);
        for i in 0..5 {
            for j in (i + 1)..5 {
                g.set_link(i, j, 1e-4, 1e9);
            }
        }
        let comm = louvain(&g);
        assert!(comm.iter().all(|&c| c == comm[0]));
    }

    #[test]
    fn singleton_graph() {
        let g = NetGraph::new(1);
        assert_eq!(louvain(&g), vec![0]);
    }

    #[test]
    fn renumber_dense() {
        assert_eq!(renumber(&[5, 5, 2, 7, 2]), vec![0, 0, 1, 2, 1]);
    }
}

