//! CompNode: one contributed GPU (§2.3). Carries the device model, peak
//! speed S*, the regression-fitted scaling factor λ (so the *actual* speed
//! is `S(p) = λ_p · S*(p)`, §3.5), and memory capacity.

/// GPU models used in the paper (Table 1 + the two testbed clusters).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuModel {
    H100,
    A100,
    Rtx4090,
    Rtx4080,
    Rtx3080,
    Rtx2080,
}

impl GpuModel {
    /// Peak tensor TFLOPS (Table 1; RTX 2080 from vendor spec).
    pub fn peak_tflops(self) -> f64 {
        match self {
            GpuModel::H100 => 756.0,
            GpuModel::A100 => 311.84,
            GpuModel::Rtx4090 => 165.16,
            GpuModel::Rtx4080 => 97.5,
            GpuModel::Rtx3080 => 59.5,
            GpuModel::Rtx2080 => 42.0,
        }
    }

    /// Device memory in bytes (Table 1).
    pub fn memory_bytes(self) -> u64 {
        let gib = match self {
            GpuModel::H100 | GpuModel::A100 => 80,
            GpuModel::Rtx4090 => 24,
            GpuModel::Rtx4080 => 16,
            GpuModel::Rtx3080 => 10,
            GpuModel::Rtx2080 => 8,
        };
        gib * (1u64 << 30)
    }

    /// Lowest Amazon price, 2023-10-10 (Table 1). RTX 2080 contemporary used
    /// price for the economics extension.
    pub fn price_usd(self) -> f64 {
        match self {
            GpuModel::H100 => 37_799.0,
            GpuModel::A100 => 6_780.0,
            GpuModel::Rtx4090 => 1_699.0,
            GpuModel::Rtx4080 => 989.0,
            GpuModel::Rtx3080 => 679.0,
            GpuModel::Rtx2080 => 420.0,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            GpuModel::H100 => "H100",
            GpuModel::A100 => "A100",
            GpuModel::Rtx4090 => "RTX 4090",
            GpuModel::Rtx4080 => "RTX 4080",
            GpuModel::Rtx3080 => "RTX 3080",
            GpuModel::Rtx2080 => "RTX 2080",
        }
    }
}

/// One compute provider in the decentralized system.
#[derive(Debug, Clone)]
pub struct CompNode {
    pub id: usize,
    /// Human label, e.g. "A/node1/gpu3".
    pub name: String,
    pub gpu: GpuModel,
    /// Regression-fitted scaling-down factor λ_p ∈ (0, 1] (§3.5, [54]);
    /// fitted by short warm-up profiling before scheduling.
    pub lambda: f64,
    /// Cluster label ("A"/"B") — ground truth for testbeds; the scheduler
    /// must NOT read this (it must discover locality via Louvain).
    pub cluster: String,
    /// Machine index within the cluster (GPUs in one box share a host).
    pub machine: usize,
}

impl CompNode {
    /// Actual sustained speed S(p) = λ_p · S*(p), in FLOP/s.
    pub fn speed_flops(&self) -> f64 {
        self.lambda * self.gpu.peak_tflops() * 1e12
    }

    pub fn memory_bytes(&self) -> u64 {
        self.gpu.memory_bytes()
    }
}

/// Table 1 reproduction: GPU-days to pre-train GPT-3 (3.14e23 FLOPs, [5])
/// and #GPUs to hold 175B fp32 parameters.
pub const GPT3_FLOPS: f64 = 3.14e23;
pub const GPT3_PARAMS: f64 = 175e9;

pub fn gpu_days_for_gpt3(gpu: GpuModel) -> f64 {
    GPT3_FLOPS / (gpu.peak_tflops() * 1e12) / 86_400.0
}

pub fn gpus_to_load_gpt3(gpu: GpuModel) -> u64 {
    // The paper counts in decimal GB (700 GB of fp32 params / N GB cards).
    let gb_needed = GPT3_PARAMS * 4.0 / 1e9;
    let card_gb = match gpu {
        GpuModel::H100 | GpuModel::A100 => 80.0,
        GpuModel::Rtx4090 => 24.0,
        GpuModel::Rtx4080 => 16.0,
        GpuModel::Rtx3080 => 10.0,
        GpuModel::Rtx2080 => 8.0,
    };
    (gb_needed / card_gb).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_gpu_days_match_paper() {
        // Paper: H100 4807 days; A100 23308 is a typo in the paper (its own
        // abstract says 13.17 years ≈ 4807 days for H100); we check H100,
        // 4080 and 3080 which are internally consistent in Table 1.
        assert!((gpu_days_for_gpt3(GpuModel::H100) - 4807.0).abs() < 5.0);
        assert!((gpu_days_for_gpt3(GpuModel::Rtx4080) - 37274.0).abs() < 60.0);
        assert!((gpu_days_for_gpt3(GpuModel::Rtx3080) - 61079.0).abs() < 100.0);
    }

    #[test]
    fn table1_gpu_counts_match_paper() {
        assert_eq!(gpus_to_load_gpt3(GpuModel::H100), 9);
        assert_eq!(gpus_to_load_gpt3(GpuModel::A100), 9);
        assert_eq!(gpus_to_load_gpt3(GpuModel::Rtx4090), 30);
        assert_eq!(gpus_to_load_gpt3(GpuModel::Rtx4080), 44);
        assert_eq!(gpus_to_load_gpt3(GpuModel::Rtx3080), 70);
    }

    #[test]
    fn lambda_scales_speed() {
        let n = CompNode {
            id: 0,
            name: "t".into(),
            gpu: GpuModel::Rtx4090,
            lambda: 0.5,
            cluster: "A".into(),
            machine: 0,
        };
        assert!((n.speed_flops() - 0.5 * 165.16e12).abs() < 1e6);
    }

    #[test]
    fn consumer_gpus_have_better_days_per_dollar() {
        // §2.3 motivation: 4090 has better GPU-days/price than H100.
        let h = gpu_days_for_gpt3(GpuModel::H100) * GpuModel::H100.price_usd();
        let c = gpu_days_for_gpt3(GpuModel::Rtx4090) * GpuModel::Rtx4090.price_usd();
        // Cost to train solo (days × price proxy): 4090 cheaper overall.
        assert!(c < h);
    }
}
