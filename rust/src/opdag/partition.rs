//! DAG partition records (§3.3, Table 3): assignment of ops to CompNodes,
//! derived sub-DAGs with their required/sent activations and gradients,
//! and the memory-constraint check of Eq. 6.

use super::{Dag, OpId, OpKind};

/// op -> CompNode assignment. Placeholders follow their (first) user.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    pub assignment: Vec<usize>, // indexed by OpId
}

/// One sub-DAG on one CompNode, with its message sets (Table 3).
#[derive(Debug, Clone)]
pub struct SubDag {
    pub node: usize,
    pub ops: Vec<OpId>,
    /// FP inputs that must arrive from other CompNodes: (src_op, dst_op).
    pub required_acti: Vec<(OpId, OpId)>,
    /// FP outputs that must be sent out: (src_op, dst_op).
    pub send_acti: Vec<(OpId, OpId)>,
    /// BP gradients that must arrive: identified by (generator, consumer)
    /// i.e. (downstream op computing the grad, op receiving it).
    pub required_grad: Vec<(OpId, OpId)>,
    /// BP gradients that must be sent out.
    pub send_grad: Vec<(OpId, OpId)>,
}

impl Partition {
    pub fn new(assignment: Vec<usize>) -> Partition {
        Partition { assignment }
    }

    pub fn node_of(&self, op: OpId) -> usize {
        self.assignment[op]
    }

    /// Number of distinct CompNodes used.
    pub fn nodes_used(&self) -> usize {
        let mut v = self.assignment.clone();
        v.sort_unstable();
        v.dedup();
        v.len()
    }

    /// Validate: complete, and placeholders co-located with a user (so no
    /// raw-data transfer happens — the privacy property of §1).
    pub fn validate(&self, dag: &Dag) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.assignment.len() == dag.len(),
            "assignment covers {} of {} ops",
            self.assignment.len(),
            dag.len()
        );
        for op in &dag.ops {
            if op.kind == OpKind::Placeholder && !op.users.is_empty() {
                let here = self.assignment[op.id];
                anyhow::ensure!(
                    op.users.iter().any(|&u| self.assignment[u] == here),
                    "placeholder `{}` not co-located with any user",
                    op.name
                );
            }
        }
        Ok(())
    }

    /// Derive per-node sub-DAGs with their Table-3 message sets.
    pub fn sub_dags(&self, dag: &Dag) -> Vec<SubDag> {
        let mut nodes: Vec<usize> = self.assignment.clone();
        nodes.sort_unstable();
        nodes.dedup();
        let mut subs: Vec<SubDag> = nodes
            .iter()
            .map(|&n| SubDag {
                node: n,
                ops: Vec::new(),
                required_acti: Vec::new(),
                send_acti: Vec::new(),
                required_grad: Vec::new(),
                send_grad: Vec::new(),
            })
            .collect();
        let idx_of = |n: usize| nodes.binary_search(&n).unwrap();

        for op in &dag.ops {
            subs[idx_of(self.assignment[op.id])].ops.push(op.id);
        }
        for op in &dag.ops {
            let src_node = self.assignment[op.id];
            for &u in &op.users {
                let dst_node = self.assignment[u];
                if src_node != dst_node {
                    // FP: activation crosses the cut.
                    subs[idx_of(src_node)].send_acti.push((op.id, u));
                    subs[idx_of(dst_node)].required_acti.push((op.id, u));
                    // BP: gradient flows back along the same edge if the
                    // producer requires grad (§3.3 "BP").
                    if op.requires_grad() {
                        subs[idx_of(dst_node)].send_grad.push((u, op.id));
                        subs[idx_of(src_node)].required_grad.push((u, op.id));
                    }
                }
            }
        }
        subs
    }

    /// Eq. 6 memory check: per node, params (×`opt_factor` for grads +
    /// optimizer state) + activation stash for `n_micro` in-flight
    /// microbatches must fit device memory.
    pub fn check_memory(
        &self,
        dag: &Dag,
        mem_bytes: &dyn Fn(usize) -> u64,
        n_micro: usize,
        opt_factor: f64,
    ) -> anyhow::Result<()> {
        let mut usage: std::collections::BTreeMap<usize, f64> = Default::default();
        for op in &dag.ops {
            let u = usage.entry(self.assignment[op.id]).or_insert(0.0);
            *u += op.param_bytes * opt_factor + op.out_bytes * n_micro as f64;
        }
        for (&node, &bytes) in &usage {
            let cap = mem_bytes(node) as f64;
            anyhow::ensure!(
                bytes <= cap,
                "node {node} needs {} > capacity {}",
                crate::util::math::fmt_bytes(bytes),
                crate::util::math::fmt_bytes(cap)
            );
        }
        Ok(())
    }

    /// Count of cut edges (communication touchpoints) — the quantity
    /// inter-layer partitioning minimizes (Opportunity 1).
    pub fn cut_edges(&self, dag: &Dag) -> usize {
        dag.ops
            .iter()
            .flat_map(|op| op.users.iter().map(move |&u| (op.id, u)))
            .filter(|&(a, b)| self.assignment[a] != self.assignment[b])
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};

    fn small_spec() -> TransformerSpec {
        TransformerSpec {
            vocab: 256,
            d_model: 64,
            n_heads: 4,
            n_layers: 4,
            seq_len: 32,
            microbatch: 2,
        }
    }

    /// Fig. 3 partition (Tables 2–3): verify required/send sets match.
    #[test]
    fn fig3_table3_message_sets() {
        use crate::opdag::OpKind;
        let mut d = Dag::default();
        let input = d.add("Input", OpKind::Placeholder, &[], 0.0, 1e3, 0.0);
        let conv = d.add("Conv", OpKind::Parametric, &[input], 1e6, 1e3, 4e3);
        let ta = d.add("TensorA", OpKind::Variable, &[], 0.0, 1e3, 1e3);
        let relu = d.add("ReLu", OpKind::NonParametric, &[ta], 1e3, 1e3, 0.0);
        let add = d.add("Add", OpKind::NonParametric, &[relu, conv], 1e3, 1e3, 0.0);
        let lin = d.add("Linear", OpKind::Parametric, &[add], 1e6, 1e2, 4e3);
        let label = d.add("Label", OpKind::Placeholder, &[], 0.0, 1e2, 0.0);
        let ce = d.add("CE", OpKind::Loss, &[label, lin], 1e2, 4.0, 0.0);
        // CompNode 1: Input, Conv; 2: TensorA, ReLu; 3: Add, Linear, Label, CE.
        let p = Partition::new(vec![1, 1, 2, 2, 3, 3, 3, 3]);
        p.validate(&d).unwrap();
        let subs = p.sub_dags(&d);
        let s1 = subs.iter().find(|s| s.node == 1).unwrap();
        let s2 = subs.iter().find(|s| s.node == 2).unwrap();
        let s3 = subs.iter().find(|s| s.node == 3).unwrap();
        // Table 3 row 1: sub-DAG 1 sends Conv, requires grad Conv-Add.
        assert_eq!(s1.send_acti, vec![(conv, add)]);
        assert_eq!(s1.required_grad, vec![(add, conv)]);
        assert!(s1.required_acti.is_empty() && s1.send_grad.is_empty());
        // Row 2: sends ReLu, requires grad ReLu-Add.
        assert_eq!(s2.send_acti, vec![(relu, add)]);
        assert_eq!(s2.required_grad, vec![(add, relu)]);
        // Row 3: requires Conv+ReLu acts, sends both grads.
        let mut req = s3.required_acti.clone();
        req.sort_unstable();
        assert_eq!(req, vec![(conv, add), (relu, add)]);
        let mut sg = s3.send_grad.clone();
        sg.sort_unstable();
        assert_eq!(sg, vec![(add, conv), (add, relu)]);
        let _ = (lin, label, ce);
    }

    #[test]
    fn chain_partition_cut_edges() {
        let d = transformer_chain(&small_spec());
        // Everything on one node: zero cuts.
        let p0 = Partition::new(vec![0; d.len()]);
        assert_eq!(p0.cut_edges(&d), 0);
        // Split at the middle block: exactly 1 cut (chain degree 1).
        let chain = d.compute_chain();
        let mid = chain[chain.len() / 2];
        let assign: Vec<usize> =
            (0..d.len()).map(|i| if i < mid { 0 } else { 1 }).collect();
        // Keep placeholders with their users.
        let mut assign = assign;
        for op in &d.ops {
            if op.kind == OpKind::Placeholder {
                assign[op.id] = assign[op.users[0]];
            }
        }
        let p = Partition::new(assign);
        p.validate(&d).unwrap();
        assert_eq!(p.cut_edges(&d), 1);
        assert_eq!(p.nodes_used(), 2);
    }

    #[test]
    fn memory_check_rejects_overload() {
        let d = transformer_chain(&small_spec());
        let p = Partition::new(vec![0; d.len()]);
        // Tiny capacity fails; huge capacity passes.
        assert!(p.check_memory(&d, &|_| 1024, 2, 4.0).is_err());
        assert!(p.check_memory(&d, &|_| 1 << 40, 2, 4.0).is_ok());
    }

    #[test]
    fn placeholder_colocation_enforced() {
        let d = transformer_chain(&small_spec());
        let chain = d.compute_chain();
        // Assign label's user (head) to node 1 but label to node 0.
        let mut assign = vec![0usize; d.len()];
        let head = *chain.last().unwrap();
        assign[head] = 1;
        let p = Partition::new(assign);
        assert!(p.validate(&d).is_err());
    }
}
