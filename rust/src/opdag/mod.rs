//! OP-DAG: the model as a directed acyclic graph of operators (§3.3).
//!
//! Each node is a layer-granularity operator (Table 2 kinds); each directed
//! edge carries activations forward and gradients backward. The BP DAG is
//! the FP DAG with edges reversed (minus placeholder legs), so — like the
//! paper — we store only the FP DAG and derive BP from it.

pub mod builders;
pub mod data;
pub mod partition;

pub use data::{CompressCfg, OpData, OpDataKind};
pub use partition::{Partition, SubDag};

/// Operator kinds (Table 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// Dataset inputs / labels: no compute, no gradients.
    Placeholder,
    /// Standalone trainable tensor.
    Variable,
    /// Layer with trainable parameters (Conv, Linear, transformer block...).
    Parametric,
    /// Stateless layer (ReLU, Add, ...).
    NonParametric,
    /// Terminal loss function.
    Loss,
}

pub type OpId = usize;

/// One operator node with its workload attributes used by the estimator.
#[derive(Debug, Clone)]
pub struct OpNode {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    /// Operators whose outputs this op consumes ("Args", Table 2).
    pub args: Vec<OpId>,
    /// Operators that consume this op's output ("OP users").
    pub users: Vec<OpId>,
    /// Forward-pass floating point operations for one microbatch.
    pub flops_fwd: f64,
    /// Bytes of this op's output activation for one microbatch (edge payload).
    pub out_bytes: f64,
    /// Bytes of trainable parameters (+grads+optimizer state live here).
    pub param_bytes: f64,
}

impl OpNode {
    /// Backward FLOPs ≈ 2× forward (standard autodiff cost model).
    pub fn flops_bwd(&self) -> f64 {
        if self.requires_grad() {
            2.0 * self.flops_fwd
        } else {
            0.0
        }
    }

    pub fn requires_grad(&self) -> bool {
        !matches!(self.kind, OpKind::Placeholder)
    }
}

/// The FP DAG G = <{o^i}, {(o^i, o^j)}>.
#[derive(Debug, Clone, Default)]
pub struct Dag {
    pub ops: Vec<OpNode>,
}

impl Dag {
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Append an op wired to its args; returns its id.
    pub fn add(
        &mut self,
        name: &str,
        kind: OpKind,
        args: &[OpId],
        flops_fwd: f64,
        out_bytes: f64,
        param_bytes: f64,
    ) -> OpId {
        let id = self.ops.len();
        for &a in args {
            assert!(a < id, "arg {a} not yet defined for `{name}`");
            self.ops[a].users.push(id);
        }
        self.ops.push(OpNode {
            id,
            name: name.to_string(),
            kind,
            args: args.to_vec(),
            users: Vec::new(),
            flops_fwd,
            out_bytes,
            param_bytes,
        });
        id
    }

    /// Topological order (ids ascend by construction, but validate anyway).
    pub fn topo_order(&self) -> Vec<OpId> {
        let mut indeg: Vec<usize> = self.ops.iter().map(|o| o.args.len()).collect();
        let mut queue: std::collections::VecDeque<OpId> = (0..self.len())
            .filter(|&i| indeg[i] == 0)
            .collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &u in &self.ops[i].users {
                indeg[u] -= 1;
                if indeg[u] == 0 {
                    queue.push_back(u);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "cycle in OP-DAG");
        order
    }

    /// Structural validation: arg/user symmetry, single loss, acyclicity.
    pub fn validate(&self) -> anyhow::Result<()> {
        for op in &self.ops {
            for &a in &op.args {
                anyhow::ensure!(
                    self.ops[a].users.contains(&op.id),
                    "user link missing {} -> {}",
                    a,
                    op.id
                );
            }
            for &u in &op.users {
                anyhow::ensure!(
                    self.ops[u].args.contains(&op.id),
                    "arg link missing {} -> {}",
                    op.id,
                    u
                );
            }
            anyhow::ensure!(
                op.flops_fwd >= 0.0 && op.out_bytes >= 0.0 && op.param_bytes >= 0.0,
                "negative workload on {}",
                op.name
            );
        }
        let losses = self.ops.iter().filter(|o| o.kind == OpKind::Loss).count();
        anyhow::ensure!(losses <= 1, "multiple loss ops");
        let _ = self.topo_order(); // panics on cycle
        Ok(())
    }

    /// Max in/out degree over compute ops — Observation 1 says this is
    /// small (≤2) for typical DNNs, which OP-Fence exploits.
    pub fn max_degree(&self) -> usize {
        self.ops
            .iter()
            .map(|o| o.args.len().max(o.users.len()))
            .max()
            .unwrap_or(0)
    }

    /// Total forward FLOPs for one microbatch.
    pub fn total_flops_fwd(&self) -> f64 {
        self.ops.iter().map(|o| o.flops_fwd).sum()
    }

    /// Total parameter bytes.
    pub fn total_param_bytes(&self) -> f64 {
        self.ops.iter().map(|o| o.param_bytes).sum()
    }

    /// The compute ops in topological order, placeholders excluded —
    /// the "chain" view used by contiguous partitioning.
    pub fn compute_chain(&self) -> Vec<OpId> {
        self.topo_order()
            .into_iter()
            .filter(|&i| !matches!(self.ops[i].kind, OpKind::Placeholder))
            .collect()
    }

    /// BP edges: reverse of FP edges, excluding edges into ops that do not
    /// require gradients (Input/Label placeholders) — §3.3 "BP".
    pub fn bp_edges(&self) -> Vec<(OpId, OpId)> {
        let mut out = Vec::new();
        for op in &self.ops {
            for &a in &op.args {
                if self.ops[a].requires_grad() {
                    out.push((op.id, a));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Fig. 3 example: Input->Conv->Add<-ReLu<-TensorA;
    /// Add->Linear->CE<-Label.
    pub fn fig3_dag() -> Dag {
        let mut d = Dag::default();
        let input = d.add("Input", OpKind::Placeholder, &[], 0.0, 1e3, 0.0);
        let conv = d.add("Conv", OpKind::Parametric, &[input], 1e6, 1e3, 4e3);
        let ta = d.add("TensorA", OpKind::Variable, &[], 0.0, 1e3, 1e3);
        let relu = d.add("ReLu", OpKind::NonParametric, &[ta], 1e3, 1e3, 0.0);
        let add = d.add("Add", OpKind::NonParametric, &[relu, conv], 1e3, 1e3, 0.0);
        let lin = d.add("Linear", OpKind::Parametric, &[add], 1e6, 1e2, 4e3);
        let label = d.add("Label", OpKind::Placeholder, &[], 0.0, 1e2, 0.0);
        let _ce = d.add("CE", OpKind::Loss, &[label, lin], 1e2, 4.0, 0.0);
        d
    }

    #[test]
    fn fig3_structure() {
        let d = fig3_dag();
        d.validate().unwrap();
        assert_eq!(d.len(), 8);
        assert_eq!(d.max_degree(), 2); // Observation 1
        let order = d.topo_order();
        let pos = |n: &str| order.iter().position(|&i| d.ops[i].name == n).unwrap();
        assert!(pos("Conv") < pos("Add"));
        assert!(pos("ReLu") < pos("Add"));
        assert!(pos("Add") < pos("Linear"));
        assert!(pos("Linear") < pos("CE"));
    }

    #[test]
    fn bp_edges_skip_placeholders() {
        let d = fig3_dag();
        let bp = d.bp_edges();
        // No gradient edges into Input or Label.
        for &(_, dst) in &bp {
            assert!(d.ops[dst].requires_grad());
        }
        // Add sends gradients to both Conv and ReLu (Table 3).
        let add = d.ops.iter().find(|o| o.name == "Add").unwrap().id;
        let conv = d.ops.iter().find(|o| o.name == "Conv").unwrap().id;
        let relu = d.ops.iter().find(|o| o.name == "ReLu").unwrap().id;
        assert!(bp.contains(&(add, conv)));
        assert!(bp.contains(&(add, relu)));
    }

    #[test]
    #[should_panic(expected = "arg 5 not yet defined")]
    fn forward_reference_panics() {
        let mut d = Dag::default();
        d.add("bad", OpKind::NonParametric, &[5], 0.0, 0.0, 0.0);
    }
}
