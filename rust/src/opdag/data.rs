//! OP-Data: the unified message structure exchanged between operators and
//! CompNodes (§3.4). Every attribute from the paper is carried; the wire
//! format is a flat little-endian encoding handled by `encode`/`decode`
//! (no serde offline, and the hot path wants zero-copy payload access
//! anyway).

use crate::opdag::OpId;

/// What the payload is (forward activation or backward gradient).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpDataKind {
    Activation,
    Gradient,
}

/// Compression metadata ("Compress_cfg", §3.4): algorithm, ratio and the
/// hyper-parameters needed to decode.
#[derive(Debug, Clone, PartialEq)]
pub enum CompressCfg {
    /// Dense f32 payload.
    None,
    /// Top-K sparsified: `values` + `indices` wire pair; `total_len` dense
    /// elements on decode. `ratio` is the user-facing compression ratio r.
    TopK { ratio: f64, total_len: u32 },
    /// Random-K baseline (same wire layout as TopK).
    RandomK { ratio: f64, total_len: u32, seed: u64 },
    /// Linear int8 quantization with per-message scale.
    Int8 { scale: f32, total_len: u32 },
}

/// One message between operators / CompNodes.
#[derive(Debug, Clone)]
pub struct OpData {
    /// Originating OP node ("Name").
    pub src_op: OpId,
    /// Consuming OP node ("OP users" — the concrete edge being served).
    pub dst_op: OpId,
    /// "Actual OP user": the arg-slot instance for gradient routing;
    /// gradients are identified by (generator, consumer) — Table 3.
    pub actual_user: OpId,
    pub kind: OpDataKind,
    /// "Is_loss": payload is the loss output.
    pub is_loss: bool,
    /// "Require_grad": whether a gradient will flow back for this edge.
    pub require_grad: bool,
    /// "Local_iter": training iteration for synchronization.
    pub local_iter: u32,
    /// "Micro_batch": microbatch index within the pipeline.
    pub micro_batch: u32,
    pub compress: CompressCfg,
    /// Payload: dense f32 values, or (values ++ indices-as-f32-bits) for
    /// sparse encodings. Interpretation is governed by `compress`.
    pub payload: Vec<f32>,
    /// Sparse indices (u32), empty for dense/int8 payloads.
    pub indices: Vec<u32>,
    /// int8 payload bytes (only for Int8).
    pub bytes_payload: Vec<u8>,
}

impl OpData {
    pub fn dense(
        src_op: OpId,
        dst_op: OpId,
        kind: OpDataKind,
        local_iter: u32,
        micro_batch: u32,
        payload: Vec<f32>,
    ) -> OpData {
        OpData {
            src_op,
            dst_op,
            actual_user: dst_op,
            kind,
            is_loss: false,
            require_grad: kind == OpDataKind::Activation,
            local_iter,
            micro_batch,
            compress: CompressCfg::None,
            payload,
            indices: Vec::new(),
            bytes_payload: Vec::new(),
        }
    }

    /// Bytes this message occupies on the wire. The paper's accounting
    /// (Fig. 6): dense = 4·d; TopK/RandomK = 4·k values + 8·k indices
    /// (indices counted at int64 width like the paper's implementation,
    /// even though we store u32 in memory).
    pub fn wire_bytes(&self) -> f64 {
        let header = 48.0; // fixed fields
        let body = match &self.compress {
            CompressCfg::None => 4.0 * self.payload.len() as f64,
            CompressCfg::TopK { .. } | CompressCfg::RandomK { .. } => {
                4.0 * self.payload.len() as f64 + 8.0 * self.indices.len() as f64
            }
            CompressCfg::Int8 { .. } => self.bytes_payload.len() as f64 + 4.0,
        };
        header + body
    }

    /// Serialize to a flat byte buffer (little endian).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64 + self.payload.len() * 4);
        let push_u32 = |out: &mut Vec<u8>, v: u32| out.extend_from_slice(&v.to_le_bytes());
        let push_u64 = |out: &mut Vec<u8>, v: u64| out.extend_from_slice(&v.to_le_bytes());
        push_u64(&mut out, self.src_op as u64);
        push_u64(&mut out, self.dst_op as u64);
        push_u64(&mut out, self.actual_user as u64);
        out.push(match self.kind {
            OpDataKind::Activation => 0,
            OpDataKind::Gradient => 1,
        });
        out.push(self.is_loss as u8);
        out.push(self.require_grad as u8);
        push_u32(&mut out, self.local_iter);
        push_u32(&mut out, self.micro_batch);
        // compress cfg
        match &self.compress {
            CompressCfg::None => {
                out.push(0);
            }
            CompressCfg::TopK { ratio, total_len } => {
                out.push(1);
                out.extend_from_slice(&ratio.to_le_bytes());
                push_u32(&mut out, *total_len);
            }
            CompressCfg::RandomK { ratio, total_len, seed } => {
                out.push(2);
                out.extend_from_slice(&ratio.to_le_bytes());
                push_u32(&mut out, *total_len);
                push_u64(&mut out, *seed);
            }
            CompressCfg::Int8 { scale, total_len } => {
                out.push(3);
                out.extend_from_slice(&scale.to_le_bytes());
                push_u32(&mut out, *total_len);
            }
        }
        push_u32(&mut out, self.payload.len() as u32);
        for v in &self.payload {
            out.extend_from_slice(&v.to_le_bytes());
        }
        push_u32(&mut out, self.indices.len() as u32);
        for v in &self.indices {
            out.extend_from_slice(&v.to_le_bytes());
        }
        push_u32(&mut out, self.bytes_payload.len() as u32);
        out.extend_from_slice(&self.bytes_payload);
        out
    }

    /// Decode a buffer produced by `encode`.
    pub fn decode(buf: &[u8]) -> anyhow::Result<OpData> {
        let mut r = Reader { b: buf, i: 0 };
        let src_op = r.u64()? as OpId;
        let dst_op = r.u64()? as OpId;
        let actual_user = r.u64()? as OpId;
        let kind = match r.u8()? {
            0 => OpDataKind::Activation,
            1 => OpDataKind::Gradient,
            k => anyhow::bail!("bad kind {k}"),
        };
        let is_loss = r.u8()? != 0;
        let require_grad = r.u8()? != 0;
        let local_iter = r.u32()?;
        let micro_batch = r.u32()?;
        let compress = match r.u8()? {
            0 => CompressCfg::None,
            1 => CompressCfg::TopK { ratio: r.f64()?, total_len: r.u32()? },
            2 => CompressCfg::RandomK {
                ratio: r.f64()?,
                total_len: r.u32()?,
                seed: r.u64()?,
            },
            3 => CompressCfg::Int8 { scale: r.f32()?, total_len: r.u32()? },
            c => anyhow::bail!("bad compress tag {c}"),
        };
        let np = r.u32()? as usize;
        let mut payload = Vec::with_capacity(np);
        for _ in 0..np {
            payload.push(r.f32()?);
        }
        let ni = r.u32()? as usize;
        let mut indices = Vec::with_capacity(ni);
        for _ in 0..ni {
            indices.push(r.u32()?);
        }
        let nb = r.u32()? as usize;
        let bytes_payload = r.bytes(nb)?.to_vec();
        anyhow::ensure!(r.i == buf.len(), "trailing bytes in OpData");
        Ok(OpData {
            src_op,
            dst_op,
            actual_user,
            kind,
            is_loss,
            require_grad,
            local_iter,
            micro_batch,
            compress,
            payload,
            indices,
            bytes_payload,
        })
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let s = self
            .b
            .get(self.i..self.i + n)
            .ok_or_else(|| anyhow::anyhow!("short OpData buffer"))?;
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_dense() {
        let d = OpData::dense(3, 4, OpDataKind::Activation, 7, 2, vec![1.0, -2.5, 0.0]);
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.src_op, 3);
        assert_eq!(back.dst_op, 4);
        assert_eq!(back.local_iter, 7);
        assert_eq!(back.micro_batch, 2);
        assert_eq!(back.payload, vec![1.0, -2.5, 0.0]);
        assert_eq!(back.compress, CompressCfg::None);
    }

    #[test]
    fn roundtrip_topk() {
        let mut d = OpData::dense(0, 1, OpDataKind::Gradient, 1, 0, vec![5.0, -7.0]);
        d.indices = vec![10, 90];
        d.compress = CompressCfg::TopK { ratio: 100.0, total_len: 100 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.indices, vec![10, 90]);
        assert_eq!(back.compress, CompressCfg::TopK { ratio: 100.0, total_len: 100 });
        assert_eq!(back.kind, OpDataKind::Gradient);
    }

    #[test]
    fn roundtrip_int8() {
        let mut d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![]);
        d.bytes_payload = vec![1, 2, 255];
        d.compress = CompressCfg::Int8 { scale: 0.5, total_len: 3 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.bytes_payload, vec![1, 2, 255]);
    }

    #[test]
    fn wire_bytes_fig6_accounting() {
        // Fig. 6: dense d floats = 32d bits; sparse k kept = 32k + 64k bits.
        let mut dense = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![0.0; 100]);
        assert_eq!(dense.wire_bytes() as u64, 48 + 400);
        dense.payload.truncate(10);
        dense.indices = vec![0; 10];
        dense.compress = CompressCfg::TopK { ratio: 10.0, total_len: 100 };
        assert_eq!(dense.wire_bytes() as u64, 48 + 40 + 80);
    }

    #[test]
    fn decode_rejects_truncated() {
        let d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![1.0; 8]);
        let enc = d.encode();
        assert!(OpData::decode(&enc[..enc.len() - 3]).is_err());
        assert!(OpData::decode(&[]).is_err());
    }
}
