//! OP-Data: the unified message structure exchanged between operators and
//! CompNodes (§3.4). Every attribute from the paper is carried; the wire
//! format is a flat little-endian encoding handled by `encode`/`decode`
//! (no serde offline, and the hot path wants zero-copy payload access
//! anyway — see `OpDataView`).

use crate::opdag::OpId;

/// Wire-accounting bytes for the fixed header fields.
pub const WIRE_HEADER_BYTES: f64 = 48.0;

/// What the payload is (forward activation or backward gradient).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpDataKind {
    Activation,
    Gradient,
}

/// Compression metadata ("Compress_cfg", §3.4): algorithm, ratio and the
/// hyper-parameters needed to decode.
#[derive(Debug, Clone, PartialEq, Default)]
pub enum CompressCfg {
    /// Dense f32 payload.
    #[default]
    None,
    /// Top-K sparsified: `values` + `indices` wire pair; `total_len` dense
    /// elements on decode. `ratio` is the user-facing compression ratio r.
    TopK { ratio: f64, total_len: u32 },
    /// Random-K baseline (same wire layout as TopK).
    RandomK { ratio: f64, total_len: u32, seed: u64 },
    /// Linear int8 quantization with per-message scale.
    Int8 { scale: f32, total_len: u32 },
    /// Combined sparse + int8: Top-K/Random-K support in `indices`, values
    /// as int8 codes in `bytes_payload`, one per-message scale. ~5 B per
    /// kept element (4 B index + 1 B code) vs 8 B for f32-sparse.
    QSparse { ratio: f64, total_len: u32, scale: f32 },
    /// Row-chunked variant of `QSparse` (pairs with `ChunkedTopK`): the
    /// f32 payload region carries `ceil(total_len / chunk)` per-row scales;
    /// the entry at dense index i decodes as `code · scale[i / chunk]`.
    QSparseRows { ratio: f64, total_len: u32, chunk: u32 },
    /// `QSparseRows` with delta-coded u24 indices: the wire index region
    /// packs 3 bytes per entry — the first entry is the absolute index,
    /// every later one the (positive) delta to its predecessor. Valid only
    /// for strictly ascending support with `total_len < 2^24`; the encoder
    /// falls back to `QSparseRows` otherwise. 4 B/kept value vs 5.
    QSparseRowsDelta { ratio: f64, total_len: u32, chunk: u32 },
}

/// Header fields of one OP-Data message (everything but the payload).
/// Lets the wire codecs encode straight from borrowed payload slices and
/// decode without materializing the payload.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpDataHeader {
    /// Originating OP node ("Name").
    pub src_op: OpId,
    /// Consuming OP node ("OP users" — the concrete edge being served).
    pub dst_op: OpId,
    /// "Actual OP user": the arg-slot instance for gradient routing;
    /// gradients are identified by (generator, consumer) — Table 3.
    pub actual_user: OpId,
    pub kind: OpDataKind,
    /// "Is_loss": payload is the loss output.
    pub is_loss: bool,
    /// "Require_grad": whether a gradient will flow back for this edge.
    pub require_grad: bool,
    /// "Local_iter": training iteration for synchronization.
    pub local_iter: u32,
    /// "Micro_batch": microbatch index within the pipeline.
    pub micro_batch: u32,
}

/// One message between operators / CompNodes.
#[derive(Debug, Clone)]
pub struct OpData {
    pub src_op: OpId,
    pub dst_op: OpId,
    pub actual_user: OpId,
    pub kind: OpDataKind,
    pub is_loss: bool,
    pub require_grad: bool,
    pub local_iter: u32,
    pub micro_batch: u32,
    pub compress: CompressCfg,
    /// Payload: dense f32 values, or (values ++ indices-as-f32-bits) for
    /// sparse encodings. Interpretation is governed by `compress`.
    pub payload: Vec<f32>,
    /// Sparse indices (u32), empty for dense/int8 payloads.
    pub indices: Vec<u32>,
    /// int8 payload bytes (only for Int8).
    pub bytes_payload: Vec<u8>,
}

impl OpData {
    pub fn dense(
        src_op: OpId,
        dst_op: OpId,
        kind: OpDataKind,
        local_iter: u32,
        micro_batch: u32,
        payload: Vec<f32>,
    ) -> OpData {
        OpData {
            src_op,
            dst_op,
            actual_user: dst_op,
            kind,
            is_loss: false,
            require_grad: kind == OpDataKind::Activation,
            local_iter,
            micro_batch,
            compress: CompressCfg::None,
            payload,
            indices: Vec::new(),
            bytes_payload: Vec::new(),
        }
    }

    fn header(&self) -> OpDataHeader {
        OpDataHeader {
            src_op: self.src_op,
            dst_op: self.dst_op,
            actual_user: self.actual_user,
            kind: self.kind,
            is_loss: self.is_loss,
            require_grad: self.require_grad,
            local_iter: self.local_iter,
            micro_batch: self.micro_batch,
        }
    }

    /// Bytes this message occupies on the wire. The paper's accounting
    /// (Fig. 6): dense = 4·d; TopK/RandomK = 4·k values + 8·k indices
    /// (indices counted at int64 width like the paper's implementation,
    /// even though we store u32 in memory). The int8-sparse encodings are
    /// counted at their actual packed layout: 1·k codes + 4·k indices +
    /// the scale(s).
    pub fn wire_bytes(&self) -> f64 {
        let body = match &self.compress {
            CompressCfg::None => 4.0 * self.payload.len() as f64,
            CompressCfg::TopK { .. } | CompressCfg::RandomK { .. } => {
                4.0 * self.payload.len() as f64 + 8.0 * self.indices.len() as f64
            }
            CompressCfg::Int8 { .. } => self.bytes_payload.len() as f64 + 4.0,
            CompressCfg::QSparse { .. } => {
                self.bytes_payload.len() as f64 + 4.0 * self.indices.len() as f64 + 4.0
            }
            CompressCfg::QSparseRows { .. } => {
                self.bytes_payload.len() as f64
                    + 4.0 * self.indices.len() as f64
                    + 4.0 * self.payload.len() as f64
            }
            CompressCfg::QSparseRowsDelta { .. } => {
                self.bytes_payload.len() as f64
                    + 3.0 * self.indices.len() as f64
                    + 4.0 * self.payload.len() as f64
            }
        };
        WIRE_HEADER_BYTES + body
    }

    /// Serialize to a fresh flat byte buffer (little endian).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Serialize into `out` (cleared first), reusing its capacity — the
    /// steady-state wire path.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        encode_parts_into(
            &self.header(),
            &self.compress,
            &self.payload,
            &self.indices,
            &self.bytes_payload,
            out,
        );
    }

    /// Decode a buffer produced by `encode`.
    pub fn decode(buf: &[u8]) -> anyhow::Result<OpData> {
        Ok(OpDataView::parse(buf)?.to_opdata())
    }
}

/// Encode one message from borrowed parts, appending to `out` (callers
/// that reuse `out` clear it first). Payload/index slices are written with
/// bulk little-endian copies — no per-element byte loop.
pub fn encode_parts_into(
    hdr: &OpDataHeader,
    compress: &CompressCfg,
    payload: &[f32],
    indices: &[u32],
    bytes_payload: &[u8],
    out: &mut Vec<u8>,
) {
    out.reserve(64 + payload.len() * 4 + indices.len() * 4 + bytes_payload.len());
    out.extend_from_slice(&(hdr.src_op as u64).to_le_bytes());
    out.extend_from_slice(&(hdr.dst_op as u64).to_le_bytes());
    out.extend_from_slice(&(hdr.actual_user as u64).to_le_bytes());
    out.push(match hdr.kind {
        OpDataKind::Activation => 0,
        OpDataKind::Gradient => 1,
    });
    out.push(hdr.is_loss as u8);
    out.push(hdr.require_grad as u8);
    out.extend_from_slice(&hdr.local_iter.to_le_bytes());
    out.extend_from_slice(&hdr.micro_batch.to_le_bytes());
    match compress {
        CompressCfg::None => {
            out.push(0);
        }
        CompressCfg::TopK { ratio, total_len } => {
            out.push(1);
            out.extend_from_slice(&ratio.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
        }
        CompressCfg::RandomK { ratio, total_len, seed } => {
            out.push(2);
            out.extend_from_slice(&ratio.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
            out.extend_from_slice(&seed.to_le_bytes());
        }
        CompressCfg::Int8 { scale, total_len } => {
            out.push(3);
            out.extend_from_slice(&scale.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
        }
        CompressCfg::QSparse { ratio, total_len, scale } => {
            out.push(4);
            out.extend_from_slice(&ratio.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
            out.extend_from_slice(&scale.to_le_bytes());
        }
        CompressCfg::QSparseRows { ratio, total_len, chunk } => {
            out.push(5);
            out.extend_from_slice(&ratio.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
            out.extend_from_slice(&chunk.to_le_bytes());
        }
        CompressCfg::QSparseRowsDelta { ratio, total_len, chunk } => {
            out.push(6);
            out.extend_from_slice(&ratio.to_le_bytes());
            out.extend_from_slice(&total_len.to_le_bytes());
            out.extend_from_slice(&chunk.to_le_bytes());
        }
    }
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    extend_f32_le(out, payload);
    out.extend_from_slice(&(indices.len() as u32).to_le_bytes());
    if matches!(compress, CompressCfg::QSparseRowsDelta { .. }) {
        extend_u24_delta(out, indices);
    } else {
        extend_u32_le(out, indices);
    }
    out.extend_from_slice(&(bytes_payload.len() as u32).to_le_bytes());
    out.extend_from_slice(bytes_payload);
}

/// Bulk little-endian f32 append — the dispatched `util::simd` kernel
/// (one memcpy on LE targets, a 4-byte-chunk loop under forced scalar or
/// big-endian).
fn extend_f32_le(out: &mut Vec<u8>, xs: &[f32]) {
    crate::util::simd::extend_f32_le(out, xs);
}

/// Delta-coded u24 index append (`QSparseRowsDelta`): 3 LE bytes per
/// entry — the first is the absolute index, each later one the delta to
/// its predecessor. The caller (the link encoder) guarantees strictly
/// ascending indices below 2^24; values are truncated to 24 bits, so a
/// violated contract degrades to a decode-side mismatch, never UB.
fn extend_u24_delta(out: &mut Vec<u8>, xs: &[u32]) {
    let start = out.len();
    out.resize(start + xs.len() * 3, 0);
    let mut prev = 0u32;
    for (c, &i) in out[start..].chunks_exact_mut(3).zip(xs) {
        let d = i.wrapping_sub(prev);
        c.copy_from_slice(&d.to_le_bytes()[..3]);
        prev = i;
    }
}

/// Bulk little-endian u32 append (see `extend_f32_le`).
fn extend_u32_le(out: &mut Vec<u8>, xs: &[u32]) {
    crate::util::simd::extend_u32_le(out, xs);
}

/// Zero-copy view of an encoded OP-Data buffer: the header is parsed, the
/// payload/index/byte regions stay as borrowed little-endian slices of the
/// input. The hot decode path scatters straight from the view without
/// materializing `Vec`s; `to_opdata` is the compat path.
#[derive(Debug, Clone)]
pub struct OpDataView<'a> {
    pub header: OpDataHeader,
    pub compress: CompressCfg,
    payload: &'a [u8],
    indices: &'a [u8],
    bytes_payload: &'a [u8],
}

impl<'a> OpDataView<'a> {
    /// Parse the header and locate the payload regions. Errors on short or
    /// trailing bytes exactly like `OpData::decode`.
    pub fn parse(buf: &'a [u8]) -> anyhow::Result<OpDataView<'a>> {
        let mut r = Reader { b: buf, i: 0 };
        let src_op = r.u64()? as OpId;
        let dst_op = r.u64()? as OpId;
        let actual_user = r.u64()? as OpId;
        let kind = match r.u8()? {
            0 => OpDataKind::Activation,
            1 => OpDataKind::Gradient,
            k => anyhow::bail!("bad kind {k}"),
        };
        let is_loss = r.u8()? != 0;
        let require_grad = r.u8()? != 0;
        let local_iter = r.u32()?;
        let micro_batch = r.u32()?;
        let compress = match r.u8()? {
            0 => CompressCfg::None,
            1 => CompressCfg::TopK { ratio: r.f64()?, total_len: r.u32()? },
            2 => CompressCfg::RandomK {
                ratio: r.f64()?,
                total_len: r.u32()?,
                seed: r.u64()?,
            },
            3 => CompressCfg::Int8 { scale: r.f32()?, total_len: r.u32()? },
            4 => CompressCfg::QSparse {
                ratio: r.f64()?,
                total_len: r.u32()?,
                scale: r.f32()?,
            },
            5 => CompressCfg::QSparseRows {
                ratio: r.f64()?,
                total_len: r.u32()?,
                chunk: r.u32()?,
            },
            6 => CompressCfg::QSparseRowsDelta {
                ratio: r.f64()?,
                total_len: r.u32()?,
                chunk: r.u32()?,
            },
            c => anyhow::bail!("bad compress tag {c}"),
        };
        let np = r.u32()? as usize;
        let payload = r.bytes(
            np.checked_mul(4).ok_or_else(|| anyhow::anyhow!("short OpData buffer"))?,
        )?;
        // Delta-coded indices travel packed at 3 B each; everything else
        // is 4 B little-endian u32s.
        let idx_width =
            if matches!(compress, CompressCfg::QSparseRowsDelta { .. }) { 3 } else { 4 };
        let ni = r.u32()? as usize;
        let indices = r.bytes(
            ni.checked_mul(idx_width)
                .ok_or_else(|| anyhow::anyhow!("short OpData buffer"))?,
        )?;
        let nb = r.u32()? as usize;
        let bytes_payload = r.bytes(nb)?;
        anyhow::ensure!(r.i == buf.len(), "trailing bytes in OpData");
        Ok(OpDataView {
            header: OpDataHeader {
                src_op,
                dst_op,
                actual_user,
                kind,
                is_loss,
                require_grad,
                local_iter,
                micro_batch,
            },
            compress,
            payload,
            indices,
            bytes_payload,
        })
    }

    pub fn payload_len(&self) -> usize {
        self.payload.len() / 4
    }

    pub fn indices_len(&self) -> usize {
        self.indices.len() / self.index_width()
    }

    /// Wire bytes per index entry (3 for delta-coded u24, else 4).
    fn index_width(&self) -> usize {
        if matches!(self.compress, CompressCfg::QSparseRowsDelta { .. }) {
            3
        } else {
            4
        }
    }

    /// Borrowed little-endian payload bytes (alignment-free).
    pub fn payload_le_bytes(&self) -> &'a [u8] {
        self.payload
    }

    /// Borrowed little-endian index bytes (alignment-free).
    pub fn indices_le_bytes(&self) -> &'a [u8] {
        self.indices
    }

    /// Borrowed int8 payload bytes.
    pub fn bytes_payload(&self) -> &'a [u8] {
        self.bytes_payload
    }

    /// Iterate payload values without materializing a `Vec` (the 4-byte
    /// chunked reads compile to unaligned loads — no copy, no alignment
    /// requirement on the buffer).
    pub fn payload_iter(&self) -> impl Iterator<Item = f32> + 'a {
        self.payload.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap()))
    }

    /// Iterate sparse indices without materializing a `Vec`. Delta-coded
    /// u24 regions are unpacked back to absolute u32 indices on the fly,
    /// so every consumer sees the same absolute-index stream regardless
    /// of the wire packing.
    pub fn indices_iter(&self) -> impl Iterator<Item = u32> + 'a {
        let delta = self.index_width() == 3;
        let mut acc = 0u32;
        self.indices.chunks_exact(self.index_width()).map(move |c| {
            if delta {
                let d = u32::from_le_bytes([c[0], c[1], c[2], 0]);
                acc = acc.wrapping_add(d);
                acc
            } else {
                u32::from_le_bytes(c.try_into().unwrap())
            }
        })
    }

    /// Materialize an owned `OpData` (the compat/decode path).
    pub fn to_opdata(&self) -> OpData {
        OpData {
            src_op: self.header.src_op,
            dst_op: self.header.dst_op,
            actual_user: self.header.actual_user,
            kind: self.header.kind,
            is_loss: self.header.is_loss,
            require_grad: self.header.require_grad,
            local_iter: self.header.local_iter,
            micro_batch: self.header.micro_batch,
            compress: self.compress.clone(),
            payload: self.payload_iter().collect(),
            indices: self.indices_iter().collect(),
            bytes_payload: self.bytes_payload.to_vec(),
        }
    }
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn bytes(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        let s = self
            .b
            .get(self.i..self.i + n)
            .ok_or_else(|| anyhow::anyhow!("short OpData buffer"))?;
        self.i += n;
        Ok(s)
    }
    fn u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.bytes(1)?[0])
    }
    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
    fn f32(&mut self) -> anyhow::Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_dense() {
        let d = OpData::dense(3, 4, OpDataKind::Activation, 7, 2, vec![1.0, -2.5, 0.0]);
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.src_op, 3);
        assert_eq!(back.dst_op, 4);
        assert_eq!(back.local_iter, 7);
        assert_eq!(back.micro_batch, 2);
        assert_eq!(back.payload, vec![1.0, -2.5, 0.0]);
        assert_eq!(back.compress, CompressCfg::None);
    }

    #[test]
    fn roundtrip_topk() {
        let mut d = OpData::dense(0, 1, OpDataKind::Gradient, 1, 0, vec![5.0, -7.0]);
        d.indices = vec![10, 90];
        d.compress = CompressCfg::TopK { ratio: 100.0, total_len: 100 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.indices, vec![10, 90]);
        assert_eq!(back.compress, CompressCfg::TopK { ratio: 100.0, total_len: 100 });
        assert_eq!(back.kind, OpDataKind::Gradient);
    }

    #[test]
    fn roundtrip_int8() {
        let mut d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![]);
        d.bytes_payload = vec![1, 2, 255];
        d.compress = CompressCfg::Int8 { scale: 0.5, total_len: 3 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.bytes_payload, vec![1, 2, 255]);
    }

    #[test]
    fn roundtrip_qsparse_variants() {
        let mut d = OpData::dense(2, 3, OpDataKind::Gradient, 4, 1, vec![]);
        d.indices = vec![5, 1700, 3200];
        d.bytes_payload = vec![127, 129, 0]; // i8 codes as raw bytes
        d.compress = CompressCfg::QSparse { ratio: 100.0, total_len: 4800, scale: 0.125 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.compress, d.compress);
        assert_eq!(back.indices, d.indices);
        assert_eq!(back.bytes_payload, d.bytes_payload);

        // Rows variant: per-row scales travel in the f32 payload region.
        d.payload = vec![0.5, 0.25, 2.0];
        d.compress = CompressCfg::QSparseRows { ratio: 100.0, total_len: 4800, chunk: 1600 };
        let back = OpData::decode(&d.encode()).unwrap();
        assert_eq!(back.compress, d.compress);
        assert_eq!(back.payload, vec![0.5, 0.25, 2.0]);
        let v = OpDataView::parse(&d.encode()).unwrap();
        assert_eq!(v.compress, d.compress);
        assert_eq!(v.payload_iter().collect::<Vec<_>>(), d.payload);
    }

    #[test]
    fn roundtrip_qsparse_rows_delta_unpacks_absolute_indices() {
        let mut d = OpData::dense(2, 3, OpDataKind::Gradient, 4, 1, vec![]);
        d.indices = vec![5, 6, 1700, 3200, 3201];
        d.bytes_payload = vec![127, 129, 0, 7, 255];
        d.payload = vec![0.5, 0.25, 2.0];
        d.compress =
            CompressCfg::QSparseRowsDelta { ratio: 100.0, total_len: 4800, chunk: 1600 };
        let enc = d.encode();
        let back = OpData::decode(&enc).unwrap();
        assert_eq!(back.compress, d.compress);
        assert_eq!(back.indices, d.indices, "absolute indices survive delta packing");
        assert_eq!(back.bytes_payload, d.bytes_payload);
        assert_eq!(back.payload, d.payload);
        let v = OpDataView::parse(&enc).unwrap();
        assert_eq!(v.indices_len(), 5);
        assert_eq!(v.indices_iter().collect::<Vec<_>>(), d.indices);
        // 3 wire bytes per index: the delta encoding is 1 B/index smaller
        // than the identical payload under plain QSparseRows.
        let mut plain = d.clone();
        plain.compress =
            CompressCfg::QSparseRows { ratio: 100.0, total_len: 4800, chunk: 1600 };
        assert_eq!(enc.len() + d.indices.len(), plain.encode().len());
        // Truncations still error cleanly.
        assert!(OpData::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn qsparse_rows_delta_accounting_is_four_bytes_per_value() {
        let mut d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![]);
        d.indices = (0..100u32).map(|i| i * 7).collect();
        d.bytes_payload = vec![0; 100];
        d.payload = vec![1.0; 10];
        d.compress = CompressCfg::QSparseRowsDelta { ratio: 10.0, total_len: 1000, chunk: 100 };
        // 100 values at 3 B index + 1 B code, + 10 row scales + header.
        assert_eq!(d.wire_bytes() as u64, 48 + 400 + 40);
    }

    #[test]
    fn qsparse_wire_accounting_is_five_bytes_per_value() {
        let mut d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![]);
        d.indices = vec![0; 100];
        d.bytes_payload = vec![0; 100];
        d.compress = CompressCfg::QSparse { ratio: 10.0, total_len: 1000, scale: 1.0 };
        // 100 values at 4 B index + 1 B code, + 4 B scale + header.
        assert_eq!(d.wire_bytes() as u64, 48 + 500 + 4);
        // Rows variant: scale overhead is 4 B per row instead.
        d.payload = vec![1.0; 10];
        d.compress = CompressCfg::QSparseRows { ratio: 10.0, total_len: 1000, chunk: 100 };
        assert_eq!(d.wire_bytes() as u64, 48 + 500 + 40);
    }

    #[test]
    fn wire_bytes_fig6_accounting() {
        // Fig. 6: dense d floats = 32d bits; sparse k kept = 32k + 64k bits.
        let mut dense = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![0.0; 100]);
        assert_eq!(dense.wire_bytes() as u64, 48 + 400);
        dense.payload.truncate(10);
        dense.indices = vec![0; 10];
        dense.compress = CompressCfg::TopK { ratio: 10.0, total_len: 100 };
        assert_eq!(dense.wire_bytes() as u64, 48 + 40 + 80);
    }

    #[test]
    fn decode_rejects_truncated() {
        let d = OpData::dense(0, 1, OpDataKind::Activation, 0, 0, vec![1.0; 8]);
        let enc = d.encode();
        assert!(OpData::decode(&enc[..enc.len() - 3]).is_err());
        assert!(OpData::decode(&[]).is_err());
    }

    #[test]
    fn encode_into_reuses_buffer_and_matches_encode() {
        let mut d = OpData::dense(9, 8, OpDataKind::Gradient, 2, 1, vec![1.5; 300]);
        d.indices = (0..300).collect();
        d.compress = CompressCfg::TopK { ratio: 4.0, total_len: 1200 };
        let fresh = d.encode();
        let mut reused = Vec::new();
        d.encode_into(&mut reused);
        assert_eq!(fresh, reused);
        let cap = reused.capacity();
        d.encode_into(&mut reused);
        assert_eq!(fresh, reused);
        assert_eq!(reused.capacity(), cap, "encode_into must reuse capacity");
    }

    #[test]
    fn view_matches_owned_decode() {
        let mut d = OpData::dense(5, 6, OpDataKind::Activation, 11, 3, vec![0.25, -4.0, 9.5]);
        d.indices = vec![7, 8, 2_000_000];
        d.compress = CompressCfg::RandomK { ratio: 12.0, total_len: 4_000_000, seed: 77 };
        let enc = d.encode();
        let v = OpDataView::parse(&enc).unwrap();
        let od = OpData::decode(&enc).unwrap();
        assert_eq!(v.header.src_op, od.src_op);
        assert_eq!(v.header.micro_batch, od.micro_batch);
        assert_eq!(v.compress, od.compress);
        assert_eq!(v.payload_iter().collect::<Vec<_>>(), od.payload);
        assert_eq!(v.indices_iter().collect::<Vec<_>>(), od.indices);
        assert_eq!(v.bytes_payload(), &od.bytes_payload[..]);
        assert_eq!(v.payload_len(), 3);
        assert_eq!(v.indices_len(), 3);
    }
}
