//! Workload builders: OP-DAGs with per-op FLOPs / output-size / parameter
//! attributes for the paper's three benchmark models (Table 6) plus the
//! configurable transformer used by the e2e driver.

use super::{Dag, OpKind};

/// Hyper-parameters of a GPT-2-style decoder-only transformer.
#[derive(Debug, Clone, Copy)]
pub struct TransformerSpec {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub microbatch: usize,
}

impl TransformerSpec {
    /// GPT2-XL as benchmarked in Table 6 (batch 3, seq 1024).
    pub fn gpt2_xl() -> TransformerSpec {
        TransformerSpec {
            vocab: 50_257,
            d_model: 1600,
            n_heads: 25,
            n_layers: 48,
            seq_len: 1024,
            microbatch: 3,
        }
    }

    /// Activation message elements between stages.
    pub fn act_bytes(&self) -> f64 {
        (self.microbatch * self.seq_len * self.d_model) as f64 * 4.0
    }

    /// One transformer block's forward FLOPs for a microbatch.
    pub fn block_flops(&self) -> f64 {
        let (b, t, d) = (self.microbatch as f64, self.seq_len as f64, self.d_model as f64);
        // qkv (2·BTD·3D) + scores (2·BT²D) + AV (2·BT²D) + proj (2·BTD·D)
        // + mlp (2·BTD·4D ×2) = 24·BTD² + 4·BT²D
        24.0 * b * t * d * d + 4.0 * b * t * t * d
    }

    pub fn block_param_bytes(&self) -> f64 {
        let d = self.d_model as f64;
        (12.0 * d * d + 13.0 * d) * 4.0
    }

    /// Total parameters (all ops), in count not bytes.
    pub fn total_params(&self) -> f64 {
        let d = self.d_model as f64;
        let v = self.vocab as f64;
        let t = self.seq_len as f64;
        (v * d + t * d) + self.n_layers as f64 * (12.0 * d * d + 13.0 * d) + (d * v + v + 2.0 * d)
    }
}

/// Build the transformer FP DAG: Input -> Embed -> Block_i ... -> Head <- Label.
pub fn transformer_chain(s: &TransformerSpec) -> Dag {
    let (b, t, d, v) =
        (s.microbatch as f64, s.seq_len as f64, s.d_model as f64, s.vocab as f64);
    let mut dag = Dag::default();
    let input = dag.add("Input", OpKind::Placeholder, &[], 0.0, b * t * 4.0, 0.0);
    let embed = dag.add(
        "Embed",
        OpKind::Parametric,
        &[input],
        2.0 * b * t * d,
        s.act_bytes(),
        (v * d + t * d) * 4.0,
    );
    let mut prev = embed;
    for i in 0..s.n_layers {
        prev = dag.add(
            &format!("Block{i}"),
            OpKind::Parametric,
            &[prev],
            s.block_flops(),
            s.act_bytes(),
            s.block_param_bytes(),
        );
    }
    let label = dag.add("Label", OpKind::Placeholder, &[], 0.0, b * t * 4.0, 0.0);
    let _loss = dag.add(
        "Head+CE",
        OpKind::Loss,
        &[prev, label],
        2.0 * b * t * d * v + 5.0 * b * t * v,
        4.0,
        (d * v + v + 2.0 * d) * 4.0,
    );
    dag
}

/// Stage-granularity chain for a pipeline with `n_stages` stages (embed /
/// body×(n−2) / head), matching the AOT artifact structure: ops are whole
/// stages, so schedulers decide stage→device placement.
pub fn stage_chain(s: &TransformerSpec, n_stages: usize) -> Dag {
    assert!(n_stages >= 3);
    let (b, t, d, v) =
        (s.microbatch as f64, s.seq_len as f64, s.d_model as f64, s.vocab as f64);
    let body_stages = n_stages - 2;
    assert_eq!(s.n_layers % body_stages, 0);
    let layers_per = (s.n_layers / body_stages) as f64;
    let mut dag = Dag::default();
    let input = dag.add("Input", OpKind::Placeholder, &[], 0.0, b * t * 4.0, 0.0);
    let mut prev = dag.add(
        "Embed",
        OpKind::Parametric,
        &[input],
        2.0 * b * t * d,
        s.act_bytes(),
        (v * d + t * d) * 4.0,
    );
    for i in 0..body_stages {
        prev = dag.add(
            &format!("BodyStage{i}"),
            OpKind::Parametric,
            &[prev],
            layers_per * s.block_flops(),
            s.act_bytes(),
            layers_per * s.block_param_bytes(),
        );
    }
    let label = dag.add("Label", OpKind::Placeholder, &[], 0.0, b * t * 4.0, 0.0);
    dag.add(
        "Head+CE",
        OpKind::Loss,
        &[prev, label],
        2.0 * b * t * d * v + 5.0 * b * t * v,
        4.0,
        (d * v + v + 2.0 * d) * 4.0,
    );
    dag
}

/// Hyper-parameters for a ResNet-style CNN workload.
#[derive(Debug, Clone, Copy)]
pub struct ResNetSpec {
    pub depth: usize, // 18 or 101
    pub batch: usize,
    pub image: usize, // input H = W
    pub classes: usize,
}

impl ResNetSpec {
    /// Table 6 row: ResNet18 on 3×32×32, batch 128.
    pub fn resnet18() -> ResNetSpec {
        ResNetSpec { depth: 18, batch: 128, image: 32, classes: 10 }
    }

    /// Table 6 row: ResNet101 on 3×64×64, batch 32.
    pub fn resnet101() -> ResNetSpec {
        ResNetSpec { depth: 101, batch: 32, image: 64, classes: 200 }
    }
}

fn conv_flops(b: f64, cin: f64, cout: f64, k: f64, h: f64, w: f64) -> f64 {
    2.0 * b * cin * cout * k * k * h * w
}

/// Build a ResNet FP DAG at residual-block granularity.
pub fn resnet_chain(s: &ResNetSpec) -> Dag {
    let b = s.batch as f64;
    let mut dag = Dag::default();
    let input =
        dag.add("Input", OpKind::Placeholder, &[], 0.0, b * 3.0 * (s.image * s.image) as f64 * 4.0, 0.0);

    // Stem: 3x3 conv (CIFAR-style stem for small inputs).
    let mut h = s.image as f64;
    let mut c = 64.0;
    let stem_flops = conv_flops(b, 3.0, c, 3.0, h, h);
    let mut prev = dag.add(
        "Stem",
        OpKind::Parametric,
        &[input],
        stem_flops,
        b * c * h * h * 4.0,
        (3.0 * c * 9.0 + 2.0 * c) * 4.0,
    );

    // (blocks per stage, bottleneck?) per depth.
    let (stages, bottleneck): (&[usize], bool) = match s.depth {
        18 => (&[2, 2, 2, 2], false),
        34 => (&[3, 4, 6, 3], false),
        50 => (&[3, 4, 6, 3], true),
        101 => (&[3, 4, 23, 3], true),
        other => panic!("unsupported resnet depth {other}"),
    };
    let widths = [64.0, 128.0, 256.0, 512.0];
    for (si, (&nblocks, &width)) in stages.iter().zip(widths.iter()).enumerate() {
        for bi in 0..nblocks {
            let stride_down = si > 0 && bi == 0;
            if stride_down {
                h /= 2.0;
            }
            let cin = c;
            let cout = if bottleneck { width * 4.0 } else { width };
            let (flops, params) = if bottleneck {
                // 1x1 cin->width, 3x3 width->width, 1x1 width->cout (+proj)
                let f = conv_flops(b, cin, width, 1.0, h, h)
                    + conv_flops(b, width, width, 3.0, h, h)
                    + conv_flops(b, width, cout, 1.0, h, h)
                    + if cin != cout { conv_flops(b, cin, cout, 1.0, h, h) } else { 0.0 };
                let p = cin * width + width * width * 9.0 + width * cout
                    + if cin != cout { cin * cout } else { 0.0 };
                (f, p * 4.0)
            } else {
                let f = conv_flops(b, cin, width, 3.0, h, h)
                    + conv_flops(b, width, width, 3.0, h, h)
                    + if cin != width { conv_flops(b, cin, width, 1.0, h, h) } else { 0.0 };
                let p = cin * width * 9.0 + width * width * 9.0
                    + if cin != width { cin * width } else { 0.0 };
                (f, p * 4.0)
            };
            c = cout;
            prev = dag.add(
                &format!("Stage{si}Block{bi}"),
                OpKind::Parametric,
                &[prev],
                flops,
                b * c * h * h * 4.0,
                params,
            );
        }
    }

    let label = dag.add("Label", OpKind::Placeholder, &[], 0.0, b * 4.0, 0.0);
    let cls = s.classes as f64;
    let _loss = dag.add(
        "Pool+FC+CE",
        OpKind::Loss,
        &[prev, label],
        2.0 * b * c * cls + b * c * h * h,
        4.0,
        (c * cls + cls) * 4.0,
    );
    dag
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_xl_params_about_1_5b() {
        let s = TransformerSpec::gpt2_xl();
        let p = s.total_params();
        assert!(p > 1.4e9 && p < 1.8e9, "params={p:.3e}");
    }

    #[test]
    fn transformer_chain_is_a_chain() {
        let s = TransformerSpec::gpt2_xl();
        let d = transformer_chain(&s);
        d.validate().unwrap();
        assert_eq!(d.len(), 2 + s.n_layers + 2); // input, embed, blocks, label, head
        assert_eq!(d.max_degree(), 2);
        // Paper §7.4: GPT2-XL intermediate features ≈ 20 MB; at batch 3 ×
        // 1024 × 1600 × 4B = 19.66 MB. ✔
        let mb = s.act_bytes() / 1e6;
        assert!((mb - 19.66).abs() < 0.5, "act MB = {mb}");
    }

    #[test]
    fn resnet18_flops_sane() {
        // ResNet18 @ 32×32 ≈ 0.56 GFLOPs/image forward; batch 128.
        let d = resnet_chain(&ResNetSpec::resnet18());
        d.validate().unwrap();
        let per_image = d.total_flops_fwd() / 128.0;
        assert!(per_image > 2e8 && per_image < 2e9, "per-image={per_image:.3e}");
    }

    #[test]
    fn resnet101_deeper_than_18() {
        let d18 = resnet_chain(&ResNetSpec::resnet18());
        let d101 = resnet_chain(&ResNetSpec::resnet101());
        assert!(d101.len() > d18.len());
        d101.validate().unwrap();
        // 33 residual blocks + stem + head + 2 placeholders.
        assert_eq!(d101.len(), 33 + 4);
    }

    #[test]
    fn chain_activation_bytes_monotone_structure() {
        // Downsampling halves H but doubles C: bytes shrink across stages.
        let d = resnet_chain(&ResNetSpec::resnet18());
        let first = d.ops.iter().find(|o| o.name == "Stage0Block0").unwrap();
        let last = d.ops.iter().find(|o| o.name == "Stage3Block1").unwrap();
        assert!(first.out_bytes > last.out_bytes);
    }
}
