//! Warm-up profiling (§3.5): fit the λ_p scaling factor from measured
//! op timings, and the α/β link parameters from measured transfers.
//!
//! In the real system these measurements come from a short profiling run on
//! each CompNode; in this reproduction the `worker` feeds back wall-clock
//! PJRT execution times, and the simulated network self-reports.

use crate::cluster::CompNode;

/// One timing sample: (FLOPs executed, seconds measured).
#[derive(Debug, Clone, Copy)]
pub struct CompSample {
    pub flops: f64,
    pub seconds: f64,
}

/// Fit λ_p from samples: measured speed / peak speed, robust mean
/// (median of per-sample ratios, clamped to (0, 1]).
pub fn fit_lambda(node: &CompNode, samples: &[CompSample]) -> f64 {
    if samples.is_empty() {
        return node.lambda;
    }
    let peak = node.gpu.peak_tflops() * 1e12;
    let ratios: Vec<f64> = samples
        .iter()
        .filter(|s| s.seconds > 0.0 && s.flops > 0.0)
        .map(|s| (s.flops / s.seconds) / peak)
        .collect();
    if ratios.is_empty() {
        return node.lambda;
    }
    crate::util::math::median(&ratios).clamp(1e-6, 1.0)
}

/// One link sample: (bytes sent, seconds measured).
#[derive(Debug, Clone, Copy)]
pub struct LinkSample {
    pub bytes: f64,
    pub seconds: f64,
}

/// Fit (α, β) from link samples via least squares.
pub fn fit_link(samples: &[LinkSample]) -> (f64, f64) {
    let xs: Vec<f64> = samples.iter().map(|s| s.bytes).collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
    let (a, b) = crate::util::math::linfit(&xs, &ys);
    (a.max(0.0), b.max(1e-15))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn node() -> CompNode {
        CompNode {
            id: 0,
            name: "t".into(),
            gpu: GpuModel::Rtx4090,
            lambda: 1.0,
            cluster: "A".into(),
            machine: 0,
        }
    }

    #[test]
    fn lambda_fit_recovers_half_speed() {
        let n = node();
        let peak = n.gpu.peak_tflops() * 1e12;
        // Device sustains 50% of peak.
        let samples: Vec<CompSample> = (1..=5)
            .map(|k| CompSample { flops: k as f64 * 1e12, seconds: k as f64 * 1e12 / (0.5 * peak) })
            .collect();
        let l = fit_lambda(&n, &samples);
        assert!((l - 0.5).abs() < 1e-9, "λ={l}");
    }

    #[test]
    fn lambda_fit_empty_keeps_prior() {
        let n = node();
        assert_eq!(fit_lambda(&n, &[]), 1.0);
    }

    #[test]
    fn lambda_clamped_to_one() {
        let n = node();
        let samples = [CompSample { flops: 1e15, seconds: 1e-3 }]; // impossible
        assert_eq!(fit_lambda(&n, &samples), 1.0);
    }

    #[test]
    fn link_fit_recovers_alpha_beta() {
        let (alpha, beta) = (0.015, 8.0 / 100e6);
        let samples: Vec<LinkSample> = (1..=8)
            .map(|k| {
                let b = k as f64 * 250_000.0;
                LinkSample { bytes: b, seconds: alpha + beta * b }
            })
            .collect();
        let (a, bfit) = fit_link(&samples);
        assert!((a - alpha).abs() < 1e-9);
        assert!((bfit - beta).abs() < 1e-12);
    }
}
