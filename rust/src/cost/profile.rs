//! Warm-up profiling (§3.5): fit the λ_p scaling factor from measured
//! op timings, and the α/β link parameters from measured transfers.
//!
//! In the real system these measurements come from a short profiling run on
//! each CompNode; in this reproduction the `worker` feeds back wall-clock
//! PJRT execution times, and the simulated network self-reports.

use crate::cluster::CompNode;

/// One timing sample: (FLOPs executed, seconds measured).
#[derive(Debug, Clone, Copy)]
pub struct CompSample {
    pub flops: f64,
    pub seconds: f64,
}

/// Fit λ_p from samples: measured speed / peak speed, robust mean
/// (median of per-sample ratios, clamped to (0, 1]).
pub fn fit_lambda(node: &CompNode, samples: &[CompSample]) -> f64 {
    if samples.is_empty() {
        return node.lambda;
    }
    let peak = node.gpu.peak_tflops() * 1e12;
    let ratios: Vec<f64> = samples
        .iter()
        .filter(|s| s.seconds > 0.0 && s.flops > 0.0)
        .map(|s| (s.flops / s.seconds) / peak)
        .collect();
    if ratios.is_empty() {
        return node.lambda;
    }
    crate::util::math::median(&ratios).clamp(1e-6, 1.0)
}

/// EWMA per-stage measured times fed by the worker `IterProfile` stream —
/// the runtime half of the profiling plane (§3.5). Where `fit_lambda`
/// calibrates the cost model *before* scheduling, the store tracks what
/// each stage actually sustains *during* training so the straggler
/// detector and the re-planner can react to observed device performance.
#[derive(Debug, Clone)]
pub struct ProfileStore {
    /// EWMA weight of a new sample (1.0 = keep only the latest).
    alpha: f64,
    n_micro: usize,
    /// Per-stage EWMA seconds per *microbatch* (fwd/bwd) and per
    /// *iteration* (update).
    fwd_s: Vec<f64>,
    bwd_s: Vec<f64>,
    update_s: Vec<f64>,
    samples: Vec<usize>,
}

impl ProfileStore {
    pub fn new(n_stages: usize, n_micro: usize, alpha: f64) -> ProfileStore {
        ProfileStore {
            alpha: alpha.clamp(0.0, 1.0),
            n_micro: n_micro.max(1),
            fwd_s: vec![0.0; n_stages],
            bwd_s: vec![0.0; n_stages],
            update_s: vec![0.0; n_stages],
            samples: vec![0; n_stages],
        }
    }

    pub fn n_stages(&self) -> usize {
        self.fwd_s.len()
    }

    /// Record one iteration's measured totals for a stage (`fwd_s`/`bwd_s`
    /// summed over the iteration's microbatches, as `IterProfile` reports).
    pub fn record_iter(&mut self, stage: usize, fwd_s: f64, bwd_s: f64, update_s: f64) {
        if stage >= self.fwd_s.len() {
            return;
        }
        let per_micro = |t: f64| t / self.n_micro as f64;
        let mix = |old: f64, new: f64, first: bool, a: f64| {
            if first {
                new
            } else {
                a * new + (1.0 - a) * old
            }
        };
        let first = self.samples[stage] == 0;
        self.fwd_s[stage] = mix(self.fwd_s[stage], per_micro(fwd_s), first, self.alpha);
        self.bwd_s[stage] = mix(self.bwd_s[stage], per_micro(bwd_s), first, self.alpha);
        self.update_s[stage] = mix(self.update_s[stage], update_s, first, self.alpha);
        self.samples[stage] += 1;
    }

    pub fn samples(&self, stage: usize) -> usize {
        self.samples.get(stage).copied().unwrap_or(0)
    }

    /// Fewest samples across stages (the re-planner's warm-up gate).
    pub fn min_samples(&self) -> usize {
        self.samples.iter().copied().min().unwrap_or(0)
    }

    /// All stages have at least one measurement.
    pub fn ready(&self) -> bool {
        !self.samples.is_empty() && self.samples.iter().all(|&s| s > 0)
    }

    /// Per-iteration busy compute seconds of a stage (the straggler
    /// metric): n_micro·(fwd+bwd) + update.
    pub fn busy_s(&self, stage: usize) -> f64 {
        self.n_micro as f64 * (self.fwd_s[stage] + self.bwd_s[stage]) + self.update_s[stage]
    }

    /// Invalidate a stage's history (call after migrating it to another
    /// device — the old EWMA describes the old silicon).
    pub fn reset_stage(&mut self, stage: usize) {
        if stage < self.samples.len() {
            self.samples[stage] = 0;
            self.fwd_s[stage] = 0.0;
            self.bwd_s[stage] = 0.0;
            self.update_s[stage] = 0.0;
        }
    }

    /// `base` with modeled compute times replaced by measured EWMAs where
    /// measurements exist (unmeasured stages keep the model's estimate).
    pub fn measured_plan(&self, base: &crate::simnet::StagePlan) -> crate::simnet::StagePlan {
        let mut plan = base.clone();
        let n = plan.n_stages().min(self.n_stages());
        for s in 0..n {
            if self.samples[s] > 0 {
                plan.fwd_s[s] = self.fwd_s[s];
                plan.bwd_s[s] = self.bwd_s[s];
                plan.update_s[s] = self.update_s[s];
            }
        }
        plan
    }

    /// Treat a plan's times as ground-truth measurements (simulation mode:
    /// the `simulate --slow-node` straggler smoke seeds the store from the
    /// slowed plan instead of live workers).
    pub fn seed_from_plan(&mut self, plan: &crate::simnet::StagePlan) {
        let n = plan.n_stages().min(self.n_stages());
        for s in 0..n {
            self.fwd_s[s] = plan.fwd_s[s];
            self.bwd_s[s] = plan.bwd_s[s];
            self.update_s[s] = plan.update_s[s];
            self.samples[s] = self.samples[s].max(1);
        }
    }
}

/// Straggler detection over the measured per-stage busy times.
#[derive(Debug, Clone)]
pub struct StragglerReport {
    /// Per-iteration busy seconds per stage.
    pub busy_s: Vec<f64>,
    pub median_busy_s: f64,
    /// Stages whose busy time exceeds threshold × median, slowest first.
    pub flagged: Vec<usize>,
}

/// Flag stages whose measured busy time exceeds `threshold` × the cluster
/// median (paper challenge 3: heterogeneous hardware → stragglers).
pub fn detect_stragglers(store: &ProfileStore, threshold: f64) -> StragglerReport {
    let busy: Vec<f64> = (0..store.n_stages()).map(|s| store.busy_s(s)).collect();
    let med = crate::util::math::median(&busy);
    let mut flagged: Vec<usize> = if store.n_stages() < 2 || med <= 0.0 || !store.ready() {
        Vec::new()
    } else {
        (0..busy.len()).filter(|&s| busy[s] > threshold * med).collect()
    };
    flagged.sort_by(|&a, &b| busy[b].partial_cmp(&busy[a]).unwrap());
    StragglerReport { busy_s: busy, median_busy_s: med, flagged }
}

/// One link sample: (bytes sent, seconds measured).
#[derive(Debug, Clone, Copy)]
pub struct LinkSample {
    pub bytes: f64,
    pub seconds: f64,
}

/// Fit (α, β) from link samples via least squares.
pub fn fit_link(samples: &[LinkSample]) -> (f64, f64) {
    let xs: Vec<f64> = samples.iter().map(|s| s.bytes).collect();
    let ys: Vec<f64> = samples.iter().map(|s| s.seconds).collect();
    let (a, b) = crate::util::math::linfit(&xs, &ys);
    (a.max(0.0), b.max(1e-15))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuModel;

    fn node() -> CompNode {
        CompNode {
            id: 0,
            name: "t".into(),
            gpu: GpuModel::Rtx4090,
            lambda: 1.0,
            cluster: "A".into(),
            machine: 0,
        }
    }

    #[test]
    fn lambda_fit_recovers_half_speed() {
        let n = node();
        let peak = n.gpu.peak_tflops() * 1e12;
        // Device sustains 50% of peak.
        let samples: Vec<CompSample> = (1..=5)
            .map(|k| CompSample { flops: k as f64 * 1e12, seconds: k as f64 * 1e12 / (0.5 * peak) })
            .collect();
        let l = fit_lambda(&n, &samples);
        assert!((l - 0.5).abs() < 1e-9, "λ={l}");
    }

    #[test]
    fn lambda_fit_empty_keeps_prior() {
        let n = node();
        assert_eq!(fit_lambda(&n, &[]), 1.0);
    }

    #[test]
    fn lambda_clamped_to_one() {
        let n = node();
        let samples = [CompSample { flops: 1e15, seconds: 1e-3 }]; // impossible
        assert_eq!(fit_lambda(&n, &samples), 1.0);
    }

    #[test]
    fn profile_store_ewma_and_straggler_flagging() {
        let mut st = ProfileStore::new(4, 2, 0.5);
        assert!(!st.ready());
        assert!(detect_stragglers(&st, 2.0).flagged.is_empty());
        // Stage 2 is ~6x slower than the rest.
        for _ in 0..3 {
            st.record_iter(0, 0.2, 0.4, 0.01);
            st.record_iter(1, 0.2, 0.4, 0.01);
            st.record_iter(2, 1.2, 2.4, 0.01);
            st.record_iter(3, 0.2, 0.4, 0.01);
        }
        assert!(st.ready());
        assert_eq!(st.min_samples(), 3);
        // First sample seeds the EWMA, identical samples keep it fixed.
        assert!((st.busy_s(0) - 0.61).abs() < 1e-9, "{}", st.busy_s(0));
        assert!((st.busy_s(2) - 3.61).abs() < 1e-9);
        let rep = detect_stragglers(&st, 2.0);
        assert_eq!(rep.flagged, vec![2]);
        assert!((rep.median_busy_s - 0.61).abs() < 1e-9);
        // Below threshold: nothing flagged.
        assert!(detect_stragglers(&st, 10.0).flagged.is_empty());
        // Migration invalidates the stage's history.
        st.reset_stage(2);
        assert!(!st.ready());
        assert_eq!(st.samples(2), 0);
    }

    #[test]
    fn measured_plan_overrides_only_sampled_stages() {
        use crate::simnet::StagePlan;
        let base = StagePlan {
            devices: vec![0, 1],
            fwd_s: vec![1.0, 1.0],
            bwd_s: vec![2.0, 2.0],
            update_s: vec![0.1, 0.1],
            act_bytes: vec![1e6],
        };
        let mut st = ProfileStore::new(2, 4, 1.0);
        // Stage 1 measured at half the modeled speed; stage 0 unmeasured.
        st.record_iter(1, 8.0, 16.0, 0.2);
        let m = st.measured_plan(&base);
        assert_eq!(m.fwd_s[0], 1.0);
        assert_eq!(m.fwd_s[1], 2.0); // 8.0 / 4 micros
        assert_eq!(m.bwd_s[1], 4.0);
        assert_eq!(m.update_s[1], 0.2);
        // Seeding marks every stage measured.
        st.seed_from_plan(&base);
        assert!(st.ready());
        assert_eq!(st.busy_s(0), 4.0 * 3.0 + 0.1);
    }

    #[test]
    fn link_fit_recovers_alpha_beta() {
        let (alpha, beta) = (0.015, 8.0 / 100e6);
        let samples: Vec<LinkSample> = (1..=8)
            .map(|k| {
                let b = k as f64 * 250_000.0;
                LinkSample { bytes: b, seconds: alpha + beta * b }
            })
            .collect();
        let (a, bfit) = fit_link(&samples);
        assert!((a - alpha).abs() < 1e-9);
        assert!((bfit - beta).abs() < 1e-12);
    }
}
