//! Iteration latency / throughput model (§3.6, Eq. 2–4; §5.2 Eq. 8).
//!
//! Evaluates a (DAG, partition, testbed, message-scaling) tuple. Message
//! scaling is how compression enters: a closure maps (src node, dst node,
//! dense bytes) -> effective wire bytes, so AdaTopK's per-link ratios
//! (Eq. 7) and uniform Top-K both plug in without this module knowing
//! about compressors.

use super::estimator::Estimator;
use crate::cluster::Testbed;
use crate::opdag::{Dag, Partition};

/// Pipeline execution parameters.
#[derive(Debug, Clone, Copy)]
pub struct PipelineParams {
    /// Number of pipelined microbatches n_b.
    pub n_micro: usize,
    /// Samples per microbatch (for Eq. 4 throughput).
    pub micro_size: usize,
    /// Include the backward pass in the estimate (the paper schedules on
    /// the FP DAG only; the full-iteration estimate doubles compute and
    /// mirrors messages).
    pub include_bwd: bool,
}

impl Default for PipelineParams {
    fn default() -> Self {
        PipelineParams { n_micro: 2, micro_size: 3, include_bwd: true }
    }
}

/// Per-node cost decomposition.
#[derive(Debug, Clone, Default)]
pub struct NodeCost {
    pub node: usize,
    /// C_p: compute seconds per microbatch.
    pub comp_s: f64,
    /// R_p: communication seconds per microbatch (incoming retrievals).
    pub comm_s: f64,
}

/// Result of evaluating Eq. 2–4.
#[derive(Debug, Clone)]
pub struct IterationEstimate {
    pub per_node: Vec<NodeCost>,
    /// T(G)_lat: one traversal of the whole graph (Eq. 2).
    pub t_lat: f64,
    /// T(G)_{n_b, pipe}: pipelined iteration time (Eq. 3).
    pub t_pipe: f64,
    /// φ = N_s / T_pipe (Eq. 4), samples/second.
    pub throughput: f64,
    /// The bottleneck term max_p max(C_p, R_p).
    pub bottleneck_s: f64,
    /// Node index realizing the bottleneck.
    pub bottleneck_node: usize,
}

/// Identity message scaling (no compression).
pub fn dense_bytes(_src: usize, _dst: usize, bytes: f64) -> f64 {
    bytes
}

/// Evaluate the model. `msg_scale(src_node, dst_node, bytes)` returns the
/// effective wire bytes for a message on that link.
pub fn evaluate(
    dag: &Dag,
    part: &Partition,
    testbed: &Testbed,
    params: PipelineParams,
    msg_scale: &dyn Fn(usize, usize, f64) -> f64,
) -> IterationEstimate {
    let est = Estimator::new(testbed);
    let mut used: Vec<usize> = part.assignment.clone();
    used.sort_unstable();
    used.dedup();
    let idx_of = |n: usize| used.binary_search(&n).unwrap();
    let mut costs: Vec<NodeCost> = used
        .iter()
        .map(|&n| NodeCost { node: n, ..Default::default() })
        .collect();

    for op in &dag.ops {
        let node = part.assignment[op.id];
        let c = &mut costs[idx_of(node)];
        c.comp_s += est.comp_time_fwd(dag, op.id, node);
        if params.include_bwd {
            c.comp_s += est.comp_time_bwd(dag, op.id, node);
        }
        // Incoming activations (FP) and outgoing-edge gradients (BP).
        for &a in &op.args {
            let src = part.assignment[a];
            if src != node {
                let eff = msg_scale(src, node, dag.ops[a].out_bytes);
                costs[idx_of(node)].comm_s += est.retrieve_time(src, node, eff);
                if params.include_bwd && dag.ops[a].requires_grad() {
                    // Gradient w.r.t. that activation flows back src <- node.
                    let effg = msg_scale(node, src, dag.ops[a].out_bytes);
                    costs[idx_of(src)].comm_s += est.retrieve_time(node, src, effg);
                }
            }
        }
    }

    let t_lat: f64 = costs.iter().map(|c| c.comp_s + c.comm_s).sum();
    let (mut bmax, mut bnode) = (0.0f64, used.first().copied().unwrap_or(0));
    for c in &costs {
        let b = c.comp_s.max(c.comm_s);
        if b > bmax {
            bmax = b;
            bnode = c.node;
        }
    }
    let t_pipe = t_lat + (params.n_micro.saturating_sub(1)) as f64 * bmax;
    let n_samples = (params.n_micro * params.micro_size) as f64;
    IterationEstimate {
        per_node: costs,
        t_lat,
        t_pipe,
        throughput: if t_pipe > 0.0 { n_samples / t_pipe } else { 0.0 },
        bottleneck_s: bmax,
        bottleneck_node: bnode,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::opdag::OpKind;

    fn chain_partition(dag: &Dag, nodes: &[usize]) -> Partition {
        // Round-robin contiguous split of the compute chain over `nodes`.
        let chain = dag.compute_chain();
        let per = (chain.len() + nodes.len() - 1) / nodes.len();
        let mut assign = vec![usize::MAX; dag.len()];
        for (i, &op) in chain.iter().enumerate() {
            assign[op] = nodes[(i / per).min(nodes.len() - 1)];
        }
        for op in &dag.ops {
            if op.kind == OpKind::Placeholder {
                assign[op.id] = assign[op.users[0]];
            }
        }
        Partition::new(assign)
    }

    #[test]
    fn single_node_has_no_comm() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = chain_partition(&dag, &[0]);
        let e = evaluate(&dag, &p, &tb, PipelineParams::default(), &dense_bytes);
        assert_eq!(e.per_node.len(), 1);
        assert_eq!(e.per_node[0].comm_s, 0.0);
        assert!(e.t_lat > 0.0);
    }

    #[test]
    fn pipelining_amortizes_latency() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = chain_partition(&dag, &[0, 1, 8, 12]);
        let p1 = PipelineParams { n_micro: 1, micro_size: 3, include_bwd: true };
        let p8 = PipelineParams { n_micro: 8, micro_size: 3, include_bwd: true };
        let e1 = evaluate(&dag, &p, &tb, p1, &dense_bytes);
        let e8 = evaluate(&dag, &p, &tb, p8, &dense_bytes);
        assert!(e8.t_pipe > e1.t_pipe);
        // Throughput per sample should improve with pipelining.
        assert!(e8.throughput > e1.throughput);
        // Eq. 3 structure: t_pipe(n) = t_lat + (n-1)·bottleneck.
        assert!((e8.t_pipe - (e8.t_lat + 7.0 * e8.bottleneck_s)).abs() < 1e-9);
    }

    #[test]
    fn compression_scaling_reduces_comm() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        // Split across clusters: node 0 (A) and node 23 (B) — slow link.
        let p = chain_partition(&dag, &[0, 23]);
        let dense = evaluate(&dag, &p, &tb, PipelineParams::default(), &dense_bytes);
        // Uniform ratio 100 => 3/100 of bytes (values + int64 indices).
        let scale = |_s: usize, _d: usize, b: f64| 3.0 * b / 100.0;
        let comp = evaluate(&dag, &p, &tb, PipelineParams::default(), &scale);
        assert!(comp.t_pipe < dense.t_pipe);
        let total_comm_dense: f64 = dense.per_node.iter().map(|c| c.comm_s).sum();
        let total_comm_comp: f64 = comp.per_node.iter().map(|c| c.comm_s).sum();
        assert!(total_comm_comp < total_comm_dense / 10.0);
    }

    #[test]
    fn int8_codec_plan_sees_cheaper_links_than_f32() {
        // Bytes-per-value awareness: at the same selection ratio, the
        // int8-sparse encoding (5 B/kept value) must cost the model
        // 12/5 = 2.4x less communication than f32-sparse (12 B/value).
        use crate::compress::{CompressKind, CompressPlan, ValueCodec};
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = chain_partition(&dag, &[0, 23]);
        let f32_plan = CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len());
        let int8_plan = CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len())
            .with_value_codec(ValueCodec::Int8);
        let params = PipelineParams::default();
        let ef = evaluate(&dag, &p, &tb, params, &f32_plan.msg_scale());
        let eq = evaluate(&dag, &p, &tb, params, &int8_plan.msg_scale());
        let comm = |e: &IterationEstimate| e.per_node.iter().map(|c| c.comm_s).sum::<f64>();
        let ratio = comm(&ef) / comm(&eq);
        // α latency terms keep it below exactly 2.4 but it must be close.
        assert!(ratio > 1.8 && ratio <= 2.4 + 1e-9, "f32/int8 comm ratio {ratio}");
    }

    #[test]
    fn comm_dominates_on_cross_cluster_gpt2xl() {
        // §7.4: FP+BP < 0.5 s while communication ≈ 20 s on slow links —
        // the bottleneck must be communication for cross-cluster splits.
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = chain_partition(&dag, &[0, 23]);
        let e = evaluate(&dag, &p, &tb, PipelineParams::default(), &dense_bytes);
        let comm: f64 = e.per_node.iter().map(|c| c.comm_s).sum();
        let comp: f64 = e.per_node.iter().map(|c| c.comp_s).sum();
        assert!(comm > comp, "comm={comm} comp={comp}");
    }
}
