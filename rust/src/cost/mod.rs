//! Workload & throughput estimation (§3.5–3.6).
//!
//! `estimator` implements the per-op performance model
//! `T(f,p) = R(Pa(f)) + C(f,p) + W(f,p)` with `C = FLOPs/S(p)` and the
//! alpha–beta communication model; `throughput` evaluates Eq. 2 (pipeline
//! stage latency), Eq. 3 (pipelined iteration time) and Eq. 4 (throughput)
//! for a (DAG, partition, testbed, compression-plan) tuple; `profile` fits
//! λ_p and link parameters from warm-up measurements.

pub mod estimator;
pub mod profile;
pub mod throughput;

pub use estimator::Estimator;
pub use profile::{detect_stragglers, ProfileStore, StragglerReport};
pub use throughput::{IterationEstimate, PipelineParams};
