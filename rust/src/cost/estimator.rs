//! Per-op computation / communication time model (§3.5).

use crate::cluster::Testbed;
use crate::opdag::{Dag, OpId};

/// Wraps a testbed and provides the paper's timing primitives.
pub struct Estimator<'a> {
    pub testbed: &'a Testbed,
    /// Fixed per-op host overhead W(f,p) (memory write / framework
    /// dispatch). The paper argues IO time is negligible; keep it small
    /// but nonzero so per-op counts still matter a little.
    pub host_overhead_s: f64,
}

impl<'a> Estimator<'a> {
    pub fn new(testbed: &'a Testbed) -> Estimator<'a> {
        Estimator { testbed, host_overhead_s: 1e-5 }
    }

    /// C(f, p) = FLOPs(f) / S(p), forward pass.
    pub fn comp_time_fwd(&self, dag: &Dag, op: OpId, node: usize) -> f64 {
        let f = &dag.ops[op];
        if f.flops_fwd == 0.0 {
            return 0.0;
        }
        f.flops_fwd / self.testbed.nodes[node].speed_flops() + self.host_overhead_s
    }

    /// Backward-pass compute (≈ 2× forward).
    pub fn comp_time_bwd(&self, dag: &Dag, op: OpId, node: usize) -> f64 {
        let f = &dag.ops[op];
        if f.flops_bwd() == 0.0 {
            return 0.0;
        }
        f.flops_bwd() / self.testbed.nodes[node].speed_flops() + self.host_overhead_s
    }

    /// R(Pa(f)): retrieving `bytes` from node `src` at node `dst`
    /// (0 when co-located — the paper drops local IO).
    pub fn retrieve_time(&self, src: usize, dst: usize, bytes: f64) -> f64 {
        self.testbed.net.comm_time(src, dst, bytes)
    }

    /// Full T(f,p) = Σ_pa R(pa) + C(f,p) + W, given an assignment.
    pub fn op_time_fwd(&self, dag: &Dag, op: OpId, assignment: &[usize]) -> f64 {
        let node = assignment[op];
        let mut t = self.comp_time_fwd(dag, op, node);
        for &a in &dag.ops[op].args {
            let src = assignment[a];
            if src != node {
                t += self.retrieve_time(src, node, dag.ops[a].out_bytes);
            }
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};

    #[test]
    fn compute_time_scales_with_speed() {
        let tb = testbed1(1);
        let est = Estimator::new(&tb);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let block = dag.ops.iter().find(|o| o.name == "Block0").unwrap().id;
        // Node 0 is a 4090, node 23 is a 2080 — 4090 must be faster.
        let fast = est.comp_time_fwd(&dag, block, 0);
        let slow = est.comp_time_fwd(&dag, block, 23);
        assert!(fast < slow, "fast={fast} slow={slow}");
        // bwd ≈ 2× fwd (modulo the fixed overhead).
        let bwd = est.comp_time_bwd(&dag, block, 0);
        assert!((bwd - 2.0 * (fast - est.host_overhead_s) - est.host_overhead_s).abs() < 1e-9);
    }

    #[test]
    fn paper_20mb_over_1mbs_is_20s() {
        // §7.4: "intermediate features occupy around 20 MB, leading to 20
        // seconds to communicate with the 1 MB/s bandwidth".
        let tb = testbed1(1);
        let est = Estimator::new(&tb);
        // Find the slowest cross-cluster link (≈ 8 Mbps = 1 MB/s).
        let mut worst = (0, 0, f64::INFINITY);
        for i in 0..tb.nodes.len() {
            for j in 0..tb.nodes.len() {
                if i != j {
                    let bw = tb.net.bandwidth_bps(i, j);
                    if bw < worst.2 {
                        worst = (i, j, bw);
                    }
                }
            }
        }
        let t = est.retrieve_time(worst.0, worst.1, 19.66e6);
        assert!(t > 1.3 && t < 25.0, "t={t} (paper says ~20s at exactly 1MB/s)");
    }

    #[test]
    fn placeholders_cost_nothing() {
        let tb = testbed1(1);
        let est = Estimator::new(&tb);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        assert_eq!(est.comp_time_fwd(&dag, 0, 0), 0.0); // Input
        assert_eq!(est.comp_time_bwd(&dag, 0, 0), 0.0);
    }
}
