//! Job definition (the IR-plane input, §3.2): which model config, which
//! testbed, which scheduler/compressor, and the training hyper-parameters.

use super::churn::ChurnTrace;
use crate::compress::adatopk::CompressDirection;
use crate::compress::{CompressKind, ValueCodec};
use crate::pipeline::ScheduleKind;
use crate::scheduler::replan::ReplanMode;
use crate::transport::{DataPlane, TransportKind};
use crate::util::cli::Args;
use crate::worker::BackendKind;
use std::path::PathBuf;

#[derive(Debug, Clone)]
pub struct Job {
    /// Artifact config name (tiny / fig8 / small / gpt2-100m).
    pub config: String,
    pub artifacts_root: PathBuf,
    /// Testbed id (Table 5: 1 = 24 GPUs, 2 = 48 GPUs).
    pub testbed: usize,
    pub seed: u64,
    /// Scheduler name (opfence / opfence-dp / equal-number / equal-compute).
    pub scheduler: String,
    pub compress: CompressKind,
    /// User-facing compression ratio r (§5.2).
    pub ratio: f64,
    /// Pipelined microbatches n_b.
    pub n_micro: usize,
    /// Training iterations.
    pub iters: usize,
    pub lr: f32,
    pub momentum: f32,
    /// Which direction to compress (both|bwd|fwd). Paper default: both.
    pub direction: CompressDirection,
    /// Per-value wire codec on compressed links (f32|int8). int8 sends
    /// Top-K values as scale + int8 codes: ~5 B/kept value instead of 8.
    pub value_codec: ValueCodec,
    /// Optimizer: "sgd" (momentum) or "adam" (per-stage adaptive, §3.3
    /// Update: "users can define optimizers ... for different OPs").
    pub optimizer: String,
    /// Explicit stage -> CompNode placement (overrides the scheduler).
    /// Used to pin stages across clusters, the realistic decentralized
    /// scenario where AdaTopK's per-link ratios differ.
    pub placement: Option<Vec<usize>>,
    /// Pipeline execution schedule the workers interpret (gpipe | 1f1b).
    pub pipeline: ScheduleKind,
    /// Straggler re-planning mode (off | advise | auto).
    pub replan: ReplanMode,
    /// Flag stages busier than this multiple of the cluster median.
    pub straggler_threshold: f64,
    /// Relative simulated-iteration improvement a candidate plan must
    /// clear before `--replan auto` migrates (anti-churn margin).
    pub replan_hysteresis: f64,
    /// Test hook: make the device initially hosting this stage run its
    /// compute `slow_factor`× slower (straggler injection).
    pub slow_stage: Option<usize>,
    pub slow_factor: f64,
    /// Compute backend: PJRT artifacts, or the artifact-free Null backend
    /// (real broker/worker/wire machinery, mocked math).
    pub backend: BackendKind,
    /// Liveness beacon interval in seconds (0 = disabled: blocking
    /// receives, no deadline monitor, no crash recovery).
    pub heartbeat_s: f64,
    /// Missed intervals before a silent stage is declared dead
    /// (deadline = heartbeat_s × heartbeat_timeout). The default 10 s
    /// deadline leaves room for multi-second PJRT tasks, during which a
    /// busy stage is legitimately silent.
    pub heartbeat_timeout: u32,
    /// Deadline multiplier before a stage's first message of a generation
    /// (`--heartbeat-grace`): multi-second PJRT compiles on slow hosts
    /// must not trip the monitor during backend init.
    pub heartbeat_grace: u32,
    /// Broker↔worker transport: in-process channels (chan, default) or
    /// TCP sockets with `fusionllm worker --connect` processes.
    pub transport: TransportKind,
    /// Where packet lanes travel under tcp: relayed through the broker
    /// (relay, default) or direct worker↔worker connections (mesh).
    pub data_plane: DataPlane,
    /// TCP listen address (`--listen host:port`).
    pub listen: String,
    /// Shared-secret handshake token for TCP workers.
    pub token: String,
    /// TCP worker pool size (None = one per stage; start one extra so
    /// crash recovery has a free device to fail over to).
    pub workers: Option<usize>,
    /// Artificial seconds per Null forward (`--pace`): paces otherwise
    /// instant Null runs for multi-process demos and the CI kill smoke.
    pub pace_s: f64,
    /// Overlapped wire pipeline in the schedule interpreter
    /// (`--overlap on|off`, default on): per-link encoder/sender threads
    /// + inbound decode prefetchers. Losses are bitwise identical either
    /// way; off is the blocking differential oracle.
    pub overlap: bool,
    /// Injected per-packet link delay in seconds (`--link-delay`): the
    /// sending side sleeps this long before each packet goes out,
    /// modelling wire transfer time (paced overlap smokes). 0 = off.
    pub link_delay_s: f64,
    /// Mesh data plane: max in-flight packets per peer link before
    /// credit-based backpressure blocks the sender (`--mesh-window`).
    pub mesh_window: usize,
    /// Persist a checkpoint every N iterations (0 = disabled).
    pub checkpoint_every: usize,
    pub checkpoint_dir: PathBuf,
    /// Checkpoint versions retained on disk.
    pub keep_checkpoints: usize,
    /// Force every Nth saved version to a full base layer, so a delta
    /// reconstruction chain holds at most N-1 links
    /// (`--checkpoint-rebase-every`, 0 = never force a re-base).
    pub checkpoint_rebase_every: usize,
    /// Churn injector: the worker on this device vanishes silently at the
    /// top of `kill_at_iter` (heartbeats stop; the deadline monitor must
    /// notice and — under `--replan auto` — recover).
    pub kill_device: Option<usize>,
    pub kill_at_iter: u32,
    /// Scripted churn (`--churn-trace FILE`): an ordered membership
    /// script of kill / join / rejoin events the broker drives. The
    /// legacy `kill_device`/`kill_at_iter` pair is folded in as a
    /// single-kill trace by `effective_churn`; setting both is an error.
    pub churn: Option<ChurnTrace>,
}

impl Default for Job {
    fn default() -> Job {
        Job {
            config: "tiny".into(),
            artifacts_root: default_artifacts_root(),
            testbed: 1,
            seed: 42,
            scheduler: "opfence".into(),
            compress: CompressKind::None,
            ratio: 100.0,
            n_micro: 2,
            iters: 20,
            lr: 0.05,
            momentum: 0.9,
            direction: CompressDirection::Both,
            value_codec: ValueCodec::F32,
            optimizer: "sgd".into(),
            placement: None,
            pipeline: ScheduleKind::GPipe,
            replan: ReplanMode::Off,
            straggler_threshold: 2.0,
            replan_hysteresis: 0.10,
            slow_stage: None,
            slow_factor: 4.0,
            backend: BackendKind::Pjrt,
            heartbeat_s: 0.25,
            heartbeat_timeout: 40,
            heartbeat_grace: 4,
            transport: TransportKind::Chan,
            data_plane: DataPlane::Relay,
            listen: "127.0.0.1:4471".into(),
            token: "fusionllm".into(),
            workers: None,
            pace_s: 0.0,
            overlap: true,
            link_delay_s: 0.0,
            mesh_window: crate::transport::mesh::MESH_WINDOW,
            checkpoint_every: 0,
            checkpoint_dir: PathBuf::from("checkpoints"),
            keep_checkpoints: 3,
            checkpoint_rebase_every: 8,
            kill_device: None,
            kill_at_iter: 0,
            churn: None,
        }
    }
}

/// `<crate root>/artifacts`, overridable with FUSIONLLM_ARTIFACTS.
pub fn default_artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("FUSIONLLM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl Job {
    pub fn from_args(args: &Args) -> anyhow::Result<Job> {
        let d = Job::default();
        Ok(Job {
            config: args.str("config", &d.config),
            artifacts_root: args
                .opt_str("artifacts")
                .map(PathBuf::from)
                .unwrap_or(d.artifacts_root),
            testbed: args.usize("testbed", d.testbed),
            seed: args.u64("seed", d.seed),
            scheduler: args.str("scheduler", &d.scheduler),
            compress: CompressKind::parse(&args.str("compress", "none"))?,
            ratio: args.f64("ratio", d.ratio),
            n_micro: args.usize("micro", d.n_micro),
            iters: args.usize("steps", d.iters),
            lr: args.f32("lr", d.lr),
            momentum: args.f32("momentum", d.momentum),
            direction: CompressDirection::parse(&args.str("direction", "both"))?,
            value_codec: ValueCodec::parse(&args.str("wire-codec", "f32"))?,
            optimizer: args.str("optimizer", "sgd"),
            placement: args.opt_str("placement").map(|s| {
                s.split(',')
                    .map(|v| v.parse().expect("--placement expects ids like 0,1,8,20"))
                    .collect()
            }),
            pipeline: ScheduleKind::parse(&args.str("pipeline", "gpipe"))?,
            replan: ReplanMode::parse(&args.str("replan", "off"))?,
            straggler_threshold: args.f64("straggler-threshold", d.straggler_threshold),
            replan_hysteresis: args.f64("replan-hysteresis", d.replan_hysteresis),
            slow_stage: args
                .opt_str("slow-stage")
                .map(|s| s.parse().expect("--slow-stage expects a stage index")),
            slow_factor: args.f64("slow-factor", d.slow_factor),
            backend: BackendKind::parse(&args.str("backend", d.backend.name()))?,
            heartbeat_s: args.f64("heartbeat-interval", d.heartbeat_s).max(0.0),
            heartbeat_timeout: args.u64("heartbeat-timeout", d.heartbeat_timeout as u64)
                as u32,
            heartbeat_grace: args.u64("heartbeat-grace", d.heartbeat_grace as u64).max(1)
                as u32,
            transport: TransportKind::parse(&args.str("transport", d.transport.name()))?,
            data_plane: DataPlane::parse(&args.str("data-plane", d.data_plane.name()))?,
            listen: args.str("listen", &d.listen),
            token: args.str("token", &d.token),
            workers: args.opt_str("workers").map(|s| {
                s.parse().expect("--workers expects a count")
            }),
            pace_s: args.f64("pace", d.pace_s).max(0.0),
            overlap: match args.str("overlap", "on").as_str() {
                "on" => true,
                "off" => false,
                other => anyhow::bail!("unknown --overlap `{other}` (on|off)"),
            },
            link_delay_s: args.f64("link-delay", d.link_delay_s).max(0.0),
            mesh_window: args.usize("mesh-window", d.mesh_window).max(1),
            checkpoint_every: args.usize("checkpoint-every", d.checkpoint_every),
            checkpoint_dir: args
                .opt_str("checkpoint-dir")
                .map(PathBuf::from)
                .unwrap_or(d.checkpoint_dir),
            keep_checkpoints: args.usize("keep-checkpoints", d.keep_checkpoints).max(1),
            checkpoint_rebase_every: args
                .usize("checkpoint-rebase-every", d.checkpoint_rebase_every),
            kill_device: args
                .opt_str("kill-node")
                .map(|s| s.parse().expect("--kill-node expects a device id")),
            kill_at_iter: args.u64("kill-at-iter", d.kill_at_iter as u64) as u32,
            churn: args
                .opt_str("churn-trace")
                .map(|p| ChurnTrace::from_file(std::path::Path::new(p)))
                .transpose()?,
        })
    }

    /// The membership script this job runs under: the explicit
    /// `--churn-trace`, or the legacy single-kill pair folded into one.
    /// Mixing both is rejected — the trace is the ordered source of truth.
    pub fn effective_churn(&self) -> anyhow::Result<Option<ChurnTrace>> {
        match (&self.churn, self.kill_device) {
            (Some(_), Some(_)) => anyhow::bail!(
                "--churn-trace and --kill-node are mutually exclusive \
                 (write the kill as a trace event)"
            ),
            (Some(t), None) => Ok(Some(t.clone())),
            (None, Some(dev)) => Ok(Some(ChurnTrace::single_kill(dev, self.kill_at_iter))),
            (None, None) => Ok(None),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_overrides_defaults() {
        let args = Args::parse(
            "train --config fig8 --steps 7 --compress adatopk --ratio 50 --scheduler equal-number"
                .split_whitespace()
                .map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert_eq!(j.config, "fig8");
        assert_eq!(j.iters, 7);
        assert_eq!(j.compress, CompressKind::AdaTopK);
        assert_eq!(j.ratio, 50.0);
        assert_eq!(j.scheduler, "equal-number");
        assert_eq!(j.n_micro, 2); // default preserved
    }

    #[test]
    fn wire_codec_parses_and_defaults_to_f32() {
        let j = Job::from_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(j.value_codec, ValueCodec::F32);
        let args = Args::parse(
            ["--compress", "adatopk", "--wire-codec", "int8"].iter().map(|s| s.to_string()),
        );
        assert_eq!(Job::from_args(&args).unwrap().value_codec, ValueCodec::Int8);
        let args = Args::parse(
            ["--compress", "adatopk", "--wire-codec", "int8-u24"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(Job::from_args(&args).unwrap().value_codec, ValueCodec::Int8Delta);
        let bad = Args::parse(["--wire-codec", "fp8"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
    }

    #[test]
    fn pipeline_and_replan_flags_parse() {
        let j = Job::from_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(j.pipeline, ScheduleKind::GPipe);
        assert_eq!(j.replan, ReplanMode::Off);
        assert_eq!(j.slow_stage, None);
        let args = Args::parse(
            "train --pipeline 1f1b --replan auto --straggler-threshold 3 --slow-stage 1 --slow-factor 8"
                .split_whitespace()
                .map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert_eq!(j.pipeline, ScheduleKind::OneFOneB);
        assert_eq!(j.replan, ReplanMode::Auto);
        assert_eq!(j.straggler_threshold, 3.0);
        assert_eq!(j.slow_stage, Some(1));
        assert_eq!(j.slow_factor, 8.0);
        let bad = Args::parse(["--pipeline", "zigzag"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
        let bad = Args::parse(["--replan", "maybe"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
    }

    #[test]
    fn fault_tolerance_flags_parse() {
        let j = Job::from_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(j.backend, BackendKind::Pjrt);
        assert_eq!(j.heartbeat_s, 0.25);
        assert_eq!(j.heartbeat_timeout, 40);
        assert_eq!(j.checkpoint_every, 0);
        assert_eq!(j.checkpoint_rebase_every, 8);
        assert_eq!(j.kill_device, None);
        let args = Args::parse(
            "train --backend null --heartbeat-interval 0.05 --heartbeat-timeout 4 \
             --checkpoint-every 2 --checkpoint-dir /tmp/ck --keep-checkpoints 5 \
             --checkpoint-rebase-every 4 --kill-node 1 --kill-at-iter 3"
                .split_whitespace()
                .map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert_eq!(j.backend, BackendKind::Null);
        assert_eq!(j.heartbeat_s, 0.05);
        assert_eq!(j.heartbeat_timeout, 4);
        assert_eq!(j.checkpoint_every, 2);
        assert_eq!(j.checkpoint_dir, PathBuf::from("/tmp/ck"));
        assert_eq!(j.keep_checkpoints, 5);
        assert_eq!(j.checkpoint_rebase_every, 4);
        assert_eq!(j.kill_device, Some(1));
        assert_eq!(j.kill_at_iter, 3);
        let bad = Args::parse(["--backend", "tpu"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
    }

    #[test]
    fn transport_flags_parse() {
        let j = Job::from_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert_eq!(j.transport, TransportKind::Chan);
        assert_eq!(j.data_plane, DataPlane::Relay);
        assert_eq!(j.listen, "127.0.0.1:4471");
        assert_eq!(j.token, "fusionllm");
        assert_eq!(j.workers, None);
        assert_eq!(j.heartbeat_grace, 4);
        assert_eq!(j.pace_s, 0.0);
        let args = Args::parse(
            "train --transport tcp --data-plane mesh --listen 0.0.0.0:9000 --token s3cret \
             --workers 5 --heartbeat-grace 8 --pace 0.1"
                .split_whitespace()
                .map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert_eq!(j.transport, TransportKind::Tcp);
        assert_eq!(j.data_plane, DataPlane::Mesh);
        assert_eq!(j.listen, "0.0.0.0:9000");
        assert_eq!(j.token, "s3cret");
        assert_eq!(j.workers, Some(5));
        assert_eq!(j.heartbeat_grace, 8);
        assert_eq!(j.pace_s, 0.1);
        let bad = Args::parse(["--transport", "udp"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
        let bad = Args::parse(["--data-plane", "ring"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
    }

    #[test]
    fn overlap_flags_parse() {
        let j = Job::from_args(&Args::parse(std::iter::empty::<String>())).unwrap();
        assert!(j.overlap, "overlap defaults to on");
        assert_eq!(j.link_delay_s, 0.0);
        assert_eq!(j.mesh_window, crate::transport::mesh::MESH_WINDOW);
        let args = Args::parse(
            "train --overlap off --link-delay 0.02 --mesh-window 16"
                .split_whitespace()
                .map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert!(!j.overlap);
        assert_eq!(j.link_delay_s, 0.02);
        assert_eq!(j.mesh_window, 16);
        // Negative delays clamp, zero windows clamp to 1.
        let args = Args::parse(
            "train --link-delay -1 --mesh-window 0".split_whitespace().map(String::from),
        );
        let j = Job::from_args(&args).unwrap();
        assert_eq!(j.link_delay_s, 0.0);
        assert_eq!(j.mesh_window, 1);
        let bad = Args::parse(["--overlap", "maybe"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&bad).is_err());
    }

    #[test]
    fn churn_trace_flag_parses_and_excludes_kill_node() {
        use crate::broker::churn::{ChurnAction, ChurnTrace};
        let dir = std::env::temp_dir()
            .join(format!("fusionllm-jobtrace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("trace.txt");
        std::fs::write(&file, "kill 1 @3\njoin 5 @5\nrejoin 1 @7\n").unwrap();
        let args = Args::parse(
            ["--churn-trace", file.to_str().unwrap()].iter().map(|s| s.to_string()),
        );
        let j = Job::from_args(&args).unwrap();
        let t = j.churn.clone().unwrap();
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.events[1].action, ChurnAction::Join);
        assert_eq!(j.effective_churn().unwrap().unwrap(), t);
        // Legacy pair folds into a single-kill trace.
        let legacy = Job { kill_device: Some(2), kill_at_iter: 4, ..Job::default() };
        assert_eq!(
            legacy.effective_churn().unwrap().unwrap(),
            ChurnTrace::single_kill(2, 4)
        );
        // No churn at all.
        assert!(Job::default().effective_churn().unwrap().is_none());
        // Mixing both is rejected.
        let both = Job { churn: Some(t), kill_device: Some(1), ..Job::default() };
        assert!(both.effective_churn().is_err());
        // A missing trace file is a clean error.
        let bad = Args::parse(
            ["--churn-trace", "/nonexistent/trace"].iter().map(|s| s.to_string()),
        );
        assert!(Job::from_args(&bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn bad_compressor_rejected() {
        let args = Args::parse(["--compress", "zstd"].iter().map(|s| s.to_string()));
        assert!(Job::from_args(&args).is_err());
    }
}
