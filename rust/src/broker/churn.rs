//! Scripted churn traces: ordered membership events the broker drives.
//!
//! A trace file holds one event per line —
//!
//! ```text
//! # comments and blank lines are skipped
//! kill 1 @3      # device 1's worker vanishes at the top of iteration 3
//! join 5 @5      # brand-new device 5 becomes available at iteration 5
//! rejoin 1 @7    # previously-killed device 1 reconnects at iteration 7
//! ```
//!
//! Events must be listed in non-decreasing iteration order. `kill` events
//! reach the workers through the existing fault injector (the worker
//! vanishes silently and the deadline monitor must notice); `join` and
//! `rejoin` are handled by the broker at the named iteration boundary:
//! the device is marked alive, parked as a spare, and folded into the
//! pipeline only when `Replanner::replan_after_join` predicts a win.
//!
//! The legacy `--kill-node N --kill-at-iter K` pair is exactly the
//! single-event trace `kill N @K`.

use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnAction {
    Kill,
    Join,
    Rejoin,
}

impl ChurnAction {
    pub fn name(self) -> &'static str {
        match self {
            ChurnAction::Kill => "kill",
            ChurnAction::Join => "join",
            ChurnAction::Rejoin => "rejoin",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    pub action: ChurnAction,
    pub device: usize,
    pub at_iter: u32,
}

/// An ordered membership script. Parsed once, validated against the
/// run's initial placement, then interpreted by the broker event loop.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ChurnTrace {
    pub events: Vec<ChurnEvent>,
}

impl ChurnTrace {
    /// The trace equivalent of the legacy `--kill-node/--kill-at-iter`
    /// injector.
    pub fn single_kill(device: usize, at_iter: u32) -> ChurnTrace {
        ChurnTrace {
            events: vec![ChurnEvent { action: ChurnAction::Kill, device, at_iter }],
        }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Parse the trace-file format. Syntax and ordering only; membership
    /// legality needs the initial placement (`validate`).
    pub fn parse(text: &str) -> anyhow::Result<ChurnTrace> {
        let mut events = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            anyhow::ensure!(
                toks.len() == 3,
                "churn trace line {}: expected `<kill|join|rejoin> <device> @<iter>`, got `{line}`",
                lineno + 1
            );
            let action = match toks[0] {
                "kill" => ChurnAction::Kill,
                "join" => ChurnAction::Join,
                "rejoin" => ChurnAction::Rejoin,
                other => anyhow::bail!(
                    "churn trace line {}: unknown action `{other}` (kill|join|rejoin)",
                    lineno + 1
                ),
            };
            let device: usize = toks[1].parse().map_err(|_| {
                anyhow::anyhow!(
                    "churn trace line {}: bad device id `{}`",
                    lineno + 1,
                    toks[1]
                )
            })?;
            let iter_tok = toks[2].strip_prefix('@').ok_or_else(|| {
                anyhow::anyhow!(
                    "churn trace line {}: iteration must be written `@N`, got `{}`",
                    lineno + 1,
                    toks[2]
                )
            })?;
            let at_iter: u32 = iter_tok.parse().map_err(|_| {
                anyhow::anyhow!(
                    "churn trace line {}: bad iteration `{}`",
                    lineno + 1,
                    toks[2]
                )
            })?;
            events.push(ChurnEvent { action, device, at_iter });
        }
        let trace = ChurnTrace { events };
        anyhow::ensure!(
            trace.events.windows(2).all(|w| w[0].at_iter <= w[1].at_iter),
            "churn trace events must be in non-decreasing iteration order"
        );
        Ok(trace)
    }

    pub fn from_file(path: &Path) -> anyhow::Result<ChurnTrace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading churn trace {}: {e}", path.display()))?;
        ChurnTrace::parse(&text)
    }

    /// Membership legality against the run's initial member set: kills
    /// target current members, rejoins target currently-killed devices,
    /// joins introduce devices never seen before. A kill and a later
    /// event for the same device must not share an iteration (the order
    /// of a simultaneous kill+rejoin is ambiguous).
    pub fn validate(&self, initial_members: &[usize]) -> anyhow::Result<()> {
        let mut members: Vec<usize> = initial_members.to_vec();
        let mut killed: Vec<usize> = Vec::new();
        let mut last_kill_iter: Vec<(usize, u32)> = Vec::new();
        for ev in &self.events {
            let d = ev.device;
            match ev.action {
                ChurnAction::Kill => {
                    anyhow::ensure!(
                        members.contains(&d),
                        "churn trace: kill {d} @{}: device {d} is not a member there",
                        ev.at_iter
                    );
                    members.retain(|&m| m != d);
                    killed.push(d);
                    last_kill_iter.retain(|&(m, _)| m != d);
                    last_kill_iter.push((d, ev.at_iter));
                }
                ChurnAction::Join => {
                    anyhow::ensure!(
                        !members.contains(&d) && !killed.contains(&d),
                        "churn trace: join {d} @{}: device {d} already seen (use rejoin)",
                        ev.at_iter
                    );
                    members.push(d);
                }
                ChurnAction::Rejoin => {
                    anyhow::ensure!(
                        killed.contains(&d),
                        "churn trace: rejoin {d} @{}: device {d} was never killed",
                        ev.at_iter
                    );
                    let k = last_kill_iter
                        .iter()
                        .find(|&&(m, _)| m == d)
                        .map(|&(_, i)| i)
                        .unwrap_or(0);
                    anyhow::ensure!(
                        ev.at_iter > k,
                        "churn trace: rejoin {d} @{} must come strictly after its kill @{k}",
                        ev.at_iter
                    );
                    killed.retain(|&m| m != d);
                    members.push(d);
                }
            }
        }
        Ok(())
    }

    /// Devices introduced by `join` events (unavailable until then: the
    /// broker pre-fails them so the initial plan cannot use them).
    pub fn join_devices(&self) -> Vec<usize> {
        self.events
            .iter()
            .filter(|e| e.action == ChurnAction::Join)
            .map(|e| e.device)
            .collect()
    }

    /// Kill events, in order.
    pub fn kills(&self) -> impl Iterator<Item = &ChurnEvent> {
        self.events.iter().filter(|e| e.action == ChurnAction::Kill)
    }

    /// Join + rejoin events, in order (the broker-driven boundary side).
    pub fn admissions(&self) -> impl Iterator<Item = &ChurnEvent> {
        self.events.iter().filter(|e| e.action != ChurnAction::Kill)
    }

    /// The earliest scripted kill for `device` at or after `from_iter` —
    /// what a generation starting at `from_iter` must arm the worker-side
    /// fault injector with. Exact-iteration matching in the interpreter
    /// makes re-arming across restores safe: a kill already fired can
    /// only re-fire if the run actually rewinds past it, which replays
    /// the identical death deterministically.
    pub fn next_kill(&self, device: usize, from_iter: u32) -> Option<u32> {
        self.kills()
            .filter(|e| e.device == device && e.at_iter >= from_iter)
            .map(|e| e.at_iter)
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_documented_format() {
        let t = ChurnTrace::parse(
            "# a comment\n\nkill 1 @3   # inline comment\njoin 5 @5\nrejoin 1 @7\n",
        )
        .unwrap();
        assert_eq!(t.events.len(), 3);
        assert_eq!(
            t.events[0],
            ChurnEvent { action: ChurnAction::Kill, device: 1, at_iter: 3 }
        );
        assert_eq!(
            t.events[1],
            ChurnEvent { action: ChurnAction::Join, device: 5, at_iter: 5 }
        );
        assert_eq!(
            t.events[2],
            ChurnEvent { action: ChurnAction::Rejoin, device: 1, at_iter: 7 }
        );
        assert_eq!(t.join_devices(), vec![5]);
        assert_eq!(t.kills().count(), 1);
        assert_eq!(t.admissions().count(), 2);
        t.validate(&[0, 1, 2, 3]).unwrap();
    }

    #[test]
    fn rejects_bad_syntax() {
        for bad in [
            "kill 1",              // missing iter
            "kill 1 3",            // missing @
            "explode 1 @3",        // unknown action
            "kill x @3",           // bad device
            "kill 1 @x",           // bad iter
            "kill 1 @5\njoin 2 @3", // out of order
        ] {
            assert!(ChurnTrace::parse(bad).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn validate_enforces_membership_legality() {
        let members = [0usize, 1, 2, 3];
        // Kill of a non-member.
        let t = ChurnTrace::parse("kill 9 @2").unwrap();
        assert!(t.validate(&members).is_err());
        // Double kill without rejoin.
        let t = ChurnTrace::parse("kill 1 @2\nkill 1 @4").unwrap();
        assert!(t.validate(&members).is_err());
        // Join of an existing member.
        let t = ChurnTrace::parse("join 2 @3").unwrap();
        assert!(t.validate(&members).is_err());
        // Rejoin without a kill.
        let t = ChurnTrace::parse("rejoin 2 @3").unwrap();
        assert!(t.validate(&members).is_err());
        // Rejoin at the kill iteration is ambiguous.
        let t = ChurnTrace::parse("kill 1 @3\nrejoin 1 @3").unwrap();
        assert!(t.validate(&members).is_err());
        // Kill -> rejoin -> kill again is legal.
        let t = ChurnTrace::parse("kill 1 @2\nrejoin 1 @4\nkill 1 @6").unwrap();
        t.validate(&members).unwrap();
        // Join -> kill -> rejoin of the joiner is legal.
        let t = ChurnTrace::parse("join 7 @1\nkill 7 @3\nrejoin 7 @5").unwrap();
        t.validate(&members).unwrap();
    }

    #[test]
    fn next_kill_respects_generation_start() {
        let t = ChurnTrace::parse("kill 1 @3\nrejoin 1 @5\nkill 1 @7").unwrap();
        assert_eq!(t.next_kill(1, 0), Some(3));
        assert_eq!(t.next_kill(1, 3), Some(3));
        assert_eq!(t.next_kill(1, 4), Some(7));
        assert_eq!(t.next_kill(1, 8), None);
        assert_eq!(t.next_kill(2, 0), None);
        assert_eq!(ChurnTrace::single_kill(1, 3).next_kill(1, 0), Some(3));
    }
}
