//! The broker / IR plane (§3.2): receives a job definition, builds the
//! stage-level OP-DAG, schedules it onto the testbed, derives the
//! compression plan, spawns the CompNode workers, feeds data, and collects
//! losses + statistics into a `TrainReport`.
//!
//! The runtime is adaptive: workers stream per-iteration `IterProfile`
//! measurements back to the broker; a `ProfileStore` maintains EWMA
//! per-stage times; when the straggler detector flags a stage and
//! `--replan auto` is set, the `Replanner` re-runs the scheduler with
//! measured (not modeled) compute times and — if the simulated iteration
//! improves past the hysteresis margin — the broker re-partitions at the
//! next iteration boundary: workers are stopped, their `StageState`
//! (params + optimizer moments) is snapshotted, links/codecs are rebuilt
//! for the new placement, and a fresh worker generation resumes at the
//! same global iteration.
//!
//! The runtime is also churn-tolerant: workers heartbeat on a
//! configurable interval, a deadline monitor in the broker's event loop
//! declares a silent stage dead (missed beacons, channel loss, or
//! `Wire::Fatal`), and — under `--replan auto` — the broker marks the
//! device failed in the `NetGraph`, re-partitions across survivors with
//! `Replanner::replan_after_failure`, restores the newest valid
//! checkpoint (broadcast `Wire::Checkpoint` every `--checkpoint-every`
//! iterations, persisted via the `checkpoint` module), rewinds the data
//! loader and resumes. Every event lands in `TrainReport.recoveries`.

pub mod job;

pub use job::Job;

use crate::checkpoint::{self, Checkpoint};
use crate::cluster::{testbed, Testbed};
use crate::compress::{CompressKind, CompressPlan};
use crate::cost::{PipelineParams, ProfileStore};
use crate::opdag::builders::{stage_chain, TransformerSpec};
use crate::opdag::{Dag, Partition};
use crate::pipeline::PipelineSchedule;
use crate::runtime::{Manifest, ModelCfg};
use crate::scheduler::replan::{ReplanInput, ReplanMode, Replanner};
use crate::simnet::{simulate_iteration, StagePlan};
use crate::trainer::{RecoveryEvent, ReplanEvent, SyntheticCorpus, TrainReport};
use crate::worker::{
    spawn_stage, BackendKind, StageCodec, StageCtx, StageState, Wire, WorkerStats,
};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

/// Iterations of measured profile required before the first replan check.
const REPLAN_WARMUP_ITERS: usize = 3;

/// Hard cap on crash recoveries per run (a persistently failing cluster
/// must eventually surface as an error, not an infinite restart loop).
const MAX_RECOVERIES: usize = 8;

/// One cohort of stage workers sharing a set of channels. Re-partitioning
/// tears a generation down (collecting state snapshots) and spawns the
/// next one on the new placement.
struct Generation {
    handles: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
    /// Broker-held senders into every stage's forward input (stage 0 gets
    /// Data; the rest are reachable for Stop/Checkpoint broadcast).
    fwd_tx: Vec<Sender<Wire>>,
    label_tx: Sender<Wire>,
    rx_driver: Receiver<Wire>,
    /// Stats messages already collected from this generation.
    stats_seen: usize,
    /// Device per stage (dead-stage attribution).
    devices: Vec<usize>,
    /// Liveness: last instant each stage was heard from (any message).
    last_seen: Vec<Instant>,
    /// Whether a stage has sent anything yet — before first contact the
    /// deadline gets a grace multiplier (backend init may be slow).
    heard: Vec<bool>,
}

/// A driver-plane event: a protocol message, or a stage declared dead
/// (fatal error, channel loss, or heartbeat deadline expiry).
enum Event {
    Msg(Wire),
    Dead { stage: usize, cause: String },
}

/// Deadline multiplier for stages that have not spoken yet (covers slow
/// backend initialization before the first beacon).
const FIRST_CONTACT_GRACE: u32 = 4;

impl Generation {
    fn note(&mut self, stage: usize) {
        if stage < self.last_seen.len() {
            self.last_seen[stage] = Instant::now();
            self.heard[stage] = true;
        }
    }

    /// Stage a message originates from, for liveness attribution.
    fn stage_of(msg: &Wire, s_n: usize) -> Option<usize> {
        match msg {
            Wire::Loss { .. } => Some(s_n - 1),
            Wire::IterProfile { stage, .. }
            | Wire::Snapshot { stage, .. }
            | Wire::Heartbeat { stage, .. }
            | Wire::Fatal { stage, .. } => Some(*stage),
            Wire::Stats(st) => Some(st.stage),
            _ => None,
        }
    }

    /// Stage furthest past its (grace-adjusted) deadline, if any.
    fn expired_stage(&self, dl: Duration) -> Option<(usize, Duration)> {
        let worst = (0..self.last_seen.len())
            .map(|s| {
                let limit = if self.heard[s] { dl } else { dl * FIRST_CONTACT_GRACE };
                let age = self.last_seen[s].elapsed();
                (s, age, age.as_secs_f64() - limit.as_secs_f64())
            })
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())?;
        if worst.2 > 0.0 {
            Some((worst.0, worst.1))
        } else {
            None
        }
    }

    /// Next driver-plane message. Heartbeats are swallowed (they only
    /// refresh deadlines). With a deadline, the receive ticks and the
    /// per-stage deadlines are checked on *every* pass — before each
    /// receive, not just on a silent tick, so survivors' beacon traffic
    /// cannot starve the death check while a dead stage stalls the run.
    /// Without a deadline this is the PR 3 blocking receive.
    fn recv_event(&mut self, deadline: Option<Duration>) -> anyhow::Result<Event> {
        let s_n = self.last_seen.len();
        loop {
            let msg = match deadline {
                None => self
                    .rx_driver
                    .recv()
                    .map_err(|_| anyhow::anyhow!("all workers exited unexpectedly"))?,
                Some(dl) => {
                    if let Some((stage, age)) = self.expired_stage(dl) {
                        return Ok(Event::Dead {
                            stage,
                            cause: format!("no heartbeat for {:.2}s", age.as_secs_f64()),
                        });
                    }
                    let tick = (dl / 4)
                        .max(Duration::from_millis(5))
                        .min(Duration::from_millis(250));
                    match self.rx_driver.recv_timeout(tick) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Disconnected) => {
                            anyhow::bail!("all workers exited unexpectedly")
                        }
                        Err(RecvTimeoutError::Timeout) => continue,
                    }
                }
            };
            if let Some(stage) = Self::stage_of(&msg, s_n) {
                self.note(stage);
            }
            match msg {
                Wire::Heartbeat { .. } => continue,
                Wire::Fatal { stage, error } => {
                    return Ok(Event::Dead { stage, cause: format!("fatal: {error}") })
                }
                m => return Ok(Event::Msg(m)),
            }
        }
    }
}

/// How one iteration's collection ended.
enum IterOutcome {
    Done { mean_loss: f32, prof: Vec<(f64, f64, f64, f64)> },
    Died { stage: usize, cause: String },
}

/// How a checkpoint snapshot collection ended.
enum SnapOutcome {
    Done(Vec<StageState>),
    Died { stage: usize, cause: String },
}

/// The model config the Null backend trains (no artifacts on disk): tiny
/// shapes, 4 stages — enough to exercise every broker/wire code path.
fn null_model_cfg(name: &str) -> ModelCfg {
    ModelCfg {
        name: name.to_string(),
        vocab: 61,
        d_model: 8,
        n_heads: 1,
        n_layers: 4,
        seq_len: 8,
        microbatch: 2,
        n_stages: 4,
        compress_ratio: 1.0,
        topk_k: 0,
    }
}

/// Build the compression plan for a (partition, testbed) pair per the
/// job's knobs — also used by the re-planner to cost candidate plans.
fn compress_plan_for(
    job: &Job,
    micro_size: usize,
    dag: &Dag,
    part: &Partition,
    tb: &Testbed,
) -> CompressPlan {
    let params = PipelineParams { n_micro: job.n_micro, micro_size, include_bwd: true };
    let mut plan = match job.compress {
        // `--compress none --wire-codec int8` = dense int8 (1 B/value).
        CompressKind::None => {
            CompressPlan::dense(tb.nodes.len()).with_value_codec(job.value_codec)
        }
        CompressKind::AdaTopK => CompressPlan::adatopk_with_codec(
            dag,
            part,
            tb,
            params,
            job.ratio,
            job.value_codec,
        ),
        kind => {
            CompressPlan::uniform(kind, job.ratio, tb.nodes.len())
                .with_value_codec(job.value_codec)
        }
    };
    plan.direction = job.direction;
    plan
}

/// Spawn one worker generation on `devices`, executing iterations
/// `[iter0, iter0 + iters)` of `schedule`. `init` entries are taken (and
/// consumed) as migrated/restored state for the matching stage.
#[allow(clippy::too_many_arguments)]
fn spawn_generation(
    manifest: &Manifest,
    job: &Job,
    schedule: &PipelineSchedule,
    devices: &[usize],
    plan: &CompressPlan,
    iter0: u32,
    iters: usize,
    init: &mut [Option<StageState>],
    slow_dev: Option<(usize, f64)>,
    heartbeat: Option<Duration>,
) -> Generation {
    let s_n = devices.len();
    let cfg = &manifest.config;
    let (tx_driver, rx_driver) = mpsc::channel::<Wire>();
    let mut fwd_tx = Vec::new();
    let mut fwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        fwd_tx.push(t);
        fwd_rx.push(Some(r));
    }
    let mut bwd_tx = Vec::new();
    let mut bwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        bwd_tx.push(t);
        bwd_rx.push(Some(r));
    }
    let (label_tx, label_rx) = mpsc::channel::<Wire>();
    let mut label_rx = Some(label_rx);

    let mut handles = Vec::new();
    for s in 0..s_n {
        let next_device = devices.get(s + 1).copied();
        let prev_device = if s > 0 { Some(devices[s - 1]) } else { None };
        let slow_factor = match slow_dev {
            Some((dev, f)) if dev == devices[s] => f,
            _ => 1.0,
        };
        // Churn injector: the stage hosted on --kill-node vanishes at the
        // top of --kill-at-iter (after recovery the failed device hosts
        // nothing, so the injector cannot re-fire).
        let kill_at_iter = match job.kill_device {
            Some(dev) if dev == devices[s] => Some(job.kill_at_iter),
            _ => None,
        };
        let ctx = StageCtx {
            stage: s,
            n_stages: s_n,
            device: devices[s],
            next_device,
            prev_device,
            manifest: manifest.clone(),
            // Per-link wire codecs: ratios keyed by the receiving device
            // (Eq. 7), scratch owned for the life of the link.
            codec: StageCodec::from_plan(plan, next_device, prev_device, cfg.d_model),
            tasks: schedule.tasks[s].clone(),
            iter0,
            iters,
            n_micro: job.n_micro,
            lr: job.lr,
            momentum: job.momentum,
            optimizer: job.optimizer.clone(),
            param_seed: job.seed.wrapping_add(s as u64),
            init_state: init[s].take(),
            slow_factor,
            backend: job.backend,
            heartbeat,
            kill_at_iter,
            rx_fwd: fwd_rx[s].take().unwrap(),
            rx_bwd: if s + 1 < s_n { bwd_rx[s].take() } else { None },
            tx_fwd: if s + 1 < s_n { Some(fwd_tx[s + 1].clone()) } else { None },
            tx_bwd: if s > 0 { Some(bwd_tx[s - 1].clone()) } else { None },
            rx_labels: if s == s_n - 1 { label_rx.take() } else { None },
            tx_driver: tx_driver.clone(),
        };
        handles.push(spawn_stage(ctx));
    }
    // The broker keeps no tx_driver clone: the channel closes when the
    // last worker of the generation exits.
    drop(tx_driver);
    Generation {
        handles,
        fwd_tx,
        label_tx,
        rx_driver,
        stats_seen: 0,
        devices: devices.to_vec(),
        last_seen: vec![Instant::now(); s_n],
        heard: vec![false; s_n],
    }
}

/// Stop a generation at an iteration boundary (workers are blocked on
/// their first recv of the next iteration), collect state snapshots and
/// remaining stats, and join the threads. Also used as the end-of-run
/// drain, where the Stop sends land on already-dropped receivers.
///
/// All threads are joined on every path. Worker errors are reported
/// *after* the join, so a failing run can no longer leak detached threads
/// — except when `join_always` is false (heartbeats disabled): a worker
/// blocked on a dead neighbor cannot observe Stop without ticking
/// receives, so a Fatal aborts immediately as in PR 3.
fn teardown(
    gen: Generation,
    s_n: usize,
    snapshots: &mut [Option<StageState>],
    all_stats: &mut Vec<WorkerStats>,
    join_always: bool,
) -> anyhow::Result<()> {
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Stop);
    }
    let _ = gen.label_tx.send(Wire::Stop);
    let mut seen = gen.stats_seen;
    let mut first_err: Option<String> = None;
    while seen < s_n {
        match gen.rx_driver.recv() {
            Ok(Wire::Stats(st)) => {
                all_stats.push(st);
                seen += 1;
            }
            Ok(Wire::Snapshot { stage, state }) => snapshots[stage] = Some(state),
            Ok(Wire::Fatal { stage, error }) => {
                let msg = format!("stage {stage} failed: {error}");
                if !join_always {
                    anyhow::bail!(msg);
                }
                if first_err.is_none() {
                    first_err = Some(msg);
                }
            }
            Ok(_) => {} // stale losses/profiles/heartbeats from the stopped iteration
            Err(_) => break, // all workers exited (join reports errors)
        }
    }
    for h in gen.handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(format!("worker failed: {e:#}"));
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some("worker panicked".into());
                }
            }
        }
    }
    match first_err {
        Some(e) => anyhow::bail!(e),
        None => Ok(()),
    }
}

/// Tear down a generation that contains a dead stage: broadcast Stop,
/// drain whatever the survivors still send (bounded by a drain budget —
/// the dead stage sends nothing), then join every thread. Survivors
/// observe Stop even when blocked on a dead neighbor because their
/// ticking receives poll the forward link, so the join cannot hang.
fn churn_teardown(
    gen: Generation,
    s_n: usize,
    deadline: Duration,
    all_stats: &mut Vec<WorkerStats>,
) {
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Stop);
    }
    let _ = gen.label_tx.send(Wire::Stop);
    let want = s_n.saturating_sub(1);
    let budget = (deadline * 4).max(Duration::from_secs(2));
    let t0 = Instant::now();
    let mut seen = gen.stats_seen;
    while seen < want && t0.elapsed() < budget {
        match gen.rx_driver.recv_timeout(Duration::from_millis(50)) {
            Ok(Wire::Stats(st)) => {
                all_stats.push(st);
                seen += 1;
            }
            Ok(_) => {} // snapshots/heartbeats/losses from the dying cohort
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    for h in gen.handles {
        let _ = h.join();
    }
}

/// Collect one iteration's `n_micro` losses and every stage's
/// `IterProfile` (sent after its Update). Workers cannot run ahead — the
/// next iteration's data is only fed after this returns — so all profiles
/// belong to `iter`.
fn collect_iteration(
    gen: &mut Generation,
    it: usize,
    iter: u32,
    s_n: usize,
    n_micro: usize,
    deadline: Option<Duration>,
    all_stats: &mut Vec<WorkerStats>,
) -> anyhow::Result<IterOutcome> {
    let mut sum = 0.0f32;
    let mut got_losses = 0usize;
    let mut prof = vec![(0.0f64, 0.0f64, 0.0f64, 0.0f64); s_n]; // fwd,bwd,upd,bytes
    let mut got_prof = vec![false; s_n];
    let mut n_prof = 0usize;
    while got_losses < n_micro || n_prof < s_n {
        match gen.recv_event(deadline)? {
            Event::Dead { stage, cause } => return Ok(IterOutcome::Died { stage, cause }),
            Event::Msg(Wire::Loss { loss, .. }) => {
                sum += loss;
                got_losses += 1;
            }
            Event::Msg(Wire::IterProfile {
                stage, iter: pit, fwd_s, bwd_s, update_s, bytes, ..
            }) => {
                anyhow::ensure!(
                    pit == iter && !got_prof[stage],
                    "stage {stage}: unexpected profile for iter {pit} during {it}"
                );
                prof[stage] = (fwd_s, bwd_s, update_s, bytes);
                got_prof[stage] = true;
                n_prof += 1;
            }
            Event::Msg(Wire::Stats(st)) => {
                // Natural end of the final generation overlaps the last
                // iteration's drain.
                all_stats.push(st);
                gen.stats_seen += 1;
            }
            Event::Msg(other) => anyhow::bail!("driver: unexpected {other:?}"),
        }
    }
    Ok(IterOutcome::Done { mean_loss: sum / n_micro as f32, prof })
}

/// Broadcast `Wire::Checkpoint` at an iteration boundary and collect one
/// snapshot per stage (workers reply and keep running).
fn collect_checkpoint_states(
    gen: &mut Generation,
    iter: u32,
    s_n: usize,
    deadline: Option<Duration>,
    all_stats: &mut Vec<WorkerStats>,
) -> anyhow::Result<SnapOutcome> {
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Checkpoint { iter });
    }
    let mut states: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
    let mut got = 0usize;
    while got < s_n {
        match gen.recv_event(deadline)? {
            Event::Dead { stage, cause } => return Ok(SnapOutcome::Died { stage, cause }),
            Event::Msg(Wire::Snapshot { stage, state }) => {
                if states[stage].is_none() {
                    got += 1;
                }
                states[stage] = Some(state);
            }
            Event::Msg(Wire::Stats(st)) => {
                all_stats.push(st);
                gen.stats_seen += 1;
            }
            Event::Msg(other) => anyhow::bail!("checkpoint: unexpected {other:?}"),
        }
    }
    Ok(SnapOutcome::Done(
        states.into_iter().map(|s| s.expect("counted")).collect(),
    ))
}

/// Run a full decentralized training job. Returns the report.
pub fn run(job: &Job) -> anyhow::Result<TrainReport> {
    let manifest = match job.backend {
        BackendKind::Pjrt => Manifest::load(&job.artifacts_root, &job.config)?,
        BackendKind::Null => Manifest::synthetic(null_model_cfg(&job.config)),
    };
    let cfg = manifest.config.clone();
    let mut tb = testbed::by_id(job.testbed, job.seed);
    anyhow::ensure!(
        cfg.n_stages <= tb.nodes.len(),
        "{} stages > {} devices",
        cfg.n_stages,
        tb.nodes.len()
    );

    // Stage-level OP-DAG for scheduling.
    let spec = TransformerSpec {
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_heads: cfg.n_heads,
        n_layers: cfg.n_layers,
        seq_len: cfg.seq_len,
        microbatch: cfg.microbatch,
    };
    let dag = stage_chain(&spec, cfg.n_stages);
    let mut part = match &job.placement {
        Some(devs) => {
            anyhow::ensure!(
                devs.len() == cfg.n_stages,
                "--placement needs {} device ids",
                cfg.n_stages
            );
            let chain = dag.compute_chain();
            let assign: Vec<usize> = {
                let mut a = vec![usize::MAX; dag.len()];
                for (i, &op) in chain.iter().enumerate() {
                    a[op] = devs[i];
                }
                for op in &dag.ops {
                    if matches!(op.kind, crate::opdag::OpKind::Placeholder) {
                        a[op.id] = a[op.users[0]];
                    }
                }
                a
            };
            crate::opdag::Partition::new(assign)
        }
        None => crate::scheduler::by_name(&job.scheduler)?.schedule(&dag, &tb)?,
    };
    part.validate(&dag)?;
    let mut stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    anyhow::ensure!(
        stage_plan.n_stages() == cfg.n_stages,
        "scheduler merged stages ({} of {})",
        stage_plan.n_stages(),
        cfg.n_stages
    );
    let s_n = cfg.n_stages;
    let mut devices = stage_plan.devices.clone();
    let mut plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);

    // The execution schedule both workers and the simulator interpret.
    let schedule = PipelineSchedule::new(job.pipeline, s_n, job.n_micro);
    schedule.validate()?;

    // Liveness plane: beacon interval and the death deadline.
    let hb = if job.heartbeat_s > 0.0 {
        Some(Duration::from_secs_f64(job.heartbeat_s))
    } else {
        None
    };
    let deadline = hb
        .map(|_| Duration::from_secs_f64(job.heartbeat_s * job.heartbeat_timeout.max(1) as f64));
    // The head stage answers boundary Checkpoints via its ticking label
    // receive — without heartbeats it would deadlock on the broadcast.
    anyhow::ensure!(
        job.checkpoint_every == 0 || hb.is_some(),
        "--checkpoint-every requires heartbeats (--heartbeat-interval > 0)"
    );

    // Straggler injection (test hook): the device initially hosting
    // --slow-stage runs slow for the whole job, wherever stages move.
    let slow_dev: Option<(usize, f64)> = match job.slow_stage {
        Some(s) => {
            anyhow::ensure!(s < s_n, "--slow-stage {s} out of range (stages: {s_n})");
            Some((devices[s], job.slow_factor.max(1.0)))
        }
        None => None,
    };

    // Profile feedback plane + re-planner.
    let mut store = ProfileStore::new(s_n, job.n_micro, 0.5);
    let replanner = Replanner {
        scheduler: job.scheduler.clone(),
        threshold: job.straggler_threshold,
        hysteresis: job.replan_hysteresis,
        min_samples: REPLAN_WARMUP_ITERS,
        keep_stage_count: true,
    };
    let mut snapshots: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
    let mut all_stats: Vec<WorkerStats> = Vec::new();
    // Last recommendation that was recorded but not applied — a persistent
    // straggler would otherwise append a near-duplicate event at every
    // iteration boundary (advise mode, or auto blocked by hysteresis).
    let mut last_unapplied: Option<(Vec<usize>, bool)> = None;

    let mut gen = spawn_generation(
        &manifest, job, &schedule, &devices, &plan, 0, job.iters, &mut snapshots, slow_dev, hb,
    );

    // ---- drive the training loop --------------------------------------
    let mut corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
    let mut report = TrainReport {
        config: cfg.name.clone(),
        scheduler: job.scheduler.clone(),
        compressor: match job.value_codec {
            crate::compress::ValueCodec::F32 => job.compress.name().to_string(),
            crate::compress::ValueCodec::Int8 => format!("{}+int8", job.compress.name()),
        },
        pipeline: job.pipeline.name().to_string(),
        ratio: job.ratio,
        n_micro: job.n_micro,
        placement: devices.clone(),
        ..Default::default()
    };

    let mut it = 0usize;
    let mut last_ckpt: Option<usize> = None;
    while it < job.iters {
        let iter = it as u32;
        let mut death: Option<(usize, String)> = None;

        // ---- checkpoint at the iteration boundary ---------------------
        if job.checkpoint_every > 0
            && it > 0
            && it % job.checkpoint_every == 0
            && last_ckpt != Some(it)
        {
            match collect_checkpoint_states(&mut gen, iter, s_n, deadline, &mut all_stats)? {
                SnapOutcome::Died { stage, cause } => death = Some((stage, cause)),
                SnapOutcome::Done(states) => {
                    checkpoint::save(
                        &job.checkpoint_dir,
                        &Checkpoint {
                            iter,
                            corpus_batches: corpus.batches_drawn(),
                            seed: job.seed,
                            config: cfg.name.clone(),
                            placement: devices.clone(),
                            states,
                        },
                        job.keep_checkpoints,
                    )?;
                    last_ckpt = Some(it);
                }
            }
        }

        // ---- run one iteration ----------------------------------------
        if death.is_none() {
            let t0 = Instant::now();
            for micro in 0..job.n_micro as u32 {
                let (tokens, targets) = corpus.next_batch(cfg.microbatch, cfg.seq_len);
                let r1 = gen.fwd_tx[0].send(Wire::Data { iter, micro, tokens });
                let r2 = gen.label_tx.send(Wire::Labels { iter, micro, targets });
                if deadline.is_none() {
                    // No liveness plane: a closed channel is fatal now.
                    for r in [r1, r2] {
                        r.map_err(|_| anyhow::anyhow!("workers exited mid-feed {it}"))?;
                    }
                }
                // Otherwise the deadline monitor identifies the dead stage.
            }
            match collect_iteration(
                &mut gen, it, iter, s_n, job.n_micro, deadline, &mut all_stats,
            )? {
                IterOutcome::Died { stage, cause } => death = Some((stage, cause)),
                IterOutcome::Done { mean_loss, prof } => {
                    report.losses.push(mean_loss);
                    report.wall_s.push(t0.elapsed().as_secs_f64());
                    // Real per-iteration wire bytes from the workers.
                    report.wire_bytes.push(prof.iter().map(|p| p.3).sum());
                    for (s, p) in prof.iter().enumerate() {
                        store.record_iter(s, p.0, p.1, p.2);
                    }
                    // Per-iteration simulated geo latency: the α–β network
                    // applied to the *measured* compute times under the
                    // current placement.
                    let measured = store.measured_plan(&stage_plan);
                    report
                        .sim_s
                        .push(simulate_iteration(&measured, &tb, &schedule, &plan).iter_s);
                }
            }
        }

        // ---- straggler check at the iteration boundary ----------------
        if death.is_none() {
            if job.replan != ReplanMode::Off && it + 1 < job.iters {
                let inp = ReplanInput {
                    dag: &dag,
                    testbed: &tb,
                    part: &part,
                    modeled: &stage_plan,
                    store: &store,
                    schedule: job.pipeline,
                    n_micro: job.n_micro,
                    current_compress: &plan,
                };
                let decision = replanner
                    .consider(&inp, &|p, t| compress_plan_for(job, cfg.microbatch, &dag, p, t))?;
                if let Some(d) = decision {
                    let apply = d.adopt && job.replan == ReplanMode::Auto;
                    let skip = if !apply {
                        let key = (d.candidate.plan.devices.clone(), d.adopt);
                        let same = last_unapplied.as_ref() == Some(&key);
                        if !same {
                            last_unapplied = Some(key);
                        }
                        same // same recommendation as last time
                    } else {
                        last_unapplied = None;
                        false
                    };
                    if !skip {
                        let mut ev = ReplanEvent {
                            iter: it + 1,
                            from: devices.clone(),
                            to: d.candidate.plan.devices.clone(),
                            flagged: d.flagged.clone(),
                            origin: d.candidate.origin.to_string(),
                            sim_before_s: d.current_sim_s,
                            sim_after_s: d.candidate_sim_s,
                            migration_s: d.migration_s,
                            applied: apply,
                        };
                        if apply {
                            let t_mig = Instant::now();
                            teardown(gen, s_n, &mut snapshots, &mut all_stats, hb.is_some())?;
                            part = d.candidate.partition.clone();
                            stage_plan = StagePlan::from_partition(&dag, &part, &tb);
                            anyhow::ensure!(
                                stage_plan.n_stages() == s_n,
                                "replan changed the stage count"
                            );
                            // Measurements for moved stages describe old
                            // silicon.
                            for s in 0..s_n {
                                if stage_plan.devices[s] != devices[s] {
                                    store.reset_stage(s);
                                }
                            }
                            devices = stage_plan.devices.clone();
                            plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);
                            gen = spawn_generation(
                                &manifest,
                                job,
                                &schedule,
                                &devices,
                                &plan,
                                iter + 1,
                                job.iters - (it + 1),
                                &mut snapshots,
                                slow_dev,
                                hb,
                            );
                            ev.migration_s = t_mig.elapsed().as_secs_f64();
                        }
                        report.replans.push(ev);
                    }
                }
            }
            it += 1;
            continue;
        }

        // ---- crash recovery -------------------------------------------
        let (dead_stage, cause) = death.expect("checked above");
        let dead_dev = gen.devices[dead_stage];
        let Some(dl) = deadline else {
            // No liveness plane (heartbeats disabled): abort as in PR 3.
            // Workers exit on their own once the broker drops the
            // generation's channels; they cannot be joined safely here.
            anyhow::bail!("stage {dead_stage} failed: {cause}");
        };
        eprintln!(
            "broker: stage {dead_stage} (device {dead_dev}) declared dead during \
             iteration {it}: {cause}"
        );
        let t_replan = Instant::now();
        tb.fail_node(dead_dev);
        churn_teardown(gen, s_n, dl, &mut all_stats);
        anyhow::ensure!(
            job.replan == ReplanMode::Auto,
            "stage {dead_stage} (device {dead_dev}) died during iteration {it} ({cause}); \
             crash recovery requires --replan auto"
        );
        anyhow::ensure!(
            report.recoveries.len() < MAX_RECOVERIES,
            "giving up after {MAX_RECOVERIES} crash recoveries"
        );
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &stage_plan,
            store: &store,
            schedule: job.pipeline,
            n_micro: job.n_micro,
            current_compress: &plan,
        };
        let cand = replanner.replan_after_failure(&inp, dead_stage)?;
        anyhow::ensure!(
            cand.plan.n_stages() == s_n,
            "failover changed the stage count"
        );
        let from = devices.clone();
        part = cand.partition.clone();
        stage_plan = cand.plan.clone();
        devices = stage_plan.devices.clone();
        plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);
        for s in 0..s_n {
            store.reset_stage(s);
        }
        let replan_s = t_replan.elapsed().as_secs_f64();

        // Restore the newest valid checkpoint — or restart from scratch.
        let t_restore = Instant::now();
        let mut init: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
        let (resume_iter, corpus_batches) = if job.checkpoint_every > 0 {
            // Only boundaries this run has already passed are restorable;
            // newer leftovers (a prior completed run sharing the
            // directory) are skipped by the version walk.
            match checkpoint::load_latest_at_or_before(&job.checkpoint_dir, iter)? {
                Some(ck) => {
                    anyhow::ensure!(
                        ck.config == cfg.name && ck.seed == job.seed,
                        "checkpoint belongs to another run (config `{}`, seed {:#x})",
                        ck.config,
                        ck.seed
                    );
                    anyhow::ensure!(
                        ck.states.len() == s_n && (ck.iter as usize) <= it,
                        "checkpoint shape/iteration mismatch"
                    );
                    for (s, st) in ck.states.into_iter().enumerate() {
                        if !st.params.is_empty() {
                            init[s] = Some(st);
                        }
                    }
                    (ck.iter as usize, ck.corpus_batches)
                }
                None => (0, 0),
            }
        } else {
            (0, 0)
        };
        // Rewind the data loader to the checkpoint cursor and roll the
        // report back — the re-run iterations rewrite their entries
        // deterministically.
        corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
        corpus.advance_to(corpus_batches, cfg.microbatch, cfg.seq_len)?;
        report.losses.truncate(resume_iter);
        report.wall_s.truncate(resume_iter);
        report.sim_s.truncate(resume_iter);
        report.wire_bytes.truncate(resume_iter);
        for sn in snapshots.iter_mut() {
            *sn = None;
        }
        last_unapplied = None;
        gen = spawn_generation(
            &manifest,
            job,
            &schedule,
            &devices,
            &plan,
            resume_iter as u32,
            job.iters - resume_iter,
            &mut init,
            slow_dev,
            hb,
        );
        let restore_s = t_restore.elapsed().as_secs_f64();
        report.recoveries.push(RecoveryEvent {
            died_iter: it,
            stage: dead_stage,
            device: dead_dev,
            cause,
            resume_iter,
            iters_lost: it - resume_iter,
            from,
            to: devices.clone(),
            origin: cand.origin.to_string(),
            replan_s,
            restore_s,
        });
        last_ckpt = Some(resume_iter);
        it = resume_iter;
    }

    // ---- drain the final generation ------------------------------------
    teardown(gen, s_n, &mut snapshots, &mut all_stats, hb.is_some())?;
    report.placement = devices;

    // Achieved wire compression (dense payload bytes / wire bytes).
    let total_bytes: f64 = all_stats.iter().map(|s| s.bytes_sent).sum();
    let total_dense: f64 = all_stats.iter().map(|s| s.dense_bytes).sum();
    report.wire_shrink = if total_bytes > 0.0 { total_dense / total_bytes } else { 1.0 };

    Ok(report)
}
