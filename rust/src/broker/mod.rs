//! The broker / IR plane (§3.2): receives a job definition, builds the
//! stage-level OP-DAG, schedules it onto the testbed, derives the
//! compression plan, spawns the CompNode workers, feeds data, and collects
//! losses + statistics into a `TrainReport`.
//!
//! The runtime is adaptive: workers stream per-iteration `IterProfile`
//! measurements back to the broker; a `ProfileStore` maintains EWMA
//! per-stage times; when the straggler detector flags a stage and
//! `--replan auto` is set, the `Replanner` re-runs the scheduler with
//! measured (not modeled) compute times and — if the simulated iteration
//! improves past the hysteresis margin — the broker re-partitions at the
//! next iteration boundary: workers are stopped, their `StageState`
//! (params + optimizer moments) is snapshotted, links/codecs are rebuilt
//! for the new placement, and a fresh worker generation resumes at the
//! same global iteration.
//!
//! The runtime is also churn-tolerant: workers heartbeat on a
//! configurable interval, a deadline monitor in the broker's event loop
//! declares a silent stage dead (missed beacons, channel loss, or
//! `Wire::Fatal`), and — under `--replan auto` — the broker marks the
//! device failed in the `NetGraph`, re-partitions across survivors with
//! `Replanner::replan_after_failure`, restores the newest valid
//! checkpoint (broadcast `Wire::Checkpoint` every `--checkpoint-every`
//! iterations, persisted via the `checkpoint` module), rewinds the data
//! loader and resumes. Every event lands in `TrainReport.recoveries`.
//!
//! The worker plane is transport-pluggable (`--transport chan|tcp`):
//! chan spawns in-process threads over mpsc lanes; tcp listens, accepts
//! an authenticated pool of `fusionllm worker` processes, ships each
//! generation as serialized `StageAssign`s (ready-barrier handshake) and
//! relays inter-stage packets between connections — with per-connection
//! socket read deadlines feeding the same death/recovery machinery.

pub mod churn;
pub mod job;

pub use churn::{ChurnAction, ChurnEvent, ChurnTrace};
pub use job::Job;

use crate::checkpoint::{self, Checkpoint};
use crate::cluster::{testbed, Testbed};
use crate::compress::{CompressKind, CompressPlan};
use crate::cost::{PipelineParams, ProfileStore};
use crate::opdag::builders::{stage_chain, TransformerSpec};
use crate::opdag::{Dag, Partition};
use crate::pipeline::PipelineSchedule;
use crate::runtime::{Manifest, ModelCfg};
use crate::scheduler::replan::{ReplanInput, ReplanMode, Replanner};
use crate::simnet::{simulate_iteration, StagePlan};
use crate::trainer::{JoinEvent, RecoveryEvent, ReplanEvent, SyntheticCorpus, TrainReport};
use crate::transport::tcp::{MonitorCfg, StageAssign, TcpPlane};
use crate::transport::{chan, DataPlane, Link, PacketPool, TransportKind};
use crate::worker::{
    spawn_stage, BackendKind, LinkSpec, StageCodec, StageCtx, StageState, Wire, WorkerStats,
};
use crate::opdag::data::OpDataKind;
use std::net::TcpListener;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

/// Iterations of measured profile required before the first replan check.
const REPLAN_WARMUP_ITERS: usize = 3;

/// Hard cap on crash recoveries per run (a persistently failing cluster
/// must eventually surface as an error, not an infinite restart loop).
const MAX_RECOVERIES: usize = 8;

/// How long the broker waits at a scripted join/rejoin boundary for a
/// worker process to claim the admitted device (tcp transport).
const ADMIT_TIMEOUT: Duration = Duration::from_secs(120);

/// Where a stage of the current generation executes.
enum Port {
    /// In-process worker thread (`ChanTransport`).
    Thread(std::thread::JoinHandle<anyhow::Result<()>>),
    /// Remote worker process over a TCP connection.
    Remote,
}

/// One cohort of stage workers sharing a set of transport lanes.
/// Re-partitioning tears a generation down (collecting state snapshots)
/// and starts the next one on the new placement — spawning threads in
/// chan mode, shipping `StageAssign`s to worker processes in tcp mode.
struct Generation {
    ports: Vec<Port>,
    /// Broker-held links into every stage's forward input (stage 0 gets
    /// Data; the rest are reachable for Stop/Checkpoint broadcast).
    fwd_tx: Vec<Box<dyn Link>>,
    label_tx: Box<dyn Link>,
    rx_driver: Receiver<Wire>,
    /// Stats messages already collected from this generation.
    stats_seen: usize,
    /// Device per stage (dead-stage attribution).
    devices: Vec<usize>,
    /// Liveness: last instant each stage was heard from (any message).
    last_seen: Vec<Instant>,
    /// Whether a stage has sent anything yet — before first contact the
    /// deadline gets a grace multiplier (backend init may be slow).
    heard: Vec<bool>,
    /// First-contact deadline multiplier (`--heartbeat-grace`).
    grace: u32,
    /// Any stage runs out-of-process (bounds the teardown drains: remote
    /// driver lanes never disconnect on their own).
    remote: bool,
}

/// A driver-plane event: a protocol message, or a stage declared dead
/// (fatal error, channel loss, socket read deadline, or heartbeat
/// deadline expiry).
enum Event {
    Msg(Wire),
    Dead { stage: usize, cause: String },
}

impl Generation {
    fn note(&mut self, stage: usize) {
        if stage < self.last_seen.len() {
            self.last_seen[stage] = Instant::now();
            self.heard[stage] = true;
        }
    }

    /// Stage a message originates from, for liveness attribution.
    fn stage_of(msg: &Wire, s_n: usize) -> Option<usize> {
        match msg {
            Wire::Loss { .. } => Some(s_n - 1),
            Wire::IterProfile { stage, .. }
            | Wire::Snapshot { stage, .. }
            | Wire::SnapshotDelta { stage, .. }
            | Wire::Heartbeat { stage, .. }
            | Wire::Fatal { stage, .. } => Some(*stage),
            Wire::Stats(st) => Some(st.stage),
            _ => None,
        }
    }

    /// Stage furthest past its (grace-adjusted) deadline, if any.
    fn expired_stage(&self, dl: Duration) -> Option<(usize, Duration)> {
        let worst = (0..self.last_seen.len())
            .map(|s| {
                let limit = if self.heard[s] { dl } else { dl * self.grace.max(1) };
                let age = self.last_seen[s].elapsed();
                (s, age, age.as_secs_f64() - limit.as_secs_f64())
            })
            .max_by(|a, b| a.2.partial_cmp(&b.2).unwrap())?;
        if worst.2 > 0.0 {
            Some((worst.0, worst.1))
        } else {
            None
        }
    }

    /// Next driver-plane message. Heartbeats are swallowed (they only
    /// refresh deadlines). With a deadline, the receive ticks and the
    /// per-stage deadlines are checked on *every* pass — before each
    /// receive, not just on a silent tick, so survivors' beacon traffic
    /// cannot starve the death check while a dead stage stalls the run.
    /// Without a deadline this is the PR 3 blocking receive.
    fn recv_event(&mut self, deadline: Option<Duration>) -> anyhow::Result<Event> {
        let s_n = self.last_seen.len();
        loop {
            let msg = match deadline {
                None => self
                    .rx_driver
                    .recv()
                    .map_err(|_| anyhow::anyhow!("all workers exited unexpectedly"))?,
                Some(dl) => {
                    if let Some((stage, age)) = self.expired_stage(dl) {
                        return Ok(Event::Dead {
                            stage,
                            cause: format!("no heartbeat for {:.2}s", age.as_secs_f64()),
                        });
                    }
                    let tick = (dl / 4)
                        .max(Duration::from_millis(5))
                        .min(Duration::from_millis(250));
                    match self.rx_driver.recv_timeout(tick) {
                        Ok(m) => m,
                        Err(RecvTimeoutError::Disconnected) => {
                            anyhow::bail!("all workers exited unexpectedly")
                        }
                        Err(RecvTimeoutError::Timeout) => continue,
                    }
                }
            };
            // Over TCP the stage field is network input: an out-of-range
            // value (version skew, buggy worker) must not index-panic the
            // broker — drop the message instead.
            if let Some(stage) = Self::stage_of(&msg, s_n) {
                if stage >= s_n {
                    eprintln!("broker: dropping message with out-of-range stage {stage}");
                    continue;
                }
                self.note(stage);
            }
            match msg {
                Wire::Heartbeat { .. } => continue,
                Wire::Fatal { stage, error } => {
                    return Ok(Event::Dead { stage, cause: format!("fatal: {error}") })
                }
                m => return Ok(Event::Msg(m)),
            }
        }
    }
}

/// How one iteration's collection ended.
enum IterOutcome {
    Done { mean_loss: f32, prof: Vec<(f64, f64, f64, f64)> },
    Died { stage: usize, cause: String },
}

/// How a checkpoint snapshot collection ended.
enum SnapOutcome {
    Done(Vec<StageState>),
    Died { stage: usize, cause: String },
}

/// The transport plane a run executes over: in-process threads + mpsc
/// lanes, or the TCP listener with its accepted worker-process pool.
enum Plane {
    Chan,
    Tcp(TcpPlane),
}

/// Build the compression plan for a (partition, testbed) pair per the
/// job's knobs — also used by the re-planner to cost candidate plans.
fn compress_plan_for(
    job: &Job,
    micro_size: usize,
    dag: &Dag,
    part: &Partition,
    tb: &Testbed,
) -> CompressPlan {
    let params = PipelineParams { n_micro: job.n_micro, micro_size, include_bwd: true };
    let mut plan = match job.compress {
        // `--compress none --wire-codec int8` = dense int8 (1 B/value).
        CompressKind::None => {
            CompressPlan::dense(tb.nodes.len()).with_value_codec(job.value_codec)
        }
        CompressKind::AdaTopK => CompressPlan::adatopk_with_codec(
            dag,
            part,
            tb,
            params,
            job.ratio,
            job.value_codec,
        ),
        kind => {
            CompressPlan::uniform(kind, job.ratio, tb.nodes.len())
                .with_value_codec(job.value_codec)
        }
    };
    plan.direction = job.direction;
    plan
}

/// Per-stage knobs for one generation. Both generation builders (chan
/// threads and tcp `StageAssign`s) MUST derive these identically — the
/// chan-vs-tcp bitwise differential rests on it — so there is exactly
/// one derivation.
struct StageParams {
    next_device: Option<usize>,
    prev_device: Option<usize>,
    /// Straggler injection factor (1.0 = off).
    slow_factor: f64,
    /// Null-backend pacing (`--pace`).
    pace_s: f64,
    /// Churn injector: the earliest scripted kill of this stage's device
    /// at or after the generation's first iteration. Exact-iteration
    /// matching in the interpreter makes re-arming across restores a
    /// deterministic replay — a kill that already fired can only re-fire
    /// if the run rewinds past it, and then it must.
    kill_at_iter: Option<u32>,
    param_seed: u64,
}

fn stage_params(
    job: &Job,
    churn: Option<&ChurnTrace>,
    devices: &[usize],
    s: usize,
    iter0: u32,
    slow_dev: Option<(usize, f64)>,
) -> StageParams {
    let device = devices[s];
    StageParams {
        next_device: devices.get(s + 1).copied(),
        prev_device: if s > 0 { Some(devices[s - 1]) } else { None },
        slow_factor: match slow_dev {
            Some((dev, f)) if dev == device => f,
            _ => 1.0,
        },
        pace_s: job.pace_s.max(0.0),
        kill_at_iter: churn.and_then(|t| t.next_kill(device, iter0)),
        param_seed: job.seed.wrapping_add(s as u64),
    }
}

/// Spawn one in-process (chan transport) worker generation on `devices`,
/// executing iterations `[iter0, iter0 + iters)` of `schedule`. `init`
/// entries are taken (and consumed) as migrated/restored state for the
/// matching stage.
#[allow(clippy::too_many_arguments)]
fn spawn_generation(
    manifest: &Manifest,
    job: &Job,
    churn: Option<&ChurnTrace>,
    schedule: &PipelineSchedule,
    devices: &[usize],
    plan: &CompressPlan,
    iter0: u32,
    iters: usize,
    init: &mut [Option<StageState>],
    slow_dev: Option<(usize, f64)>,
    heartbeat: Option<Duration>,
) -> Generation {
    let s_n = devices.len();
    let cfg = &manifest.config;
    let (tx_driver, rx_driver) = mpsc::channel::<Wire>();
    let mut fwd_tx = Vec::new();
    let mut fwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        fwd_tx.push(t);
        fwd_rx.push(Some(r));
    }
    let mut bwd_tx = Vec::new();
    let mut bwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        bwd_tx.push(t);
        bwd_rx.push(Some(r));
    }
    let (label_tx, label_rx) = mpsc::channel::<Wire>();
    let mut label_rx = Some(label_rx);

    // Per-link wire codecs: ratios keyed by the receiving device (Eq. 7),
    // scratch owned for the life of the link. Built up front so each
    // receiving stage can hold its upstream encoder's packet free-list
    // (drained buffers cycle back — the zero-allocation send path).
    let mut codecs: Vec<Option<StageCodec>> = (0..s_n)
        .map(|s| {
            let next_device = devices.get(s + 1).copied();
            let prev_device = if s > 0 { Some(devices[s - 1]) } else { None };
            Some(StageCodec::from_plan(plan, next_device, prev_device, cfg.d_model))
        })
        .collect();
    let fwd_pools: Vec<Option<PacketPool>> = codecs
        .iter()
        .map(|c| c.as_ref().unwrap().fwd.as_ref().map(|e| e.pool()))
        .collect();
    let bwd_pools: Vec<Option<PacketPool>> = codecs
        .iter()
        .map(|c| c.as_ref().unwrap().bwd.as_ref().map(|e| e.pool()))
        .collect();

    let mut ports = Vec::new();
    for s in 0..s_n {
        let p = stage_params(job, churn, devices, s, iter0, slow_dev);
        let ctx = StageCtx {
            stage: s,
            n_stages: s_n,
            device: devices[s],
            next_device: p.next_device,
            prev_device: p.prev_device,
            manifest: manifest.clone(),
            codec: codecs[s].take().unwrap(),
            tasks: schedule.tasks[s].clone(),
            iter0,
            iters,
            n_micro: job.n_micro,
            lr: job.lr,
            momentum: job.momentum,
            optimizer: job.optimizer.clone(),
            param_seed: p.param_seed,
            init_state: init[s].take(),
            slow_factor: p.slow_factor,
            pace_s: p.pace_s,
            backend: job.backend,
            heartbeat,
            kill_at_iter: p.kill_at_iter,
            overlap: job.overlap,
            link_delay_s: job.link_delay_s,
            rx_fwd: chan::endpoint(fwd_rx[s].take().unwrap()),
            rx_bwd: if s + 1 < s_n {
                bwd_rx[s].take().map(chan::endpoint)
            } else {
                None
            },
            tx_fwd: if s + 1 < s_n { Some(chan::link(fwd_tx[s + 1].clone())) } else { None },
            tx_bwd: if s > 0 { Some(chan::link(bwd_tx[s - 1].clone())) } else { None },
            rx_labels: if s == s_n - 1 {
                label_rx.take().map(chan::endpoint)
            } else {
                None
            },
            tx_driver: chan::link(tx_driver.clone()),
            fwd_return: if s > 0 { fwd_pools[s - 1].clone() } else { None },
            bwd_return: if s + 1 < s_n { bwd_pools[s + 1].clone() } else { None },
        };
        ports.push(Port::Thread(spawn_stage(ctx)));
    }
    // The broker keeps no tx_driver clone: the channel closes when the
    // last worker of the generation exits.
    drop(tx_driver);
    Generation {
        ports,
        fwd_tx: fwd_tx.into_iter().map(chan::link).collect(),
        label_tx: chan::link(label_tx),
        rx_driver,
        stats_seen: 0,
        devices: devices.to_vec(),
        last_seen: vec![Instant::now(); s_n],
        heard: vec![false; s_n],
        grace: job.heartbeat_grace.max(1),
        remote: false,
    }
}

/// Start one generation on remote worker processes: build each stage's
/// `StageAssign` (the serialized `StagePlan` + `StageCodec` config of the
/// handshake), route it to the worker connection owning the device, and
/// pass the ready barrier. The interpreter running in those processes is
/// the same one the chan path runs in threads.
#[allow(clippy::too_many_arguments)]
fn assign_generation(
    plane: &mut TcpPlane,
    manifest: &Manifest,
    job: &Job,
    churn: Option<&ChurnTrace>,
    schedule: &PipelineSchedule,
    devices: &[usize],
    plan: &CompressPlan,
    iter0: u32,
    iters: usize,
    init: &mut [Option<StageState>],
    slow_dev: Option<(usize, f64)>,
    deadline: Duration,
) -> anyhow::Result<Generation> {
    let s_n = devices.len();
    let cfg = &manifest.config;
    // Mesh data plane: snapshot each placed worker's advertised peer
    // listener into this generation's route table, stamped with a fresh
    // generation id so stale dials from torn-down generations are
    // rejected at the peer listener. Replan/join/rejoin boundaries pass
    // through here, so membership changes re-issue routes automatically.
    let (mesh_gen, peers) = if job.data_plane == DataPlane::Mesh {
        let mut peers = Vec::with_capacity(s_n);
        for (s, &dev) in devices.iter().enumerate() {
            let addr = plane.peer_addr(dev).ok_or_else(|| {
                anyhow::anyhow!(
                    "mesh data plane: device {dev} advertised no peer listener \
                     (start its worker with --peer-listen)"
                )
            })?;
            peers.push((s, addr));
        }
        (plane.next_mesh_gen(), peers)
    } else {
        (0, Vec::new())
    };
    let mut assigns = Vec::with_capacity(s_n);
    for s in 0..s_n {
        let p = stage_params(job, churn, devices, s, iter0, slow_dev);
        assigns.push(StageAssign {
            stage: s,
            n_stages: s_n,
            device: devices[s],
            next_device: p.next_device,
            prev_device: p.prev_device,
            config: cfg.name.clone(),
            backend: job.backend,
            optimizer: job.optimizer.clone(),
            chunk: cfg.d_model,
            fwd: p.next_device.map(|d| LinkSpec::from_plan(plan, d, OpDataKind::Activation)),
            bwd: p.prev_device.map(|d| LinkSpec::from_plan(plan, d, OpDataKind::Gradient)),
            tasks: schedule.tasks[s].clone(),
            iter0,
            iters,
            n_micro: job.n_micro,
            lr: job.lr,
            momentum: job.momentum,
            param_seed: p.param_seed,
            slow_factor: p.slow_factor,
            pace_s: p.pace_s,
            heartbeat_s: job.heartbeat_s,
            kill_at_iter: p.kill_at_iter,
            init_state: init[s].take(),
            mesh_gen,
            peers: peers.clone(),
            overlap: job.overlap,
            link_delay_s: job.link_delay_s,
            mesh_window: job.mesh_window,
        });
    }
    let ready_timeout = (deadline * job.heartbeat_grace.max(1)).max(Duration::from_secs(5));
    let (rx_driver, fwd_tx, label_tx) = plane.begin_generation(devices, assigns, ready_timeout)?;
    Ok(Generation {
        ports: (0..s_n).map(|_| Port::Remote).collect(),
        fwd_tx,
        label_tx,
        rx_driver,
        stats_seen: 0,
        devices: devices.to_vec(),
        last_seen: vec![Instant::now(); s_n],
        heard: vec![false; s_n],
        grace: job.heartbeat_grace.max(1),
        remote: true,
    })
}

/// Start a generation over whichever plane the job runs on.
#[allow(clippy::too_many_arguments)]
fn start_generation(
    plane: &mut Plane,
    manifest: &Manifest,
    job: &Job,
    churn: Option<&ChurnTrace>,
    schedule: &PipelineSchedule,
    devices: &[usize],
    plan: &CompressPlan,
    iter0: u32,
    iters: usize,
    init: &mut [Option<StageState>],
    slow_dev: Option<(usize, f64)>,
    hb: Option<Duration>,
    deadline: Option<Duration>,
) -> anyhow::Result<Generation> {
    match plane {
        Plane::Chan => Ok(spawn_generation(
            manifest, job, churn, schedule, devices, plan, iter0, iters, init, slow_dev, hb,
        )),
        Plane::Tcp(p) => assign_generation(
            p,
            manifest,
            job,
            churn,
            schedule,
            devices,
            plan,
            iter0,
            iters,
            init,
            slow_dev,
            deadline.expect("tcp transport requires heartbeats"),
        ),
    }
}

/// Stop a generation at an iteration boundary (workers are blocked on
/// their first recv of the next iteration), collect state snapshots and
/// remaining stats, and join the threads (chan) / leave the worker
/// processes idling for the next Assign (tcp). Also used as the
/// end-of-run drain, where the Stop sends land on already-dropped
/// receivers.
///
/// All threads are joined on every path. Worker errors are reported
/// *after* the join, so a failing run can no longer leak detached threads
/// — except when `join_always` is false (heartbeats disabled): a worker
/// blocked on a dead neighbor cannot observe Stop without ticking
/// receives, so a Fatal aborts immediately as in PR 3.
fn teardown(
    plane: &mut Plane,
    gen: Generation,
    s_n: usize,
    snapshots: &mut [Option<StageState>],
    all_stats: &mut Vec<WorkerStats>,
    join_always: bool,
    deadline: Option<Duration>,
) -> anyhow::Result<()> {
    // Between generations remote workers are legitimately silent: disarm
    // the socket deadline monitors before they misread the quiet.
    if let Plane::Tcp(p) = plane {
        p.monitor_off();
    }
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Stop);
    }
    let _ = gen.label_tx.send(Wire::Stop);
    let mut seen = gen.stats_seen;
    let mut first_err: Option<String> = None;
    // Remote driver lanes never disconnect on their own (the plane holds
    // a sender), so the drain is budgeted instead of open-ended.
    let budget = gen
        .remote
        .then(|| (deadline.unwrap_or_default() * 4).max(Duration::from_secs(10)));
    let t0 = Instant::now();
    while seen < s_n {
        let msg = match budget {
            None => gen.rx_driver.recv().map_err(|_| ()),
            Some(b) => match gen.rx_driver.recv_timeout(Duration::from_millis(100)) {
                Ok(m) => Ok(m),
                Err(RecvTimeoutError::Disconnected) => Err(()),
                Err(RecvTimeoutError::Timeout) => {
                    if t0.elapsed() > b {
                        if first_err.is_none() {
                            first_err = Some(format!(
                                "teardown drain: {seen}/{s_n} worker stats after {:.1}s",
                                b.as_secs_f64()
                            ));
                        }
                        break;
                    }
                    continue;
                }
            },
        };
        match msg {
            Ok(Wire::Stats(st)) => {
                all_stats.push(st);
                seen += 1;
            }
            Ok(Wire::Snapshot { stage, state }) => {
                // `stage` is network input over TCP: bounds-check it.
                if let Some(slot) = snapshots.get_mut(stage) {
                    *slot = Some(state);
                }
            }
            Ok(Wire::Fatal { stage, error }) => {
                let msg = format!("stage {stage} failed: {error}");
                if !join_always {
                    anyhow::bail!(msg);
                }
                if first_err.is_none() {
                    first_err = Some(msg);
                }
            }
            Ok(_) => {} // stale losses/profiles/heartbeats from the stopped iteration
            Err(()) => break, // all workers exited (join reports errors)
        }
    }
    if let Plane::Tcp(p) = plane {
        // Post-drain stragglers must not leak into the next generation.
        p.clear_driver();
    }
    for p in gen.ports {
        let Port::Thread(h) = p else { continue };
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if first_err.is_none() {
                    first_err = Some(format!("worker failed: {e:#}"));
                }
            }
            Err(_) => {
                if first_err.is_none() {
                    first_err = Some("worker panicked".into());
                }
            }
        }
    }
    match first_err {
        Some(e) => anyhow::bail!(e),
        None => Ok(()),
    }
}

/// Tear down a generation that contains `n_dead` dead stages: broadcast
/// Stop, drain whatever the survivors still send (bounded by a drain
/// budget — the dead stages send nothing), then join every thread.
/// Survivors observe Stop even when blocked on a dead neighbor because
/// their ticking receives poll the forward link, so the join cannot hang.
/// Remote survivors park awaiting the recovery generation's Assign.
fn churn_teardown(
    plane: &mut Plane,
    gen: Generation,
    s_n: usize,
    deadline: Duration,
    all_stats: &mut Vec<WorkerStats>,
    n_dead: usize,
) {
    if let Plane::Tcp(p) = plane {
        p.monitor_off();
    }
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Stop);
    }
    let _ = gen.label_tx.send(Wire::Stop);
    let want = s_n.saturating_sub(n_dead.max(1));
    let budget = (deadline * 4).max(Duration::from_secs(2));
    let t0 = Instant::now();
    let mut seen = gen.stats_seen;
    while seen < want && t0.elapsed() < budget {
        match gen.rx_driver.recv_timeout(Duration::from_millis(50)) {
            Ok(Wire::Stats(st)) => {
                all_stats.push(st);
                seen += 1;
            }
            Ok(_) => {} // snapshots/heartbeats/losses from the dying cohort
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    if let Plane::Tcp(p) = plane {
        p.clear_driver();
    }
    for p in gen.ports {
        if let Port::Thread(h) = p {
            let _ = h.join();
        }
    }
}

/// A teardown or generation start failed mid-migration. If the failure
/// traces to dead worker connections, convert it into the churn the
/// recovery path handles: `(stage, device, cause)` triples against the
/// placement that was being started. Otherwise propagate the original
/// error — on the chan plane a teardown with a dead thread succeeds
/// silently, so an error there is a real worker bug, not churn.
fn migration_deaths(
    e: anyhow::Error,
    plane: &Plane,
    devices: &[usize],
) -> anyhow::Result<Vec<(usize, usize, String)>> {
    let mut dead = Vec::new();
    if let Plane::Tcp(p) = plane {
        for d in p.dead_devices() {
            if let Some(s) = devices.iter().position(|&x| x == d) {
                dead.push((s, d, format!("died during migration: {e:#}")));
            }
        }
    }
    if dead.is_empty() {
        return Err(e);
    }
    Ok(dead)
}

/// Collect one iteration's `n_micro` losses and every stage's
/// `IterProfile` (sent after its Update). Workers cannot run ahead — the
/// next iteration's data is only fed after this returns — so all profiles
/// belong to `iter`.
fn collect_iteration(
    gen: &mut Generation,
    it: usize,
    iter: u32,
    s_n: usize,
    n_micro: usize,
    deadline: Option<Duration>,
    all_stats: &mut Vec<WorkerStats>,
) -> anyhow::Result<IterOutcome> {
    let mut sum = 0.0f32;
    let mut got_losses = 0usize;
    let mut prof = vec![(0.0f64, 0.0f64, 0.0f64, 0.0f64); s_n]; // fwd,bwd,upd,bytes
    let mut got_prof = vec![false; s_n];
    let mut n_prof = 0usize;
    while got_losses < n_micro || n_prof < s_n {
        match gen.recv_event(deadline)? {
            Event::Dead { stage, cause } => return Ok(IterOutcome::Died { stage, cause }),
            Event::Msg(Wire::Loss { loss, .. }) => {
                sum += loss;
                got_losses += 1;
            }
            Event::Msg(Wire::IterProfile {
                stage, iter: pit, fwd_s, bwd_s, update_s, bytes, ..
            }) => {
                anyhow::ensure!(
                    pit == iter && !got_prof[stage],
                    "stage {stage}: unexpected profile for iter {pit} during {it}"
                );
                prof[stage] = (fwd_s, bwd_s, update_s, bytes);
                got_prof[stage] = true;
                n_prof += 1;
            }
            Event::Msg(Wire::Stats(st)) => {
                // Natural end of the final generation overlaps the last
                // iteration's drain.
                all_stats.push(st);
                gen.stats_seen += 1;
            }
            Event::Msg(other) => anyhow::bail!("driver: unexpected {other:?}"),
        }
    }
    Ok(IterOutcome::Done { mean_loss: sum / n_micro as f32, prof })
}

/// Broadcast `Wire::Checkpoint` at an iteration boundary and collect one
/// snapshot per stage (workers reply and keep running).
///
/// `base` is the broker's last saved, fully materialized version. Its
/// iteration rides in the broadcast as the acknowledged base: a worker
/// whose retained shadow matches it answers with `Wire::SnapshotDelta`
/// (only changed values on the wire), which is materialized here against
/// the base copy so the returned states are always full. Workers without
/// a matching shadow (fresh or respawned generations) answer with full
/// `Wire::Snapshot`s exactly as before.
fn collect_checkpoint_states(
    gen: &mut Generation,
    iter: u32,
    s_n: usize,
    base: Option<(u32, &[StageState])>,
    deadline: Option<Duration>,
    all_stats: &mut Vec<WorkerStats>,
) -> anyhow::Result<SnapOutcome> {
    let base_iter = base.map(|(b, _)| b);
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Checkpoint { iter, base: base_iter });
    }
    let mut states: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
    let mut got = 0usize;
    while got < s_n {
        match gen.recv_event(deadline)? {
            Event::Dead { stage, cause } => return Ok(SnapOutcome::Died { stage, cause }),
            Event::Msg(Wire::Snapshot { stage, state }) => {
                if states[stage].is_none() {
                    got += 1;
                }
                states[stage] = Some(state);
            }
            Event::Msg(Wire::SnapshotDelta { stage, base_iter: b, blob }) => {
                let Some((ack, base_states)) = base else {
                    anyhow::bail!(
                        "checkpoint: stage {stage} sent a delta but no base was offered"
                    )
                };
                anyhow::ensure!(
                    b == ack && base_states.len() == s_n,
                    "checkpoint: stage {stage} delta against version {b}, base is {ack}"
                );
                let full =
                    checkpoint::apply_stage_delta(stage, iter, &base_states[stage], &blob)?;
                if states[stage].is_none() {
                    got += 1;
                }
                states[stage] = Some(full);
            }
            Event::Msg(Wire::Stats(st)) => {
                all_stats.push(st);
                gen.stats_seen += 1;
            }
            Event::Msg(other) => anyhow::bail!("checkpoint: unexpected {other:?}"),
        }
    }
    Ok(SnapOutcome::Done(
        states.into_iter().map(|s| s.expect("counted")).collect(),
    ))
}

/// Run a full decentralized training job. Returns the report.
pub fn run(job: &Job) -> anyhow::Result<TrainReport> {
    run_with_listener(job, None)
}

/// `run` over an externally bound TCP listener (tests bind port 0 and
/// must know the address before workers can connect; ignored — and
/// rejected — under the chan transport).
pub fn run_with_listener(
    job: &Job,
    listener: Option<TcpListener>,
) -> anyhow::Result<TrainReport> {
    let manifest = match job.backend {
        BackendKind::Pjrt => Manifest::load(&job.artifacts_root, &job.config)?,
        BackendKind::Null => Manifest::synthetic(ModelCfg::null_sim(&job.config)),
    };
    let cfg = manifest.config.clone();

    // Liveness plane: beacon interval and the death deadline.
    let hb = if job.heartbeat_s > 0.0 {
        Some(Duration::from_secs_f64(job.heartbeat_s))
    } else {
        None
    };
    let deadline = hb
        .map(|_| Duration::from_secs_f64(job.heartbeat_s * job.heartbeat_timeout.max(1) as f64));

    let mut tb = testbed::by_id(job.testbed, job.seed);
    anyhow::ensure!(
        cfg.n_stages <= tb.nodes.len(),
        "{} stages > {} devices",
        cfg.n_stages,
        tb.nodes.len()
    );

    // Scripted membership: the ordered kill/join/rejoin trace (or the
    // legacy --kill-node pair folded into one). Scripted joiners are
    // unavailable until their join iteration — pre-fail them so neither
    // the initial placement nor the failover re-planner can use them.
    let churn = job.effective_churn()?;
    if let Some(t) = &churn {
        for ev in &t.events {
            anyhow::ensure!(
                ev.device < tb.nodes.len(),
                "churn trace: device {} out of range (testbed has {} nodes)",
                ev.device,
                tb.nodes.len()
            );
            anyhow::ensure!(
                (ev.at_iter as usize) < job.iters,
                "churn trace: `{} {} @{}` is at or past the last iteration ({})",
                ev.action.name(),
                ev.device,
                ev.at_iter,
                job.iters
            );
        }
        for d in t.join_devices() {
            tb.fail_node(d);
        }
    }

    // Transport plane. The TCP plane accepts the worker-process pool up
    // front: scheduling below only places stages on connected devices.
    let mut plane = match job.transport {
        TransportKind::Chan => {
            anyhow::ensure!(
                listener.is_none(),
                "a TCP listener was provided but the transport is chan"
            );
            anyhow::ensure!(
                job.data_plane == DataPlane::Relay,
                "--data-plane mesh requires --transport tcp \
                 (chan lanes are already direct in-process channels)"
            );
            Plane::Chan
        }
        TransportKind::Tcp => {
            let dl = deadline.ok_or_else(|| {
                anyhow::anyhow!(
                    "--transport tcp requires the liveness plane (--heartbeat-interval > 0)"
                )
            })?;
            let n_workers = job.workers.unwrap_or(cfg.n_stages);
            anyhow::ensure!(
                n_workers >= cfg.n_stages,
                "--workers {n_workers} < {} pipeline stages",
                cfg.n_stages
            );
            anyhow::ensure!(
                n_workers <= tb.nodes.len(),
                "--workers {n_workers} > {} testbed devices",
                tb.nodes.len()
            );
            Plane::Tcp(TcpPlane::start(
                &job.listen,
                listener,
                &job.token,
                n_workers,
                tb.nodes.len(),
                MonitorCfg { deadline: dl, grace: job.heartbeat_grace.max(1) },
            )?)
        }
    };

    // TCP: a device is only real if a worker process owns it — fail every
    // other testbed node so schedulers and the failover re-planner never
    // place a stage where no process can run it.
    if let Plane::Tcp(p) = &plane {
        let live = p.live_devices();
        for d in 0..tb.nodes.len() {
            if !live.contains(&d) {
                tb.fail_node(d);
            }
        }
        anyhow::ensure!(
            tb.alive_nodes().len() >= cfg.n_stages,
            "{} connected workers < {} stages",
            tb.alive_nodes().len(),
            cfg.n_stages
        );
    }

    // Stage-level OP-DAG for scheduling.
    let spec = TransformerSpec {
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_heads: cfg.n_heads,
        n_layers: cfg.n_layers,
        seq_len: cfg.seq_len,
        microbatch: cfg.microbatch,
    };
    let dag = stage_chain(&spec, cfg.n_stages);
    let mut part = match &job.placement {
        Some(devs) => {
            anyhow::ensure!(
                devs.len() == cfg.n_stages,
                "--placement needs {} device ids",
                cfg.n_stages
            );
            for &d in devs {
                anyhow::ensure!(
                    d < tb.nodes.len() && !tb.is_failed(d),
                    "--placement device {d} has no live worker"
                );
            }
            let chain = dag.compute_chain();
            let assign: Vec<usize> = {
                let mut a = vec![usize::MAX; dag.len()];
                for (i, &op) in chain.iter().enumerate() {
                    a[op] = devs[i];
                }
                for op in &dag.ops {
                    if matches!(op.kind, crate::opdag::OpKind::Placeholder) {
                        a[op.id] = a[op.users[0]];
                    }
                }
                a
            };
            crate::opdag::Partition::new(assign)
        }
        None if tb.alive_nodes().len() == tb.nodes.len() => {
            crate::scheduler::by_name(&job.scheduler)?.schedule(&dag, &tb)?
        }
        None => {
            // Schedule on the surviving view (only connected devices) and
            // map the partition back to original device ids.
            let (sub, map) = tb.surviving();
            let sub_part = crate::scheduler::by_name(&job.scheduler)?.schedule(&dag, &sub)?;
            let assign: Vec<usize> =
                (0..dag.len()).map(|op| map[sub_part.node_of(op)]).collect();
            Partition::new(assign)
        }
    };
    part.validate(&dag)?;
    let mut stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    anyhow::ensure!(
        stage_plan.n_stages() == cfg.n_stages,
        "scheduler merged stages ({} of {})",
        stage_plan.n_stages(),
        cfg.n_stages
    );
    let s_n = cfg.n_stages;
    let mut devices = stage_plan.devices.clone();
    let mut plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);

    // Membership legality is relative to the devices actually hosting
    // stages: kills must target initially-placed (or later-joined)
    // devices — the worker-side injector only reaches stage hosts.
    if let Some(t) = &churn {
        t.validate(&devices)?;
    }

    // The execution schedule both workers and the simulator interpret.
    let schedule = PipelineSchedule::new(job.pipeline, s_n, job.n_micro);
    schedule.validate()?;

    // The head stage answers boundary Checkpoints via its ticking label
    // receive — without heartbeats it would deadlock on the broadcast.
    anyhow::ensure!(
        job.checkpoint_every == 0 || hb.is_some(),
        "--checkpoint-every requires heartbeats (--heartbeat-interval > 0)"
    );

    // Straggler injection (test hook): the device initially hosting
    // --slow-stage runs slow for the whole job, wherever stages move.
    let slow_dev: Option<(usize, f64)> = match job.slow_stage {
        Some(s) => {
            anyhow::ensure!(s < s_n, "--slow-stage {s} out of range (stages: {s_n})");
            Some((devices[s], job.slow_factor.max(1.0)))
        }
        None => None,
    };

    // Profile feedback plane + re-planner.
    let mut store = ProfileStore::new(s_n, job.n_micro, 0.5);
    let replanner = Replanner {
        scheduler: job.scheduler.clone(),
        threshold: job.straggler_threshold,
        hysteresis: job.replan_hysteresis,
        min_samples: REPLAN_WARMUP_ITERS,
        keep_stage_count: true,
    };
    let mut snapshots: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
    let mut all_stats: Vec<WorkerStats> = Vec::new();
    // Last recommendation that was recorded but not applied — a persistent
    // straggler would otherwise append a near-duplicate event at every
    // iteration boundary (advise mode, or auto blocked by hysteresis).
    let mut last_unapplied: Option<(Vec<usize>, bool)> = None;

    // `None` only transiently: a failed mid-migration teardown/start
    // leaves no generation, and the recovery path rebuilds one.
    let mut gen: Option<Generation> = Some(start_generation(
        &mut plane,
        &manifest,
        job,
        churn.as_ref(),
        &schedule,
        &devices,
        &plan,
        0,
        job.iters,
        &mut snapshots,
        slow_dev,
        hb,
        deadline,
    )?);

    // Broker-driven side of the trace: join/rejoin admissions at
    // iteration boundaries. The cursor is monotonic and never rewinds on
    // recovery — an admission is a physical event, not replayable state.
    let admissions: Vec<ChurnEvent> = churn
        .as_ref()
        .map(|t| t.admissions().copied().collect())
        .unwrap_or_default();
    let mut next_admission = 0usize;

    // ---- drive the training loop --------------------------------------
    let mut corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
    let mut report = TrainReport {
        config: cfg.name.clone(),
        scheduler: job.scheduler.clone(),
        compressor: match job.value_codec {
            crate::compress::ValueCodec::F32 => job.compress.name().to_string(),
            codec => format!("{}+{}", job.compress.name(), codec.name()),
        },
        pipeline: job.pipeline.name().to_string(),
        ratio: job.ratio,
        n_micro: job.n_micro,
        placement: devices.clone(),
        ..Default::default()
    };

    let mut it = 0usize;
    let mut last_ckpt: Option<usize> = None;
    // Incremental checkpoints: the last saved version, kept materialized
    // so worker deltas can be applied against it and the next on-disk
    // layer diffed from it. None = the next save writes a base layer.
    let mut ckpt_base: Option<(u32, Vec<StageState>)> = None;
    // Delta layers chained since the last base (--checkpoint-rebase-every
    // forces a fresh base once this count would reach N - 1).
    let mut deltas_since_base = 0usize;
    while it < job.iters {
        let iter = it as u32;
        let mut death: Option<(usize, String)> = None;
        // Deaths already attributed when no generation is live (a failed
        // mid-migration teardown/start): (stage, device, cause).
        let mut pending_dead: Vec<(usize, usize, String)> = Vec::new();

        // ---- scripted admissions at the iteration boundary ------------
        while next_admission < admissions.len()
            && admissions[next_admission].at_iter as usize <= it
            && death.is_none()
        {
            let ev = admissions[next_admission];
            next_admission += 1;
            let dev = ev.device;
            let kind = ev.action.name();
            eprintln!("broker: churn trace: awaiting {kind} of device {dev} at iteration {it}");
            if let Plane::Tcp(p) = &mut plane {
                p.await_device(dev, ADMIT_TIMEOUT)?;
            }
            // Back in the pool — but with no reputation: the next
            // generation's first-contact grace applies to its connection,
            // and any stage folded onto it gets a fresh EWMA entry below.
            tb.unfail_node(dev);
            let mut jev = JoinEvent {
                iter: it,
                device: dev,
                kind: kind.to_string(),
                adopted: false,
                from: devices.clone(),
                to: devices.clone(),
                sim_before_s: 0.0,
                sim_after_s: 0.0,
            };
            if job.replan != ReplanMode::Off && it < job.iters {
                let inp = ReplanInput {
                    dag: &dag,
                    testbed: &tb,
                    part: &part,
                    modeled: &stage_plan,
                    store: &store,
                    schedule: job.pipeline,
                    n_micro: job.n_micro,
                    current_compress: &plan,
                };
                let decision = replanner.replan_after_join(&inp, dev, &|p, t| {
                    compress_plan_for(job, cfg.microbatch, &dag, p, t)
                })?;
                if let Some(d) = decision {
                    jev.sim_before_s = d.current_sim_s;
                    jev.sim_after_s = d.candidate_sim_s;
                    if d.adopt && job.replan == ReplanMode::Auto {
                        eprintln!(
                            "broker: folding device {dev} into the pipeline at iteration {it} \
                             ({} -> {:?})",
                            d.candidate.origin, d.candidate.plan.devices
                        );
                        let old = gen.take().expect("generation live at the boundary");
                        match teardown(
                            &mut plane,
                            old,
                            s_n,
                            &mut snapshots,
                            &mut all_stats,
                            hb.is_some(),
                            deadline,
                        ) {
                            Err(e) => {
                                pending_dead = migration_deaths(e, &plane, &devices)?;
                                death = Some((
                                    pending_dead[0].0,
                                    pending_dead[0].2.clone(),
                                ));
                            }
                            Ok(()) => {
                                part = d.candidate.partition.clone();
                                stage_plan = StagePlan::from_partition(&dag, &part, &tb);
                                anyhow::ensure!(
                                    stage_plan.n_stages() == s_n,
                                    "join replan changed the stage count"
                                );
                                // Measurements for moved stages describe
                                // old silicon; the newcomer has none.
                                for s in 0..s_n {
                                    if stage_plan.devices[s] != devices[s] {
                                        store.reset_stage(s);
                                    }
                                }
                                devices = stage_plan.devices.clone();
                                plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);
                                match start_generation(
                                    &mut plane,
                                    &manifest,
                                    job,
                                    churn.as_ref(),
                                    &schedule,
                                    &devices,
                                    &plan,
                                    iter,
                                    job.iters - it,
                                    &mut snapshots,
                                    slow_dev,
                                    hb,
                                    deadline,
                                ) {
                                    Ok(g) => {
                                        gen = Some(g);
                                        jev.adopted = true;
                                        jev.to = devices.clone();
                                        last_unapplied = None;
                                    }
                                    Err(e) => {
                                        if let Plane::Tcp(p) = &plane {
                                            p.abort_generation();
                                        }
                                        pending_dead = migration_deaths(e, &plane, &devices)?;
                                        death = Some((
                                            pending_dead[0].0,
                                            pending_dead[0].2.clone(),
                                        ));
                                    }
                                }
                            }
                        }
                    }
                }
            }
            report.joins.push(jev);
        }

        // ---- checkpoint at the iteration boundary ---------------------
        if death.is_none()
            && job.checkpoint_every > 0
            && it > 0
            && it % job.checkpoint_every == 0
            && last_ckpt != Some(it)
        {
            let g = gen.as_mut().expect("generation live");
            let offered = ckpt_base.as_ref().map(|(b, st)| (*b, st.as_slice()));
            match collect_checkpoint_states(g, iter, s_n, offered, deadline, &mut all_stats)? {
                SnapOutcome::Died { stage, cause } => death = Some((stage, cause)),
                SnapOutcome::Done(states) => {
                    // Periodic re-basing bounds the reconstruction chain:
                    // every `checkpoint_rebase_every`-th version is forced
                    // to a full base layer (0 = never force).
                    let rebase_due = job.checkpoint_rebase_every > 0
                        && deltas_since_base + 1 >= job.checkpoint_rebase_every;
                    let ckpt = Checkpoint {
                        iter,
                        corpus_batches: corpus.batches_drawn(),
                        seed: job.seed,
                        config: cfg.name.clone(),
                        placement: devices.clone(),
                        states,
                    };
                    let parent = if rebase_due {
                        None
                    } else {
                        ckpt_base.as_ref().map(|(b, st)| (*b, st.as_slice()))
                    };
                    let info = checkpoint::save(
                        &job.checkpoint_dir,
                        &ckpt,
                        parent,
                        job.keep_checkpoints,
                    )?;
                    match info.kind {
                        checkpoint::LayerKind::Base => deltas_since_base = 0,
                        checkpoint::LayerKind::Delta { .. } => {
                            deltas_since_base += 1;
                            // Steady-state shrink accounting: what this
                            // version cost on disk vs what a full snapshot
                            // of the same states would have cost.
                            report.checkpoint_bytes_delta += info.bytes_written as f64;
                            report.checkpoint_bytes_full += info.bytes_full as f64;
                        }
                    }
                    ckpt_base = Some((iter, ckpt.states));
                    last_ckpt = Some(it);
                }
            }
        }

        // ---- run one iteration ----------------------------------------
        if death.is_none() {
            let g = gen.as_mut().expect("generation live");
            let t0 = Instant::now();
            for micro in 0..job.n_micro as u32 {
                let (tokens, targets) = corpus.next_batch(cfg.microbatch, cfg.seq_len);
                let r1 = g.fwd_tx[0].send(Wire::Data { iter, micro, tokens });
                let r2 = g.label_tx.send(Wire::Labels { iter, micro, targets });
                if deadline.is_none() {
                    // No liveness plane: a closed channel is fatal now.
                    for r in [r1, r2] {
                        r.map_err(|_| anyhow::anyhow!("workers exited mid-feed {it}"))?;
                    }
                }
                // Otherwise the deadline monitor identifies the dead stage.
            }
            match collect_iteration(g, it, iter, s_n, job.n_micro, deadline, &mut all_stats)? {
                IterOutcome::Died { stage, cause } => death = Some((stage, cause)),
                IterOutcome::Done { mean_loss, prof } => {
                    // Progress to stderr (unbuffered): CI churn smokes pace
                    // scripted join/rejoin worker starts off these lines.
                    eprintln!("broker: iteration {it} complete (loss {mean_loss:.4})");
                    report.losses.push(mean_loss);
                    report.wall_s.push(t0.elapsed().as_secs_f64());
                    // Real per-iteration wire bytes from the workers.
                    report.wire_bytes.push(prof.iter().map(|p| p.3).sum());
                    for (s, p) in prof.iter().enumerate() {
                        store.record_iter(s, p.0, p.1, p.2);
                    }
                    // Per-iteration simulated geo latency: the α–β network
                    // applied to the *measured* compute times under the
                    // current placement.
                    let measured = store.measured_plan(&stage_plan);
                    report
                        .sim_s
                        .push(simulate_iteration(&measured, &tb, &schedule, &plan).iter_s);
                }
            }
        }

        // ---- straggler check at the iteration boundary ----------------
        if death.is_none() {
            if job.replan != ReplanMode::Off && it + 1 < job.iters {
                // A silently-dead idle connection (e.g. a spare worker
                // that crashed) must never be a migration candidate.
                if let Plane::Tcp(p) = &plane {
                    for d in p.dead_devices() {
                        if !tb.is_failed(d) {
                            tb.fail_node(d);
                        }
                    }
                }
                let inp = ReplanInput {
                    dag: &dag,
                    testbed: &tb,
                    part: &part,
                    modeled: &stage_plan,
                    store: &store,
                    schedule: job.pipeline,
                    n_micro: job.n_micro,
                    current_compress: &plan,
                };
                let decision = replanner
                    .consider(&inp, &|p, t| compress_plan_for(job, cfg.microbatch, &dag, p, t))?;
                if let Some(d) = decision {
                    let apply = d.adopt && job.replan == ReplanMode::Auto;
                    let skip = if !apply {
                        let key = (d.candidate.plan.devices.clone(), d.adopt);
                        let same = last_unapplied.as_ref() == Some(&key);
                        if !same {
                            last_unapplied = Some(key);
                        }
                        same // same recommendation as last time
                    } else {
                        last_unapplied = None;
                        false
                    };
                    if !skip {
                        let mut ev = ReplanEvent {
                            iter: it + 1,
                            from: devices.clone(),
                            to: d.candidate.plan.devices.clone(),
                            flagged: d.flagged.clone(),
                            origin: d.candidate.origin.to_string(),
                            sim_before_s: d.current_sim_s,
                            sim_after_s: d.candidate_sim_s,
                            migration_s: d.migration_s,
                            applied: apply,
                        };
                        if apply {
                            let t_mig = Instant::now();
                            let old = gen.take().expect("generation live");
                            match teardown(
                                &mut plane,
                                old,
                                s_n,
                                &mut snapshots,
                                &mut all_stats,
                                hb.is_some(),
                                deadline,
                            ) {
                                Err(e) => {
                                    // A device died while the migration was
                                    // in flight: hand it to crash recovery.
                                    pending_dead = migration_deaths(e, &plane, &devices)?;
                                    death = Some((
                                        pending_dead[0].0,
                                        pending_dead[0].2.clone(),
                                    ));
                                }
                                Ok(()) => {
                                    part = d.candidate.partition.clone();
                                    stage_plan = StagePlan::from_partition(&dag, &part, &tb);
                                    anyhow::ensure!(
                                        stage_plan.n_stages() == s_n,
                                        "replan changed the stage count"
                                    );
                                    // Measurements for moved stages describe
                                    // old silicon.
                                    for s in 0..s_n {
                                        if stage_plan.devices[s] != devices[s] {
                                            store.reset_stage(s);
                                        }
                                    }
                                    devices = stage_plan.devices.clone();
                                    plan = compress_plan_for(
                                        job,
                                        cfg.microbatch,
                                        &dag,
                                        &part,
                                        &tb,
                                    );
                                    match start_generation(
                                        &mut plane,
                                        &manifest,
                                        job,
                                        churn.as_ref(),
                                        &schedule,
                                        &devices,
                                        &plan,
                                        iter + 1,
                                        job.iters - (it + 1),
                                        &mut snapshots,
                                        slow_dev,
                                        hb,
                                        deadline,
                                    ) {
                                        Ok(g) => {
                                            gen = Some(g);
                                            ev.migration_s = t_mig.elapsed().as_secs_f64();
                                        }
                                        Err(e) => {
                                            if let Plane::Tcp(p) = &plane {
                                                p.abort_generation();
                                            }
                                            pending_dead =
                                                migration_deaths(e, &plane, &devices)?;
                                            death = Some((
                                                pending_dead[0].0,
                                                pending_dead[0].2.clone(),
                                            ));
                                        }
                                    }
                                }
                            }
                        }
                        if death.is_none() {
                            report.replans.push(ev);
                        }
                    }
                }
            }
            if death.is_none() {
                it += 1;
                continue;
            }
        }

        // ---- crash recovery -------------------------------------------
        let (first_stage, first_cause) = death.expect("checked above");
        let Some(dl) = deadline else {
            // No liveness plane (heartbeats disabled): abort as in PR 3.
            // Workers exit on their own once the broker drops the
            // generation's channels; they cannot be joined safely here.
            anyhow::bail!("stage {first_stage} failed: {first_cause}");
        };
        let t_replan = Instant::now();
        // Collect the FULL dead set before tearing down: the declared
        // stage, any concurrently queued Fatals, and stages whose own
        // deadline expires inside a short settle window — N simultaneous
        // deaths then recover in ONE teardown + replan + restore pass.
        let mut dead_devs: Vec<(usize, usize, String)> = pending_dead;
        if let Some(mut old) = gen.take() {
            dead_devs.push((first_stage, old.devices[first_stage], first_cause));
            let settle = (dl / 2).min(Duration::from_secs(2));
            let t0 = Instant::now();
            loop {
                while let Ok(msg) = old.rx_driver.try_recv() {
                    if let Some(s) = Generation::stage_of(&msg, s_n) {
                        if s < s_n {
                            old.note(s);
                        }
                    }
                    match msg {
                        Wire::Fatal { stage, error } if stage < s_n => {
                            if !dead_devs.iter().any(|d| d.0 == stage) {
                                dead_devs.push((
                                    stage,
                                    old.devices[stage],
                                    format!("fatal: {error}"),
                                ));
                            }
                        }
                        Wire::Stats(st) => {
                            all_stats.push(st);
                            old.stats_seen += 1;
                        }
                        _ => {} // losses/profiles of the aborted iteration
                    }
                }
                for s in 0..s_n {
                    if dead_devs.iter().any(|d| d.0 == s) {
                        continue;
                    }
                    let limit =
                        if old.heard[s] { dl } else { dl * old.grace.max(1) };
                    let age = old.last_seen[s].elapsed();
                    if age > limit {
                        dead_devs.push((
                            s,
                            old.devices[s],
                            format!("no heartbeat for {:.2}s", age.as_secs_f64()),
                        ));
                    }
                }
                if t0.elapsed() >= settle {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            for (s, dev, cause) in &dead_devs {
                eprintln!(
                    "broker: stage {s} (device {dev}) declared dead during \
                     iteration {it}: {cause}"
                );
            }
            for &(_, dev, _) in &dead_devs {
                tb.fail_node(dev);
            }
            // Other silently-dead worker connections (e.g. an idle spare
            // that vanished) must not receive stages either.
            if let Plane::Tcp(p) = &plane {
                for d in p.dead_devices() {
                    tb.fail_node(d);
                }
            }
            churn_teardown(&mut plane, old, s_n, dl, &mut all_stats, dead_devs.len());
        } else {
            // The generation was already consumed by a failed migration;
            // the deaths were attributed there.
            for (s, dev, cause) in &dead_devs {
                eprintln!(
                    "broker: stage {s} (device {dev}) lost mid-migration at \
                     iteration {it}: {cause}"
                );
            }
            for &(_, dev, _) in &dead_devs {
                tb.fail_node(dev);
            }
            if let Plane::Tcp(p) = &plane {
                for d in p.dead_devices() {
                    tb.fail_node(d);
                }
            }
        }
        let (dead_stage, dead_dev) = (dead_devs[0].0, dead_devs[0].1);
        anyhow::ensure!(
            job.replan == ReplanMode::Auto,
            "stage {dead_stage} (device {dead_dev}) died during iteration {it} ({}); \
             crash recovery requires --replan auto",
            dead_devs[0].2
        );
        anyhow::ensure!(
            report.recoveries.len() < MAX_RECOVERIES,
            "giving up after {MAX_RECOVERIES} crash recoveries"
        );
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &stage_plan,
            store: &store,
            schedule: job.pipeline,
            n_micro: job.n_micro,
            current_compress: &plan,
        };
        let cand = replanner.replan_after_failure(&inp, dead_stage)?;
        anyhow::ensure!(
            cand.plan.n_stages() == s_n,
            "failover changed the stage count"
        );
        // The failover generators reason about the *primary* dead stage;
        // with concurrent deaths the candidate must dodge every one.
        anyhow::ensure!(
            cand.plan.devices.iter().all(|&d| !tb.is_failed(d)),
            "failover placement {:?} still uses a dead device",
            cand.plan.devices
        );
        let from = devices.clone();
        part = cand.partition.clone();
        stage_plan = cand.plan.clone();
        devices = stage_plan.devices.clone();
        plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);
        for s in 0..s_n {
            store.reset_stage(s);
        }
        let replan_s = t_replan.elapsed().as_secs_f64();

        // Restore the newest valid checkpoint — or restart from scratch.
        let t_restore = Instant::now();
        let mut init: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
        let (resume_iter, corpus_batches) = if job.checkpoint_every > 0 {
            // Only boundaries this run has already passed are restorable;
            // newer leftovers (a prior completed run sharing the
            // directory) are skipped by the version walk.
            match checkpoint::load_latest_at_or_before(&job.checkpoint_dir, iter)? {
                Some(ck) => {
                    anyhow::ensure!(
                        ck.config == cfg.name && ck.seed == job.seed,
                        "checkpoint belongs to another run (config `{}`, seed {:#x})",
                        ck.config,
                        ck.seed
                    );
                    anyhow::ensure!(
                        ck.states.len() == s_n && (ck.iter as usize) <= it,
                        "checkpoint shape/iteration mismatch"
                    );
                    // The restored version becomes the acknowledged base:
                    // respawned workers hold no shadow and will answer the
                    // next broadcast with full snapshots, but the broker
                    // can still persist that version as a delta layer
                    // against this materialized copy.
                    ckpt_base = Some((ck.iter, ck.states.clone()));
                    deltas_since_base = 0;
                    for (s, st) in ck.states.into_iter().enumerate() {
                        if !st.params.is_empty() {
                            init[s] = Some(st);
                        }
                    }
                    (ck.iter as usize, ck.corpus_batches)
                }
                None => {
                    ckpt_base = None;
                    deltas_since_base = 0;
                    (0, 0)
                }
            }
        } else {
            (0, 0)
        };
        // Rewind the data loader to the checkpoint cursor and roll the
        // report back — the re-run iterations rewrite their entries
        // deterministically.
        corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
        corpus.advance_to(corpus_batches, cfg.microbatch, cfg.seq_len)?;
        report.losses.truncate(resume_iter);
        report.wall_s.truncate(resume_iter);
        report.sim_s.truncate(resume_iter);
        report.wire_bytes.truncate(resume_iter);
        for sn in snapshots.iter_mut() {
            *sn = None;
        }
        last_unapplied = None;
        gen = Some(start_generation(
            &mut plane,
            &manifest,
            job,
            churn.as_ref(),
            &schedule,
            &devices,
            &plan,
            resume_iter as u32,
            job.iters - resume_iter,
            &mut init,
            slow_dev,
            hb,
            deadline,
        )?);
        let restore_s = t_restore.elapsed().as_secs_f64();
        // One event per dead device; the pass-level numbers (resume point,
        // placements, timings) are shared across the concurrent set.
        for (s, dev, cause) in dead_devs {
            report.recoveries.push(RecoveryEvent {
                died_iter: it,
                stage: s,
                device: dev,
                cause,
                resume_iter,
                iters_lost: it - resume_iter,
                from: from.clone(),
                to: devices.clone(),
                origin: cand.origin.to_string(),
                replan_s,
                restore_s,
            });
        }
        last_ckpt = Some(resume_iter);
        it = resume_iter;
    }

    // ---- drain the final generation ------------------------------------
    let last = gen.take().expect("generation live at end of run");
    teardown(&mut plane, last, s_n, &mut snapshots, &mut all_stats, hb.is_some(), deadline)?;
    if let Plane::Tcp(p) = &plane {
        p.shutdown();
        // Data-plane accounting: bytes the broker relayed worker→worker
        // (frame-level, counted at the relay hop) vs stage payload bytes
        // that traveled direct peer links. Under mesh the former must be
        // ~0 — the CI mesh smokes grep for exactly that.
        report.relayed_packet_bytes = p.relayed_packet_bytes() as f64;
        if job.data_plane == DataPlane::Mesh {
            report.peer_packet_bytes = all_stats.iter().map(|s| s.bytes_sent).sum();
        }
    }
    report.placement = devices;

    // Achieved wire compression (dense payload bytes / wire bytes).
    let total_bytes: f64 = all_stats.iter().map(|s| s.bytes_sent).sum();
    let total_dense: f64 = all_stats.iter().map(|s| s.dense_bytes).sum();
    report.wire_shrink = if total_bytes > 0.0 { total_dense / total_bytes } else { 1.0 };

    Ok(report)
}
