//! The broker / IR plane (§3.2): receives a job definition, builds the
//! stage-level OP-DAG, schedules it onto the testbed, derives the
//! compression plan, spawns the CompNode workers, feeds data, and collects
//! losses + statistics into a `TrainReport`.
//!
//! The runtime is adaptive: workers stream per-iteration `IterProfile`
//! measurements back to the broker; a `ProfileStore` maintains EWMA
//! per-stage times; when the straggler detector flags a stage and
//! `--replan auto` is set, the `Replanner` re-runs the scheduler with
//! measured (not modeled) compute times and — if the simulated iteration
//! improves past the hysteresis margin — the broker re-partitions at the
//! next iteration boundary: workers are stopped, their `StageState`
//! (params + optimizer moments) is snapshotted, links/codecs are rebuilt
//! for the new placement, and a fresh worker generation resumes at the
//! same global iteration.

pub mod job;

pub use job::Job;

use crate::cluster::{testbed, Testbed};
use crate::compress::{CompressKind, CompressPlan};
use crate::cost::{PipelineParams, ProfileStore};
use crate::opdag::builders::{stage_chain, TransformerSpec};
use crate::opdag::{Dag, Partition};
use crate::pipeline::PipelineSchedule;
use crate::runtime::Manifest;
use crate::scheduler::replan::{ReplanInput, ReplanMode, Replanner};
use crate::simnet::{simulate_iteration, StagePlan};
use crate::trainer::{ReplanEvent, SyntheticCorpus, TrainReport};
use crate::worker::{spawn_stage, StageCodec, StageCtx, StageState, Wire, WorkerStats};
use std::sync::mpsc::{self, Receiver, Sender};
use std::time::Instant;

/// Iterations of measured profile required before the first replan check.
const REPLAN_WARMUP_ITERS: usize = 3;

/// One cohort of stage workers sharing a set of channels. Re-partitioning
/// tears a generation down (collecting state snapshots) and spawns the
/// next one on the new placement.
struct Generation {
    handles: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
    /// Broker-held senders into every stage's forward input (stage 0 gets
    /// Data; the rest are reachable for Stop broadcast).
    fwd_tx: Vec<Sender<Wire>>,
    label_tx: Sender<Wire>,
    rx_driver: Receiver<Wire>,
    /// Stats messages already collected from this generation.
    stats_seen: usize,
}

/// Build the compression plan for a (partition, testbed) pair per the
/// job's knobs — also used by the re-planner to cost candidate plans.
fn compress_plan_for(
    job: &Job,
    micro_size: usize,
    dag: &Dag,
    part: &Partition,
    tb: &Testbed,
) -> CompressPlan {
    let params = PipelineParams { n_micro: job.n_micro, micro_size, include_bwd: true };
    let mut plan = match job.compress {
        // `--compress none --wire-codec int8` = dense int8 (1 B/value).
        CompressKind::None => {
            CompressPlan::dense(tb.nodes.len()).with_value_codec(job.value_codec)
        }
        CompressKind::AdaTopK => CompressPlan::adatopk_with_codec(
            dag,
            part,
            tb,
            params,
            job.ratio,
            job.value_codec,
        ),
        kind => {
            CompressPlan::uniform(kind, job.ratio, tb.nodes.len())
                .with_value_codec(job.value_codec)
        }
    };
    plan.direction = job.direction;
    plan
}

/// Spawn one worker generation on `devices`, executing iterations
/// `[iter0, iter0 + iters)` of `schedule`. `init` entries are taken (and
/// consumed) as migrated state for the matching stage.
#[allow(clippy::too_many_arguments)]
fn spawn_generation(
    manifest: &Manifest,
    job: &Job,
    schedule: &PipelineSchedule,
    devices: &[usize],
    plan: &CompressPlan,
    iter0: u32,
    iters: usize,
    init: &mut [Option<StageState>],
    slow_dev: Option<(usize, f64)>,
) -> Generation {
    let s_n = devices.len();
    let cfg = &manifest.config;
    let (tx_driver, rx_driver) = mpsc::channel::<Wire>();
    let mut fwd_tx = Vec::new();
    let mut fwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        fwd_tx.push(t);
        fwd_rx.push(Some(r));
    }
    let mut bwd_tx = Vec::new();
    let mut bwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        bwd_tx.push(t);
        bwd_rx.push(Some(r));
    }
    let (label_tx, label_rx) = mpsc::channel::<Wire>();
    let mut label_rx = Some(label_rx);

    let mut handles = Vec::new();
    for s in 0..s_n {
        let next_device = devices.get(s + 1).copied();
        let prev_device = if s > 0 { Some(devices[s - 1]) } else { None };
        let slow_factor = match slow_dev {
            Some((dev, f)) if dev == devices[s] => f,
            _ => 1.0,
        };
        let ctx = StageCtx {
            stage: s,
            n_stages: s_n,
            device: devices[s],
            next_device,
            prev_device,
            manifest: manifest.clone(),
            // Per-link wire codecs: ratios keyed by the receiving device
            // (Eq. 7), scratch owned for the life of the link.
            codec: StageCodec::from_plan(plan, next_device, prev_device, cfg.d_model),
            tasks: schedule.tasks[s].clone(),
            iter0,
            iters,
            n_micro: job.n_micro,
            lr: job.lr,
            momentum: job.momentum,
            optimizer: job.optimizer.clone(),
            param_seed: job.seed.wrapping_add(s as u64),
            init_state: init[s].take(),
            slow_factor,
            rx_fwd: fwd_rx[s].take().unwrap(),
            rx_bwd: if s + 1 < s_n { bwd_rx[s].take() } else { None },
            tx_fwd: if s + 1 < s_n { Some(fwd_tx[s + 1].clone()) } else { None },
            tx_bwd: if s > 0 { Some(bwd_tx[s - 1].clone()) } else { None },
            rx_labels: if s == s_n - 1 { label_rx.take() } else { None },
            tx_driver: tx_driver.clone(),
        };
        handles.push(spawn_stage(ctx));
    }
    // The broker keeps no tx_driver clone: the channel closes when the
    // last worker of the generation exits.
    drop(tx_driver);
    Generation { handles, fwd_tx, label_tx, rx_driver, stats_seen: 0 }
}

/// Stop a generation at an iteration boundary (workers are blocked on
/// their first recv of the next iteration), collect state snapshots and
/// remaining stats, and join the threads. Also used as the end-of-run
/// drain, where the Stop sends land on already-dropped receivers.
fn teardown(
    gen: Generation,
    s_n: usize,
    snapshots: &mut [Option<StageState>],
    all_stats: &mut Vec<WorkerStats>,
) -> anyhow::Result<()> {
    for tx in &gen.fwd_tx {
        let _ = tx.send(Wire::Stop);
    }
    let _ = gen.label_tx.send(Wire::Stop);
    let mut seen = gen.stats_seen;
    while seen < s_n {
        match gen.rx_driver.recv() {
            Ok(Wire::Stats(st)) => {
                all_stats.push(st);
                seen += 1;
            }
            Ok(Wire::Snapshot { stage, state }) => snapshots[stage] = Some(state),
            Ok(Wire::Fatal { stage, error }) => {
                anyhow::bail!("stage {stage} failed: {error}")
            }
            Ok(_) => {} // stale losses/profiles from the stopped iteration
            Err(_) => break, // all workers exited (join reports errors)
        }
    }
    for h in gen.handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => anyhow::bail!("worker failed: {e:#}"),
            Err(_) => anyhow::bail!("worker panicked"),
        }
    }
    Ok(())
}

/// Run a full decentralized training job. Returns the report.
pub fn run(job: &Job) -> anyhow::Result<TrainReport> {
    let manifest = Manifest::load(&job.artifacts_root, &job.config)?;
    let cfg = manifest.config.clone();
    let tb = testbed::by_id(job.testbed, job.seed);
    anyhow::ensure!(
        cfg.n_stages <= tb.nodes.len(),
        "{} stages > {} devices",
        cfg.n_stages,
        tb.nodes.len()
    );

    // Stage-level OP-DAG for scheduling.
    let spec = TransformerSpec {
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_heads: cfg.n_heads,
        n_layers: cfg.n_layers,
        seq_len: cfg.seq_len,
        microbatch: cfg.microbatch,
    };
    let dag = stage_chain(&spec, cfg.n_stages);
    let mut part = match &job.placement {
        Some(devs) => {
            anyhow::ensure!(
                devs.len() == cfg.n_stages,
                "--placement needs {} device ids",
                cfg.n_stages
            );
            let chain = dag.compute_chain();
            let assign: Vec<usize> = {
                let mut a = vec![usize::MAX; dag.len()];
                for (i, &op) in chain.iter().enumerate() {
                    a[op] = devs[i];
                }
                for op in &dag.ops {
                    if matches!(op.kind, crate::opdag::OpKind::Placeholder) {
                        a[op.id] = a[op.users[0]];
                    }
                }
                a
            };
            crate::opdag::Partition::new(assign)
        }
        None => crate::scheduler::by_name(&job.scheduler)?.schedule(&dag, &tb)?,
    };
    part.validate(&dag)?;
    let mut stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    anyhow::ensure!(
        stage_plan.n_stages() == cfg.n_stages,
        "scheduler merged stages ({} of {})",
        stage_plan.n_stages(),
        cfg.n_stages
    );
    let s_n = cfg.n_stages;
    let mut devices = stage_plan.devices.clone();
    let mut plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);

    // The execution schedule both workers and the simulator interpret.
    let schedule = PipelineSchedule::new(job.pipeline, s_n, job.n_micro);
    schedule.validate()?;

    // Straggler injection (test hook): the device initially hosting
    // --slow-stage runs slow for the whole job, wherever stages move.
    let slow_dev: Option<(usize, f64)> = match job.slow_stage {
        Some(s) => {
            anyhow::ensure!(s < s_n, "--slow-stage {s} out of range (stages: {s_n})");
            Some((devices[s], job.slow_factor.max(1.0)))
        }
        None => None,
    };

    // Profile feedback plane + re-planner.
    let mut store = ProfileStore::new(s_n, job.n_micro, 0.5);
    let replanner = Replanner {
        scheduler: job.scheduler.clone(),
        threshold: job.straggler_threshold,
        hysteresis: job.replan_hysteresis,
        min_samples: REPLAN_WARMUP_ITERS,
        keep_stage_count: true,
    };
    let mut snapshots: Vec<Option<StageState>> = (0..s_n).map(|_| None).collect();
    let mut all_stats: Vec<WorkerStats> = Vec::new();
    // Last recommendation that was recorded but not applied — a persistent
    // straggler would otherwise append a near-duplicate event at every
    // iteration boundary (advise mode, or auto blocked by hysteresis).
    let mut last_unapplied: Option<(Vec<usize>, bool)> = None;

    let mut gen = spawn_generation(
        &manifest, job, &schedule, &devices, &plan, 0, job.iters, &mut snapshots, slow_dev,
    );

    // ---- drive the training loop --------------------------------------
    let mut corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
    let mut report = TrainReport {
        config: cfg.name.clone(),
        scheduler: job.scheduler.clone(),
        compressor: match job.value_codec {
            crate::compress::ValueCodec::F32 => job.compress.name().to_string(),
            crate::compress::ValueCodec::Int8 => format!("{}+int8", job.compress.name()),
        },
        pipeline: job.pipeline.name().to_string(),
        ratio: job.ratio,
        n_micro: job.n_micro,
        placement: devices.clone(),
        ..Default::default()
    };

    for it in 0..job.iters {
        let iter = it as u32;
        let t0 = Instant::now();
        for micro in 0..job.n_micro as u32 {
            let (tokens, targets) = corpus.next_batch(cfg.microbatch, cfg.seq_len);
            gen.fwd_tx[0].send(Wire::Data { iter, micro, tokens })?;
            gen.label_tx.send(Wire::Labels { iter, micro, targets })?;
        }
        // Collect this iteration's n_micro losses AND every stage's
        // IterProfile (sent after its Update). Workers cannot run ahead —
        // the next iteration's data is only fed after this loop — so all
        // profiles belong to `iter`.
        let mut sum = 0.0f32;
        let mut got_losses = 0usize;
        let mut prof = vec![(0.0f64, 0.0f64, 0.0f64, 0.0f64); s_n]; // fwd,bwd,upd,bytes
        let mut got_prof = vec![false; s_n];
        let mut n_prof = 0usize;
        while got_losses < job.n_micro || n_prof < s_n {
            let msg = gen
                .rx_driver
                .recv()
                .map_err(|_| anyhow::anyhow!("workers exited mid-iteration {it}"))?;
            match msg {
                Wire::Loss { loss, .. } => {
                    sum += loss;
                    got_losses += 1;
                }
                Wire::IterProfile { stage, iter: pit, fwd_s, bwd_s, update_s, bytes, .. } => {
                    anyhow::ensure!(
                        pit == iter && !got_prof[stage],
                        "stage {stage}: unexpected profile for iter {pit} during {it}"
                    );
                    prof[stage] = (fwd_s, bwd_s, update_s, bytes);
                    got_prof[stage] = true;
                    n_prof += 1;
                }
                Wire::Stats(st) => {
                    // Natural end of the final generation overlaps the
                    // last iteration's drain.
                    all_stats.push(st);
                    gen.stats_seen += 1;
                }
                Wire::Fatal { stage, error } => {
                    anyhow::bail!("stage {stage} failed: {error}")
                }
                other => anyhow::bail!("driver: unexpected {other:?}"),
            }
        }
        report.losses.push(sum / job.n_micro as f32);
        report.wall_s.push(t0.elapsed().as_secs_f64());
        // Real per-iteration wire bytes, straight from the workers.
        report.wire_bytes.push(prof.iter().map(|p| p.3).sum());
        for (s, p) in prof.iter().enumerate() {
            store.record_iter(s, p.0, p.1, p.2);
        }
        // Per-iteration simulated geo latency: the α–β network applied to
        // the *measured* compute times under the current placement.
        let measured = store.measured_plan(&stage_plan);
        report
            .sim_s
            .push(simulate_iteration(&measured, &tb, &schedule, &plan).iter_s);

        // ---- straggler check at the iteration boundary ----------------
        if job.replan != ReplanMode::Off && it + 1 < job.iters {
            let inp = ReplanInput {
                dag: &dag,
                testbed: &tb,
                part: &part,
                modeled: &stage_plan,
                store: &store,
                schedule: job.pipeline,
                n_micro: job.n_micro,
                current_compress: &plan,
            };
            let decision = replanner
                .consider(&inp, &|p, t| compress_plan_for(job, cfg.microbatch, &dag, p, t))?;
            if let Some(d) = decision {
                let apply = d.adopt && job.replan == ReplanMode::Auto;
                if !apply {
                    let key = (d.candidate.plan.devices.clone(), d.adopt);
                    if last_unapplied.as_ref() == Some(&key) {
                        continue; // same recommendation as last time
                    }
                    last_unapplied = Some(key);
                } else {
                    last_unapplied = None;
                }
                let mut ev = ReplanEvent {
                    iter: it + 1,
                    from: devices.clone(),
                    to: d.candidate.plan.devices.clone(),
                    flagged: d.flagged.clone(),
                    origin: d.candidate.origin.to_string(),
                    sim_before_s: d.current_sim_s,
                    sim_after_s: d.candidate_sim_s,
                    migration_s: d.migration_s,
                    applied: apply,
                };
                if apply {
                    let t_mig = Instant::now();
                    teardown(gen, s_n, &mut snapshots, &mut all_stats)?;
                    part = d.candidate.partition.clone();
                    stage_plan = StagePlan::from_partition(&dag, &part, &tb);
                    anyhow::ensure!(
                        stage_plan.n_stages() == s_n,
                        "replan changed the stage count"
                    );
                    // Measurements for moved stages describe old silicon.
                    for s in 0..s_n {
                        if stage_plan.devices[s] != devices[s] {
                            store.reset_stage(s);
                        }
                    }
                    devices = stage_plan.devices.clone();
                    plan = compress_plan_for(job, cfg.microbatch, &dag, &part, &tb);
                    gen = spawn_generation(
                        &manifest,
                        job,
                        &schedule,
                        &devices,
                        &plan,
                        iter + 1,
                        job.iters - (it + 1),
                        &mut snapshots,
                        slow_dev,
                    );
                    ev.migration_s = t_mig.elapsed().as_secs_f64();
                }
                report.replans.push(ev);
            }
        }
    }

    // ---- drain the final generation ------------------------------------
    teardown(gen, s_n, &mut snapshots, &mut all_stats)?;
    report.placement = devices;

    // Achieved wire compression (dense payload bytes / wire bytes).
    let total_bytes: f64 = all_stats.iter().map(|s| s.bytes_sent).sum();
    let total_dense: f64 = all_stats.iter().map(|s| s.dense_bytes).sum();
    report.wire_shrink = if total_bytes > 0.0 { total_dense / total_bytes } else { 1.0 };

    Ok(report)
}
