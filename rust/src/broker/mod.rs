//! The broker / IR plane (§3.2): receives a job definition, builds the
//! stage-level OP-DAG, schedules it onto the testbed, derives the
//! compression plan, spawns the CompNode workers, feeds data, and collects
//! losses + statistics into a `TrainReport`.

pub mod job;

pub use job::Job;

use crate::cluster::testbed;
use crate::compress::{CompressKind, CompressPlan};
use crate::cost::throughput::PipelineParams;
use crate::opdag::builders::{stage_chain, TransformerSpec};
use crate::pipeline::{PipelineSchedule, ScheduleKind};
use crate::runtime::Manifest;
use crate::simnet::{simulate_iteration, StagePlan};
use crate::trainer::{SyntheticCorpus, TrainReport};
use crate::worker::{spawn_stage, StageCodec, StageCtx, Wire, WorkerStats};
use std::sync::mpsc;
use std::time::Instant;

/// Run a full decentralized training job. Returns the report.
pub fn run(job: &Job) -> anyhow::Result<TrainReport> {
    let manifest = Manifest::load(&job.artifacts_root, &job.config)?;
    let cfg = manifest.config.clone();
    let tb = testbed::by_id(job.testbed, job.seed);
    anyhow::ensure!(
        cfg.n_stages <= tb.nodes.len(),
        "{} stages > {} devices",
        cfg.n_stages,
        tb.nodes.len()
    );

    // Stage-level OP-DAG for scheduling.
    let spec = TransformerSpec {
        vocab: cfg.vocab,
        d_model: cfg.d_model,
        n_heads: cfg.n_heads,
        n_layers: cfg.n_layers,
        seq_len: cfg.seq_len,
        microbatch: cfg.microbatch,
    };
    let dag = stage_chain(&spec, cfg.n_stages);
    let part = match &job.placement {
        Some(devs) => {
            anyhow::ensure!(
                devs.len() == cfg.n_stages,
                "--placement needs {} device ids",
                cfg.n_stages
            );
            let chain = dag.compute_chain();
            let assign: Vec<usize> = {
                let mut a = vec![usize::MAX; dag.len()];
                for (i, &op) in chain.iter().enumerate() {
                    a[op] = devs[i];
                }
                for op in &dag.ops {
                    if matches!(op.kind, crate::opdag::OpKind::Placeholder) {
                        a[op.id] = a[op.users[0]];
                    }
                }
                a
            };
            crate::opdag::Partition::new(assign)
        }
        None => crate::scheduler::by_name(&job.scheduler)?.schedule(&dag, &tb)?,
    };
    part.validate(&dag)?;
    let stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    anyhow::ensure!(
        stage_plan.n_stages() == cfg.n_stages,
        "scheduler merged stages ({} of {})",
        stage_plan.n_stages(),
        cfg.n_stages
    );
    let devices = stage_plan.devices.clone();

    // Compression plan.
    let params = PipelineParams {
        n_micro: job.n_micro,
        micro_size: cfg.microbatch,
        include_bwd: true,
    };
    let mut plan = match job.compress {
        // `--compress none --wire-codec int8` = dense int8 (1 B/value).
        CompressKind::None => {
            CompressPlan::dense(tb.nodes.len()).with_value_codec(job.value_codec)
        }
        CompressKind::AdaTopK => CompressPlan::adatopk_with_codec(
            &dag,
            &part,
            &tb,
            params,
            job.ratio,
            job.value_codec,
        ),
        kind => {
            CompressPlan::uniform(kind, job.ratio, tb.nodes.len())
                .with_value_codec(job.value_codec)
        }
    };
    plan.direction = job.direction;

    // ---- spawn workers ------------------------------------------------
    let s_n = cfg.n_stages;
    let (tx_driver, rx_driver) = mpsc::channel::<Wire>();
    // Forward links: driver->0 is Data; s->s+1 are Packets.
    let mut fwd_tx = Vec::new();
    let mut fwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        fwd_tx.push(t);
        fwd_rx.push(Some(r));
    }
    let mut bwd_tx = Vec::new();
    let mut bwd_rx = Vec::new();
    for _ in 0..s_n {
        let (t, r) = mpsc::channel::<Wire>();
        bwd_tx.push(t);
        bwd_rx.push(Some(r));
    }
    let (label_tx, label_rx) = mpsc::channel::<Wire>();
    let mut label_rx = Some(label_rx);

    let mut handles = Vec::new();
    for s in 0..s_n {
        let next_device = devices.get(s + 1).copied();
        let prev_device = if s > 0 { Some(devices[s - 1]) } else { None };
        let ctx = StageCtx {
            stage: s,
            n_stages: s_n,
            device: devices[s],
            next_device,
            prev_device,
            manifest: manifest.clone(),
            // Per-link wire codecs: ratios keyed by the receiving device
            // (Eq. 7), scratch owned for the life of the link.
            codec: StageCodec::from_plan(&plan, next_device, prev_device, cfg.d_model),
            iters: job.iters,
            n_micro: job.n_micro,
            lr: job.lr,
            momentum: job.momentum,
            optimizer: job.optimizer.clone(),
            param_seed: job.seed.wrapping_add(s as u64),
            rx_fwd: fwd_rx[s].take().unwrap(),
            rx_bwd: if s + 1 < s_n { bwd_rx[s].take() } else { None },
            tx_fwd: if s + 1 < s_n { Some(fwd_tx[s + 1].clone()) } else { None },
            tx_bwd: if s > 0 { Some(bwd_tx[s - 1].clone()) } else { None },
            rx_labels: if s == s_n - 1 { label_rx.take() } else { None },
            tx_driver: tx_driver.clone(),
        };
        handles.push(spawn_stage(ctx));
    }
    drop(tx_driver);

    // ---- drive the training loop --------------------------------------
    let mut corpus = SyntheticCorpus::new(cfg.vocab, job.seed ^ 0xDA7A);
    let mut report = TrainReport {
        config: cfg.name.clone(),
        scheduler: job.scheduler.clone(),
        compressor: match job.value_codec {
            crate::compress::ValueCodec::F32 => job.compress.name().to_string(),
            crate::compress::ValueCodec::Int8 => format!("{}+int8", job.compress.name()),
        },
        ratio: job.ratio,
        n_micro: job.n_micro,
        placement: devices.clone(),
        ..Default::default()
    };

    let mut stats: Vec<WorkerStats> = Vec::new();
    let mut bytes_prev = 0.0f64;
    for iter in 0..job.iters as u32 {
        let t0 = Instant::now();
        for micro in 0..job.n_micro as u32 {
            let (tokens, targets) = corpus.next_batch(cfg.microbatch, cfg.seq_len);
            fwd_tx[0].send(Wire::Data { iter, micro, tokens })?;
            label_tx.send(Wire::Labels { iter, micro, targets })?;
        }
        // Collect the n_micro losses of this iteration.
        let mut sum = 0.0f32;
        let mut got = 0usize;
        while got < job.n_micro {
            match rx_driver.recv()? {
                Wire::Loss { loss, .. } => {
                    sum += loss;
                    got += 1;
                }
                Wire::Stats(st) => stats.push(st),
                Wire::Fatal { stage, error } => {
                    anyhow::bail!("stage {stage} failed: {error}")
                }
                other => anyhow::bail!("driver: unexpected {other:?}"),
            }
        }
        report.losses.push(sum / job.n_micro as f32);
        report.wall_s.push(t0.elapsed().as_secs_f64());
        // Wire bytes are reported at the end; estimate per-iteration from
        // the plan for the running log, corrected after stats arrive.
        report.wire_bytes.push(bytes_prev);
        bytes_prev = 0.0;
    }

    // ---- drain worker stats --------------------------------------------
    while stats.len() < s_n {
        match rx_driver.recv() {
            Ok(Wire::Stats(st)) => stats.push(st),
            Ok(_) => {}
            Err(_) => break,
        }
    }
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => anyhow::bail!("worker failed: {e:#}"),
            Err(_) => anyhow::bail!("worker panicked"),
        }
    }

    // Actual wire bytes per iteration (uniform across iters by protocol).
    let total_bytes: f64 = stats.iter().map(|s| s.bytes_sent).sum();
    let per_iter = total_bytes / job.iters.max(1) as f64;
    for b in report.wire_bytes.iter_mut() {
        *b = per_iter;
    }
    // Achieved wire compression (dense payload bytes / wire bytes).
    let total_dense: f64 = stats.iter().map(|s| s.dense_bytes).sum();
    report.wire_shrink = if total_bytes > 0.0 { total_dense / total_bytes } else { 1.0 };

    // ---- post-hoc geo-simulation with measured compute ------------------
    // Replace the cost-model compute times with measured PJRT wall times
    // (per microbatch), then run the discrete-event simulator to get the
    // iteration latency this run WOULD have had on the geo testbed.
    let mut measured = stage_plan.clone();
    let denom = (job.iters * job.n_micro) as f64;
    for st in &stats {
        let s = st.stage;
        measured.fwd_s[s] = st.fwd_s / denom;
        measured.bwd_s[s] = st.bwd_s / denom;
        measured.update_s[s] = st.update_s / job.iters.max(1) as f64;
    }
    let sched = PipelineSchedule::new(ScheduleKind::GPipe, s_n, job.n_micro);
    let sim = simulate_iteration(&measured, &tb, &sched, &plan);
    report.sim_s = vec![sim.iter_s; job.iters];

    Ok(report)
}
