//! Straggler-aware re-planning: close the loop between the measured
//! profile plane and OP-Fence.
//!
//! The offline scheduler plans with *believed* per-device λ factors; when
//! a device underperforms at runtime (thermal throttling, contention,
//! a co-tenant — paper challenge 3), the measured `ProfileStore` times
//! diverge from the model. The `Replanner`:
//!
//! 1. calibrates per-device λ so the cost model reproduces the
//!    *measured* stage times,
//! 2. generates candidate partitions — a full re-run of the configured
//!    scheduler on the calibrated testbed, plus a targeted swap of the
//!    worst straggler onto the fastest unused device,
//! 3. scores candidates with `simnet::simulate_iteration` against the
//!    simulated iteration time of the *current* plan under measured
//!    times, and
//! 4. recommends adoption only when the best candidate beats the current
//!    plan by more than a hysteresis margin (so noise does not cause
//!    migration churn).
//!
//! The broker applies an adopted decision at the next iteration boundary
//! (tear down workers, migrate `StageState`, respawn); `simulate` uses the
//! same machinery for the CI straggler smoke.

use super::Scheduler;
use crate::cluster::Testbed;
use crate::compress::CompressPlan;
use crate::cost::{detect_stragglers, ProfileStore};
use crate::opdag::{Dag, Partition};
use crate::pipeline::{PipelineSchedule, ScheduleKind};
use crate::simnet::{simulate_iteration, StagePlan};

/// What the runtime does with a re-plan recommendation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanMode {
    /// Never re-plan (static schedule, the seed behavior).
    Off,
    /// Detect + log recommendations, but keep the current plan.
    Advise,
    /// Apply adopted recommendations at the next iteration boundary.
    Auto,
}

impl ReplanMode {
    pub fn parse(s: &str) -> anyhow::Result<ReplanMode> {
        Ok(match s {
            "off" => ReplanMode::Off,
            "advise" => ReplanMode::Advise,
            "auto" => ReplanMode::Auto,
            other => anyhow::bail!("unknown replan mode `{other}` (off|advise|auto)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ReplanMode::Off => "off",
            ReplanMode::Advise => "advise",
            ReplanMode::Auto => "auto",
        }
    }
}

/// A scored candidate re-plan.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub partition: Partition,
    /// Predicted stage plan (measurement-calibrated times).
    pub plan: StagePlan,
    /// How the candidate was generated: "reschedule" or "swap".
    pub origin: &'static str,
}

/// The re-planner's verdict for one check.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    /// Straggler stages that triggered the check, slowest first.
    pub flagged: Vec<usize>,
    /// Simulated iteration seconds of the current plan (measured times).
    pub current_sim_s: f64,
    /// Simulated iteration seconds of the best candidate.
    pub candidate_sim_s: f64,
    /// Modeled parameter-migration time (per-link batched transfers of
    /// the moved stages' parameters, links in parallel).
    pub migration_s: f64,
    /// True when the improvement clears the hysteresis margin.
    pub adopt: bool,
    pub candidate: Candidate,
}

/// Everything `Replanner::consider` needs about the current run.
pub struct ReplanInput<'a> {
    pub dag: &'a Dag,
    pub testbed: &'a Testbed,
    /// Current partition (op -> device).
    pub part: &'a Partition,
    /// Model-estimated stage plan for the current partition on the
    /// believed testbed (the calibration baseline).
    pub modeled: &'a StagePlan,
    pub store: &'a ProfileStore,
    pub schedule: ScheduleKind,
    pub n_micro: usize,
    /// Compression plan in force for the current partition.
    pub current_compress: &'a CompressPlan,
}

#[derive(Debug, Clone)]
pub struct Replanner {
    /// Scheduler to re-run on the calibrated testbed ("opfence", ...).
    pub scheduler: String,
    /// Straggler threshold: flag stages busier than threshold × median.
    pub threshold: f64,
    /// Required relative improvement of the simulated iteration before a
    /// candidate is adopted (0.1 = 10% better).
    pub hysteresis: f64,
    /// Minimum measured iterations per stage before the first check.
    pub min_samples: usize,
    /// Reject candidates that change the stage count (the live worker
    /// chain cannot grow/shrink mid-run; `simulate` may relax this).
    pub keep_stage_count: bool,
}

impl Default for Replanner {
    fn default() -> Replanner {
        Replanner {
            scheduler: "opfence".into(),
            threshold: 2.0,
            hysteresis: 0.10,
            min_samples: 3,
            keep_stage_count: true,
        }
    }
}

impl Replanner {
    /// Calibrate per-device λ so the cost model reproduces the measured
    /// per-stage busy times: λ' = λ · modeled/measured, clamped to (0, 1].
    /// Devices without measurements keep their believed λ.
    pub fn calibrate_testbed(
        &self,
        tb: &Testbed,
        modeled: &StagePlan,
        measured: &StagePlan,
    ) -> Testbed {
        let mut cal = tb.clone();
        for s in 0..modeled.n_stages().min(measured.n_stages()) {
            let dev = modeled.devices[s];
            let t_model = modeled.fwd_s[s] + modeled.bwd_s[s];
            let t_meas = measured.fwd_s[s] + measured.bwd_s[s];
            if t_model > 0.0 && t_meas > 0.0 {
                let l = cal.nodes[dev].lambda * t_model / t_meas;
                cal.nodes[dev].lambda = l.clamp(1e-6, 1.0);
            }
        }
        cal
    }

    /// Check the measured profile for stragglers and, if any, search for
    /// a better partition. Returns None when there is nothing to do
    /// (insufficient samples, no straggler, or no distinct candidate).
    pub fn consider(
        &self,
        inp: &ReplanInput,
        rebuild_compress: &dyn Fn(&Partition, &Testbed) -> CompressPlan,
    ) -> anyhow::Result<Option<ReplanDecision>> {
        if !inp.store.ready() || inp.store.min_samples() < self.min_samples {
            return Ok(None);
        }
        let report = detect_stragglers(inp.store, self.threshold);
        if report.flagged.is_empty() {
            return Ok(None);
        }

        let measured = inp.store.measured_plan(inp.modeled);
        let cal_tb = self.calibrate_testbed(inp.testbed, inp.modeled, &measured);
        let cur_sched =
            PipelineSchedule::new(inp.schedule, measured.n_stages(), inp.n_micro);
        let current_sim =
            simulate_iteration(&measured, &cal_tb, &cur_sched, inp.current_compress).iter_s;

        let mut candidates: Vec<Candidate> = Vec::new();
        // (a) full re-run of the configured scheduler with calibrated λ.
        if let Ok(sched) = super::by_name(&self.scheduler) {
            if let Ok(part) = sched.schedule(inp.dag, &cal_tb) {
                if part.validate(inp.dag).is_ok() {
                    let plan = StagePlan::from_partition(inp.dag, &part, &cal_tb);
                    candidates.push(Candidate { partition: part, plan, origin: "reschedule" });
                }
            }
        }
        // (b) targeted swap: worst straggler -> fastest unused device.
        if let Some(c) = self.swap_candidate(inp, &cal_tb, &measured, &report.flagged) {
            candidates.push(c);
        }

        let mut best: Option<(f64, Candidate)> = None;
        for cand in candidates {
            if self.keep_stage_count && cand.plan.n_stages() != measured.n_stages() {
                continue;
            }
            // Skip identical assignments (device order can be unchanged
            // while ops still move across split points, so compare per-op).
            if (0..inp.dag.len())
                .all(|op| cand.partition.node_of(op) == inp.part.node_of(op))
            {
                continue; // nothing would move
            }
            let sched =
                PipelineSchedule::new(inp.schedule, cand.plan.n_stages(), inp.n_micro);
            let compress = rebuild_compress(&cand.partition, &cal_tb);
            let sim = simulate_iteration(&cand.plan, &cal_tb, &sched, &compress).iter_s;
            if best.as_ref().map(|(s, _)| sim < *s).unwrap_or(true) {
                best = Some((sim, cand));
            }
        }
        let (candidate_sim_s, candidate) = match best {
            Some(b) => b,
            None => return Ok(None),
        };

        let migration_s =
            migration_time(inp.dag, inp.part, &candidate.partition, inp.testbed);
        let adopt = candidate_sim_s < current_sim * (1.0 - self.hysteresis);
        Ok(Some(ReplanDecision {
            flagged: report.flagged,
            current_sim_s: current_sim,
            candidate_sim_s,
            migration_s,
            adopt,
            candidate,
        }))
    }

    /// Re-partition around a device declared dead (churn recovery). The
    /// testbed must already have the device marked failed
    /// (`Testbed::fail_node`). Candidates, in preference order:
    ///
    /// 1. "failover-reschedule" — the configured scheduler re-run on the
    ///    compacted surviving testbed, mapped back to original ids;
    /// 2. "failover-swap" — the dead stage alone moves to the fastest
    ///    surviving device not hosting a stage;
    /// 3. "failover-cohost" — no free device left: the dead stage joins
    ///    an adjacent stage's device (the chain stays contiguous), so the
    ///    run limps on rather than dying.
    ///
    /// Unlike the straggler path there is no "keep the current plan"
    /// option, so the first structurally valid candidate wins.
    pub fn replan_after_failure(
        &self,
        inp: &ReplanInput,
        dead_stage: usize,
    ) -> anyhow::Result<Candidate> {
        let tb = inp.testbed;
        let s_n = inp.modeled.n_stages();
        anyhow::ensure!(dead_stage < s_n, "dead stage {dead_stage} out of range");
        let dead_dev = inp.modeled.devices[dead_stage];
        anyhow::ensure!(
            tb.net.is_failed(dead_dev),
            "device {dead_dev} not marked failed before failover replan"
        );
        anyhow::ensure!(tb.net.n_alive() > 0, "no surviving devices");
        let measured = inp.store.measured_plan(inp.modeled);

        // (1) full re-run of the configured scheduler across survivors.
        let (sub, map) = tb.surviving();
        if let Ok(sched) = super::by_name(&self.scheduler) {
            if let Ok(sub_part) = sched.schedule(inp.dag, &sub) {
                let assign: Vec<usize> =
                    (0..inp.dag.len()).map(|op| map[sub_part.node_of(op)]).collect();
                let part = Partition::new(assign);
                if part.validate(inp.dag).is_ok() {
                    let plan = StagePlan::from_partition(inp.dag, &part, tb);
                    if !self.keep_stage_count || plan.n_stages() == s_n {
                        return Ok(Candidate {
                            partition: part,
                            plan,
                            origin: "failover-reschedule",
                        });
                    }
                }
            }
        }

        // (2) move only the dead stage to the fastest free survivor.
        let free_best = (0..tb.nodes.len())
            .filter(|&d| !tb.net.is_failed(d) && !measured.devices.contains(&d))
            .max_by(|&a, &b| {
                tb.nodes[a]
                    .speed_flops()
                    .partial_cmp(&tb.nodes[b].speed_flops())
                    .unwrap()
            });
        let (new_dev, origin) = match free_best {
            Some(d) => (d, "failover-swap"),
            // (3) co-host on the faster surviving *adjacent* stage's
            // device so the device sequence stays contiguous.
            None => {
                let neighbor = [dead_stage.checked_sub(1), Some(dead_stage + 1)]
                    .into_iter()
                    .flatten()
                    .filter(|&s| s < s_n)
                    .map(|s| measured.devices[s])
                    .filter(|&d| !tb.net.is_failed(d))
                    .max_by(|&a, &b| {
                        tb.nodes[a]
                            .speed_flops()
                            .partial_cmp(&tb.nodes[b].speed_flops())
                            .unwrap()
                    })
                    .ok_or_else(|| {
                        anyhow::anyhow!("no surviving device adjacent to stage {dead_stage}")
                    })?;
                (neighbor, "failover-cohost")
            }
        };
        let assign: Vec<usize> = (0..inp.dag.len())
            .map(|op| {
                let d = inp.part.node_of(op);
                if d == dead_dev {
                    new_dev
                } else {
                    d
                }
            })
            .collect();
        let mut plan = measured.clone();
        plan.devices[dead_stage] = new_dev;
        let scale = tb.nodes[dead_dev].speed_flops() / tb.nodes[new_dev].speed_flops();
        plan.fwd_s[dead_stage] *= scale;
        plan.bwd_s[dead_stage] *= scale;
        plan.update_s[dead_stage] *= scale;
        Ok(Candidate { partition: Partition::new(assign), plan, origin })
    }

    /// Consider folding a newly admitted (joined or rejoined) device into
    /// the pipeline. The testbed must already have the device marked
    /// alive (`Testbed::unfail_node` / `add_node`). Candidates that do
    /// not *use* the newcomer are discarded — reshuffles among incumbents
    /// belong to the straggler path — and adoption is hysteresis-gated
    /// exactly like `consider`, so a slow joiner stays parked as a spare
    /// instead of causing migration churn. Returns None when there is no
    /// candidate that exploits the newcomer.
    pub fn replan_after_join(
        &self,
        inp: &ReplanInput,
        joined_dev: usize,
        rebuild_compress: &dyn Fn(&Partition, &Testbed) -> CompressPlan,
    ) -> anyhow::Result<Option<ReplanDecision>> {
        let tb = inp.testbed;
        anyhow::ensure!(
            joined_dev < tb.nodes.len(),
            "joined device {joined_dev} out of range"
        );
        anyhow::ensure!(
            !tb.net.is_failed(joined_dev),
            "device {joined_dev} still marked failed after admission"
        );
        // A fresh joiner has no measurements yet; fall back to the model.
        let measured = if inp.store.ready() && inp.store.min_samples() >= 1 {
            inp.store.measured_plan(inp.modeled)
        } else {
            inp.modeled.clone()
        };
        if measured.devices.contains(&joined_dev) {
            return Ok(None); // already hosting a stage; nothing to fold in
        }
        let cal_tb = self.calibrate_testbed(tb, inp.modeled, &measured);
        let cur_sched =
            PipelineSchedule::new(inp.schedule, measured.n_stages(), inp.n_micro);
        let current_sim =
            simulate_iteration(&measured, &cal_tb, &cur_sched, inp.current_compress).iter_s;

        let mut candidates: Vec<Candidate> = Vec::new();
        // (a) full re-run of the configured scheduler across the alive
        // view (newcomer included), mapped back to original ids.
        let (sub, map) = cal_tb.surviving();
        if let Ok(sched) = super::by_name(&self.scheduler) {
            if let Ok(sub_part) = sched.schedule(inp.dag, &sub) {
                let assign: Vec<usize> =
                    (0..inp.dag.len()).map(|op| map[sub_part.node_of(op)]).collect();
                let part = Partition::new(assign);
                if part.validate(inp.dag).is_ok() {
                    let plan = StagePlan::from_partition(inp.dag, &part, &cal_tb);
                    candidates.push(Candidate {
                        partition: part,
                        plan,
                        origin: "join-reschedule",
                    });
                }
            }
        }
        // (b) targeted: the slowest stage moves onto the newcomer, if the
        // newcomer is faster than that stage's current host.
        if let Some(c) = self.join_swap_candidate(inp, &cal_tb, &measured, joined_dev) {
            candidates.push(c);
        }

        let mut best: Option<(f64, Candidate)> = None;
        for cand in candidates {
            if self.keep_stage_count && cand.plan.n_stages() != measured.n_stages() {
                continue;
            }
            if !cand.plan.devices.contains(&joined_dev) {
                continue; // must exploit the newcomer
            }
            let sched =
                PipelineSchedule::new(inp.schedule, cand.plan.n_stages(), inp.n_micro);
            let compress = rebuild_compress(&cand.partition, &cal_tb);
            let sim = simulate_iteration(&cand.plan, &cal_tb, &sched, &compress).iter_s;
            if best.as_ref().map(|(s, _)| sim < *s).unwrap_or(true) {
                best = Some((sim, cand));
            }
        }
        let (candidate_sim_s, candidate) = match best {
            Some(b) => b,
            None => return Ok(None),
        };
        let migration_s =
            migration_time(inp.dag, inp.part, &candidate.partition, tb);
        let adopt = candidate_sim_s < current_sim * (1.0 - self.hysteresis);
        Ok(Some(ReplanDecision {
            flagged: Vec::new(),
            current_sim_s: current_sim,
            candidate_sim_s,
            migration_s,
            adopt,
            candidate,
        }))
    }

    /// Move the slowest stage (by measured fwd+bwd) onto the freshly
    /// joined device, if the newcomer out-runs that stage's current host.
    fn join_swap_candidate(
        &self,
        inp: &ReplanInput,
        cal_tb: &Testbed,
        measured: &StagePlan,
        new_dev: usize,
    ) -> Option<Candidate> {
        let worst = (0..measured.n_stages()).max_by(|&a, &b| {
            (measured.fwd_s[a] + measured.bwd_s[a])
                .partial_cmp(&(measured.fwd_s[b] + measured.bwd_s[b]))
                .unwrap()
        })?;
        let old_dev = measured.devices[worst];
        let speed_old = cal_tb.nodes[old_dev].speed_flops();
        let speed_new = cal_tb.nodes[new_dev].speed_flops();
        if speed_new <= speed_old {
            return None;
        }
        let assign: Vec<usize> = (0..inp.dag.len())
            .map(|op| {
                let d = inp.part.node_of(op);
                if d == old_dev {
                    new_dev
                } else {
                    d
                }
            })
            .collect();
        let mut plan = measured.clone();
        plan.devices[worst] = new_dev;
        let scale = speed_old / speed_new;
        plan.fwd_s[worst] *= scale;
        plan.bwd_s[worst] *= scale;
        plan.update_s[worst] *= scale;
        Some(Candidate { partition: Partition::new(assign), plan, origin: "join-swap" })
    }

    /// Move the worst straggler stage onto the fastest device not
    /// currently hosting any stage. Times for the moved stage scale with
    /// the calibrated speed ratio; everything else keeps its measurement.
    fn swap_candidate(
        &self,
        inp: &ReplanInput,
        cal_tb: &Testbed,
        measured: &StagePlan,
        flagged: &[usize],
    ) -> Option<Candidate> {
        let worst = *flagged.first()?;
        let old_dev = measured.devices[worst];
        let best_dev = (0..cal_tb.nodes.len())
            .filter(|d| !measured.devices.contains(d))
            .max_by(|&a, &b| {
                cal_tb.nodes[a]
                    .speed_flops()
                    .partial_cmp(&cal_tb.nodes[b].speed_flops())
                    .unwrap()
            })?;
        let speed_old = cal_tb.nodes[old_dev].speed_flops();
        let speed_new = cal_tb.nodes[best_dev].speed_flops();
        if speed_new <= speed_old {
            return None;
        }
        let assign: Vec<usize> = (0..inp.dag.len())
            .map(|op| {
                let d = inp.part.node_of(op);
                if d == old_dev {
                    best_dev
                } else {
                    d
                }
            })
            .collect();
        let mut plan = measured.clone();
        plan.devices[worst] = best_dev;
        let scale = speed_old / speed_new;
        plan.fwd_s[worst] *= scale;
        plan.bwd_s[worst] *= scale;
        plan.update_s[worst] *= scale;
        Some(Candidate { partition: Partition::new(assign), plan, origin: "swap" })
    }
}

/// Modeled parameter-migration time from `from` to `to`: per-op parameter
/// bytes batched per (src, dst) link, links transferring in parallel.
pub fn migration_time(dag: &Dag, from: &Partition, to: &Partition, tb: &Testbed) -> f64 {
    let mut per_link: std::collections::BTreeMap<(usize, usize), f64> = Default::default();
    for op in &dag.ops {
        let (a, b) = (from.node_of(op.id), to.node_of(op.id));
        if a != b && op.param_bytes > 0.0 {
            *per_link.entry((a, b)).or_insert(0.0) += op.param_bytes;
        }
    }
    per_link
        .iter()
        .map(|(&(a, b), &bytes)| tb.net.comm_time(a, b, bytes))
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::scheduler::by_name;

    fn setup() -> (Dag, Testbed, Partition, StagePlan) {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let part = by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let plan = StagePlan::from_partition(&dag, &part, &tb);
        (dag, tb, part, plan)
    }

    fn store_from(plan: &StagePlan, n_micro: usize) -> ProfileStore {
        let mut st = ProfileStore::new(plan.n_stages(), n_micro, 1.0);
        st.seed_from_plan(plan);
        st
    }

    #[test]
    fn calibration_recovers_slowdown() {
        let (_, tb, _, plan) = setup();
        let mut slowed = plan.clone();
        let dev = slowed.devices[0];
        slowed.fwd_s[0] *= 4.0;
        slowed.bwd_s[0] *= 4.0;
        let r = Replanner::default();
        let cal = r.calibrate_testbed(&tb, &plan, &slowed);
        let ratio = cal.nodes[dev].lambda / tb.nodes[dev].lambda;
        assert!((ratio - 0.25).abs() < 1e-9, "λ ratio {ratio}");
        // Everyone else untouched.
        for n in &tb.nodes {
            if n.id != dev {
                assert_eq!(cal.nodes[n.id].lambda, n.lambda);
            }
        }
    }

    #[test]
    fn healthy_cluster_yields_no_decision() {
        let (dag, tb, part, plan) = setup();
        let st = store_from(&plan, 2);
        let r = Replanner { min_samples: 1, ..Default::default() };
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let d = r
            .consider(&inp, &|_, t| CompressPlan::dense(t.nodes.len()))
            .unwrap();
        assert!(d.is_none(), "no straggler must mean no decision");
    }

    #[test]
    fn straggler_triggers_adoptable_replan() {
        let (dag, tb, part, plan) = setup();
        // One device 6x slower than believed.
        let slow_stage = plan.n_stages() / 2;
        let mut st = ProfileStore::new(plan.n_stages(), 2, 1.0);
        let mut slowed = plan.clone();
        slowed.fwd_s[slow_stage] *= 6.0;
        slowed.bwd_s[slow_stage] *= 6.0;
        st.seed_from_plan(&slowed);
        let r = Replanner { min_samples: 1, hysteresis: 0.05, ..Default::default() };
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let d = r
            .consider(&inp, &|_, t| CompressPlan::dense(t.nodes.len()))
            .unwrap()
            .expect("slowdown must produce a decision");
        assert_eq!(d.flagged[0], slow_stage);
        assert!(
            d.candidate_sim_s < d.current_sim_s,
            "candidate {} !< current {}",
            d.candidate_sim_s,
            d.current_sim_s
        );
        assert!(d.adopt, "6x straggler must clear the hysteresis margin");
        assert!(d.candidate.plan.n_stages() == plan.n_stages());
        assert!(d.migration_s >= 0.0);
    }

    #[test]
    fn hysteresis_blocks_marginal_wins() {
        let (dag, tb, part, plan) = setup();
        let slow_stage = plan.n_stages() / 2;
        let mut st = ProfileStore::new(plan.n_stages(), 2, 1.0);
        let mut slowed = plan.clone();
        slowed.fwd_s[slow_stage] *= 6.0;
        slowed.bwd_s[slow_stage] *= 6.0;
        st.seed_from_plan(&slowed);
        // An impossible hysteresis bar: nothing can be 99.99% faster.
        let r = Replanner { min_samples: 1, hysteresis: 0.9999, ..Default::default() };
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let d = r
            .consider(&inp, &|_, t| CompressPlan::dense(t.nodes.len()))
            .unwrap()
            .expect("straggler still flagged");
        assert!(!d.adopt, "hysteresis must block adoption");
    }

    #[test]
    fn failover_reschedules_around_dead_device() {
        // Short chain -> few stages, so survivors can host the same
        // stage count and the scheduler path wins.
        let mut tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec {
            vocab: 1000,
            d_model: 128,
            n_heads: 4,
            n_layers: 2,
            seq_len: 64,
            microbatch: 2,
        });
        let part = by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let plan = StagePlan::from_partition(&dag, &part, &tb);
        let s_n = plan.n_stages();
        let dead_stage = s_n / 2;
        let dead_dev = plan.devices[dead_stage];
        tb.fail_node(dead_dev);
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r = Replanner { min_samples: 1, ..Default::default() };
        let c = r.replan_after_failure(&inp, dead_stage).unwrap();
        assert_eq!(c.origin, "failover-reschedule");
        assert_eq!(c.plan.n_stages(), s_n, "stage count must survive failover");
        assert!(
            !c.plan.devices.contains(&dead_dev),
            "dead device {dead_dev} still hosts a stage: {:?}",
            c.plan.devices
        );
        c.partition.validate(&dag).unwrap();
        for op in 0..dag.len() {
            assert_ne!(c.partition.node_of(op), dead_dev);
        }
    }

    #[test]
    fn failover_cohosts_when_no_free_device_remains() {
        // gpt2-xl uses all 24 devices; killing one leaves 23 survivors
        // for 24 stages — the dead stage must co-host with a neighbor.
        let (dag, mut tb, part, plan) = setup();
        let s_n = plan.n_stages();
        assert_eq!(s_n, tb.nodes.len(), "precondition: every device hosts a stage");
        let dead_stage = 5.min(s_n - 1);
        let dead_dev = plan.devices[dead_stage];
        tb.fail_node(dead_dev);
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r = Replanner { min_samples: 1, ..Default::default() };
        let c = r.replan_after_failure(&inp, dead_stage).unwrap();
        assert_eq!(c.origin, "failover-cohost");
        assert_eq!(c.plan.n_stages(), s_n);
        let host = c.plan.devices[dead_stage];
        assert_ne!(host, dead_dev);
        let neighbors: Vec<usize> = [dead_stage.wrapping_sub(1), dead_stage + 1]
            .iter()
            .filter(|&&s| s < s_n)
            .map(|&s| plan.devices[s])
            .collect();
        assert!(neighbors.contains(&host), "{host} not adjacent: {neighbors:?}");
        for op in 0..dag.len() {
            assert_ne!(c.partition.node_of(op), dead_dev);
        }
    }

    #[test]
    fn failover_requires_marked_device() {
        let (dag, tb, part, plan) = setup();
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r = Replanner::default();
        assert!(r.replan_after_failure(&inp, 0).is_err());
    }

    /// 3 slow RTX 2080s + 1 fast RTX 4090, uniform fast links. The fast
    /// device starts failed so the plan lands on the slow trio.
    fn tiny_join_setup() -> (Dag, Testbed, Partition, StagePlan) {
        use crate::cluster::{CompNode, GpuModel, NetGraph};
        let mut nodes = Vec::new();
        for i in 0..4 {
            nodes.push(CompNode {
                id: i,
                name: format!("n{i}"),
                gpu: if i == 3 { GpuModel::Rtx4090 } else { GpuModel::Rtx2080 },
                lambda: 0.5,
                cluster: "A".into(),
                machine: i,
            });
        }
        let mut net = NetGraph::new(4);
        for i in 0..4 {
            for j in (i + 1)..4 {
                net.set_link(i, j, 1e-4, 1e9);
            }
        }
        let mut tb = Testbed { name: "tiny".into(), nodes, net };
        tb.fail_node(3);
        let dag = transformer_chain(&TransformerSpec {
            vocab: 1000,
            d_model: 128,
            n_heads: 4,
            n_layers: 2,
            seq_len: 64,
            microbatch: 2,
        });
        let (sub, map) = tb.surviving();
        let sub_part = by_name("opfence").unwrap().schedule(&dag, &sub).unwrap();
        let assign: Vec<usize> =
            (0..dag.len()).map(|op| map[sub_part.node_of(op)]).collect();
        let part = Partition::new(assign);
        let plan = StagePlan::from_partition(&dag, &part, &tb);
        (dag, tb, part, plan)
    }

    #[test]
    fn join_replan_exploits_a_faster_newcomer() {
        let (dag, mut tb, part, plan) = tiny_join_setup();
        assert!(!plan.devices.contains(&3), "precondition: spare not hosting");
        tb.unfail_node(3);
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r = Replanner { min_samples: 1, hysteresis: 0.01, ..Default::default() };
        let d = r
            .replan_after_join(&inp, 3, &|_, t| CompressPlan::dense(t.nodes.len()))
            .unwrap()
            .expect("a strictly faster newcomer must yield a candidate");
        assert!(
            d.candidate.plan.devices.contains(&3),
            "candidate must use the newcomer: {:?}",
            d.candidate.plan.devices
        );
        assert!(d.candidate.origin.starts_with("join-"), "{}", d.candidate.origin);
        assert_eq!(d.candidate.plan.n_stages(), plan.n_stages());
        assert!(
            d.candidate_sim_s < d.current_sim_s,
            "candidate {} !< current {}",
            d.candidate_sim_s,
            d.current_sim_s
        );
        assert!(d.adopt, "a 4090 joining a 2080 trio must clear 1% hysteresis");
        assert!(d.migration_s >= 0.0);
        d.candidate.partition.validate(&dag).unwrap();
    }

    #[test]
    fn join_replan_hysteresis_parks_the_spare() {
        let (dag, mut tb, part, plan) = tiny_join_setup();
        tb.unfail_node(3);
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r =
            Replanner { min_samples: 1, hysteresis: 0.9999, ..Default::default() };
        let d = r
            .replan_after_join(&inp, 3, &|_, t| CompressPlan::dense(t.nodes.len()))
            .unwrap()
            .expect("candidate still generated");
        assert!(!d.adopt, "impossible hysteresis bar must park the joiner");
    }

    #[test]
    fn join_replan_rejects_failed_or_hosting_devices() {
        let (dag, tb, part, plan) = tiny_join_setup();
        let st = store_from(&plan, 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let inp = ReplanInput {
            dag: &dag,
            testbed: &tb,
            part: &part,
            modeled: &plan,
            store: &st,
            schedule: ScheduleKind::GPipe,
            n_micro: 2,
            current_compress: &dense,
        };
        let r = Replanner { min_samples: 1, ..Default::default() };
        // Still marked failed -> error (broker must unfail first).
        assert!(r
            .replan_after_join(&inp, 3, &|_, t| CompressPlan::dense(t.nodes.len()))
            .is_err());
        // Already hosting a stage -> no-op.
        let hosted = plan.devices[0];
        let d = r
            .replan_after_join(&inp, hosted, &|_, t| {
                CompressPlan::dense(t.nodes.len())
            })
            .unwrap();
        assert!(d.is_none());
    }

    #[test]
    fn replan_mode_parses() {
        assert_eq!(ReplanMode::parse("off").unwrap(), ReplanMode::Off);
        assert_eq!(ReplanMode::parse("advise").unwrap(), ReplanMode::Advise);
        assert_eq!(ReplanMode::parse("auto").unwrap(), ReplanMode::Auto);
        assert!(ReplanMode::parse("sometimes").is_err());
        assert_eq!(ReplanMode::Auto.name(), "auto");
    }
}
