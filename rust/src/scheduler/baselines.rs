//! The paper's two baseline schedulers (§7.2 "Baselines"):
//!
//! 1. equal-number: the same number of user-defined modules per CompNode,
//!    devices in id order (blind to both compute speed and bandwidth).
//! 2. equal-compute: contiguous partitions balancing total FLOPs,
//!    devices in id order (load-balanced but bandwidth-blind).

use super::{partition_from_chain, proportional_contiguous_split, Scheduler};
use crate::cluster::Testbed;
use crate::opdag::{Dag, Partition};

/// Equal number of ops per device, device id order.
pub struct EqualNumber;

impl Scheduler for EqualNumber {
    fn name(&self) -> &'static str {
        "equal-number"
    }

    fn schedule(&self, dag: &Dag, testbed: &Testbed) -> anyhow::Result<Partition> {
        let chain = dag.compute_chain();
        let n_dev = testbed.nodes.len().min(chain.len());
        let weights = vec![1.0; chain.len()];
        let capacity = vec![1.0; n_dev];
        let segs = proportional_contiguous_split(&weights, &capacity);
        let assign: Vec<usize> = segs.iter().map(|&s| s).collect();
        Ok(partition_from_chain(dag, &chain, &assign))
    }
}

/// Equal computation cost per device (FLOPs-balanced), device id order.
pub struct EqualCompute;

impl Scheduler for EqualCompute {
    fn name(&self) -> &'static str {
        "equal-compute"
    }

    fn schedule(&self, dag: &Dag, testbed: &Testbed) -> anyhow::Result<Partition> {
        let chain = dag.compute_chain();
        let n_dev = testbed.nodes.len().min(chain.len());
        let weights: Vec<f64> = chain.iter().map(|&op| dag.ops[op].flops_fwd).collect();
        let capacity = vec![1.0; n_dev];
        let segs = proportional_contiguous_split(&weights, &capacity);
        Ok(partition_from_chain(dag, &chain, &segs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};

    fn big_chain() -> Dag {
        transformer_chain(&TransformerSpec {
            vocab: 1000,
            d_model: 128,
            n_heads: 4,
            n_layers: 46, // chain = 48 compute ops
            seq_len: 64,
            microbatch: 2,
        })
    }

    #[test]
    fn equal_number_uses_all_devices_evenly() {
        let tb = testbed1(1); // 24 devices
        let dag = big_chain(); // 48 compute ops
        let p = EqualNumber.schedule(&dag, &tb).unwrap();
        p.validate(&dag).unwrap();
        assert_eq!(p.nodes_used(), 24);
        // Exactly 2 compute ops per device.
        let chain = dag.compute_chain();
        let mut counts = vec![0usize; 24];
        for &op in &chain {
            counts[p.node_of(op)] += 1;
        }
        assert!(counts.iter().all(|&c| c == 2), "{counts:?}");
    }

    #[test]
    fn equal_compute_balances_flops() {
        let tb = testbed1(1);
        let dag = big_chain();
        let p = EqualCompute.schedule(&dag, &tb).unwrap();
        p.validate(&dag).unwrap();
        let mut flops = vec![0.0f64; 24];
        for op in &dag.ops {
            flops[p.node_of(op.id)] += op.flops_fwd;
        }
        let max = flops.iter().cloned().fold(0.0, f64::max);
        let min = flops.iter().cloned().fold(f64::MAX, f64::min);
        // Head op is heavy; allow 4x imbalance but not the 100x the
        // equal-number split would give on this skewed chain.
        assert!(max / min < 6.0, "max={max:.2e} min={min:.2e}");
    }

    #[test]
    fn more_devices_than_ops_is_ok() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec {
            vocab: 100,
            d_model: 32,
            n_heads: 2,
            n_layers: 4,
            seq_len: 16,
            microbatch: 1,
        });
        let p = EqualNumber.schedule(&dag, &tb).unwrap();
        p.validate(&dag).unwrap();
        assert!(p.nodes_used() <= dag.compute_chain().len());
    }
}
