//! Min-bottleneck chain partition DP (the "opfence-dp" ablation).
//!
//! Given a FIXED device order (from OP-Fence's cluster path), choose the
//! contiguous segment boundaries minimizing the pipeline bottleneck
//! `max_p max(C_p, R_p)` (the term that multiplies (n_b−1) in Eq. 3),
//! breaking ties toward smaller total latency. O(n²·k).

use crate::cluster::Testbed;
use crate::opdag::Dag;

/// Returns segment index (position in `order`) per chain position.
pub fn min_bottleneck_split(
    dag: &Dag,
    chain: &[usize],
    testbed: &Testbed,
    order: &[usize],
    _n_micro: usize,
) -> Vec<usize> {
    let n = chain.len();
    let k = order.len().min(n);

    // Prefix FLOPs (fwd+bwd) for O(1) segment compute cost.
    let mut prefix = vec![0.0f64; n + 1];
    for (i, &op) in chain.iter().enumerate() {
        prefix[i + 1] =
            prefix[i] + dag.ops[op].flops_fwd + dag.ops[op].flops_bwd();
    }

    // Cost of assigning chain[j..i] to device slot d (0-based in `order`):
    // C = flops / speed; R = incoming activation over link (d-1 -> d) and
    // incoming gradient over link (d+1 -> d) — neighbors known because the
    // order is fixed. Boundary bytes use the edge op's activation size.
    let seg_cost = |j: usize, i: usize, d: usize| -> (f64, f64) {
        let dev = order[d];
        let c = (prefix[i] - prefix[j]) / testbed.nodes[dev].speed_flops();
        let mut r = 0.0;
        if j > 0 && d > 0 {
            let bytes = dag.ops[chain[j - 1]].out_bytes;
            r += testbed.net.comm_time(order[d - 1], dev, bytes);
        }
        if i < n && d + 1 < k {
            // Gradient w.r.t. our last op's output comes back from d+1.
            let bytes = dag.ops[chain[i - 1]].out_bytes;
            r += testbed.net.comm_time(order[d + 1], dev, bytes);
        }
        (c, r)
    };

    // dp[i][d] = (bottleneck, total) covering chain[..i] with devices[..=d].
    const INF: f64 = f64::INFINITY;
    let mut dp = vec![vec![(INF, INF); k]; n + 1];
    let mut parent = vec![vec![0usize; k]; n + 1];
    for i in 1..=n {
        // Device 0 takes the whole prefix.
        let (c, r) = seg_cost(0, i, 0);
        dp[i][0] = (c.max(r), c + r);
    }
    for d in 1..k {
        for i in (d + 1)..=n {
            let mut best = (INF, INF);
            let mut bj = d;
            for j in d..i {
                let (c, r) = seg_cost(j, i, d);
                let prev = dp[j][d - 1];
                if prev.0 == INF {
                    continue;
                }
                let cand = (prev.0.max(c.max(r)), prev.1 + c + r);
                if cand < best {
                    best = cand;
                    bj = j;
                }
            }
            dp[i][d] = best;
            parent[i][d] = bj;
        }
    }

    // Pick the best device count d* ≤ k (using fewer devices is allowed).
    let mut best_d = 0;
    let mut best = dp[n][0];
    for d in 1..k {
        if dp[n][d] < best {
            best = dp[n][d];
            best_d = d;
        }
    }

    // Walk back the boundaries.
    let mut segs = vec![0usize; n];
    let mut i = n;
    let mut d = best_d;
    loop {
        let j = if d == 0 { 0 } else { parent[i][d] };
        for pos in j..i {
            segs[pos] = d;
        }
        if d == 0 {
            break;
        }
        i = j;
        d -= 1;
    }
    segs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};

    #[test]
    fn dp_split_is_contiguous_and_complete() {
        let tb = testbed1(2);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let chain = dag.compute_chain();
        let order: Vec<usize> = (0..24).collect();
        let segs = min_bottleneck_split(&dag, &chain, &tb, &order, 2);
        assert_eq!(segs.len(), chain.len());
        assert!(segs.windows(2).all(|w| w[0] <= w[1] && w[1] - w[0] <= 1));
        assert_eq!(segs[0], 0);
    }

    #[test]
    fn dp_on_uniform_two_devices_splits_near_middle() {
        // Uniform chain, two identical devices, genuinely negligible comm
        // (zero latency, petabit link): the DP must split near the middle.
        let mut tb = testbed1(2);
        tb.nodes.truncate(2);
        tb.nodes[1].gpu = tb.nodes[0].gpu;
        tb.nodes[1].lambda = tb.nodes[0].lambda;
        tb.net = crate::cluster::NetGraph::new(2);
        tb.net.set_link(0, 1, 0.0, 1e15);
        let dag = transformer_chain(&TransformerSpec {
            vocab: 512,
            d_model: 512,
            n_heads: 8,
            n_layers: 18,
            seq_len: 128,
            microbatch: 4,
        });
        let chain = dag.compute_chain();
        let segs = min_bottleneck_split(&dag, &chain, &tb, &[0, 1], 2);
        let n0 = segs.iter().filter(|&&s| s == 0).count();
        assert!(segs.contains(&1), "never split: {segs:?}");
        // Head op is heavier; allow middle ± 4.
        assert!(
            (n0 as i64 - (chain.len() / 2) as i64).abs() <= 4,
            "n0={n0} of {}",
            chain.len()
        );
    }

    #[test]
    fn dp_may_use_fewer_devices_when_comm_dominates() {
        // Two devices across a dreadful link and a tiny model: best plan
        // is to not split at all.
        let mut tb = testbed1(2);
        tb.nodes.truncate(2);
        tb.net = crate::cluster::NetGraph::new(24);
        tb.net.set_link(0, 1, 5.0, 8e6); // 5 s latency
        let dag = transformer_chain(&TransformerSpec {
            vocab: 64,
            d_model: 32,
            n_heads: 2,
            n_layers: 4,
            seq_len: 8,
            microbatch: 1,
        });
        let chain = dag.compute_chain();
        let segs = min_bottleneck_split(&dag, &chain, &tb, &[0, 1], 2);
        assert!(segs.iter().all(|&s| s == 0), "{segs:?}");
    }
}
