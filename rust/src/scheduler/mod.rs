//! Partitioning & placement (§4): the OP-Fence scheduler plus the paper's
//! two baselines (equal-number and equal-compute), and a DP-optimal chain
//! splitter used as an ablation upper bound.
//!
//! All schedulers consume the FP DAG only (the BP DAG mirrors it, §4) and
//! return a `Partition` assigning every op — placeholders included — to a
//! CompNode.

pub mod baselines;
pub mod dp;
pub mod opfence;
pub mod replan;

use crate::cluster::Testbed;
use crate::opdag::{Dag, OpKind, Partition};

/// Common scheduler interface.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Produce an assignment of all ops onto the testbed's CompNodes.
    fn schedule(&self, dag: &Dag, testbed: &Testbed) -> anyhow::Result<Partition>;
}

/// Parse a scheduler by CLI name.
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn Scheduler>> {
    Ok(match name {
        "opfence" => Box::new(opfence::OpFence::default()),
        "opfence-dp" => Box::new(opfence::OpFence { use_dp: true, ..Default::default() }),
        "equal-number" => Box::new(baselines::EqualNumber),
        "equal-compute" => Box::new(baselines::EqualCompute),
        other => anyhow::bail!("unknown scheduler `{other}`"),
    })
}

/// Shared helper: turn per-chain-position device choices into a full
/// Partition, snapping placeholders to their first user's device.
pub(crate) fn partition_from_chain(
    dag: &Dag,
    chain: &[usize],
    chain_assign: &[usize],
) -> Partition {
    assert_eq!(chain.len(), chain_assign.len());
    let mut assign = vec![usize::MAX; dag.len()];
    for (&op, &dev) in chain.iter().zip(chain_assign) {
        assign[op] = dev;
    }
    for op in &dag.ops {
        if op.kind == OpKind::Placeholder {
            assign[op.id] = assign[op.users[0]];
        }
    }
    debug_assert!(assign.iter().all(|&d| d != usize::MAX));
    Partition::new(assign)
}

/// Split `weights` (chain order) into `k` contiguous segments with
/// capacity proportional to `capacity` — greedy prefix walker used by both
/// equal-compute and OP-Fence's within-cluster split. Returns segment id
/// per position (non-decreasing, all k used when possible).
pub(crate) fn proportional_contiguous_split(
    weights: &[f64],
    capacity: &[f64],
) -> Vec<usize> {
    let k = capacity.len();
    assert!(k > 0);
    let n = weights.len();
    let total_w: f64 = weights.iter().sum();
    let total_c: f64 = capacity.iter().sum();
    let mut out = vec![0usize; n];
    let mut seg = 0usize;
    let mut acc = 0.0;
    // Target cumulative weight at the end of each segment.
    let mut target: f64 = total_w * capacity[0] / total_c;
    let mut cum_cap = capacity[0];
    for i in 0..n {
        let remaining_ops = n - i;
        // Segments after the current one still needing >= 1 op each.
        let segs_after = k - 1 - seg;
        // Forced advance: exactly one op left per remaining segment.
        let must_advance = seg + 1 < k && remaining_ops == segs_after;
        let may_advance = seg + 1 < k && remaining_ops > segs_after;
        if must_advance || (may_advance && acc + 0.5 * weights[i] > target) {
            seg += 1;
            cum_cap += capacity[seg];
            target = total_w * cum_cap / total_c;
        }
        out[i] = seg;
        acc += weights[i];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proportional_split_balances_uniform() {
        let w = vec![1.0; 12];
        let c = vec![1.0; 4];
        let s = proportional_contiguous_split(&w, &c);
        // 3 ops per segment.
        assert_eq!(s, vec![0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn proportional_split_respects_capacity() {
        let w = vec![1.0; 10];
        let c = vec![3.0, 1.0];
        let s = proportional_contiguous_split(&w, &c);
        let seg0 = s.iter().filter(|&&x| x == 0).count();
        assert!((7..=8).contains(&seg0), "seg0={seg0}");
        // Both segments non-empty.
        assert!(s.contains(&1));
    }

    #[test]
    fn proportional_split_more_segments_than_ops() {
        let w = vec![1.0; 2];
        let c = vec![1.0; 5];
        let s = proportional_contiguous_split(&w, &c);
        assert_eq!(s.len(), 2);
        // Non-decreasing and within range.
        assert!(s.windows(2).all(|p| p[0] <= p[1]));
        assert!(s.iter().all(|&x| x < 5));
    }

    #[test]
    fn heavy_first_op_gets_own_segment() {
        let w = vec![100.0, 1.0, 1.0, 1.0];
        let c = vec![1.0, 1.0];
        let s = proportional_contiguous_split(&w, &c);
        assert_eq!(s[0], 0);
        assert_eq!(s[1], 1); // everything else pushed to segment 1
    }
}
