//! OP-Fence (§4): bandwidth-aware partitioning.
//!
//! 1. Detect high-bandwidth device clusters with Louvain (Observation 2).
//! 2. Order clusters along a max-bandwidth path, and devices within a
//!    cluster by machine, so the op chain crosses slow links as few times
//!    as possible (Fig. 5) — each cluster receives a *connected* sub-graph.
//! 3. Split the chain contiguously with per-device capacity proportional
//!    to the measured speed S(p) = λ_p·S*(p), so C_p is balanced (Eq. 5).
//! 4. Optionally refine cut points with a min-bottleneck DP over the fixed
//!    device order (`use_dp`, the "opfence-dp" ablation).

use super::{partition_from_chain, proportional_contiguous_split, Scheduler};
use crate::cluster::louvain::louvain;
use crate::cluster::Testbed;
use crate::opdag::{Dag, Partition};

#[derive(Debug, Clone)]
pub struct OpFence {
    /// Refine split points with the DP (slower, Eq. 3-optimal for the
    /// chosen device order).
    pub use_dp: bool,
    /// Pipeline depth assumed by the DP objective.
    pub n_micro: usize,
    /// Rotate cluster members so the chain crosses each community boundary
    /// on the best link pair (ablated in benches/ablations.rs).
    pub refine_boundaries: bool,
}

impl Default for OpFence {
    fn default() -> Self {
        OpFence { use_dp: false, n_micro: 2, refine_boundaries: true }
    }
}

impl Scheduler for OpFence {
    fn name(&self) -> &'static str {
        if self.use_dp {
            "opfence-dp"
        } else {
            "opfence"
        }
    }

    fn schedule(&self, dag: &Dag, testbed: &Testbed) -> anyhow::Result<Partition> {
        let order = self.device_order(testbed);
        let chain = dag.compute_chain();
        let n_dev = order.len().min(chain.len());
        let order = &order[..n_dev];

        let chain_assign = if self.use_dp {
            let segs = super::dp::min_bottleneck_split(dag, &chain, testbed, order, self.n_micro);
            segs.iter().map(|&s| order[s]).collect::<Vec<_>>()
        } else {
            let weights: Vec<f64> =
                chain.iter().map(|&op| dag.ops[op].flops_fwd.max(1.0)).collect();
            let capacity: Vec<f64> =
                order.iter().map(|&d| testbed.nodes[d].speed_flops()).collect();
            let segs = proportional_contiguous_split(&weights, &capacity);
            segs.iter().map(|&s| order[s]).collect::<Vec<_>>()
        };
        Ok(partition_from_chain(dag, &chain, &chain_assign))
    }
}

impl OpFence {
    /// Cluster-major device order: Louvain communities chained along a
    /// greedy max-bandwidth path; within a community, devices grouped by
    /// machine and ordered by id.
    pub fn device_order(&self, testbed: &Testbed) -> Vec<usize> {
        let n = testbed.nodes.len();
        let comm = louvain(&testbed.net);
        let k = comm.iter().max().map(|&c| c + 1).unwrap_or(0);

        // Members per community.
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, &c) in comm.iter().enumerate() {
            members[c].push(i);
        }
        // Within community: stable order by (cluster label, machine, id) —
        // labels only group co-machine devices; we derive machine grouping
        // purely from bandwidth if labels are absent by sorting on the
        // nearest-neighbor structure. Here machine/id sort is equivalent.
        for m in members.iter_mut() {
            m.sort_by_key(|&i| (testbed.nodes[i].machine, i));
        }

        // Aggregate capacity per community.
        let cap: Vec<f64> = members
            .iter()
            .map(|m| m.iter().map(|&i| testbed.nodes[i].speed_flops()).sum())
            .collect();
        // Mean inter-community bandwidth.
        let mean_bw = |a: &Vec<usize>, b: &Vec<usize>| -> f64 {
            let mut s = 0.0;
            let mut c = 0usize;
            for &i in a {
                for &j in b {
                    s += testbed.net.louvain_weight(i, j);
                    c += 1;
                }
            }
            if c == 0 {
                0.0
            } else {
                s / c as f64
            }
        };

        // Greedy path: start from the highest-capacity community, then
        // repeatedly append the unvisited community with the best
        // bandwidth to the current tail.
        let mut unvisited: Vec<usize> = (0..k).collect();
        let start = (0..k)
            .max_by(|&a, &b| cap[a].partial_cmp(&cap[b]).unwrap())
            .unwrap_or(0);
        let mut path = vec![start];
        unvisited.retain(|&c| c != start);
        while !unvisited.is_empty() {
            let tail = *path.last().unwrap();
            let next = *unvisited
                .iter()
                .max_by(|&&a, &&b| {
                    mean_bw(&members[tail], &members[a])
                        .partial_cmp(&mean_bw(&members[tail], &members[b]))
                        .unwrap()
                })
                .unwrap();
            path.push(next);
            unvisited.retain(|&c| c != next);
        }

        // Boundary refinement: the chain crosses community boundaries at
        // (last device of prev, first device of next). Devices within a
        // machine are interchangeable (uniform fast links), so rotate each
        // community to put the best cross-boundary pair on the boundary.
        let n_bounds = if self.refine_boundaries {
            path.len().saturating_sub(1)
        } else {
            0
        };
        for w in 0..n_bounds {
            let (pa, pb) = (path[w], path[w + 1]);
            let (mut bi, mut bj, mut best) = (0usize, 0usize, -1.0f64);
            for (ii, &i) in members[pa].iter().enumerate() {
                for (jj, &j) in members[pb].iter().enumerate() {
                    let bw = testbed.net.louvain_weight(i, j);
                    if bw > best {
                        best = bw;
                        bi = ii;
                        bj = jj;
                    }
                }
            }
            // Exit device: rotate pa so bi's machine block is last and bi
            // is the final element of that block.
            let exit_machine = testbed.nodes[members[pa][bi]].machine;
            let exit_dev = members[pa][bi];
            let mut pa_new: Vec<usize> = members[pa]
                .iter()
                .copied()
                .filter(|&d| testbed.nodes[d].machine != exit_machine)
                .collect();
            pa_new.extend(
                members[pa]
                    .iter()
                    .copied()
                    .filter(|&d| testbed.nodes[d].machine == exit_machine && d != exit_dev),
            );
            pa_new.push(exit_dev);
            members[pa] = pa_new;
            // Entry device: rotate pb so bj's machine block is first and bj
            // leads it.
            let entry_machine = testbed.nodes[members[pb][bj]].machine;
            let entry_dev = members[pb][bj];
            let mut pb_new = vec![entry_dev];
            pb_new.extend(
                members[pb]
                    .iter()
                    .copied()
                    .filter(|&d| testbed.nodes[d].machine == entry_machine && d != entry_dev),
            );
            pb_new.extend(
                members[pb]
                    .iter()
                    .copied()
                    .filter(|&d| testbed.nodes[d].machine != entry_machine),
            );
            members[pb] = pb_new;
        }

        let mut order = Vec::with_capacity(n);
        for c in path {
            order.extend(&members[c]);
        }
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::{testbed1, testbed2};
    use crate::cost::throughput::{dense_bytes, evaluate, PipelineParams};
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::scheduler::baselines::{EqualCompute, EqualNumber};

    fn gpt2() -> Dag {
        transformer_chain(&TransformerSpec::gpt2_xl())
    }

    #[test]
    fn device_order_keeps_clusters_contiguous() {
        let tb = testbed2(3);
        let order = OpFence::default().device_order(&tb);
        assert_eq!(order.len(), 48);
        // Cluster labels along the order must form contiguous runs.
        let labels: Vec<&str> =
            order.iter().map(|&i| tb.nodes[i].cluster.as_str()).collect();
        let transitions = labels.windows(2).filter(|w| w[0] != w[1]).count();
        assert_eq!(transitions, 1, "order crosses clusters {transitions} times");
    }

    #[test]
    fn opfence_partition_valid_and_cluster_contiguous() {
        let tb = testbed1(1);
        let dag = gpt2();
        let p = OpFence::default().schedule(&dag, &tb).unwrap();
        p.validate(&dag).unwrap();
        // Walk the chain: cluster label changes at most once.
        let chain = dag.compute_chain();
        let labels: Vec<&str> = chain
            .iter()
            .map(|&op| tb.nodes[p.node_of(op)].cluster.as_str())
            .collect();
        let transitions = labels.windows(2).filter(|w| w[0] != w[1]).count();
        assert!(transitions <= 1, "chain crosses clusters {transitions} times");
    }

    #[test]
    fn opfence_beats_baselines_on_iteration_latency() {
        // The headline Fig. 10 ordering: OP-Fence < equal-compute <
        // equal-number, on both testbeds, dense.
        for tb in [testbed1(1), testbed2(1)] {
            let dag = gpt2();
            let params = PipelineParams { n_micro: 2, micro_size: 3, include_bwd: true };
            let t = |s: &dyn Scheduler| {
                let p = s.schedule(&dag, &tb).unwrap();
                p.validate(&dag).unwrap();
                evaluate(&dag, &p, &tb, params, &dense_bytes).t_pipe
            };
            let t_fence = t(&OpFence::default());
            let t_eq_n = t(&EqualNumber);
            let t_eq_c = t(&EqualCompute);
            assert!(
                t_fence < t_eq_c && t_fence < t_eq_n,
                "{}: fence={t_fence:.1} eq_c={t_eq_c:.1} eq_n={t_eq_n:.1}",
                tb.name
            );
        }
    }

    #[test]
    fn dp_refinement_not_worse() {
        let tb = testbed1(5);
        let dag = gpt2();
        let params = PipelineParams { n_micro: 2, micro_size: 3, include_bwd: true };
        let base = OpFence::default().schedule(&dag, &tb).unwrap();
        let dp = OpFence { use_dp: true, ..Default::default() }.schedule(&dag, &tb).unwrap();
        dp.validate(&dag).unwrap();
        let t_base = evaluate(&dag, &base, &tb, params, &dense_bytes).t_pipe;
        let t_dp = evaluate(&dag, &dp, &tb, params, &dense_bytes).t_pipe;
        // DP optimizes the bottleneck; allow small slack on t_pipe (sum
        // term may differ) but it must not be drastically worse.
        assert!(t_dp <= t_base * 1.10, "dp={t_dp} base={t_base}");
    }
}
