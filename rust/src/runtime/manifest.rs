//! Artifact manifest parsing (shared, Send; the PJRT handles are per-thread).

use crate::util::json::Json;
use crate::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Model configuration the artifacts were compiled for.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub microbatch: usize,
    pub n_stages: usize,
    pub compress_ratio: f64,
    pub topk_k: usize,
}

impl ModelCfg {
    /// Elements in one inter-stage activation tensor.
    pub fn act_elems(&self) -> usize {
        self.microbatch * self.seq_len * self.d_model
    }

    /// The config the Null backend trains (no artifacts on disk): tiny
    /// shapes, 4 stages — enough to exercise every broker/wire code
    /// path. Shared by the broker and remote worker processes so both
    /// sides of a TCP handshake derive identical shapes from the name.
    pub fn null_sim(name: &str) -> ModelCfg {
        ModelCfg {
            name: name.to_string(),
            vocab: 61,
            d_model: 8,
            n_heads: 1,
            n_layers: 4,
            seq_len: 8,
            microbatch: 2,
            n_stages: 4,
            compress_ratio: 1.0,
            topk_k: 0,
        }
    }
}

/// Parameter initialization spec.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum InitSpec {
    Zeros,
    Ones,
    Normal(f32),
}

impl InitSpec {
    fn parse(s: &str) -> anyhow::Result<InitSpec> {
        Ok(match s {
            "zeros" => InitSpec::Zeros,
            "ones" => InitSpec::Ones,
            other => {
                let std: f32 = other
                    .strip_prefix("normal:")
                    .ok_or_else(|| anyhow::anyhow!("bad init `{other}`"))?
                    .parse()?;
                InitSpec::Normal(std)
            }
        })
    }
}

/// One named slice of a stage's flat parameter vector.
#[derive(Debug, Clone)]
pub struct SegmentSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub size: usize,
    pub offset: usize,
    pub init: InitSpec,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Embed,
    Body,
    Head,
}

/// One pipeline stage: which artifacts run it and its parameter layout.
#[derive(Debug, Clone)]
pub struct StageSpec {
    pub kind: StageKind,
    pub param_size: usize,
    pub fwd_entry: String,
    pub bwd_entry: String,
    pub segments: Vec<SegmentSpec>,
}

impl StageSpec {
    /// Optimizer update entry for this stage kind.
    pub fn sgd_entry(&self) -> &'static str {
        match self.kind {
            StageKind::Embed => "sgd_embed",
            StageKind::Body => "sgd_body",
            StageKind::Head => "sgd_head",
        }
    }

    pub fn adam_entry(&self) -> &'static str {
        match self.kind {
            StageKind::Embed => "adam_embed",
            StageKind::Body => "adam_body",
            StageKind::Head => "adam_head",
        }
    }

    /// Initialize the flat parameter vector (deterministic per seed).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.param_size];
        let mut rng = Rng::new(seed);
        for seg in &self.segments {
            let dst = &mut flat[seg.offset..seg.offset + seg.size];
            match seg.init {
                InitSpec::Zeros => {}
                InitSpec::Ones => dst.fill(1.0),
                InitSpec::Normal(std) => rng.fill_normal_f32(dst, std),
            }
        }
        flat
    }
}

/// IO tensor description of an artifact entry.
#[derive(Debug, Clone)]
pub struct IoSpec {
    pub name: String,
    pub dtype: String,
    pub shape: Vec<usize>,
}

/// One AOT-compiled computation.
#[derive(Debug, Clone)]
pub struct EntrySpec {
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The per-config artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelCfg,
    pub stages: Vec<StageSpec>,
    pub entries: BTreeMap<String, EntrySpec>,
}

fn parse_io(list: &[Json]) -> anyhow::Result<Vec<IoSpec>> {
    list.iter()
        .map(|j| {
            Ok(IoSpec {
                name: j.req_str("name")?.to_string(),
                dtype: j.req_str("dtype")?.to_string(),
                shape: j
                    .req_arr("shape")?
                    .iter()
                    .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape")))
                    .collect::<anyhow::Result<_>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// A manifest with no artifacts on disk: config only, no stages or
    /// entries. Used by the Null compute backend (`simulate --kill-node`,
    /// churn tests), which mocks the math but runs the real broker /
    /// worker / wire machinery. Loading a PJRT runtime from it fails.
    pub fn synthetic(config: ModelCfg) -> Manifest {
        Manifest {
            dir: PathBuf::from("<synthetic>"),
            config,
            stages: Vec::new(),
            entries: BTreeMap::new(),
        }
    }

    /// Load `<root>/<config>/manifest.json`.
    pub fn load(root: &Path, config: &str) -> anyhow::Result<Manifest> {
        let dir = root.join(config);
        let j = Json::parse_file(&dir.join("manifest.json"))?;
        anyhow::ensure!(j.req_usize("format")? == 1, "unsupported manifest format");

        let c = j.get("config");
        let config = ModelCfg {
            name: c.req_str("name")?.to_string(),
            vocab: c.req_usize("vocab")?,
            d_model: c.req_usize("d_model")?,
            n_heads: c.req_usize("n_heads")?,
            n_layers: c.req_usize("n_layers")?,
            seq_len: c.req_usize("seq_len")?,
            microbatch: c.req_usize("microbatch")?,
            n_stages: c.req_usize("n_stages")?,
            compress_ratio: c.req_f64("compress_ratio")?,
            topk_k: c.req_usize("topk_k")?,
        };

        let mut stages = Vec::new();
        for s in j.req_arr("stages")? {
            let kind = match s.req_str("kind")? {
                "embed" => StageKind::Embed,
                "body" => StageKind::Body,
                "head" => StageKind::Head,
                other => anyhow::bail!("unknown stage kind `{other}`"),
            };
            let segments = s
                .req_arr("segments")?
                .iter()
                .map(|seg| {
                    Ok(SegmentSpec {
                        name: seg.req_str("name")?.to_string(),
                        shape: seg
                            .req_arr("shape")?
                            .iter()
                            .map(|v| v.as_usize().unwrap_or(0))
                            .collect(),
                        size: seg.req_usize("size")?,
                        offset: seg.req_usize("offset")?,
                        init: InitSpec::parse(seg.req_str("init")?)?,
                    })
                })
                .collect::<anyhow::Result<Vec<_>>>()?;
            stages.push(StageSpec {
                kind,
                param_size: s.req_usize("param_size")?,
                fwd_entry: s.req_str("fwd")?.to_string(),
                bwd_entry: s.req_str("bwd")?.to_string(),
                segments,
            });
        }
        anyhow::ensure!(stages.len() == config.n_stages, "stage count mismatch");

        let mut entries = BTreeMap::new();
        let eobj = j
            .get("entries")
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("missing entries"))?;
        for (name, e) in eobj {
            entries.insert(
                name.clone(),
                EntrySpec {
                    file: dir.join(e.req_str("file")?),
                    inputs: parse_io(e.req_arr("inputs")?)?,
                    outputs: parse_io(e.req_arr("outputs")?)?,
                },
            );
        }
        Ok(Manifest { dir, config, stages, entries })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&EntrySpec> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no artifact entry `{name}`"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_root().join("tiny/manifest.json").exists()
    }

    #[test]
    fn loads_tiny_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        assert_eq!(m.config.name, "tiny");
        assert_eq!(m.stages.len(), m.config.n_stages);
        assert_eq!(m.stages[0].kind, StageKind::Embed);
        assert_eq!(m.stages.last().unwrap().kind, StageKind::Head);
        for st in &m.stages {
            assert_eq!(
                st.param_size,
                st.segments.iter().map(|s| s.size).sum::<usize>()
            );
            assert!(m.entries.contains_key(&st.fwd_entry));
            assert!(m.entries.contains_key(st.sgd_entry()));
        }
    }

    #[test]
    fn init_params_deterministic_and_respects_spec() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_root(), "tiny").unwrap();
        let st = &m.stages[1]; // body
        let p1 = st.init_params(7);
        let p2 = st.init_params(7);
        assert_eq!(p1, p2);
        let p3 = st.init_params(8);
        assert_ne!(p1, p3);
        // ln gains are ones, biases zeros.
        for seg in &st.segments {
            let sl = &p1[seg.offset..seg.offset + seg.size];
            match seg.init {
                InitSpec::Ones => assert!(sl.iter().all(|&v| v == 1.0), "{}", seg.name),
                InitSpec::Zeros => assert!(sl.iter().all(|&v| v == 0.0), "{}", seg.name),
                InitSpec::Normal(std) => {
                    let mean: f32 = sl.iter().sum::<f32>() / sl.len() as f32;
                    assert!(mean.abs() < 5.0 * std, "{}", seg.name);
                    assert!(sl.iter().any(|&v| v != 0.0));
                }
            }
        }
    }
}
