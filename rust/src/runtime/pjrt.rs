//! Per-thread PJRT execution: compile HLO-text artifacts once, execute many
//! times. `PjRtClient` is Rc-based (not Send), so every worker thread
//! builds its own `Runtime` with only the entries it needs.

use super::manifest::{EntrySpec, Manifest};
use std::collections::HashMap;
use std::time::Instant;
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

pub struct Runtime {
    client: PjRtClient,
    exes: HashMap<String, PjRtLoadedExecutable>,
    /// Cumulative wall seconds per entry (feeds λ profiling, §3.5).
    pub exec_seconds: HashMap<String, (u64, f64)>,
}

/// XLA's client factory and compiler are not safe to enter from multiple
/// threads simultaneously (workers each build their own client because the
/// handles are Rc-based). Serialize creation + compilation globally.
static LOAD_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

impl Runtime {
    /// Compile the named entries from the manifest (None = all).
    pub fn load(manifest: &Manifest, entries: Option<&[&str]>) -> anyhow::Result<Runtime> {
        let _guard = LOAD_LOCK.lock().unwrap();
        let client = PjRtClient::cpu().map_err(anyhow::Error::msg)?;
        let mut exes = HashMap::new();
        let names: Vec<String> = match entries {
            Some(list) => list.iter().map(|s| s.to_string()).collect(),
            None => manifest.entries.keys().cloned().collect(),
        };
        for name in names {
            let spec: &EntrySpec = manifest.entry(&name)?;
            let proto = HloModuleProto::from_text_file(&spec.file)
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", spec.file.display()))?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile `{name}`: {e}"))?;
            exes.insert(name, exe);
        }
        Ok(Runtime { client, exes, exec_seconds: HashMap::new() })
    }

    /// Execute an entry; returns the decomposed output tuple.
    pub fn exec(&mut self, entry: &str, inputs: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let exe = self
            .exes
            .get(entry)
            .ok_or_else(|| anyhow::anyhow!("entry `{entry}` not loaded"))?;
        let t0 = Instant::now();
        let result = exe
            .execute::<Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute `{entry}`: {e}"))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch `{entry}`: {e}"))?;
        let parts = out.to_tuple().map_err(|e| anyhow::anyhow!("untuple `{entry}`: {e}"))?;
        let dt = t0.elapsed().as_secs_f64();
        let slot = self.exec_seconds.entry(entry.to_string()).or_insert((0, 0.0));
        slot.0 += 1;
        slot.1 += dt;
        Ok(parts)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    // ---- Literal construction helpers -------------------------------------

    /// f32 tensor literal with the given dims.
    pub fn f32_tensor(data: &[f32], dims: &[i64]) -> anyhow::Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Literal::vec1(data).reshape(dims).map_err(anyhow::Error::msg)
    }

    /// i32 tensor literal.
    pub fn i32_tensor(data: &[i32], dims: &[i64]) -> anyhow::Result<Literal> {
        let n: i64 = dims.iter().product();
        anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
        Literal::vec1(data).reshape(dims).map_err(anyhow::Error::msg)
    }

    /// f32 scalar literal.
    pub fn f32_scalar(v: f32) -> Literal {
        Literal::scalar(v)
    }

    /// Extract an f32 vector from a literal.
    pub fn to_f32_vec(lit: &Literal) -> anyhow::Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(anyhow::Error::msg)
    }

    /// Extract the single f32 value of a scalar literal.
    pub fn to_f32_scalar(lit: &Literal) -> anyhow::Result<f32> {
        lit.get_first_element::<f32>().map_err(anyhow::Error::msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        Some(Manifest::load(&root, "tiny").unwrap())
    }

    #[test]
    fn embed_forward_shapes_and_determinism() {
        let Some(m) = manifest() else { return };
        let mut rt = Runtime::load(&m, Some(&["embed_fwd"])).unwrap();
        let cfg = &m.config;
        let params = m.stages[0].init_params(1);
        let p = Runtime::f32_tensor(&params, &[params.len() as i64]).unwrap();
        let tokens: Vec<i32> = (0..cfg.microbatch * cfg.seq_len)
            .map(|i| (i % cfg.vocab) as i32)
            .collect();
        let t = Runtime::i32_tensor(&tokens, &[cfg.microbatch as i64, cfg.seq_len as i64])
            .unwrap();
        let out = rt.exec("embed_fwd", &[p, t]).unwrap();
        assert_eq!(out.len(), 1);
        let x = Runtime::to_f32_vec(&out[0]).unwrap();
        assert_eq!(x.len(), cfg.act_elems());
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn full_stage_roundtrip_loss_is_near_ln_vocab() {
        // Compose embed -> bodies -> head through PJRT and check that the
        // random-init loss sits near ln(V) — proves all artifact legs and
        // the flat-param plumbing line up with the python tests.
        let Some(m) = manifest() else { return };
        let mut rt = Runtime::load(
            &m,
            Some(&["embed_fwd", "body_fwd", "head_fwd_loss"]),
        )
        .unwrap();
        let cfg = m.config.clone();
        let (b, t) = (cfg.microbatch as i64, cfg.seq_len as i64);
        let mut rng = crate::util::rng::Rng::new(3);
        let tokens: Vec<i32> =
            (0..(b * t) as usize).map(|_| rng.below(cfg.vocab as u64) as i32).collect();
        let targets: Vec<i32> =
            (0..(b * t) as usize).map(|_| rng.below(cfg.vocab as u64) as i32).collect();

        let p0 = m.stages[0].init_params(10);
        let mut act = {
            let p = Runtime::f32_tensor(&p0, &[p0.len() as i64]).unwrap();
            let tk = Runtime::i32_tensor(&tokens, &[b, t]).unwrap();
            rt.exec("embed_fwd", &[p, tk]).unwrap().remove(0)
        };
        for (si, st) in m.stages.iter().enumerate() {
            if st.kind != super::super::manifest::StageKind::Body {
                continue;
            }
            let ps = st.init_params(10 + si as u64);
            let p = Runtime::f32_tensor(&ps, &[ps.len() as i64]).unwrap();
            act = rt.exec("body_fwd", &[p, act]).unwrap().remove(0);
        }
        let ph = m.stages.last().unwrap().init_params(99);
        let p = Runtime::f32_tensor(&ph, &[ph.len() as i64]).unwrap();
        let tg = Runtime::i32_tensor(&targets, &[b, t]).unwrap();
        let out = rt.exec("head_fwd_loss", &[p, act, tg]).unwrap();
        assert_eq!(out.len(), 3);
        let loss = Runtime::to_f32_scalar(&out[0]).unwrap();
        let expected = (cfg.vocab as f32).ln();
        assert!(
            (loss - expected).abs() < 1.0,
            "loss={loss} vs ln(V)={expected}"
        );
    }

    #[test]
    fn sgd_update_artifact_moves_params() {
        let Some(m) = manifest() else { return };
        let mut rt = Runtime::load(&m, Some(&["sgd_body"])).unwrap();
        let st = &m.stages[1];
        let p0 = st.init_params(5);
        let grads = vec![1.0f32; st.param_size];
        let mom = vec![0.0f32; st.param_size];
        let out = rt
            .exec(
                "sgd_body",
                &[
                    Runtime::f32_tensor(&p0, &[st.param_size as i64]).unwrap(),
                    Runtime::f32_tensor(&grads, &[st.param_size as i64]).unwrap(),
                    Runtime::f32_tensor(&mom, &[st.param_size as i64]).unwrap(),
                    Runtime::f32_scalar(0.1),
                    Runtime::f32_scalar(0.9),
                ],
            )
            .unwrap();
        let p1 = Runtime::to_f32_vec(&out[0]).unwrap();
        let m1 = Runtime::to_f32_vec(&out[1]).unwrap();
        for i in 0..8 {
            assert!((p1[i] - (p0[i] - 0.1)).abs() < 1e-6);
            assert!((m1[i] - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn pallas_parity_artifact_matches_jnp_body() {
        // body_fwd_pallas (L1 kernels lowered into HLO) must equal body_fwd.
        let Some(m) = manifest() else { return };
        if !m.entries.contains_key("body_fwd_pallas") {
            return;
        }
        let mut rt =
            Runtime::load(&m, Some(&["body_fwd", "body_fwd_pallas"])).unwrap();
        let cfg = &m.config;
        let st = &m.stages[1];
        let ps = st.init_params(42);
        let mut rng = crate::util::rng::Rng::new(17);
        let x: Vec<f32> = (0..cfg.act_elems()).map(|_| rng.f32() - 0.5).collect();
        let dims = [cfg.microbatch as i64, cfg.seq_len as i64, cfg.d_model as i64];
        let run = |rt: &mut Runtime, entry: &str| {
            let p = Runtime::f32_tensor(&ps, &[ps.len() as i64]).unwrap();
            let xx = Runtime::f32_tensor(&x, &dims).unwrap();
            let out = rt.exec(entry, &[p, xx]).unwrap();
            Runtime::to_f32_vec(&out[0]).unwrap()
        };
        let a = run(&mut rt, "body_fwd");
        let b = run(&mut rt, "body_fwd_pallas");
        for (i, (u, v)) in a.iter().zip(&b).enumerate() {
            assert!((u - v).abs() < 5e-4, "elem {i}: {u} vs {v}");
        }
    }

    #[test]
    fn topk_compress_artifact_matches_rust_topk() {
        // The L1 compression path and the rust wire compressor agree on
        // the kept support.
        let Some(m) = manifest() else { return };
        let mut rt = Runtime::load(&m, Some(&["topk_compress_act"])).unwrap();
        let cfg = &m.config;
        let mut rng = crate::util::rng::Rng::new(23);
        let x: Vec<f32> = (0..cfg.act_elems()).map(|_| rng.f32() - 0.5).collect();
        let dims = [cfg.microbatch as i64, cfg.seq_len as i64, cfg.d_model as i64];
        let out = rt
            .exec("topk_compress_act", &[Runtime::f32_tensor(&x, &dims).unwrap()])
            .unwrap();
        let sparse = Runtime::to_f32_vec(&out[0]).unwrap();

        let comp = crate::compress::TopK { ratio: cfg.act_elems() as f64 / cfg.topk_k as f64 };
        use crate::compress::Compressor;
        let c = comp.compress(&x);
        let mut dense = vec![0.0f32; x.len()];
        comp.decompress(&c, &mut dense);

        let nz_pjrt = sparse.iter().filter(|v| **v != 0.0).count();
        let nz_rust = dense.iter().filter(|v| **v != 0.0).count();
        assert!((nz_pjrt as i64 - nz_rust as i64).abs() <= 2);
        // Supports overlap almost entirely (ties may differ).
        let mism = sparse
            .iter()
            .zip(&dense)
            .filter(|(a, b)| (**a != 0.0) != (**b != 0.0))
            .count();
        assert!(mism <= 4, "support mismatch {mism}");
    }
}
