//! PJRT runtime (the execution plane, §3.2): loads the AOT artifacts
//! emitted by `python/compile/aot.py` (HLO text + manifest.json), compiles
//! them on the PJRT CPU client, and executes them from the coordinator.
//!
//! Python never runs here — the rust binary is self-contained once
//! `make artifacts` has produced the HLO files.

pub mod manifest;
pub mod pjrt;

pub use manifest::{EntrySpec, InitSpec, Manifest, ModelCfg, SegmentSpec, StageKind, StageSpec};
pub use pjrt::Runtime;
