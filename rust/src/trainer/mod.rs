//! Training-side substrates: synthetic datasets and the training report.

pub mod data;
pub mod report;

pub use data::SyntheticCorpus;
pub use report::{JoinEvent, RecoveryEvent, ReplanEvent, TrainReport};
