//! Synthetic byte-level language-modeling corpus.
//!
//! Substitution for WikiText-2 / CIFAR-10 (see DESIGN.md): a seeded
//! first-order Markov chain over the byte vocabulary whose rows concentrate
//! mass on a few successors. The entropy floor is ≈ ln(branch) + noise, so
//! a model that learns the transition table drives the loss well below the
//! ln(V) of the random-init model — exactly the signal Fig. 8 needs.

use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct SyntheticCorpus {
    vocab: usize,
    /// successors[v] = the `branch` likely next tokens after v.
    successors: Vec<Vec<u32>>,
    /// Probability of following the chain (vs. uniform noise).
    fidelity: f64,
    rng: Rng,
    state: u32,
    /// Microbatches drawn so far (the checkpointable data-loader cursor).
    drawn: u64,
}

impl SyntheticCorpus {
    pub fn new(vocab: usize, seed: u64) -> SyntheticCorpus {
        Self::with_params(vocab, 4, 0.9, seed)
    }

    pub fn with_params(vocab: usize, branch: usize, fidelity: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5EED_C0DE);
        let successors = (0..vocab)
            .map(|_| (0..branch).map(|_| rng.below(vocab as u64) as u32).collect())
            .collect();
        SyntheticCorpus {
            vocab,
            successors,
            fidelity,
            rng: Rng::new(seed),
            state: 0,
            drawn: 0,
        }
    }

    /// Data-loader cursor: microbatches drawn so far. Persisted in
    /// checkpoints so recovery can rewind the stream.
    pub fn batches_drawn(&self) -> u64 {
        self.drawn
    }

    /// Replay forward to an absolute cursor (draw-and-discard): after this
    /// call the next `next_batch(batch, seq)` returns exactly what it
    /// would have on an uninterrupted run. Errors if the cursor is behind
    /// the current position (streams only run forward).
    pub fn advance_to(&mut self, batches: u64, batch: usize, seq: usize) -> anyhow::Result<()> {
        anyhow::ensure!(
            batches >= self.drawn,
            "corpus cursor {} is ahead of checkpoint cursor {batches}",
            self.drawn
        );
        while self.drawn < batches {
            let _ = self.next_batch(batch, seq);
        }
        Ok(())
    }

    fn next_token(&mut self) -> u32 {
        let t = if self.rng.f64() < self.fidelity {
            let succ = &self.successors[self.state as usize];
            succ[self.rng.below(succ.len() as u64) as usize]
        } else {
            self.rng.below(self.vocab as u64) as u32
        };
        self.state = t;
        t
    }

    /// One microbatch: (tokens, targets) with targets[t] = tokens[t+1].
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        self.drawn += 1;
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let next = self.next_token();
                tokens.push(prev as i32);
                targets.push(next as i32);
                prev = next;
            }
        }
        (tokens, targets)
    }

    /// Theoretical per-token cross-entropy floor (nats), for test bounds:
    /// H ≈ f·ln(branch/f-ish) — we report the loose mixture entropy.
    pub fn entropy_floor(&self) -> f64 {
        let b = self.successors[0].len() as f64;
        let f = self.fidelity;
        let v = self.vocab as f64;
        // H(mixture) <= f·ln(b/f) + (1-f)·ln(v/(1-f)) (grouping bound).
        f * (b / f).ln() + (1.0 - f) * (v / (1.0 - f)).ln()
    }
}

/// Synthetic CIFAR-like image batches for the CNN workload (Fig. 8 ResNet
/// rows): class-conditional Gaussian blobs — linearly separable enough
/// that a small CNN's loss visibly decreases.
#[derive(Debug, Clone)]
pub struct SyntheticImages {
    pub classes: usize,
    pub ch: usize,
    pub hw: usize,
    prototypes: Vec<Vec<f32>>,
    rng: Rng,
}

impl SyntheticImages {
    pub fn new(classes: usize, ch: usize, hw: usize, seed: u64) -> SyntheticImages {
        let mut rng = Rng::new(seed ^ 0x131_7E57);
        let dim = ch * hw * hw;
        let prototypes = (0..classes)
            .map(|_| {
                let mut p = vec![0.0f32; dim];
                rng.fill_normal_f32(&mut p, 1.0);
                p
            })
            .collect();
        SyntheticImages { classes, ch, hw, prototypes, rng: Rng::new(seed) }
    }

    /// (images [B, C, H, W] flattened, labels [B]).
    pub fn next_batch(&mut self, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let dim = self.ch * self.hw * self.hw;
        let mut images = Vec::with_capacity(batch * dim);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let c = self.rng.below(self.classes as u64) as usize;
            labels.push(c as i32);
            for d in 0..dim {
                images.push(self.prototypes[c][d] + self.rng.normal() as f32 * 0.5);
            }
        }
        (images, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batch_shapes_and_range() {
        let mut c = SyntheticCorpus::new(256, 1);
        let (tok, tgt) = c.next_batch(4, 32);
        assert_eq!(tok.len(), 128);
        assert_eq!(tgt.len(), 128);
        assert!(tok.iter().all(|&t| (0..256).contains(&t)));
        // Next-token property within a row.
        assert_eq!(&tok[1..32], &tgt[..31]);
    }

    #[test]
    fn deterministic_by_seed() {
        let mut a = SyntheticCorpus::new(256, 9);
        let mut b = SyntheticCorpus::new(256, 9);
        assert_eq!(a.next_batch(2, 16), b.next_batch(2, 16));
    }

    #[test]
    fn cursor_replay_matches_uninterrupted_stream() {
        // Draw 7 batches on one corpus; a fresh corpus advanced to cursor
        // 7 must continue with the identical stream (checkpoint rewind).
        let mut live = SyntheticCorpus::new(61, 5);
        for _ in 0..7 {
            let _ = live.next_batch(2, 8);
        }
        assert_eq!(live.batches_drawn(), 7);
        let mut replay = SyntheticCorpus::new(61, 5);
        replay.advance_to(7, 2, 8).unwrap();
        assert_eq!(replay.batches_drawn(), 7);
        for _ in 0..3 {
            assert_eq!(live.next_batch(2, 8), replay.next_batch(2, 8));
        }
        // Rewinding backwards is an error, not silent corruption.
        assert!(replay.advance_to(3, 2, 8).is_err());
    }

    #[test]
    fn corpus_is_learnable_structure() {
        // Empirical conditional entropy must be far below ln(V):
        // count bigram stats over a long stream.
        let mut c = SyntheticCorpus::with_params(64, 4, 0.95, 3);
        let (tok, tgt) = c.next_batch(1, 200_000);
        let mut counts = vec![vec![0u32; 64]; 64];
        for (a, b) in tok.iter().zip(&tgt) {
            counts[*a as usize][*b as usize] += 1;
        }
        let total: u32 = counts.iter().map(|r| r.iter().sum::<u32>()).sum();
        // Conditional entropy H(Y|X) in nats.
        let mut hcond = 0.0f64;
        for row in &counts {
            let rt: u32 = row.iter().sum();
            if rt == 0 {
                continue;
            }
            let px = rt as f64 / total as f64;
            let mut hrow = 0.0;
            for &n in row {
                if n > 0 {
                    let p = n as f64 / rt as f64;
                    hrow -= p * p.ln();
                }
            }
            hcond += px * hrow;
        }
        assert!(
            hcond < (64f64).ln() * 0.6,
            "H(Y|X)={hcond:.3} vs ln(V)={:.3}",
            (64f64).ln()
        );
        assert!(hcond > 0.5, "too deterministic: {hcond}");
    }

    #[test]
    fn images_batch_shape() {
        let mut g = SyntheticImages::new(10, 3, 8, 2);
        let (x, y) = g.next_batch(16);
        assert_eq!(x.len(), 16 * 3 * 64);
        assert_eq!(y.len(), 16);
        assert!(y.iter().all(|&c| (0..10).contains(&c)));
    }
}
