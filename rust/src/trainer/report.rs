//! Training run report: per-iteration losses, wall times, wire bytes, and
//! the post-hoc simulated geo-network latency (the testbed link model
//! applied to the *measured* message sizes and compute times).

use crate::util::json::{arr, n, ni, obj, s, Json};

/// One straggler-driven re-partitioning (applied or advised) at an
/// iteration boundary.
#[derive(Debug, Clone, Default)]
pub struct ReplanEvent {
    /// First iteration executed under the new plan.
    pub iter: usize,
    /// Stage -> device placement before / after.
    pub from: Vec<usize>,
    pub to: Vec<usize>,
    /// Straggler stages that triggered the check, slowest first.
    pub flagged: Vec<usize>,
    /// Candidate generator ("reschedule" or "swap").
    pub origin: String,
    /// Simulated iteration seconds: current plan vs adopted candidate.
    pub sim_before_s: f64,
    pub sim_after_s: f64,
    /// Migration cost: measured teardown+respawn wall time in `train`,
    /// modeled parameter-transfer time in `simulate`.
    pub migration_s: f64,
    /// False under `--replan advise` (recommendation only).
    pub applied: bool,
}

impl ReplanEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("iter", ni(self.iter)),
            ("from", arr(self.from.iter().map(|&d| ni(d)).collect())),
            ("to", arr(self.to.iter().map(|&d| ni(d)).collect())),
            ("flagged", arr(self.flagged.iter().map(|&st| ni(st)).collect())),
            ("origin", s(&self.origin)),
            ("sim_before_s", n(self.sim_before_s)),
            ("sim_after_s", n(self.sim_after_s)),
            ("migration_s", n(self.migration_s)),
            ("applied", Json::Bool(self.applied)),
        ])
    }
}

/// One crash recovery: a stage's device was declared dead (missed
/// heartbeats, channel loss, or a fatal error), the run re-planned across
/// survivors, restored the newest valid checkpoint, rewound the data
/// loader and resumed.
#[derive(Debug, Clone, Default)]
pub struct RecoveryEvent {
    /// Iteration in flight when the death was declared.
    pub died_iter: usize,
    /// Dead stage and the device it was running on.
    pub stage: usize,
    pub device: usize,
    /// Why the stage was declared dead ("heartbeat", "fatal: ...").
    pub cause: String,
    /// Checkpoint boundary the run resumed from (0 = no checkpoint found,
    /// restarted from initialization).
    pub resume_iter: usize,
    /// Completed-then-rewound iterations: died_iter - resume_iter.
    pub iters_lost: usize,
    /// Stage -> device placement before / after the failover re-plan.
    pub from: Vec<usize>,
    pub to: Vec<usize>,
    /// Candidate generator ("failover-reschedule" / "failover-swap" /
    /// "failover-cohost").
    pub origin: String,
    /// Wall seconds: declaring + tearing down + re-planning, and
    /// checkpoint restore + respawn.
    pub replan_s: f64,
    pub restore_s: f64,
}

impl RecoveryEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("died_iter", ni(self.died_iter)),
            ("stage", ni(self.stage)),
            ("device", ni(self.device)),
            ("cause", s(&self.cause)),
            ("resume_iter", ni(self.resume_iter)),
            ("iters_lost", ni(self.iters_lost)),
            ("from", arr(self.from.iter().map(|&d| ni(d)).collect())),
            ("to", arr(self.to.iter().map(|&d| ni(d)).collect())),
            ("origin", s(&self.origin)),
            ("replan_s", n(self.replan_s)),
            ("restore_s", n(self.restore_s)),
        ])
    }
}

/// One elastic-membership admission: a device joined (brand new) or
/// rejoined (came back after a failure) at an iteration boundary. The
/// broker parks it as a spare either way; `adopted` says whether the
/// re-planner folded it into the pipeline immediately.
#[derive(Debug, Clone, Default)]
pub struct JoinEvent {
    /// Iteration boundary at which the device was admitted.
    pub iter: usize,
    pub device: usize,
    /// "join" (never seen before) or "rejoin" (previously failed).
    pub kind: String,
    /// True if `replan_after_join` predicted a win and the pipeline was
    /// re-partitioned onto the newcomer at this boundary.
    pub adopted: bool,
    /// Stage -> device placement before / after (equal when not adopted).
    pub from: Vec<usize>,
    pub to: Vec<usize>,
    /// Simulated iteration seconds: current plan vs the candidate that
    /// uses the newcomer (sim_after_s == sim_before_s when not adopted).
    pub sim_before_s: f64,
    pub sim_after_s: f64,
}

impl JoinEvent {
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("iter", ni(self.iter)),
            ("device", ni(self.device)),
            ("kind", s(&self.kind)),
            ("adopted", Json::Bool(self.adopted)),
            ("from", arr(self.from.iter().map(|&d| ni(d)).collect())),
            ("to", arr(self.to.iter().map(|&d| ni(d)).collect())),
            ("sim_before_s", n(self.sim_before_s)),
            ("sim_after_s", n(self.sim_after_s)),
        ])
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainReport {
    pub config: String,
    pub scheduler: String,
    pub compressor: String,
    /// Pipeline schedule executed by the workers ("gpipe" / "1f1b").
    pub pipeline: String,
    pub ratio: f64,
    pub n_micro: usize,
    /// Mean loss per iteration (averaged over microbatches).
    pub losses: Vec<f32>,
    /// Wall seconds per iteration (local CPU execution).
    pub wall_s: Vec<f64>,
    /// Simulated geo-distributed seconds per iteration (α–β model over the
    /// actual wire bytes + measured per-stage compute).
    pub sim_s: Vec<f64>,
    /// Total wire bytes sent per iteration.
    pub wire_bytes: Vec<f64>,
    /// Achieved wire compression: dense payload bytes / wire bytes sent
    /// (e.g. ≈ r/3 for f32 Top-K, ≈ 4r/5 for int8-sparse at ratio r).
    pub wire_shrink: f64,
    /// Frame bytes the broker relayed worker→worker over the tcp
    /// transport (0 under chan; ≈0 under `--data-plane mesh`, where only
    /// a stray pre-teardown frame could ever transit the broker).
    pub relayed_packet_bytes: f64,
    /// Stage payload bytes that traveled direct worker↔worker peer links
    /// (non-zero only under `--data-plane mesh`).
    pub peer_packet_bytes: f64,
    /// Incremental checkpointing: cumulative bytes a full (dense) snapshot
    /// of each delta-persisted version would have cost on disk. Base
    /// layers are excluded from both counters, so full/delta is the
    /// steady-state shrink of the delta encoding itself.
    pub checkpoint_bytes_full: f64,
    /// Cumulative bytes actually written for those delta-persisted
    /// versions (stage delta layers; always < `checkpoint_bytes_full`).
    pub checkpoint_bytes_delta: f64,
    /// Stage -> device placement used (final placement after any replans).
    pub placement: Vec<usize>,
    /// Straggler-driven re-partitionings, in iteration order.
    pub replans: Vec<ReplanEvent>,
    /// Crash recoveries (device churn), in occurrence order.
    pub recoveries: Vec<RecoveryEvent>,
    /// Elastic-membership admissions (join/rejoin), in occurrence order.
    pub joins: Vec<JoinEvent>,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.losses.last().copied().unwrap_or(f32::NAN)
    }

    /// Mean simulated iteration latency (the Fig. 10 metric).
    pub fn mean_sim_latency(&self) -> f64 {
        crate::util::math::mean(&self.sim_s)
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("config", s(&self.config)),
            ("scheduler", s(&self.scheduler)),
            ("compressor", s(&self.compressor)),
            ("pipeline", s(&self.pipeline)),
            ("ratio", n(self.ratio)),
            ("n_micro", ni(self.n_micro)),
            (
                "losses",
                arr(self.losses.iter().map(|&l| n(l as f64)).collect()),
            ),
            ("wall_s", arr(self.wall_s.iter().map(|&v| n(v)).collect())),
            ("sim_s", arr(self.sim_s.iter().map(|&v| n(v)).collect())),
            (
                "wire_bytes",
                arr(self.wire_bytes.iter().map(|&v| n(v)).collect()),
            ),
            ("wire_shrink", n(self.wire_shrink)),
            ("relayed_packet_bytes", n(self.relayed_packet_bytes)),
            ("peer_packet_bytes", n(self.peer_packet_bytes)),
            ("checkpoint_bytes_full", n(self.checkpoint_bytes_full)),
            ("checkpoint_bytes_delta", n(self.checkpoint_bytes_delta)),
            (
                "placement",
                arr(self.placement.iter().map(|&p| ni(p)).collect()),
            ),
            (
                "replans",
                arr(self.replans.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "recoveries",
                arr(self.recoveries.iter().map(|e| e.to_json()).collect()),
            ),
            (
                "joins",
                arr(self.joins.iter().map(|e| e.to_json()).collect()),
            ),
        ])
    }

    /// CSV of (iter, loss, wall_s, sim_s, wire_bytes) for plotting Fig. 8.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("iter,loss,wall_s,sim_s,wire_bytes\n");
        for i in 0..self.losses.len() {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                i,
                self.losses[i],
                self.wall_s.get(i).unwrap_or(&0.0),
                self.sim_s.get(i).unwrap_or(&0.0),
                self.wire_bytes.get(i).unwrap_or(&0.0),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_and_json_roundtrip() {
        let r = TrainReport {
            config: "tiny".into(),
            scheduler: "opfence".into(),
            compressor: "adatopk".into(),
            pipeline: "1f1b".into(),
            ratio: 100.0,
            n_micro: 2,
            losses: vec![5.5, 5.0, 4.5],
            wall_s: vec![0.1, 0.1, 0.1],
            sim_s: vec![1.0, 1.0, 1.0],
            wire_bytes: vec![100.0, 100.0, 100.0],
            wire_shrink: 33.3,
            relayed_packet_bytes: 0.0,
            peer_packet_bytes: 4096.0,
            checkpoint_bytes_full: 17072.0,
            checkpoint_bytes_delta: 768.0,
            placement: vec![0, 1, 2, 3],
            replans: vec![ReplanEvent {
                iter: 2,
                from: vec![0, 1, 2, 3],
                to: vec![0, 9, 2, 3],
                flagged: vec![1],
                origin: "swap".into(),
                sim_before_s: 2.0,
                sim_after_s: 1.0,
                migration_s: 0.3,
                applied: true,
            }],
            recoveries: vec![RecoveryEvent {
                died_iter: 3,
                stage: 1,
                device: 9,
                cause: "heartbeat".into(),
                resume_iter: 2,
                iters_lost: 1,
                from: vec![0, 9, 2, 3],
                to: vec![0, 7, 2, 3],
                origin: "failover-reschedule".into(),
                replan_s: 0.4,
                restore_s: 0.1,
            }],
            joins: vec![JoinEvent {
                iter: 5,
                device: 24,
                kind: "join".into(),
                adopted: true,
                from: vec![0, 7, 2, 3],
                to: vec![0, 7, 24, 3],
                sim_before_s: 2.0,
                sim_after_s: 1.5,
            }],
        };
        let csv = r.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.contains("0,5.5"));
        let j = r.to_json();
        assert_eq!(j.get("scheduler").as_str().unwrap(), "opfence");
        assert_eq!(j.get("pipeline").as_str().unwrap(), "1f1b");
        assert_eq!(j.get("losses").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("relayed_packet_bytes").as_f64().unwrap(), 0.0);
        assert_eq!(j.get("peer_packet_bytes").as_f64().unwrap(), 4096.0);
        assert_eq!(j.get("checkpoint_bytes_full").as_f64().unwrap(), 17072.0);
        assert_eq!(j.get("checkpoint_bytes_delta").as_f64().unwrap(), 768.0);
        let reps = j.get("replans").as_arr().unwrap();
        assert_eq!(reps.len(), 1);
        assert_eq!(reps[0].get("origin").as_str().unwrap(), "swap");
        assert!(reps[0].get("applied").as_bool().unwrap());
        assert_eq!(reps[0].get("to").as_arr().unwrap().len(), 4);
        let recs = j.get("recoveries").as_arr().unwrap();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("stage").as_usize().unwrap(), 1);
        assert_eq!(recs[0].get("iters_lost").as_usize().unwrap(), 1);
        assert_eq!(recs[0].get("origin").as_str().unwrap(), "failover-reschedule");
        let joins = j.get("joins").as_arr().unwrap();
        assert_eq!(joins.len(), 1);
        assert_eq!(joins[0].get("device").as_usize().unwrap(), 24);
        assert_eq!(joins[0].get("kind").as_str().unwrap(), "join");
        assert!(joins[0].get("adopted").as_bool().unwrap());
        assert!((r.mean_sim_latency() - 1.0).abs() < 1e-12);
    }
}
