//! FusionLLM leader entrypoint.
//!
//! Subcommands:
//!   testbed   — print the synthesized geo-distributed testbed (Fig. 9)
//!   schedule  — partition a model DAG onto a testbed and print the plan
//!   simulate  — discrete-event iteration-latency simulation (Fig. 10/11)
//!   train     — end-to-end pipeline training over PJRT artifacts (Fig. 8)
//!   worker    — remote stage executor (`--connect` to a tcp-transport broker)
//!   economics — GPU cost table (Table 1)
//!   bench-diff — compare two BENCH_micro_hotpath.json files (CI perf gate)

use fusionllm::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "testbed" => fusionllm::cmd::testbed(&args),
        "schedule" => fusionllm::cmd::schedule(&args),
        "simulate" => fusionllm::cmd::simulate(&args),
        "train" => fusionllm::cmd::train(&args),
        "worker" => fusionllm::cmd::worker(&args),
        "economics" => fusionllm::cmd::economics(&args),
        "bench-diff" => fusionllm::cmd::bench_diff(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand `{other}`\n");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "fusionllm — decentralized LLM training with adaptive compression\n\
         \n\
         USAGE: fusionllm <subcommand> [--flags]\n\
         \n\
         SUBCOMMANDS\n\
           testbed   --testbed 1|2              print CompNodes + link matrix (Fig. 9)\n\
           schedule  --testbed N --scheduler S  partition the model, print the plan\n\
           simulate  --testbed N --scheduler S --compress C --ratio R\n\
                                                 iteration-latency simulation (Fig. 10/11)\n\
                     [--slow-node I --slow-factor F --replan M [--min-recovery X]]\n\
                                                 straggler scenario + re-planning smoke\n\
           train     --config PATH --steps N    real pipeline training over artifacts (Fig. 8)\n\
           worker    --connect HOST:PORT        remote stage executor for a tcp-transport\n\
                     [--token T --device D]      broker (one process = one device)\n\
           economics                             GPU-days table (Table 1)\n\
           bench-diff OLD.json NEW.json [--max-regress 20]\n\
                                                 perf gate: fail on median-time regression\n\
         \n\
         Schedulers: opfence | equal-number | equal-compute\n\
         Compressors: none | topk | adatopk | randomk | int8\n\
         Wire codec (--wire-codec): f32 | int8   (int8 = scale+codes per value,\n\
                                                  ~5 B/kept value vs 8, dense ~1 B)\n\
         Pipeline (--pipeline): gpipe | 1f1b     both run through the same schedule\n\
                                                  interpreter; identical losses, 1f1b\n\
                                                  stashes fewer activations\n\
         Re-planning (train & simulate):\n\
           --replan off|advise|auto              react to measured stragglers (default off)\n\
           --straggler-threshold T               flag stages busier than T x median (2.0)\n\
           --replan-hysteresis H                 min simulated improvement to migrate (0.10)\n\
           --slow-stage S / --slow-node I, --slow-factor F\n\
                                                 straggler injection (train: stage's device;\n\
                                                  simulate: device id)\n\
         Transport (train & simulate churn mode):\n\
           --transport chan|tcp                  worker plane: in-process channels (default)\n\
                                                  or TCP sockets + worker processes\n\
           --listen HOST:PORT                    tcp: broker listen address (127.0.0.1:4471)\n\
           --token T                             tcp: shared handshake secret (fusionllm)\n\
           --workers N                           tcp: worker pool size (default = stages;\n\
                                                  start one spare so failover has a device)\n\
           --pace S                              Null backend: sleep S sec per forward\n\
                                                  (paces demos so kills land mid-run)\n\
         Fault tolerance (train & simulate churn mode):\n\
           --heartbeat-interval S                worker liveness beacon period, sec (0.25;\n\
                                                  0 disables the liveness plane)\n\
           --heartbeat-timeout N                 missed intervals before a stage is dead (40)\n\
           --heartbeat-grace G                   first-contact deadline multiplier (4):\n\
                                                  covers slow PJRT compiles before beacon 1\n\
           --checkpoint-every K                  broker-side checkpoint every K iters (0=off)\n\
           --checkpoint-dir DIR                  versioned checkpoint store (checkpoints/)\n\
           --keep-checkpoints N                  versions retained on disk (3)\n\
           --kill-node N --kill-at-iter K        churn injector: device N vanishes at iter K\n\
                                                  (with --replan auto the run must recover;\n\
                                                  `simulate --kill-node` is the CI churn gate)\n\
           --backend pjrt|null                   compute backend (null = artifact-free mock)"
    );
}
