//! Pipeline task schedules over S stages × n_b microbatches.

/// What a pipeline task does.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Forward,
    Backward,
    /// Optimizer step (once per device, after all backwards).
    Update,
}

/// One schedulable unit: (stage, microbatch, kind).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Task {
    pub stage: usize,
    pub micro: usize,
    pub kind: TaskKind,
}

/// Schedule flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleKind {
    /// GPipe: all microbatch forwards, then all backwards (flush).
    GPipe,
    /// 1F1B (PipeDream-flush): steady state interleaves one forward with
    /// one backward, reducing peak activation stash.
    OneFOneB,
}

impl ScheduleKind {
    pub fn parse(s: &str) -> anyhow::Result<ScheduleKind> {
        Ok(match s {
            "gpipe" => ScheduleKind::GPipe,
            "1f1b" => ScheduleKind::OneFOneB,
            other => anyhow::bail!("unknown schedule `{other}`"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ScheduleKind::GPipe => "gpipe",
            ScheduleKind::OneFOneB => "1f1b",
        }
    }
}

/// Per-device ordered task lists for an S-stage pipeline.
#[derive(Debug, Clone)]
pub struct PipelineSchedule {
    pub kind: ScheduleKind,
    pub n_stages: usize,
    pub n_micro: usize,
    /// tasks[stage] = ordered execution list for the device owning stage.
    pub tasks: Vec<Vec<Task>>,
}

impl PipelineSchedule {
    pub fn new(kind: ScheduleKind, n_stages: usize, n_micro: usize) -> PipelineSchedule {
        let tasks = match kind {
            ScheduleKind::GPipe => gpipe(n_stages, n_micro),
            ScheduleKind::OneFOneB => one_f_one_b(n_stages, n_micro),
        };
        PipelineSchedule { kind, n_stages, n_micro, tasks }
    }

    /// Peak number of stashed forward activations on a stage (memory
    /// pressure): GPipe stashes n_micro everywhere; 1F1B stashes at most
    /// (n_stages - stage) per PipeDream-flush.
    pub fn peak_stash(&self, stage: usize) -> usize {
        let mut live = 0usize;
        let mut peak = 0usize;
        for t in &self.tasks[stage] {
            match t.kind {
                TaskKind::Forward => {
                    live += 1;
                    peak = peak.max(live);
                }
                TaskKind::Backward => live = live.saturating_sub(1),
                TaskKind::Update => {}
            }
        }
        peak
    }

    /// Structural validation: every (micro, kind) exactly once per stage,
    /// each backward after its forward, update last.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (s, list) in self.tasks.iter().enumerate() {
            anyhow::ensure!(
                list.len() == 2 * self.n_micro + 1,
                "stage {s}: {} tasks",
                list.len()
            );
            let mut seen_f = vec![false; self.n_micro];
            let mut seen_b = vec![false; self.n_micro];
            for (pos, t) in list.iter().enumerate() {
                anyhow::ensure!(t.stage == s, "task on wrong stage");
                match t.kind {
                    TaskKind::Forward => {
                        anyhow::ensure!(!seen_f[t.micro], "dup fwd");
                        seen_f[t.micro] = true;
                    }
                    TaskKind::Backward => {
                        anyhow::ensure!(seen_f[t.micro], "bwd before fwd");
                        anyhow::ensure!(!seen_b[t.micro], "dup bwd");
                        seen_b[t.micro] = true;
                    }
                    TaskKind::Update => {
                        anyhow::ensure!(pos == list.len() - 1, "update not last");
                    }
                }
            }
            anyhow::ensure!(seen_f.iter().all(|&x| x), "missing fwd");
            anyhow::ensure!(seen_b.iter().all(|&x| x), "missing bwd");
        }
        Ok(())
    }
}

fn gpipe(n_stages: usize, n_micro: usize) -> Vec<Vec<Task>> {
    (0..n_stages)
        .map(|s| {
            let mut v: Vec<Task> = (0..n_micro)
                .map(|m| Task { stage: s, micro: m, kind: TaskKind::Forward })
                .collect();
            // Backwards in reverse microbatch order (last fwd, first bwd).
            v.extend(
                (0..n_micro)
                    .rev()
                    .map(|m| Task { stage: s, micro: m, kind: TaskKind::Backward }),
            );
            v.push(Task { stage: s, micro: 0, kind: TaskKind::Update });
            v
        })
        .collect()
}

fn one_f_one_b(n_stages: usize, n_micro: usize) -> Vec<Vec<Task>> {
    (0..n_stages)
        .map(|s| {
            // Warmup forwards: min(n_stages - s, n_micro).
            let warmup = (n_stages - s).min(n_micro);
            let mut v = Vec::with_capacity(2 * n_micro + 1);
            let mut f = 0usize;
            let mut b = 0usize;
            for _ in 0..warmup {
                v.push(Task { stage: s, micro: f, kind: TaskKind::Forward });
                f += 1;
            }
            // Steady state: 1B1F until forwards exhausted, then drain.
            while b < n_micro {
                v.push(Task { stage: s, micro: b, kind: TaskKind::Backward });
                b += 1;
                if f < n_micro {
                    v.push(Task { stage: s, micro: f, kind: TaskKind::Forward });
                    f += 1;
                }
            }
            v.push(Task { stage: s, micro: 0, kind: TaskKind::Update });
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpipe_valid() {
        for (s, m) in [(1, 1), (2, 3), (4, 8), (6, 2)] {
            PipelineSchedule::new(ScheduleKind::GPipe, s, m).validate().unwrap();
        }
    }

    #[test]
    fn one_f_one_b_valid() {
        for (s, m) in [(1, 1), (2, 3), (4, 8), (6, 2), (8, 4)] {
            PipelineSchedule::new(ScheduleKind::OneFOneB, s, m).validate().unwrap();
        }
    }

    #[test]
    fn one_f_one_b_reduces_stash() {
        let g = PipelineSchedule::new(ScheduleKind::GPipe, 4, 8);
        let o = PipelineSchedule::new(ScheduleKind::OneFOneB, 4, 8);
        // First stage benefits most: GPipe stashes all 8, 1F1B only 4.
        assert_eq!(g.peak_stash(0), 8);
        assert_eq!(o.peak_stash(0), 4);
        // Last stage: both stash 1 under 1F1B-with-immediate-bwd? GPipe
        // stashes all; 1F1B stashes 1.
        assert_eq!(o.peak_stash(3), 1);
    }

    #[test]
    fn gpipe_backward_order_is_lifo() {
        let g = PipelineSchedule::new(ScheduleKind::GPipe, 2, 3);
        let kinds: Vec<(TaskKind, usize)> =
            g.tasks[0].iter().map(|t| (t.kind, t.micro)).collect();
        assert_eq!(
            kinds,
            vec![
                (TaskKind::Forward, 0),
                (TaskKind::Forward, 1),
                (TaskKind::Forward, 2),
                (TaskKind::Backward, 2),
                (TaskKind::Backward, 1),
                (TaskKind::Backward, 0),
                (TaskKind::Update, 0),
            ]
        );
    }
}
