//! Microbatch pipeline schedules (§3.6, Eq. 3): GPipe-style all-forward /
//! all-backward and 1F1B (PipeDream-flush) as an ablation. The schedule is
//! a per-device ordered task list consumed by the discrete-event simulator
//! (`simnet`) and the real threaded workers (`worker`).

pub mod schedule;

pub use schedule::{PipelineSchedule, ScheduleKind, Task, TaskKind};
