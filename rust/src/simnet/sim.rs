//! Event-driven pipeline execution over the alpha–beta network.
//!
//! Each stage's device executes its schedule strictly in order; messages
//! between adjacent stages pay `α + β·bytes` and serialize FIFO per
//! directed link. Compression enters through the `CompressPlan`: a message
//! delivered to device d carries `scale_bytes(d, dense_bytes)` wire bytes.

use super::stageplan::StagePlan;
use crate::cluster::Testbed;
use crate::compress::CompressPlan;
use crate::pipeline::{PipelineSchedule, TaskKind};
use crate::util::rng::Rng;

/// Network-instability model (paper §8 "Network stability"): each transfer
/// is independently lost with `loss_prob` and retransmitted after an RTO of
/// `rto_s` seconds, repeating until delivered (geometric retries).
#[derive(Debug, Clone, Copy)]
pub struct FaultModel {
    pub loss_prob: f64,
    pub rto_s: f64,
    pub seed: u64,
}

impl FaultModel {
    pub fn none() -> FaultModel {
        FaultModel { loss_prob: 0.0, rto_s: 0.2, seed: 0 }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Wall-clock seconds for the full iteration (all stages done).
    pub iter_s: f64,
    /// Per-stage busy compute seconds.
    pub busy_s: Vec<f64>,
    /// Per-stage seconds spent blocked waiting on messages/deps.
    pub stall_s: Vec<f64>,
    /// Total bytes put on the wire.
    pub wire_bytes: f64,
    /// Pipeline bubble fraction: 1 - busy / (stages · iter).
    pub bubble_frac: f64,
}

/// Execution-model knobs for one simulated iteration.
#[derive(Debug, Clone, Copy)]
pub struct SimOpts {
    /// Overlapped wire pipeline (the interpreter's default): a sender's
    /// device clock is NOT advanced by its transfers — encode/send run on
    /// a dedicated thread, so each stage pays `max(compute, comm)` per
    /// link instead of the sum. `false` models `--overlap off`: the
    /// device blocks until the transfer drains (inline encode+send).
    pub overlap: bool,
    pub faults: FaultModel,
}

impl SimOpts {
    pub fn overlapped() -> SimOpts {
        SimOpts { overlap: true, faults: FaultModel::none() }
    }

    pub fn blocking() -> SimOpts {
        SimOpts { overlap: false, faults: FaultModel::none() }
    }
}

/// Simulate one training iteration (no network faults, overlapped sends).
pub fn simulate_iteration(
    plan: &StagePlan,
    testbed: &Testbed,
    schedule: &PipelineSchedule,
    compress: &CompressPlan,
) -> SimResult {
    simulate_iteration_faulty(plan, testbed, schedule, compress, FaultModel::none())
}

/// Simulate one training iteration under the given fault model
/// (overlapped sends — the interpreter's default wire pipeline).
pub fn simulate_iteration_faulty(
    plan: &StagePlan,
    testbed: &Testbed,
    schedule: &PipelineSchedule,
    compress: &CompressPlan,
    faults: FaultModel,
) -> SimResult {
    simulate_iteration_with(plan, testbed, schedule, compress, SimOpts { overlap: true, faults })
}

/// Simulate one training iteration under explicit execution-model options.
pub fn simulate_iteration_with(
    plan: &StagePlan,
    testbed: &Testbed,
    schedule: &PipelineSchedule,
    compress: &CompressPlan,
    opts: SimOpts,
) -> SimResult {
    let faults = opts.faults;
    let mut frng = Rng::new(faults.seed ^ 0xFA17);
    // Retransmission overhead for one logical transfer of base time `t`:
    // lost tries each cost a full timeout + resend.
    let mut xfer_time = move |t: f64| -> f64 {
        if faults.loss_prob <= 0.0 {
            return t;
        }
        let mut total = t;
        while frng.f64() < faults.loss_prob {
            total += faults.rto_s + t;
        }
        total
    };
    let s_n = plan.n_stages();
    assert_eq!(schedule.n_stages, s_n, "schedule/plan stage mismatch");
    let m_n = schedule.n_micro;
    const UNSET: f64 = -1.0;

    // arrival_f[s][m]: time the fwd input for (s,m) is available.
    let mut arrival_f = vec![vec![UNSET; m_n]; s_n];
    // arrival_b[s][m]: time the grad input for (s,m) is available.
    let mut arrival_b = vec![vec![UNSET; m_n]; s_n];
    // fwd_done[s][m]: forward must precede its own backward locally.
    let mut fwd_done = vec![vec![UNSET; m_n]; s_n];
    for m in 0..m_n {
        arrival_f[0][m] = 0.0; // data is local to stage 0
    }
    // last stage computes loss in fwd; its "grad arrival" is its own fwd.

    let mut dev_time = vec![0.0f64; s_n];
    let mut next_task = vec![0usize; s_n];
    let mut busy = vec![0.0f64; s_n];
    let mut stall = vec![0.0f64; s_n];
    // FIFO serialization per directed inter-stage link.
    let mut link_free_fwd = vec![0.0f64; s_n.saturating_sub(1)]; // s -> s+1
    let mut link_free_bwd = vec![0.0f64; s_n.saturating_sub(1)]; // s+1 -> s
    let mut wire_bytes = 0.0f64;

    let total_tasks: usize = schedule.tasks.iter().map(|t| t.len()).sum();
    let mut done_tasks = 0usize;

    while done_tasks < total_tasks {
        let mut progressed = false;
        for s in 0..s_n {
            while next_task[s] < schedule.tasks[s].len() {
                let t = schedule.tasks[s][next_task[s]];
                // Readiness check.
                let ready_at = match t.kind {
                    TaskKind::Forward => arrival_f[s][t.micro],
                    TaskKind::Backward => {
                        if s == s_n - 1 {
                            fwd_done[s][t.micro]
                        } else {
                            let a = arrival_b[s][t.micro];
                            let f = fwd_done[s][t.micro];
                            if a < 0.0 || f < 0.0 {
                                UNSET
                            } else {
                                a.max(f)
                            }
                        }
                    }
                    TaskKind::Update => dev_time[s], // always ready (deps via order)
                };
                if ready_at < 0.0 {
                    break; // head task blocked; device waits
                }
                let start = dev_time[s].max(ready_at);
                stall[s] += start - dev_time[s];
                let dur = match t.kind {
                    TaskKind::Forward => plan.fwd_s[s],
                    TaskKind::Backward => plan.bwd_s[s],
                    TaskKind::Update => plan.update_s[s],
                };
                let end = start + dur;
                busy[s] += dur;
                dev_time[s] = end;
                next_task[s] += 1;
                done_tasks += 1;
                progressed = true;

                match t.kind {
                    TaskKind::Forward => {
                        fwd_done[s][t.micro] = end;
                        if s + 1 < s_n {
                            // Send activation to stage s+1.
                            let (src, dst) = (plan.devices[s], plan.devices[s + 1]);
                            let eff = compress.scale_bytes(dst, plan.act_bytes[s]);
                            let xfer_start = end.max(link_free_fwd[s]);
                            let xfer_end = xfer_start
                                + xfer_time(testbed.net.comm_time(src, dst, eff));
                            link_free_fwd[s] = xfer_end;
                            arrival_f[s + 1][t.micro] = xfer_end;
                            wire_bytes += eff;
                            if !opts.overlap {
                                // Inline encode+send: the device blocks
                                // until the wire drains.
                                stall[s] += xfer_end - end;
                                dev_time[s] = xfer_end;
                            }
                        }
                    }
                    TaskKind::Backward => {
                        if s > 0 {
                            // Send gradient to stage s-1 (same size as the
                            // activation on that edge).
                            let (src, dst) = (plan.devices[s], plan.devices[s - 1]);
                            let eff =
                                compress.scale_bytes(dst, plan.act_bytes[s - 1]);
                            let xfer_start = end.max(link_free_bwd[s - 1]);
                            let xfer_end = xfer_start
                                + xfer_time(testbed.net.comm_time(src, dst, eff));
                            link_free_bwd[s - 1] = xfer_end;
                            arrival_b[s - 1][t.micro] = xfer_end;
                            wire_bytes += eff;
                            if !opts.overlap {
                                stall[s] += xfer_end - end;
                                dev_time[s] = xfer_end;
                            }
                        }
                    }
                    TaskKind::Update => {}
                }
            }
        }
        assert!(progressed, "pipeline deadlock (schedule/dependency bug)");
    }

    let iter_s = dev_time.iter().cloned().fold(0.0, f64::max);
    let total_busy: f64 = busy.iter().sum();
    SimResult {
        iter_s,
        busy_s: busy,
        stall_s: stall,
        wire_bytes,
        bubble_frac: if iter_s > 0.0 && s_n > 0 {
            1.0 - total_busy / (s_n as f64 * iter_s)
        } else {
            0.0
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::compress::CompressKind;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::pipeline::ScheduleKind;
    use crate::scheduler::{by_name, Scheduler};

    fn setup() -> (crate::opdag::Dag, crate::cluster::Testbed, StagePlan) {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let plan = StagePlan::from_partition(&dag, &p, &tb);
        (dag, tb, plan)
    }

    #[test]
    fn iteration_completes_and_is_positive() {
        let (_, tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let r = simulate_iteration(&plan, &tb, &sched, &dense);
        assert!(r.iter_s > 0.0);
        assert!(r.bubble_frac >= 0.0 && r.bubble_frac <= 1.0);
        assert!(r.wire_bytes > 0.0);
    }

    #[test]
    fn compression_cuts_simulated_latency() {
        let (_, tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let topk = CompressPlan::uniform(CompressKind::TopK, 100.0, tb.nodes.len());
        let td = simulate_iteration(&plan, &tb, &sched, &dense).iter_s;
        let tc = simulate_iteration(&plan, &tb, &sched, &topk).iter_s;
        assert!(tc < td, "topk {tc} !< dense {td}");
    }

    #[test]
    fn sim_bounded_by_serial_and_floor() {
        // Simulated iteration must be at least the critical compute path
        // and at most the fully serialized Eq. 2 estimate × n_micro.
        let (dag, tb, plan) = setup();
        let n_micro = 2;
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), n_micro);
        let dense = CompressPlan::dense(tb.nodes.len());
        let r = simulate_iteration(&plan, &tb, &sched, &dense);
        let floor: f64 = plan
            .fwd_s
            .iter()
            .zip(&plan.bwd_s)
            .map(|(f, b)| (f + b) * n_micro as f64)
            .fold(0.0, f64::max);
        assert!(r.iter_s >= floor, "{} < floor {}", r.iter_s, floor);
        let _ = dag;
        // Serial ceiling: everything sequential.
        let serial: f64 = plan
            .fwd_s
            .iter()
            .zip(&plan.bwd_s)
            .map(|(f, b)| f + b)
            .sum::<f64>()
            * n_micro as f64
            + plan
                .act_bytes
                .iter()
                .enumerate()
                .map(|(s, &b)| {
                    2.0 * n_micro as f64
                        * tb.net.comm_time(plan.devices[s], plan.devices[s + 1], b)
                })
                .sum::<f64>()
            + plan.update_s.iter().sum::<f64>();
        assert!(r.iter_s <= serial * 1.01, "{} > serial {}", r.iter_s, serial);
    }

    #[test]
    fn more_microbatches_improve_per_sample_time() {
        let (_, tb, plan) = setup();
        let dense = CompressPlan::dense(tb.nodes.len());
        let t2 = simulate_iteration(
            &plan,
            &tb,
            &PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2),
            &dense,
        )
        .iter_s
            / 2.0;
        let t8 = simulate_iteration(
            &plan,
            &tb,
            &PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 8),
            &dense,
        )
        .iter_s
            / 8.0;
        assert!(t8 < t2, "per-micro t8={t8} t2={t2}");
    }

    #[test]
    fn one_f_one_b_no_slower_than_gpipe() {
        let (_, tb, plan) = setup();
        let dense = CompressPlan::dense(tb.nodes.len());
        let tg = simulate_iteration(
            &plan,
            &tb,
            &PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 4),
            &dense,
        )
        .iter_s;
        let to = simulate_iteration(
            &plan,
            &tb,
            &PipelineSchedule::new(ScheduleKind::OneFOneB, plan.n_stages(), 4),
            &dense,
        )
        .iter_s;
        // 1F1B should be within a whisker (it mainly saves memory).
        assert!(to <= tg * 1.25, "1f1b={to} gpipe={tg}");
    }

    #[test]
    fn blocking_sends_never_beat_overlapped() {
        // `--overlap off` only adds constraints (the sender's clock also
        // pays the transfer), so blocking must be >= overlapped, and on a
        // comm-heavy dense cross-cluster plan strictly slower.
        let (_, tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 4);
        let dense = CompressPlan::dense(tb.nodes.len());
        let ov =
            simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::overlapped());
        let bl =
            simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::blocking());
        assert!(bl.iter_s > ov.iter_s, "blocking {} !> overlapped {}", bl.iter_s, ov.iter_s);
        // Same traffic either way; only the timing differs.
        assert_eq!(bl.wire_bytes, ov.wire_bytes);
        // The overlapped entry points are unchanged behavior.
        let def = simulate_iteration(&plan, &tb, &sched, &dense);
        assert_eq!(def.iter_s, ov.iter_s);
    }

    #[test]
    fn blocking_equals_overlapped_without_comm() {
        // With a single stage there are no transfers, so the execution
        // models coincide exactly.
        let tb = testbed1(1);
        let plan = StagePlan {
            devices: vec![0],
            fwd_s: vec![0.5],
            bwd_s: vec![1.0],
            update_s: vec![0.1],
            act_bytes: vec![],
        };
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, 1, 3);
        let dense = CompressPlan::dense(tb.nodes.len());
        let ov =
            simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::overlapped());
        let bl =
            simulate_iteration_with(&plan, &tb, &sched, &dense, SimOpts::blocking());
        assert_eq!(ov.iter_s, bl.iter_s);
        assert!((ov.iter_s - (3.0 * 1.5 + 0.1)).abs() < 1e-9);
    }

    #[test]
    fn single_stage_pipeline_works() {
        let tb = testbed1(1);
        let plan = StagePlan {
            devices: vec![0],
            fwd_s: vec![0.5],
            bwd_s: vec![1.0],
            update_s: vec![0.1],
            act_bytes: vec![],
        };
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, 1, 3);
        let dense = CompressPlan::dense(tb.nodes.len());
        let r = simulate_iteration(&plan, &tb, &sched, &dense);
        assert!((r.iter_s - (3.0 * 1.5 + 0.1)).abs() < 1e-9);
        assert_eq!(r.wire_bytes, 0.0);
    }
}

#[cfg(test)]
mod fault_tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::compress::CompressPlan;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::pipeline::ScheduleKind;
    use crate::scheduler::{by_name, Scheduler};

    fn setup() -> (crate::cluster::Testbed, StagePlan) {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let plan = StagePlan::from_partition(&dag, &p, &tb);
        (tb, plan)
    }

    #[test]
    fn zero_loss_equals_baseline() {
        let (tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let a = simulate_iteration(&plan, &tb, &sched, &dense).iter_s;
        let b = simulate_iteration_faulty(
            &plan,
            &tb,
            &sched,
            &dense,
            FaultModel { loss_prob: 0.0, rto_s: 1.0, seed: 9 },
        )
        .iter_s;
        assert_eq!(a, b);
    }

    #[test]
    fn packet_loss_monotonically_slows_iterations() {
        let (tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2);
        let dense = CompressPlan::dense(tb.nodes.len());
        let mut prev = 0.0;
        for p in [0.0, 0.05, 0.2, 0.5] {
            let t = simulate_iteration_faulty(
                &plan,
                &tb,
                &sched,
                &dense,
                FaultModel { loss_prob: p, rto_s: 0.2, seed: 42 },
            )
            .iter_s;
            assert!(t >= prev, "p={p}: {t} < {prev}");
            prev = t;
        }
        // 50% loss should hurt a lot.
        assert!(prev > simulate_iteration(&plan, &tb, &sched, &dense).iter_s * 1.5);
    }

    #[test]
    fn compression_mitigates_faulty_links() {
        // Fewer/smaller transfers => fewer loss events on the wire clock.
        let (tb, plan) = setup();
        let sched = PipelineSchedule::new(ScheduleKind::GPipe, plan.n_stages(), 2);
        let faults = FaultModel { loss_prob: 0.2, rto_s: 0.2, seed: 7 };
        let dense = CompressPlan::dense(tb.nodes.len());
        let topk = CompressPlan::uniform(crate::compress::CompressKind::TopK, 100.0, tb.nodes.len());
        let td = simulate_iteration_faulty(&plan, &tb, &sched, &dense, faults).iter_s;
        let tc = simulate_iteration_faulty(&plan, &tb, &sched, &topk, faults).iter_s;
        assert!(tc < td);
    }
}
