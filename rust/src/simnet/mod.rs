//! Discrete-event simulation of one training iteration over the
//! geo-distributed testbed (drives Fig. 10 / Fig. 11).
//!
//! Unlike the closed-form Eq. 3 estimate, the simulator executes the actual
//! pipeline schedule (GPipe or 1F1B) with per-link α+βM transfer times and
//! FIFO link serialization, so compute/communication overlap and stragglers
//! emerge rather than being assumed.

pub mod sim;
pub mod stageplan;

pub use sim::{
    simulate_iteration, simulate_iteration_faulty, simulate_iteration_with, FaultModel,
    SimOpts, SimResult,
};
pub use stageplan::StagePlan;
