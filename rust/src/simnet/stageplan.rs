//! StagePlan: the linearized pipeline view of a (DAG, Partition) pair.
//!
//! Contiguous chain partitions induce a sequence of stages, one per device
//! in chain order, with per-stage compute seconds and inter-stage message
//! sizes — the structure both the simulator and the real workers execute.

use crate::cluster::Testbed;
use crate::cost::Estimator;
use crate::opdag::{Dag, Partition};

#[derive(Debug, Clone)]
pub struct StagePlan {
    /// Device id per stage, in chain order.
    pub devices: Vec<usize>,
    /// Forward compute seconds per stage (one microbatch).
    pub fwd_s: Vec<f64>,
    /// Backward compute seconds per stage.
    pub bwd_s: Vec<f64>,
    /// Optimizer-update seconds per stage.
    pub update_s: Vec<f64>,
    /// Dense activation bytes on edge stage s -> s+1 (len = stages - 1).
    pub act_bytes: Vec<f64>,
}

impl StagePlan {
    /// Linearize a contiguous chain partition. Panics if the partition is
    /// not contiguous along the chain (all our schedulers produce
    /// contiguous partitions; a non-contiguous one is a scheduler bug).
    pub fn from_partition(dag: &Dag, part: &Partition, testbed: &Testbed) -> StagePlan {
        let est = Estimator::new(testbed);
        let chain = dag.compute_chain();
        let mut devices: Vec<usize> = Vec::new();
        let mut fwd_s = Vec::new();
        let mut bwd_s = Vec::new();
        let mut update_s = Vec::new();
        let mut act_bytes = Vec::new();

        for (i, &op) in chain.iter().enumerate() {
            let dev = part.node_of(op);
            if devices.last() != Some(&dev) {
                assert!(
                    !devices.contains(&dev),
                    "partition not contiguous: device {dev} appears twice"
                );
                devices.push(dev);
                fwd_s.push(0.0);
                bwd_s.push(0.0);
                update_s.push(0.0);
                if devices.len() > 1 {
                    // Boundary payload: previous op's activation.
                    act_bytes.push(dag.ops[chain[i - 1]].out_bytes);
                }
            }
            let s = devices.len() - 1;
            fwd_s[s] += est.comp_time_fwd(dag, op, dev);
            bwd_s[s] += est.comp_time_bwd(dag, op, dev);
            // Update cost model: one fused axpy pass over params — tiny
            // next to fwd/bwd but nonzero (bytes / ~20 GB/s effective).
            update_s[s] += dag.ops[op].param_bytes * 3.0 / 20e9;
        }
        StagePlan { devices, fwd_s, bwd_s, update_s, act_bytes }
    }

    pub fn n_stages(&self) -> usize {
        self.devices.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::testbed::testbed1;
    use crate::opdag::builders::{transformer_chain, TransformerSpec};
    use crate::scheduler::{by_name, Scheduler};

    #[test]
    fn linearizes_opfence_partition() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let p = by_name("opfence").unwrap().schedule(&dag, &tb).unwrap();
        let plan = StagePlan::from_partition(&dag, &p, &tb);
        assert_eq!(plan.n_stages(), p.nodes_used());
        assert_eq!(plan.act_bytes.len(), plan.n_stages() - 1);
        assert!(plan.fwd_s.iter().all(|&t| t >= 0.0));
        // GPT2-XL inter-stage messages ≈ 19.66 MB everywhere.
        for &b in &plan.act_bytes {
            assert!((b - 19.66e6).abs() < 1e6, "bytes={b}");
        }
    }

    #[test]
    #[should_panic(expected = "not contiguous")]
    fn rejects_non_contiguous() {
        let tb = testbed1(1);
        let dag = transformer_chain(&TransformerSpec::gpt2_xl());
        let chain = dag.compute_chain();
        // Alternate devices 0/1 along the chain.
        let mut assign = vec![0usize; dag.len()];
        for (i, &op) in chain.iter().enumerate() {
            assign[op] = i % 2;
        }
        let p = Partition::new(assign);
        let _ = StagePlan::from_partition(&dag, &p, &tb);
    }
}
