//! The `fusionllm worker` process: a remote stage executor.
//!
//! Connects to the broker (`--connect host:port`), authenticates with the
//! shared token, then serves `StageAssign`s until the broker says Exit:
//! each assignment builds the manifest/backend locally (PJRT artifacts
//! come from this machine's `--artifacts` root; Null configs are
//! synthesized from the config name), installs the per-generation lane
//! queues, answers the ready barrier, and runs the *same* schedule
//! interpreter the in-process workers run — `stage::run_stage` — over
//! TCP-backed links. Re-partitions and crash recovery therefore reach
//! remote workers for free: a new generation is just the next Assign.

use super::messages::{StageCodec, Wire};
use super::stage::{self, BackendKind, StageCtx};
use super::RunOutcome;
use crate::runtime::{Manifest, ModelCfg};
use crate::transport::chan;
use crate::transport::frame::Lane;
use crate::transport::mesh::PeerNode;
use crate::transport::tcp::{StageAssign, WorkerCtl, WorkerSession};
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::time::Duration;

/// CLI-level options of one worker process.
#[derive(Debug, Clone)]
pub struct WorkerOpts {
    /// Broker address (`host:port`).
    pub connect: String,
    /// Shared-secret token (must match the broker's `--token`).
    pub token: String,
    /// Requested device id (None = broker assigns the next free one).
    pub device: Option<usize>,
    /// Local PJRT artifacts root (Null assignments ignore it).
    pub artifacts: PathBuf,
    /// How long to keep retrying the initial connect (the broker may
    /// start after the workers).
    pub retry: Duration,
    /// Mesh data plane: bind a peer listener on this address and
    /// advertise it to the broker (None = relay-only worker; a broker
    /// running `--data-plane mesh` will refuse to place stages on it).
    pub peer_listen: Option<String>,
}

/// Run the worker process until the broker exits (or the connection is
/// lost). Returns Ok on a clean broker-initiated Exit.
pub fn run_worker(opts: &WorkerOpts) -> anyhow::Result<()> {
    // The peer listener outlives generations: neighbors dial it afresh
    // each time a route table arrives, and generation ids in the peer
    // hello keep stale dials from crossing a replan boundary.
    let node = match &opts.peer_listen {
        Some(spec) => Some(PeerNode::bind(spec, &opts.token)?),
        None => None,
    };
    let session = WorkerSession::connect(
        &opts.connect,
        &opts.token,
        opts.device,
        node.as_ref().map(|p| p.advert().to_string()),
        opts.retry,
    )?;
    eprintln!(
        "worker: connected to broker {} (requested device: {}, peer listener: {})",
        session.peer(),
        opts.device.map(|d| d.to_string()).unwrap_or_else(|| "any".into()),
        node.as_ref().map(|p| p.advert().to_string()).unwrap_or_else(|| "off".into())
    );
    loop {
        match session.ctl().recv() {
            Err(_) => anyhow::bail!("broker connection lost"),
            Ok(WorkerCtl::Lost(why)) => anyhow::bail!("broker connection lost: {why}"),
            Ok(WorkerCtl::Exit) => {
                eprintln!("worker: broker finished, exiting");
                return Ok(());
            }
            Ok(WorkerCtl::Assign(a)) => {
                eprintln!(
                    "worker: assigned stage {}/{} (device {}, iters {}..{})",
                    a.stage,
                    a.n_stages,
                    a.device,
                    a.iter0,
                    a.iter0 as usize + a.iters
                );
                if !serve_assignment(&session, node.as_ref(), *a, &opts.artifacts)? {
                    // Churn injector fired: vanish like a kill -9 (the
                    // socket closes when `session` drops).
                    return Ok(());
                }
            }
        }
    }
}

/// Serve one generation's stage. Returns false when the process should
/// disappear (fault-injection kill).
fn serve_assignment(
    session: &WorkerSession,
    node: Option<&PeerNode>,
    a: StageAssign,
    artifacts: &Path,
) -> anyhow::Result<bool> {
    let stage = a.stage;
    let is_head = a.stage + 1 == a.n_stages;
    let manifest = match a.backend {
        BackendKind::Pjrt => Manifest::load(artifacts, &a.config)?,
        BackendKind::Null => Manifest::synthetic(ModelCfg::null_sim(&a.config)),
    };
    let codec = StageCodec::from_specs(a.fwd, a.bwd, a.chunk);
    let fwd_pool = codec.fwd.as_ref().map(|e| e.pool());
    let bwd_pool = codec.bwd.as_ref().map(|e| e.pool());

    let (fwd_tx, fwd_rx) = mpsc::channel::<Wire>();
    let (bwd_tx, bwd_rx) = mpsc::channel::<Wire>();
    let (lbl_tx, lbl_rx) = mpsc::channel::<Wire>();
    session.install_lanes(
        fwd_tx.clone(),
        (!is_head).then(|| bwd_tx.clone()),
        is_head.then_some(lbl_tx),
    );

    // Mesh data plane: a non-empty route table means this generation's
    // packet lanes run on direct peer connections. Incoming peer packets
    // land in the same fwd/bwd queues the broker demux feeds, so the
    // interpreter below is untouched; it must be up before the ready
    // barrier — the broker only starts the generation once every stage
    // has its peer links (dials can't miss: listeners bind at startup).
    let mesh = if a.peers.is_empty() {
        None
    } else {
        let node = node.ok_or_else(|| {
            anyhow::anyhow!(
                "broker issued a mesh route table but this worker has no --peer-listen"
            )
        })?;
        Some(node.establish(
            &a,
            fwd_tx,
            (!is_head).then_some(bwd_tx),
            session.rx_pool(),
            fwd_pool.clone(),
            bwd_pool.clone(),
        )?)
    };

    let ctx = StageCtx {
        stage: a.stage,
        n_stages: a.n_stages,
        device: a.device,
        next_device: a.next_device,
        prev_device: a.prev_device,
        manifest,
        codec,
        tasks: a.tasks,
        iter0: a.iter0,
        iters: a.iters,
        n_micro: a.n_micro,
        lr: a.lr,
        momentum: a.momentum,
        optimizer: a.optimizer,
        param_seed: a.param_seed,
        init_state: a.init_state,
        slow_factor: a.slow_factor,
        pace_s: a.pace_s,
        backend: a.backend,
        heartbeat: (a.heartbeat_s > 0.0).then(|| Duration::from_secs_f64(a.heartbeat_s)),
        kill_at_iter: a.kill_at_iter,
        overlap: a.overlap,
        link_delay_s: a.link_delay_s,
        rx_fwd: chan::endpoint(fwd_rx),
        rx_bwd: (!is_head).then(|| chan::endpoint(bwd_rx)),
        tx_fwd: match &mesh {
            Some(m) => m.fwd_link(),
            None => (!is_head).then(|| session.link(Lane::Fwd, fwd_pool)),
        },
        tx_bwd: match &mesh {
            Some(m) => m.bwd_link(),
            None => (a.stage > 0).then(|| session.link(Lane::Bwd, bwd_pool)),
        },
        rx_labels: is_head.then(|| chan::endpoint(lbl_rx)),
        tx_driver: session.link(Lane::Driver, None),
        // Incoming packet bodies come from the demux reader's pool;
        // drained buffers cycle back to it.
        fwd_return: Some(session.rx_pool()),
        bwd_return: Some(session.rx_pool()),
    };

    // Ready barrier: lanes are installed, the broker may start the
    // generation (backend init is covered by the first-contact grace).
    session.send_ready(stage)?;
    let outcome = stage::run_stage(ctx);
    session.clear_lanes();
    // Tear the generation's peer links down *after* the interpreter has
    // fully quiesced: windows close, sockets shut, threads join. The
    // next Assign re-establishes with a fresh generation id.
    drop(mesh);
    match outcome {
        Ok(RunOutcome::Killed) => {
            eprintln!("worker: fault injector fired — vanishing (simulated kill -9)");
            Ok(false)
        }
        Ok(_) => Ok(true),
        Err(e) => {
            // Report and stay connected: the broker fails this device and
            // re-plans; this process can still host a later generation.
            let _ = session
                .link(Lane::Driver, None)
                .send(Wire::Fatal { stage, error: format!("{e:#}") });
            eprintln!("worker: stage {stage} failed: {e:#}");
            Ok(true)
        }
    }
}
