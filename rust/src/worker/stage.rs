//! Stage worker threads: the per-CompNode executor.
//!
//! GPipe iteration protocol (matching `pipeline::ScheduleKind::GPipe`):
//!   fwd phase: for m in 0..n_micro — recv input, run fwd, send output
//!   bwd phase: for m in rev      — recv grad, run bwd, send grad back
//!   update    : scale accumulated grads by 1/n_micro, run SGD artifact
//!
//! The head stage computes loss+gradients in its forward leg
//! (head_fwd_loss) and replays the stored dx in reverse order during the
//! bwd phase — a GPipe flush.

use super::messages::{decode_payload_into, StageCodec, Wire, WorkerStats};
use crate::opdag::data::OpDataKind;
use crate::runtime::{Manifest, Runtime, StageKind};
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

/// Everything a stage worker needs (all Send).
pub struct StageCtx {
    pub stage: usize,
    pub n_stages: usize,
    /// CompNode id hosting this stage (selects compression ratios).
    pub device: usize,
    /// CompNode id of the next stage (dst of our fwd messages).
    pub next_device: Option<usize>,
    /// CompNode id of the previous stage (dst of our bwd messages).
    pub prev_device: Option<usize>,
    pub manifest: Manifest,
    /// Per-link wire codecs (compression scratch + staging buffers), built
    /// by the broker from the `CompressPlan`.
    pub codec: StageCodec,
    pub iters: usize,
    pub n_micro: usize,
    pub lr: f32,
    pub momentum: f32,
    /// "sgd" or "adam".
    pub optimizer: String,
    pub param_seed: u64,
    /// Forward input (None for embed: tokens come from the driver).
    pub rx_fwd: Receiver<Wire>,
    /// Backward gradient input (None for head).
    pub rx_bwd: Option<Receiver<Wire>>,
    /// Forward output (None for head).
    pub tx_fwd: Option<Sender<Wire>>,
    /// Backward gradient output (None for embed).
    pub tx_bwd: Option<Sender<Wire>>,
    /// Head only: label stream from the driver.
    pub rx_labels: Option<Receiver<Wire>>,
    /// Loss + stats reporting to the driver.
    pub tx_driver: Sender<Wire>,
}

/// Spawn the worker thread for one stage. Errors are reported to the
/// driver as `Wire::Fatal` so the job aborts instead of hanging.
pub fn spawn_stage(ctx: StageCtx) -> std::thread::JoinHandle<anyhow::Result<()>> {
    std::thread::Builder::new()
        .name(format!("stage{}", ctx.stage))
        .spawn(move || {
            let stage = ctx.stage;
            let tx = ctx.tx_driver.clone();
            let r = run_stage(ctx);
            if let Err(e) = &r {
                let _ = tx.send(Wire::Fatal { stage, error: format!("{e:#}") });
            }
            r
        })
        .expect("spawn stage worker")
}

fn axpy_acc(acc: &mut [f32], x: &[f32]) {
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

fn run_stage(mut ctx: StageCtx) -> anyhow::Result<()> {
    let spec = ctx.manifest.stages[ctx.stage].clone();
    let cfg = ctx.manifest.config.clone();
    let act_n = cfg.act_elems();
    let act_dims = [cfg.microbatch as i64, cfg.seq_len as i64, cfg.d_model as i64];
    let tok_dims = [cfg.microbatch as i64, cfg.seq_len as i64];

    // Per-thread PJRT runtime with only this stage's entries.
    let use_adam = ctx.optimizer == "adam";
    let opt_entry: String = if use_adam {
        spec.adam_entry().to_string()
    } else {
        spec.sgd_entry().to_string()
    };
    let mut entries: Vec<&str> = match spec.kind {
        StageKind::Embed => vec!["embed_fwd", "embed_bwd"],
        StageKind::Body => vec!["body_fwd", "body_bwd"],
        StageKind::Head => vec!["head_fwd_loss"],
    };
    entries.push(&opt_entry);
    let mut rt = Runtime::load(&ctx.manifest, Some(&entries))?;

    let mut params = spec.init_params(ctx.param_seed);
    let mut momentum = vec![0.0f32; spec.param_size];
    // Second moment buffer (Adam only).
    let mut second = vec![0.0f32; if use_adam { spec.param_size } else { 0 }];
    let mut stats = WorkerStats {
        stage: ctx.stage,
        device: ctx.device,
        ..Default::default()
    };

    // Reusable decode buffers: `recycle` feeds the activation stash (bufs
    // return on the backward pass), `grad_buf` holds transient gradients.
    let mut recycle: Vec<Vec<f32>> = Vec::new();
    let mut grad_buf = vec![0.0f32; act_n];

    for iter in 0..ctx.iters as u32 {
        // ---------------- forward phase ----------------
        // Stash: embed keeps tokens; body keeps inputs; head keeps dx.
        let mut stash_tokens: Vec<Vec<i32>> = Vec::new();
        let mut stash_acts: Vec<Vec<f32>> = Vec::new();
        let mut stash_dx: Vec<Vec<f32>> = Vec::new();
        let mut grad_acc = vec![0.0f32; spec.param_size];

        for micro in 0..ctx.n_micro as u32 {
            let t_wait = Instant::now();
            match spec.kind {
                StageKind::Embed => {
                    let msg = ctx.rx_fwd.recv()?;
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    let tokens = match msg {
                        Wire::Data { tokens, .. } => tokens,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("embed: unexpected {other:?}"),
                    };
                    let t0 = Instant::now();
                    let out = rt.exec(
                        "embed_fwd",
                        &[
                            Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                            Runtime::i32_tensor(&tokens, &tok_dims)?,
                        ],
                    )?;
                    stats.fwd_s += t0.elapsed().as_secs_f64();
                    let y = Runtime::to_f32_vec(&out[0])?;
                    stash_tokens.push(tokens);
                    send_act(&mut ctx, &mut stats, iter, micro, &y)?;
                }
                StageKind::Body => {
                    let msg = ctx.rx_fwd.recv()?;
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    let buf = match msg {
                        Wire::Packet(b) => b,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("body: unexpected {other:?}"),
                    };
                    let mut x = recycle.pop().unwrap_or_default();
                    x.resize(act_n, 0.0);
                    decode_payload_into(&buf, &mut x)?;
                    let t0 = Instant::now();
                    let out = rt.exec(
                        "body_fwd",
                        &[
                            Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                            Runtime::f32_tensor(&x, &act_dims)?,
                        ],
                    )?;
                    stats.fwd_s += t0.elapsed().as_secs_f64();
                    let y = Runtime::to_f32_vec(&out[0])?;
                    stash_acts.push(x);
                    send_act(&mut ctx, &mut stats, iter, micro, &y)?;
                }
                StageKind::Head => {
                    // Labels first (driver sends them eagerly), then act.
                    let labels = match ctx.rx_labels.as_ref().unwrap().recv()? {
                        Wire::Labels { targets, .. } => targets,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("head labels: unexpected {other:?}"),
                    };
                    let buf = match ctx.rx_fwd.recv()? {
                        Wire::Packet(b) => b,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("head: unexpected {other:?}"),
                    };
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    let mut x = recycle.pop().unwrap_or_default();
                    x.resize(act_n, 0.0);
                    decode_payload_into(&buf, &mut x)?;
                    let t0 = Instant::now();
                    let out = rt.exec(
                        "head_fwd_loss",
                        &[
                            Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                            Runtime::f32_tensor(&x, &act_dims)?,
                            Runtime::i32_tensor(&labels, &tok_dims)?,
                        ],
                    )?;
                    recycle.push(x);
                    stats.fwd_s += t0.elapsed().as_secs_f64();
                    let loss = Runtime::to_f32_scalar(&out[0])?;
                    let dx = Runtime::to_f32_vec(&out[1])?;
                    let dp = Runtime::to_f32_vec(&out[2])?;
                    axpy_acc(&mut grad_acc, &dp);
                    stash_dx.push(dx);
                    ctx.tx_driver.send(Wire::Loss { iter, micro, loss })?;
                }
            }
        }

        // ---------------- backward phase (reverse microbatch order) ------
        for micro in (0..ctx.n_micro as u32).rev() {
            match spec.kind {
                StageKind::Head => {
                    // Replay stored dx (GPipe flush).
                    let dx = stash_dx.pop().expect("head dx stash");
                    send_grad(&mut ctx, &mut stats, iter, micro, &dx)?;
                }
                StageKind::Body => {
                    let t_wait = Instant::now();
                    let buf = match ctx.rx_bwd.as_ref().unwrap().recv()? {
                        Wire::Packet(b) => b,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("body bwd: unexpected {other:?}"),
                    };
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    decode_payload_into(&buf, &mut grad_buf)?;
                    let x = stash_acts.pop().expect("body act stash");
                    let t0 = Instant::now();
                    let out = rt.exec(
                        "body_bwd",
                        &[
                            Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                            Runtime::f32_tensor(&x, &act_dims)?,
                            Runtime::f32_tensor(&grad_buf, &act_dims)?,
                        ],
                    )?;
                    stats.bwd_s += t0.elapsed().as_secs_f64();
                    recycle.push(x);
                    let dx = Runtime::to_f32_vec(&out[0])?;
                    let dp = Runtime::to_f32_vec(&out[1])?;
                    axpy_acc(&mut grad_acc, &dp);
                    send_grad(&mut ctx, &mut stats, iter, micro, &dx)?;
                }
                StageKind::Embed => {
                    let t_wait = Instant::now();
                    let buf = match ctx.rx_bwd.as_ref().unwrap().recv()? {
                        Wire::Packet(b) => b,
                        Wire::Stop => return finish(&ctx, stats),
                        other => anyhow::bail!("embed bwd: unexpected {other:?}"),
                    };
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    decode_payload_into(&buf, &mut grad_buf)?;
                    let tokens = stash_tokens.pop().expect("embed token stash");
                    let t0 = Instant::now();
                    let out = rt.exec(
                        "embed_bwd",
                        &[
                            Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                            Runtime::i32_tensor(&tokens, &tok_dims)?,
                            Runtime::f32_tensor(&grad_buf, &act_dims)?,
                        ],
                    )?;
                    stats.bwd_s += t0.elapsed().as_secs_f64();
                    let dp = Runtime::to_f32_vec(&out[0])?;
                    axpy_acc(&mut grad_acc, &dp);
                }
            }
        }

        // ---------------- update ----------------
        let scale = 1.0 / ctx.n_micro as f32;
        for g in grad_acc.iter_mut() {
            *g *= scale;
        }
        let t0 = Instant::now();
        if use_adam {
            let out = rt.exec(
                &opt_entry,
                &[
                    Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                    Runtime::f32_tensor(&grad_acc, &[spec.param_size as i64])?,
                    Runtime::f32_tensor(&momentum, &[spec.param_size as i64])?,
                    Runtime::f32_tensor(&second, &[spec.param_size as i64])?,
                    Runtime::f32_scalar(ctx.lr),
                    Runtime::f32_scalar((iter + 1) as f32),
                ],
            )?;
            stats.update_s += t0.elapsed().as_secs_f64();
            params = Runtime::to_f32_vec(&out[0])?;
            momentum = Runtime::to_f32_vec(&out[1])?;
            second = Runtime::to_f32_vec(&out[2])?;
        } else {
            let out = rt.exec(
                &opt_entry,
                &[
                    Runtime::f32_tensor(&params, &[spec.param_size as i64])?,
                    Runtime::f32_tensor(&grad_acc, &[spec.param_size as i64])?,
                    Runtime::f32_tensor(&momentum, &[spec.param_size as i64])?,
                    Runtime::f32_scalar(ctx.lr),
                    Runtime::f32_scalar(ctx.momentum),
                ],
            )?;
            stats.update_s += t0.elapsed().as_secs_f64();
            params = Runtime::to_f32_vec(&out[0])?;
            momentum = Runtime::to_f32_vec(&out[1])?;
        }
    }

    finish(&ctx, stats)
}

fn finish(ctx: &StageCtx, stats: WorkerStats) -> anyhow::Result<()> {
    let _ = ctx.tx_driver.send(Wire::Stats(stats));
    Ok(())
}

fn send_act(
    ctx: &mut StageCtx,
    stats: &mut WorkerStats,
    iter: u32,
    micro: u32,
    dense: &[f32],
) -> anyhow::Result<()> {
    if let (Some(tx), Some(enc)) = (&ctx.tx_fwd, ctx.codec.fwd.as_mut()) {
        let (buf, wire) = enc.encode(
            ctx.stage,
            ctx.stage + 1,
            OpDataKind::Activation,
            iter,
            micro,
            dense,
        );
        stats.bytes_sent += wire;
        stats.dense_bytes += 4.0 * dense.len() as f64;
        stats.msgs_sent += 1;
        tx.send(Wire::Packet(buf))?;
    }
    Ok(())
}

fn send_grad(
    ctx: &mut StageCtx,
    stats: &mut WorkerStats,
    iter: u32,
    micro: u32,
    dense: &[f32],
) -> anyhow::Result<()> {
    if let (Some(tx), Some(enc)) = (&ctx.tx_bwd, ctx.codec.bwd.as_mut()) {
        let (buf, wire) = enc.encode(
            ctx.stage,
            ctx.stage - 1,
            OpDataKind::Gradient,
            iter,
            micro,
            dense,
        );
        stats.bytes_sent += wire;
        stats.dense_bytes += 4.0 * dense.len() as f64;
        stats.msgs_sent += 1;
        tx.send(Wire::Packet(buf))?;
    }
    Ok(())
}
