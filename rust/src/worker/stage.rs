//! Stage worker threads: the per-CompNode executor.
//!
//! The worker no longer hardcodes a GPipe phase loop: it hands its
//! `PipelineSchedule` task row to the generic schedule interpreter
//! (`worker::interpreter::run_schedule`) and supplies a `PjrtBackend`
//! that owns the PJRT runtime, flat parameters, optimizer state and the
//! per-micro stashes. GPipe and 1F1B are therefore the *same* execution
//! path with different task orders.
//!
//! Determinism: per-micro parameter gradients are stashed and summed in
//! ascending micro order at Update, so the loss trajectory is bitwise
//! identical across schedule kinds (the 1F1B-vs-GPipe differential test
//! relies on this).

use super::interpreter::{
    run_schedule_with, BwdOut, FwdInput, FwdOut, NullBackend, RunOpts, RunOutcome, StageBackend,
    StageLinks,
};
use super::messages::{StageCodec, StageState, Wire};
use crate::pipeline::Task;
use crate::runtime::{Manifest, ModelCfg, Runtime, StageKind, StageSpec};
use crate::transport::{Endpoint, Link, PacketPool};
use std::time::{Duration, Instant};

/// Which compute backend a stage worker runs. `Null` is the artifact-free
/// arithmetic backend (`interpreter::NullBackend`, stateful flavor) used
/// by `simulate --kill-node` and the churn tests: the full broker —
/// channels, codecs, heartbeats, checkpoints, recovery — runs for real,
/// only the math is mocked.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Null,
}

impl BackendKind {
    pub fn parse(s: &str) -> anyhow::Result<BackendKind> {
        Ok(match s {
            "pjrt" => BackendKind::Pjrt,
            "null" => BackendKind::Null,
            other => anyhow::bail!("unknown backend `{other}` (pjrt|null)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Pjrt => "pjrt",
            BackendKind::Null => "null",
        }
    }
}

/// Everything a stage worker needs (all Send).
pub struct StageCtx {
    pub stage: usize,
    pub n_stages: usize,
    /// CompNode id hosting this stage (selects compression ratios).
    pub device: usize,
    /// CompNode id of the next stage (dst of our fwd messages).
    pub next_device: Option<usize>,
    /// CompNode id of the previous stage (dst of our bwd messages).
    pub prev_device: Option<usize>,
    pub manifest: Manifest,
    /// Per-link wire codecs (compression scratch + staging buffers), built
    /// by the broker from the `CompressPlan`.
    pub codec: StageCodec,
    /// This stage's ordered task row from the `PipelineSchedule`.
    pub tasks: Vec<Task>,
    /// First global iteration this generation executes (continues across
    /// re-partitions so data/optimizer step counts stay aligned).
    pub iter0: u32,
    /// Iterations this generation runs (the remaining budget).
    pub iters: usize,
    pub n_micro: usize,
    pub lr: f32,
    pub momentum: f32,
    /// "sgd" or "adam".
    pub optimizer: String,
    pub param_seed: u64,
    /// Migrated state from a previous generation (None = fresh init).
    pub init_state: Option<StageState>,
    /// Straggler-injection test hook: sleep (factor-1)× the measured
    /// compute time after each fwd/bwd execution. 1.0 = off.
    pub slow_factor: f64,
    /// Artificial seconds per Null forward (`--pace`): pacing for
    /// multi-process demos and the CI kill smoke. 0 = off.
    pub pace_s: f64,
    /// Compute backend (PJRT in production, Null for artifact-free runs).
    pub backend: BackendKind,
    /// Liveness beacon interval (None = blocking receives, no beacons).
    pub heartbeat: Option<Duration>,
    /// Churn injector: vanish silently at the top of this global
    /// iteration (set by the broker when this stage's device matches
    /// `--kill-node` and the generation covers `--kill-at-iter`).
    pub kill_at_iter: Option<u32>,
    /// Overlapped wire pipeline (`--overlap`, on by default): per-link
    /// encoder/sender threads + inbound decode prefetchers in the
    /// schedule interpreter. Bitwise-identical losses either way.
    pub overlap: bool,
    /// Injected per-packet link delay in seconds (`--link-delay`): models
    /// slow-link occupancy for the overlap smoke. 0 = off.
    pub link_delay_s: f64,
    /// Forward input (Data from the driver for stage 0, Packets after).
    pub rx_fwd: Box<dyn Endpoint>,
    /// Backward gradient input (None for head).
    pub rx_bwd: Option<Box<dyn Endpoint>>,
    /// Forward output (None for head).
    pub tx_fwd: Option<Box<dyn Link>>,
    /// Backward gradient output (None for embed).
    pub tx_bwd: Option<Box<dyn Link>>,
    /// Head only: label stream from the driver.
    pub rx_labels: Option<Box<dyn Endpoint>>,
    /// Loss + stats reporting to the driver.
    pub tx_driver: Box<dyn Link>,
    /// Return free-lists for drained packet buffers (upstream fwd
    /// encoder / downstream bwd encoder; None where no packets arrive).
    pub fwd_return: Option<PacketPool>,
    pub bwd_return: Option<PacketPool>,
}

/// Spawn the worker thread for one stage (the `ChanTransport` execution
/// mode). Errors are reported to the driver as `Wire::Fatal` so the job
/// aborts instead of hanging.
pub fn spawn_stage(ctx: StageCtx) -> std::thread::JoinHandle<anyhow::Result<()>> {
    std::thread::Builder::new()
        .name(format!("stage{}", ctx.stage))
        .spawn(move || {
            let stage = ctx.stage;
            let tx = ctx.tx_driver.clone_link();
            let r = run_stage(ctx);
            if let Err(e) = &r {
                let _ = tx.send(Wire::Fatal { stage, error: format!("{e:#}") });
            }
            r.map(|_| ())
        })
        .expect("spawn stage worker")
}

fn axpy_acc(acc: &mut [f32], x: &[f32]) {
    for (a, b) in acc.iter_mut().zip(x) {
        *a += b;
    }
}

/// The PJRT compute backend: owns the runtime, the flat parameter vector,
/// optimizer moments and per-micro stashes keyed by microbatch id (so any
/// legal task interleaving finds its state).
struct PjrtBackend {
    spec: StageSpec,
    cfg: ModelCfg,
    rt: Runtime,
    use_adam: bool,
    opt_entry: String,
    params: Vec<f32>,
    momentum: Vec<f32>,
    second: Vec<f32>,
    lr: f32,
    mom: f32,
    n_micro: usize,
    act_dims: [i64; 3],
    tok_dims: [i64; 2],
    /// Embed: token microbatches awaiting their backward.
    stash_tokens: Vec<Option<Vec<i32>>>,
    /// Body: forward inputs awaiting their backward.
    stash_acts: Vec<Option<Vec<f32>>>,
    /// Head: loss gradients replayed in the backward task.
    stash_dx: Vec<Option<Vec<f32>>>,
    /// Per-micro parameter gradients, summed in ascending micro order at
    /// Update (the schedule-independence contract).
    dp: Vec<Option<Vec<f32>>>,
    /// Straggler-injection factor (>= 1.0; 1.0 = off).
    slow_factor: f64,
}

impl PjrtBackend {
    fn new(ctx: &StageCtx) -> anyhow::Result<PjrtBackend> {
        let spec = ctx.manifest.stages[ctx.stage].clone();
        let cfg = ctx.manifest.config.clone();
        let use_adam = ctx.optimizer == "adam";
        let opt_entry: String = if use_adam {
            spec.adam_entry().to_string()
        } else {
            spec.sgd_entry().to_string()
        };
        let mut entries: Vec<&str> = match spec.kind {
            StageKind::Embed => vec!["embed_fwd", "embed_bwd"],
            StageKind::Body => vec!["body_fwd", "body_bwd"],
            StageKind::Head => vec!["head_fwd_loss"],
        };
        entries.push(&opt_entry);
        let rt = Runtime::load(&ctx.manifest, Some(&entries))?;

        let (params, momentum, second) = match &ctx.init_state {
            Some(st) => {
                anyhow::ensure!(
                    st.params.len() == spec.param_size,
                    "stage {}: migrated params {} != spec {}",
                    ctx.stage,
                    st.params.len(),
                    spec.param_size
                );
                let second = if use_adam && st.second.is_empty() {
                    vec![0.0f32; spec.param_size]
                } else {
                    st.second.clone()
                };
                (st.params.clone(), st.momentum.clone(), second)
            }
            None => (
                spec.init_params(ctx.param_seed),
                vec![0.0f32; spec.param_size],
                vec![0.0f32; if use_adam { spec.param_size } else { 0 }],
            ),
        };

        Ok(PjrtBackend {
            act_dims: [cfg.microbatch as i64, cfg.seq_len as i64, cfg.d_model as i64],
            tok_dims: [cfg.microbatch as i64, cfg.seq_len as i64],
            spec,
            cfg,
            rt,
            use_adam,
            opt_entry,
            params,
            momentum,
            second,
            lr: ctx.lr,
            mom: ctx.momentum,
            n_micro: ctx.n_micro,
            stash_tokens: (0..ctx.n_micro).map(|_| None).collect(),
            stash_acts: (0..ctx.n_micro).map(|_| None).collect(),
            stash_dx: (0..ctx.n_micro).map(|_| None).collect(),
            dp: (0..ctx.n_micro).map(|_| None).collect(),
            slow_factor: ctx.slow_factor.max(1.0),
        })
    }

    /// Straggler injection: stretch the observed compute time.
    fn drag(&self, t0: Instant) {
        if self.slow_factor > 1.0 {
            let extra = t0.elapsed().as_secs_f64() * (self.slow_factor - 1.0);
            std::thread::sleep(Duration::from_secs_f64(extra));
        }
    }
}

impl StageBackend for PjrtBackend {
    fn act_elems(&self) -> usize {
        self.cfg.act_elems()
    }

    fn forward(
        &mut self,
        _iter: u32,
        micro: usize,
        input: FwdInput,
        labels: Option<Vec<i32>>,
    ) -> anyhow::Result<FwdOut> {
        let psz = self.spec.param_size as i64;
        let t0 = Instant::now();
        match (self.spec.kind, input) {
            (StageKind::Embed, FwdInput::Tokens(tokens)) => {
                let out = self.rt.exec(
                    "embed_fwd",
                    &[
                        Runtime::f32_tensor(&self.params, &[psz])?,
                        Runtime::i32_tensor(&tokens, &self.tok_dims)?,
                    ],
                )?;
                let y = Runtime::to_f32_vec(&out[0])?;
                self.stash_tokens[micro] = Some(tokens);
                self.drag(t0);
                Ok(FwdOut::Act(y))
            }
            (StageKind::Body, FwdInput::Act(x)) => {
                let out = self.rt.exec(
                    "body_fwd",
                    &[
                        Runtime::f32_tensor(&self.params, &[psz])?,
                        Runtime::f32_tensor(&x, &self.act_dims)?,
                    ],
                )?;
                let y = Runtime::to_f32_vec(&out[0])?;
                self.stash_acts[micro] = Some(x);
                self.drag(t0);
                Ok(FwdOut::Act(y))
            }
            (StageKind::Head, FwdInput::Act(x)) => {
                let targets = labels
                    .ok_or_else(|| anyhow::anyhow!("head forward without labels"))?;
                let out = self.rt.exec(
                    "head_fwd_loss",
                    &[
                        Runtime::f32_tensor(&self.params, &[psz])?,
                        Runtime::f32_tensor(&x, &self.act_dims)?,
                        Runtime::i32_tensor(&targets, &self.tok_dims)?,
                    ],
                )?;
                let loss = Runtime::to_f32_scalar(&out[0])?;
                let dx = Runtime::to_f32_vec(&out[1])?;
                let dp = Runtime::to_f32_vec(&out[2])?;
                self.dp[micro] = Some(dp);
                self.stash_dx[micro] = Some(dx);
                self.drag(t0);
                Ok(FwdOut::Loss { loss, free: Some(x) })
            }
            (kind, _) => anyhow::bail!("{kind:?} stage got a mismatched forward input"),
        }
    }

    fn backward(
        &mut self,
        _iter: u32,
        micro: usize,
        grad: Option<&[f32]>,
    ) -> anyhow::Result<BwdOut> {
        let psz = self.spec.param_size as i64;
        let t0 = Instant::now();
        match self.spec.kind {
            StageKind::Head => {
                // Replay the stored loss gradient (PipeDream-flush).
                let dx = self.stash_dx[micro]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("head backward before forward"))?;
                Ok(BwdOut { dx: Some(dx), free: None })
            }
            StageKind::Body => {
                let g = grad.ok_or_else(|| anyhow::anyhow!("body backward without grad"))?;
                let x = self.stash_acts[micro]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("body backward before forward"))?;
                let out = self.rt.exec(
                    "body_bwd",
                    &[
                        Runtime::f32_tensor(&self.params, &[psz])?,
                        Runtime::f32_tensor(&x, &self.act_dims)?,
                        Runtime::f32_tensor(g, &self.act_dims)?,
                    ],
                )?;
                let dx = Runtime::to_f32_vec(&out[0])?;
                let dp = Runtime::to_f32_vec(&out[1])?;
                self.dp[micro] = Some(dp);
                self.drag(t0);
                Ok(BwdOut { dx: Some(dx), free: Some(x) })
            }
            StageKind::Embed => {
                let g = grad.ok_or_else(|| anyhow::anyhow!("embed backward without grad"))?;
                let tokens = self.stash_tokens[micro]
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("embed backward before forward"))?;
                let out = self.rt.exec(
                    "embed_bwd",
                    &[
                        Runtime::f32_tensor(&self.params, &[psz])?,
                        Runtime::i32_tensor(&tokens, &self.tok_dims)?,
                        Runtime::f32_tensor(g, &self.act_dims)?,
                    ],
                )?;
                let dp = Runtime::to_f32_vec(&out[0])?;
                self.dp[micro] = Some(dp);
                self.drag(t0);
                Ok(BwdOut { dx: None, free: None })
            }
        }
    }

    fn update(&mut self, iter: u32) -> anyhow::Result<()> {
        let psz = self.spec.param_size as i64;
        // Fixed accumulation order (ascending micro): schedule-independent.
        let mut grad_acc = vec![0.0f32; self.spec.param_size];
        for m in 0..self.n_micro {
            let dp = self.dp[m]
                .take()
                .ok_or_else(|| anyhow::anyhow!("update before backward of micro {m}"))?;
            axpy_acc(&mut grad_acc, &dp);
        }
        let scale = 1.0 / self.n_micro as f32;
        for g in grad_acc.iter_mut() {
            *g *= scale;
        }
        if self.use_adam {
            let out = self.rt.exec(
                &self.opt_entry,
                &[
                    Runtime::f32_tensor(&self.params, &[psz])?,
                    Runtime::f32_tensor(&grad_acc, &[psz])?,
                    Runtime::f32_tensor(&self.momentum, &[psz])?,
                    Runtime::f32_tensor(&self.second, &[psz])?,
                    Runtime::f32_scalar(self.lr),
                    Runtime::f32_scalar((iter + 1) as f32),
                ],
            )?;
            self.params = Runtime::to_f32_vec(&out[0])?;
            self.momentum = Runtime::to_f32_vec(&out[1])?;
            self.second = Runtime::to_f32_vec(&out[2])?;
        } else {
            let out = self.rt.exec(
                &self.opt_entry,
                &[
                    Runtime::f32_tensor(&self.params, &[psz])?,
                    Runtime::f32_tensor(&grad_acc, &[psz])?,
                    Runtime::f32_tensor(&self.momentum, &[psz])?,
                    Runtime::f32_scalar(self.lr),
                    Runtime::f32_scalar(self.mom),
                ],
            )?;
            self.params = Runtime::to_f32_vec(&out[0])?;
            self.momentum = Runtime::to_f32_vec(&out[1])?;
        }
        Ok(())
    }

    fn snapshot(&self) -> Option<StageState> {
        Some(StageState {
            params: self.params.clone(),
            momentum: self.momentum.clone(),
            second: self.second.clone(),
        })
    }
}

/// Execute one stage to completion on the calling thread. This is the
/// single execution path for both transports: `spawn_stage` wraps it in a
/// thread (chan mode), `worker::remote::run_worker` calls it directly per
/// `StageAssign` (one stage of one generation, tcp mode).
pub fn run_stage(ctx: StageCtx) -> anyhow::Result<RunOutcome> {
    let kind = ctx.backend;
    let tasks = ctx.tasks.clone();
    let (iter0, iters) = (ctx.iter0, ctx.iters);
    let opts = RunOpts {
        heartbeat: ctx.heartbeat,
        kill_at_iter: ctx.kill_at_iter,
        overlap: ctx.overlap,
        link_delay_s: ctx.link_delay_s,
    };
    match kind {
        BackendKind::Pjrt => {
            let mut backend = PjrtBackend::new(&ctx)?;
            let mut links = links_from_ctx(ctx);
            run_schedule_with(&mut links, &mut backend, &tasks, iter0, iters, opts)
        }
        BackendKind::Null => {
            // Activation payload = one f32 per token (no artifacts, no
            // d_model blow-up); the embed stage maps tokens 1:1.
            let cfg = &ctx.manifest.config;
            let n = (cfg.microbatch * cfg.seq_len).max(1);
            let is_head = ctx.stage + 1 == ctx.n_stages;
            let mut backend = NullBackend::stateful(n, ctx.n_micro, is_head);
            backend.pace_s = ctx.pace_s.max(0.0);
            // Deterministic auxiliary weight block: gives Null snapshots a
            // realistic size (1025 f32s/stage) while each optimizer step
            // touches a single slot, so the incremental-checkpoint path
            // has a measurable full-vs-delta gap even in artifact-free CI.
            backend.seed_bulk(ctx.param_seed ^ ctx.stage as u64, 1024);
            if let Some(st) = &ctx.init_state {
                backend.restore(st);
            }
            let mut links = links_from_ctx(ctx);
            run_schedule_with(&mut links, &mut backend, &tasks, iter0, iters, opts)
        }
    }
}

fn links_from_ctx(ctx: StageCtx) -> StageLinks {
    StageLinks {
        stage: ctx.stage,
        device: ctx.device,
        codec: ctx.codec,
        rx_fwd: ctx.rx_fwd,
        rx_bwd: ctx.rx_bwd,
        tx_fwd: ctx.tx_fwd,
        tx_bwd: ctx.tx_bwd,
        rx_labels: ctx.rx_labels,
        tx_driver: ctx.tx_driver,
        fwd_return: ctx.fwd_return,
        bwd_return: ctx.bwd_return,
    }
}
