//! The schedule interpreter: one loop that executes *any*
//! `PipelineSchedule` task list (GPipe, 1F1B, or generated variants)
//! against a pluggable `StageBackend`.
//!
//! The interpreter owns everything protocol-shaped — channel receives and
//! sends, wire decode/encode through the per-link codecs, Stop/teardown
//! handling, per-message statistics and the per-iteration `IterProfile`
//! feedback — while the backend owns the math (PJRT execution in
//! production, trivial arithmetic in tests and benches). This is what
//! makes `ScheduleKind::OneFOneB` a real execution mode rather than a
//! sim-only fiction, and what lets the schedule-legality property tests
//! drive the *production* task loop without artifacts.
//!
//! Determinism contract: gradient accumulation order is fixed per micro
//! (backends stash per-micro parameter gradients and sum them in
//! ascending micro order at Update), so GPipe and 1F1B produce bitwise
//! identical loss trajectories.
//!
//! Overlapped wire pipeline (`RunOpts::overlap`, on by default): each
//! outgoing link gets a dedicated encoder/sender thread fed by a bounded
//! (`OVERLAP_DEPTH`) queue of raw activations/gradients, so compression,
//! `OpData` encode and the transport send of micro *i* overlap the
//! compute of micro *i+1*; each incoming packet lane gets a prefetch
//! thread that receives *and decodes* up to `OVERLAP_DEPTH` messages
//! ahead, so the task loop's receive is a slot take. Determinism is
//! preserved because each link's codec state advances in strict
//! micro-order FIFO on exactly one thread — the bytes on the wire (and
//! therefore the losses) are bitwise identical to the blocking mode,
//! which `--overlap off` keeps available as a differential oracle.

use super::messages::{
    decode_payload_into, LinkEncoder, StageCodec, StageState, Wire, WorkerStats,
};
use crate::opdag::data::OpDataKind;
use crate::pipeline::{Task, TaskKind};
use crate::transport::{Endpoint, Link, PacketPool, RecvError};
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Bounded depth of every overlap queue: a sender thread runs at most
/// this many micro-batches behind the task loop, and a prefetch thread
/// holds at most this many received-and-decoded messages ahead of it.
/// Depth 2 is double buffering — enough to hide one link transfer behind
/// one compute step without ballooning buffered activations.
pub const OVERLAP_DEPTH: usize = 2;

/// Transport + codec endpoints for one stage: everything the interpreter
/// needs to talk to its pipeline neighbors and the driver. The lanes are
/// trait objects, so the same loop runs over in-process channels
/// (`ChanTransport`) and sockets (`TcpTransport`) unchanged.
pub struct StageLinks {
    pub stage: usize,
    /// CompNode id hosting this stage (for stats attribution).
    pub device: usize,
    /// Per-link wire codecs (compression scratch + staging buffers).
    pub codec: StageCodec,
    /// Forward input (Data from the driver for stage 0, Packets otherwise).
    pub rx_fwd: Box<dyn Endpoint>,
    /// Backward gradient input (None for the head stage).
    pub rx_bwd: Option<Box<dyn Endpoint>>,
    /// Forward output (None for the head stage).
    pub tx_fwd: Option<Box<dyn Link>>,
    /// Backward gradient output (None for the embed stage).
    pub tx_bwd: Option<Box<dyn Link>>,
    /// Head only: label stream from the driver.
    pub rx_labels: Option<Box<dyn Endpoint>>,
    /// Loss + profile + stats reporting to the driver.
    pub tx_driver: Box<dyn Link>,
    /// Free-list of the *previous* stage's fwd `LinkEncoder`: drained
    /// activation packet buffers go back to their sender (None when the
    /// upstream is the driver or out-of-process).
    pub fwd_return: Option<PacketPool>,
    /// Free-list of the *next* stage's bwd `LinkEncoder` (gradients).
    pub bwd_return: Option<PacketPool>,
}

/// Forward input handed to the backend. Stage 0 receives raw tokens from
/// the driver; every other stage receives a decoded dense activation
/// (ownership transfers so the backend can stash it for its backward).
pub enum FwdInput {
    Tokens(Vec<i32>),
    Act(Vec<f32>),
}

/// Forward result. `Act` is sent downstream (the buffer is recycled into
/// the decode pool afterwards); `Loss` goes to the driver. `free` returns
/// a consumed input buffer to the interpreter's decode pool.
pub enum FwdOut {
    Act(Vec<f32>),
    Loss { loss: f32, free: Option<Vec<f32>> },
}

/// Backward result: `dx` travels upstream (if a backward link exists),
/// `free` returns a stashed buffer to the decode pool.
pub struct BwdOut {
    pub dx: Option<Vec<f32>>,
    pub free: Option<Vec<f32>>,
}

/// The compute side of a stage. Implementations own parameters, optimizer
/// state and per-micro stashes; the contract that keeps GPipe and 1F1B
/// bitwise identical is that `update` accumulates the stashed per-micro
/// parameter gradients in ascending micro order regardless of the order
/// the schedule executed them in.
pub trait StageBackend {
    /// Dense element count of one inter-stage activation (decode buffer
    /// size for packets and gradients).
    fn act_elems(&self) -> usize;
    fn forward(
        &mut self,
        iter: u32,
        micro: usize,
        input: FwdInput,
        labels: Option<Vec<i32>>,
    ) -> anyhow::Result<FwdOut>;
    /// `grad` is None only on the head stage (it replays its stored dx).
    fn backward(&mut self, iter: u32, micro: usize, grad: Option<&[f32]>)
        -> anyhow::Result<BwdOut>;
    /// Optimizer step closing the iteration.
    fn update(&mut self, iter: u32) -> anyhow::Result<()>;
    /// Live-migration snapshot, requested on a mid-run Stop. Backends
    /// without portable state (mocks) return None.
    fn snapshot(&self) -> Option<StageState> {
        None
    }
}

/// How a schedule run ended: all iterations done, a driver Stop, or the
/// churn fault injector firing (the worker vanishes without a trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunOutcome {
    Completed,
    Stopped,
    Killed,
}

/// Fault-tolerance + overlap knobs for a schedule run. `Default` keeps
/// the PR 3 fault semantics (blocking receives, no beacons, no injector)
/// with the overlapped wire pipeline ON — the overlapped and blocking
/// paths are bitwise identical, so defaulting to fast is safe.
#[derive(Debug, Clone, Copy)]
pub struct RunOpts {
    /// Send `Wire::Heartbeat` at most once per this interval — while
    /// blocked on a channel and between tasks — so the broker's deadline
    /// monitor can tell a slow stage from a dead one. None = blocking
    /// receives (a vanished neighbor then surfaces as an error, not a
    /// quiesce, exactly as before).
    pub heartbeat: Option<Duration>,
    /// Churn injector: exit silently (no Stats, no Snapshot) at the top
    /// of this global iteration, simulating a device that disappears.
    pub kill_at_iter: Option<u32>,
    /// Overlapped wire pipeline: per-link encoder/sender threads plus
    /// inbound decode prefetchers (`--overlap off` disables both and
    /// restores the fully inline blocking path).
    pub overlap: bool,
    /// Injected per-packet transport delay in seconds (`--link-delay`):
    /// the sender sleeps this long after each packet leaves, modelling a
    /// slow link's occupancy. Inline mode pays it in the task loop;
    /// overlap mode hides it behind compute. Never touches the math, so
    /// the loss trajectory is delay-independent.
    pub link_delay_s: f64,
}

impl Default for RunOpts {
    fn default() -> RunOpts {
        RunOpts { heartbeat: None, kill_at_iter: None, overlap: true, link_delay_s: 0.0 }
    }
}

/// Shared dense-buffer free list: the task loop, the prefetch threads and
/// the sender threads all draw decode/compute buffers from (and return
/// them to) one pool, so the steady state allocates nothing even though
/// buffers cross threads.
type BufPool = Arc<Mutex<Vec<Vec<f32>>>>;

fn pool_take(pool: &BufPool, n: usize) -> Vec<f32> {
    let mut b = pool.lock().unwrap().pop().unwrap_or_default();
    b.resize(n, 0.0);
    b
}

fn pool_give(pool: &BufPool, b: Vec<f32>) {
    pool.lock().unwrap().push(b);
}

/// Placeholder endpoint left behind when a lane is moved into a prefetch
/// thread (`StageLinks` keeps its shape; the vacated slot reads closed).
struct ClosedEndpoint;

impl Endpoint for ClosedEndpoint {
    fn recv(&self) -> Result<Wire, RecvError> {
        Err(RecvError::Closed)
    }
    fn recv_deadline(&self, _d: Duration) -> Result<Wire, RecvError> {
        Err(RecvError::Closed)
    }
    fn try_recv(&self) -> Result<Wire, RecvError> {
        Err(RecvError::Closed)
    }
}

/// One message off an inbound lane as the task loop sees it. Under
/// overlap, packets arrive pre-decoded (`Act`); in blocking mode (and for
/// non-packet traffic) the raw `Wire` passes through.
#[derive(Debug)]
enum LaneMsg {
    Wire(Wire),
    /// A packet the prefetch thread already decoded into a dense buffer.
    Act { micro: u32, data: Vec<f32> },
    /// The prefetch thread hit a decode error; the lane is poisoned.
    Failed(String),
}

/// An inbound lane: the raw endpoint (blocking mode) or the bounded
/// channel out of a prefetch thread that receives and decodes up to
/// `OVERLAP_DEPTH` messages ahead of the task loop.
enum InLane {
    Direct(Box<dyn Endpoint>),
    Pre(mpsc::Receiver<LaneMsg>),
}

impl InLane {
    /// Spawn the lookahead thread for one packet lane. It owns the
    /// endpoint, decodes each `Wire::Packet` into a dense buffer from the
    /// shared pool (recycling the packet buffer to `ret`), and forwards
    /// everything else untouched — in arrival order, so the control
    /// stream (Stop/Checkpoint) is never reordered against data.
    ///
    /// Deliberately detached: the thread is usually parked inside
    /// `recv()` and only unblocks when the upstream closes at generation
    /// teardown; joining here would deadlock a mid-run Stop.
    fn prefetch(
        rx: Box<dyn Endpoint>,
        act_n: usize,
        ret: Option<PacketPool>,
        pool: BufPool,
        name: String,
    ) -> InLane {
        let (tx, out) = mpsc::sync_channel::<LaneMsg>(OVERLAP_DEPTH);
        std::thread::Builder::new()
            .name(name)
            .spawn(move || loop {
                match rx.recv() {
                    Err(_) => break,
                    Ok(Wire::Packet(buf)) => {
                        let mut x = pool_take(&pool, act_n);
                        let msg = match decode_payload_into(&buf, &mut x) {
                            Ok(hdr) => {
                                if let Some(p) = &ret {
                                    p.give(buf);
                                }
                                LaneMsg::Act { micro: hdr.micro_batch, data: x }
                            }
                            Err(e) => {
                                pool_give(&pool, x);
                                LaneMsg::Failed(format!("{e:#}"))
                            }
                        };
                        if tx.send(msg).is_err() {
                            break;
                        }
                    }
                    Ok(w) => {
                        if tx.send(LaneMsg::Wire(w)).is_err() {
                            break;
                        }
                    }
                }
            })
            .expect("spawn lane prefetcher");
        InLane::Pre(out)
    }

    fn recv(&self) -> Result<LaneMsg, RecvError> {
        match self {
            InLane::Direct(rx) => rx.recv().map(LaneMsg::Wire),
            InLane::Pre(rx) => rx.recv().map_err(|_| RecvError::Closed),
        }
    }

    fn recv_deadline(&self, d: Duration) -> Result<LaneMsg, RecvError> {
        match self {
            InLane::Direct(rx) => rx.recv_deadline(d).map(LaneMsg::Wire),
            InLane::Pre(rx) => rx.recv_timeout(d).map_err(|e| match e {
                mpsc::RecvTimeoutError::Timeout => RecvError::Timeout,
                mpsc::RecvTimeoutError::Disconnected => RecvError::Closed,
            }),
        }
    }

    fn try_recv(&self) -> Result<LaneMsg, RecvError> {
        match self {
            InLane::Direct(rx) => rx.try_recv().map(LaneMsg::Wire),
            InLane::Pre(rx) => rx.try_recv().map_err(|e| match e {
                mpsc::TryRecvError::Empty => RecvError::Timeout,
                mpsc::TryRecvError::Disconnected => RecvError::Closed,
            }),
        }
    }
}

/// One job for a link's encoder/sender thread: a raw dense payload to
/// compress, encode and put on the wire.
struct SendJob {
    iter: u32,
    micro: u32,
    data: Vec<f32>,
}

#[derive(Default)]
struct SenderState {
    /// Jobs enqueued but not yet fully sent + accounted.
    inflight: usize,
    /// Wire/dense/message accounting since the last `flush`.
    wire: f64,
    dense: f64,
    msgs: u64,
    /// A transport send failed — the neighbor is gone. Later jobs are
    /// drained without sending so the task loop can never block forever.
    failed: bool,
}

#[derive(Default)]
struct SenderShared {
    state: Mutex<SenderState>,
    cv: Condvar,
}

/// The outbound half of the overlap pipeline for one link: a dedicated
/// thread owning the link's `LinkEncoder` (compression scratch, packet
/// pool, any error-feedback residual) and a clone of the transport link,
/// fed through a bounded queue. Jobs are processed in strict FIFO order,
/// so per-message codec state advances exactly as it would inline — the
/// byte stream is bitwise identical to the blocking path.
struct OverlapSender {
    tx: Option<mpsc::SyncSender<SendJob>>,
    shared: Arc<SenderShared>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl OverlapSender {
    #[allow(clippy::too_many_arguments)]
    fn spawn(
        mut enc: LinkEncoder,
        link: Box<dyn Link>,
        src: usize,
        dst: usize,
        kind: OpDataKind,
        link_delay_s: f64,
        pool: BufPool,
        name: String,
    ) -> OverlapSender {
        let (tx, rx) = mpsc::sync_channel::<SendJob>(OVERLAP_DEPTH);
        let shared = Arc::new(SenderShared::default());
        let sh = Arc::clone(&shared);
        let handle = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    let failed = sh.state.lock().unwrap().failed;
                    let mut sent = None;
                    if !failed {
                        let (buf, wire) =
                            enc.encode(src, dst, kind, job.iter, job.micro, &job.data);
                        let dense = 4.0 * job.data.len() as f64;
                        // Pace BEFORE the send: the delay models wire
                        // transfer time, so the receiver must not see the
                        // packet early. The sleep runs on this thread, so
                        // compute on the task thread still overlaps it.
                        if link_delay_s > 0.0 {
                            std::thread::sleep(Duration::from_secs_f64(link_delay_s));
                        }
                        if link.send(Wire::Packet(buf)).is_ok() {
                            sent = Some((wire, dense));
                        }
                    }
                    pool_give(&pool, job.data);
                    let mut st = sh.state.lock().unwrap();
                    match sent {
                        Some((wire, dense)) => {
                            st.wire += wire;
                            st.dense += dense;
                            st.msgs += 1;
                        }
                        None => st.failed = true,
                    }
                    st.inflight -= 1;
                    drop(st);
                    sh.cv.notify_all();
                }
            })
            .expect("spawn link sender");
        OverlapSender { tx: Some(tx), shared, handle: Some(handle) }
    }

    /// Enqueue one payload (blocks when the queue holds `OVERLAP_DEPTH`
    /// jobs — bounded lookahead is the backpressure). Returns false when
    /// the sender thread has seen a transport failure.
    fn send(&self, iter: u32, micro: u32, data: Vec<f32>) -> bool {
        {
            let mut st = self.shared.state.lock().unwrap();
            if st.failed {
                return false;
            }
            st.inflight += 1;
        }
        if self.tx.as_ref().unwrap().send(SendJob { iter, micro, data }).is_err() {
            let mut st = self.shared.state.lock().unwrap();
            st.inflight -= 1;
            st.failed = true;
            return false;
        }
        true
    }

    /// Wait until every enqueued job is on the wire, then take the
    /// accounting deltas (wire bytes, dense bytes, messages) accumulated
    /// since the previous flush. None = a send failed (neighbor gone).
    fn flush(&self) -> Option<(f64, f64, u64)> {
        let mut st = self.shared.state.lock().unwrap();
        while st.inflight > 0 {
            st = self.shared.cv.wait(st).unwrap();
        }
        if st.failed {
            return None;
        }
        let out = (st.wire, st.dense, st.msgs);
        st.wire = 0.0;
        st.dense = 0.0;
        st.msgs = 0;
        Some(out)
    }
}

impl Drop for OverlapSender {
    fn drop(&mut self) {
        // Closing the queue lets the thread drain remaining jobs and
        // exit; then join so the encoder state dies with the generation.
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Heartbeat if the interval elapsed since the last beacon.
fn beat(
    tx_driver: &dyn Link,
    stage: usize,
    iter: u32,
    hb: Option<Duration>,
    last_beat: &mut Instant,
) {
    if let Some(int) = hb {
        if last_beat.elapsed() >= int {
            let _ = tx_driver.send(Wire::Heartbeat { stage, iter });
            *last_beat = Instant::now();
        }
    }
}

/// Receive the next message from `rx`, heartbeating on every timeout
/// tick. When `fwd_ctl` is given (`rx` is NOT the forward lane), the
/// forward lane is polled for control messages (Stop / Checkpoint) on
/// each tick — they are returned as if they arrived on `rx`, and any
/// early data messages found on the way are stashed into `pending` for
/// the next forward receive. Returns None when `rx` disconnected.
#[allow(clippy::too_many_arguments)]
fn recv_lane(
    rx: &InLane,
    fwd_ctl: Option<&InLane>,
    pending: &mut VecDeque<LaneMsg>,
    tx_driver: &dyn Link,
    stage: usize,
    iter: u32,
    hb: Option<Duration>,
    last_beat: &mut Instant,
) -> anyhow::Result<Option<LaneMsg>> {
    let Some(int) = hb else {
        return Ok(rx.recv().ok());
    };
    loop {
        match rx.recv_deadline(int) {
            Ok(m) => return Ok(Some(m)),
            Err(RecvError::Closed) => return Ok(None),
            Err(RecvError::Timeout) => {
                let _ = tx_driver.send(Wire::Heartbeat { stage, iter });
                *last_beat = Instant::now();
                if let Some(f) = fwd_ctl {
                    loop {
                        match f.try_recv() {
                            Ok(m @ LaneMsg::Wire(Wire::Stop | Wire::Checkpoint { .. })) => {
                                return Ok(Some(m))
                            }
                            Ok(other) => pending.push_back(other),
                            Err(_) => break,
                        }
                    }
                }
            }
        }
    }
}

/// Answer a broadcast `Wire::Checkpoint` with this stage's state (empty
/// for backends without portable state) and keep running.
///
/// Incremental path: when the broker's acknowledged base (`base`) matches
/// the version of the locally retained shadow copy, only the lossless
/// delta against that shadow goes on the wire (`Wire::SnapshotDelta`,
/// the exact `checkpoint::encode_stage_delta` stage-layer encoding).
/// Any mismatch — first checkpoint, respawned worker with no shadow, or
/// a broker that re-based — falls back to a full `Wire::Snapshot`.
/// Either way the shadow advances to this version afterwards.
fn checkpoint_reply<B: StageBackend>(
    links: &StageLinks,
    backend: &B,
    iter: u32,
    base: Option<u32>,
    shadow: &mut Option<(u32, StageState)>,
) {
    let state = backend.snapshot().unwrap_or_default();
    let delta = match (base, shadow.as_ref()) {
        (Some(b), Some((shadow_iter, shadow_state))) if *shadow_iter == b => {
            Some(Wire::SnapshotDelta {
                stage: links.stage,
                base_iter: b,
                blob: crate::checkpoint::encode_stage_delta(
                    links.stage,
                    iter,
                    shadow_state,
                    &state,
                ),
            })
        }
        _ => None,
    };
    let msg = delta.unwrap_or_else(|| Wire::Snapshot { stage: links.stage, state: state.clone() });
    let _ = links.tx_driver.send(msg);
    *shadow = Some((iter, state));
}

/// A pipeline neighbor vanished mid-run (send failed or its channel
/// closed). Park: keep heartbeating, answer boundary Checkpoints, drop
/// stale data, and exit cleanly (snapshot + stats) on the driver's Stop.
/// Without heartbeats there is no way to poll, so fail hard as before.
#[allow(clippy::too_many_arguments)]
fn quiesce<B: StageBackend>(
    links: &StageLinks,
    fwd_lane: &InLane,
    backend: &B,
    stats: WorkerStats,
    hb: Option<Duration>,
    iter: u32,
    pending: &mut VecDeque<LaneMsg>,
    shadow: &mut Option<(u32, StageState)>,
) -> anyhow::Result<RunOutcome> {
    let Some(int) = hb else {
        anyhow::bail!("stage {}: pipeline neighbor vanished mid-run", links.stage)
    };
    loop {
        let msg = match pending.pop_front() {
            Some(m) => Some(m),
            None => match fwd_lane.recv_deadline(int) {
                Ok(m) => Some(m),
                Err(RecvError::Timeout) => None,
                Err(RecvError::Closed) => {
                    anyhow::bail!("stage {}: driver went away during quiesce", links.stage)
                }
            },
        };
        match msg {
            Some(LaneMsg::Wire(Wire::Stop)) => return stop(links, backend, stats),
            Some(LaneMsg::Wire(Wire::Checkpoint { iter: ckpt_iter, base })) => {
                checkpoint_reply(links, backend, ckpt_iter, base, shadow)
            }
            Some(_) => {} // data for the broken pipeline — drop
            None => {
                let _ = links
                    .tx_driver
                    .send(Wire::Heartbeat { stage: links.stage, iter });
            }
        }
    }
}

/// Execute `iters` iterations of this stage's schedule row starting at
/// global iteration `iter0`. Sends `Wire::IterProfile` after every Update
/// and `Wire::Stats` (plus `Wire::Snapshot` on Stop) before returning.
pub fn run_schedule<B: StageBackend>(
    links: &mut StageLinks,
    backend: &mut B,
    tasks: &[Task],
    iter0: u32,
    iters: usize,
) -> anyhow::Result<RunOutcome> {
    run_schedule_with(links, backend, tasks, iter0, iters, RunOpts::default())
}

/// `run_schedule` with fault-tolerance + overlap options. The
/// schedule/compute semantics are identical in every mode.
pub fn run_schedule_with<B: StageBackend>(
    links: &mut StageLinks,
    backend: &mut B,
    tasks: &[Task],
    iter0: u32,
    iters: usize,
    opts: RunOpts,
) -> anyhow::Result<RunOutcome> {
    let mut stats = WorkerStats {
        stage: links.stage,
        device: links.device,
        ..Default::default()
    };
    let act_n = backend.act_elems();
    // Dense-buffer pool: buffers cycle recv -> backend stash -> backward
    // free -> pool (crossing the prefetch/sender threads under overlap),
    // so the steady state allocates nothing on this side.
    let pool: BufPool = Arc::new(Mutex::new(Vec::new()));
    let overlap = opts.overlap;

    // Inbound lanes. Under overlap the packet lanes move into prefetch
    // threads (a `ClosedEndpoint` placeholder keeps `StageLinks`' shape);
    // the label lane stays direct — label decode is trivial and the
    // driver sends them eagerly anyway.
    let fwd_lane = {
        let rx = std::mem::replace(&mut links.rx_fwd, Box::new(ClosedEndpoint));
        if overlap {
            InLane::prefetch(
                rx,
                act_n,
                links.fwd_return.take(),
                Arc::clone(&pool),
                format!("prefetch-f{}", links.stage),
            )
        } else {
            InLane::Direct(rx)
        }
    };
    let bwd_lane = links.rx_bwd.take().map(|rx| {
        if overlap {
            InLane::prefetch(
                rx,
                act_n,
                links.bwd_return.take(),
                Arc::clone(&pool),
                format!("prefetch-b{}", links.stage),
            )
        } else {
            InLane::Direct(rx)
        }
    });
    let labels_lane = links.rx_labels.take().map(InLane::Direct);

    // Outbound: one encoder/sender thread per link. The `LinkEncoder`
    // moves into the thread wholesale, so all per-message compression
    // state stays on exactly one thread, in FIFO micro order.
    let fwd_sender = if overlap && links.tx_fwd.is_some() {
        links.codec.fwd.take().map(|enc| {
            OverlapSender::spawn(
                enc,
                links.tx_fwd.as_ref().unwrap().clone_link(),
                links.stage,
                links.stage + 1,
                OpDataKind::Activation,
                opts.link_delay_s,
                Arc::clone(&pool),
                format!("send-f{}", links.stage),
            )
        })
    } else {
        None
    };
    let bwd_sender = if overlap && links.tx_bwd.is_some() {
        links.codec.bwd.take().map(|enc| {
            OverlapSender::spawn(
                enc,
                links.tx_bwd.as_ref().unwrap().clone_link(),
                links.stage,
                links.stage - 1,
                OpDataKind::Gradient,
                opts.link_delay_s,
                Arc::clone(&pool),
                format!("send-b{}", links.stage),
            )
        })
    } else {
        None
    };

    let mut grad_buf = vec![0.0f32; act_n];
    let hb = opts.heartbeat;
    let mut last_beat = Instant::now();
    // First beacon up front: tells the broker "alive and initialized"
    // (backend construction happens before this function runs).
    if hb.is_some() {
        let _ = links.tx_driver.send(Wire::Heartbeat { stage: links.stage, iter: iter0 });
    }
    // Forward-lane messages popped early while scanning for control
    // messages during a blocked backward/label receive.
    let mut pending: VecDeque<LaneMsg> = VecDeque::new();
    // Shadow copy of the last checkpointed state: (version, state). While
    // the broker acknowledges this version as its base, checkpoint replies
    // ship only the delta against it. A fresh generation starts with no
    // shadow, so its first reply is always a full snapshot.
    let mut shadow: Option<(u32, StageState)> = None;

    for iter in iter0..iter0 + iters as u32 {
        if opts.kill_at_iter == Some(iter) {
            // Churn injector: vanish. No Stats, no Snapshot — the broker
            // must notice via the deadline monitor, like a real death.
            return Ok(RunOutcome::Killed);
        }
        // Per-iteration profile accumulators (reset every iteration).
        let (mut p_fwd, mut p_bwd, mut p_upd) = (0.0f64, 0.0f64, 0.0f64);
        let (mut p_bytes, mut p_msgs) = (0.0f64, 0u64);
        for t in tasks {
            debug_assert_eq!(t.stage, links.stage, "task from another stage's row");
            match t.kind {
                TaskKind::Forward => {
                    // Labels first on the head (the driver sends them
                    // eagerly, in ascending micro order).
                    let labels = match &labels_lane {
                        Some(rx) => {
                            let t_wait = Instant::now();
                            let msg = loop {
                                match recv_lane(
                                    rx,
                                    Some(&fwd_lane),
                                    &mut pending,
                                    links.tx_driver.as_ref(),
                                    links.stage,
                                    iter,
                                    hb,
                                    &mut last_beat,
                                )? {
                                    // The label sender is the driver.
                                    None => anyhow::bail!(
                                        "stage {}: driver went away mid-run",
                                        links.stage
                                    ),
                                    Some(LaneMsg::Wire(Wire::Checkpoint {
                                        iter: ckpt_iter,
                                        base,
                                    })) => {
                                        checkpoint_reply(links, backend, ckpt_iter, base, &mut shadow)
                                    }
                                    Some(m) => break m,
                                }
                            };
                            stats.wait_s += t_wait.elapsed().as_secs_f64();
                            match msg {
                                LaneMsg::Wire(Wire::Labels { micro, targets, .. }) => {
                                    anyhow::ensure!(
                                        micro as usize == t.micro,
                                        "stage {}: labels for micro {micro}, schedule expects {}",
                                        links.stage,
                                        t.micro
                                    );
                                    Some(targets)
                                }
                                LaneMsg::Wire(Wire::Stop) => return stop(links, backend, stats),
                                other => anyhow::bail!(
                                    "stage {}: unexpected {other:?} on label link",
                                    links.stage
                                ),
                            }
                        }
                        None => None,
                    };
                    let t_wait = Instant::now();
                    let input = loop {
                        let msg = match pending.pop_front() {
                            Some(m) => Some(m),
                            None => recv_lane(
                                &fwd_lane,
                                None,
                                &mut pending,
                                links.tx_driver.as_ref(),
                                links.stage,
                                iter,
                                hb,
                                &mut last_beat,
                            )?,
                        };
                        match msg {
                            // rx_fwd's senders include the driver; a close
                            // means the whole run is gone.
                            None => anyhow::bail!(
                                "stage {}: forward link closed (driver went away)",
                                links.stage
                            ),
                            Some(LaneMsg::Wire(Wire::Checkpoint { iter: ckpt_iter, base })) => {
                                checkpoint_reply(links, backend, ckpt_iter, base, &mut shadow)
                            }
                            Some(LaneMsg::Wire(Wire::Data { micro, tokens, .. })) => {
                                anyhow::ensure!(
                                    micro as usize == t.micro,
                                    "stage {}: data for micro {micro}, schedule expects {}",
                                    links.stage,
                                    t.micro
                                );
                                break FwdInput::Tokens(tokens);
                            }
                            // Blocking mode: packets decode inline here.
                            Some(LaneMsg::Wire(Wire::Packet(buf))) => {
                                let mut x = pool_take(&pool, act_n);
                                let hdr = decode_payload_into(&buf, &mut x)?;
                                // Drained packet buffer returns to the
                                // sender's free-list (zero-alloc sends).
                                if let Some(p) = &links.fwd_return {
                                    p.give(buf);
                                }
                                anyhow::ensure!(
                                    hdr.micro_batch as usize == t.micro,
                                    "stage {}: activation for micro {}, schedule expects {} \
                                     (cross-stage schedule orders disagree)",
                                    links.stage,
                                    hdr.micro_batch,
                                    t.micro
                                );
                                break FwdInput::Act(x);
                            }
                            // Overlap mode: the prefetcher already decoded.
                            Some(LaneMsg::Act { micro, data }) => {
                                anyhow::ensure!(
                                    micro as usize == t.micro,
                                    "stage {}: activation for micro {micro}, schedule expects {} \
                                     (cross-stage schedule orders disagree)",
                                    links.stage,
                                    t.micro
                                );
                                break FwdInput::Act(data);
                            }
                            Some(LaneMsg::Failed(e)) => anyhow::bail!(
                                "stage {}: forward packet decode failed: {e}",
                                links.stage
                            ),
                            Some(LaneMsg::Wire(Wire::Stop)) => {
                                stats.wait_s += t_wait.elapsed().as_secs_f64();
                                return stop(links, backend, stats);
                            }
                            Some(other) => anyhow::bail!(
                                "stage {}: unexpected {other:?} on forward link",
                                links.stage
                            ),
                        }
                    };
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    let t0 = Instant::now();
                    let out = backend.forward(iter, t.micro, input, labels)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.fwd_s += dt;
                    p_fwd += dt;
                    match out {
                        FwdOut::Act(y) => {
                            if let Some(snd) = &fwd_sender {
                                // Hand off to the encoder/sender thread;
                                // compression + send overlap the next task.
                                if !snd.send(iter, t.micro as u32, y) {
                                    // Downstream vanished: park for Stop.
                                    return quiesce(
                                        links, &fwd_lane, backend, stats, hb, iter, &mut pending, &mut shadow,
                                    );
                                }
                            } else if let (Some(tx), Some(enc)) =
                                (&links.tx_fwd, links.codec.fwd.as_mut())
                            {
                                let (buf, wire) = enc.encode(
                                    links.stage,
                                    links.stage + 1,
                                    OpDataKind::Activation,
                                    iter,
                                    t.micro as u32,
                                    &y,
                                );
                                if opts.link_delay_s > 0.0 {
                                    std::thread::sleep(Duration::from_secs_f64(
                                        opts.link_delay_s,
                                    ));
                                }
                                if tx.send(Wire::Packet(buf)).is_err() {
                                    // Downstream vanished: park for Stop.
                                    return quiesce(
                                        links, &fwd_lane, backend, stats, hb, iter, &mut pending, &mut shadow,
                                    );
                                }
                                stats.bytes_sent += wire;
                                stats.dense_bytes += 4.0 * y.len() as f64;
                                stats.msgs_sent += 1;
                                p_bytes += wire;
                                p_msgs += 1;
                                pool_give(&pool, y);
                            } else {
                                pool_give(&pool, y);
                            }
                        }
                        FwdOut::Loss { loss, free } => {
                            if let Some(b) = free {
                                pool_give(&pool, b);
                            }
                            links.tx_driver.send(Wire::Loss {
                                iter,
                                micro: t.micro as u32,
                                loss,
                            })?;
                        }
                    }
                }
                TaskKind::Backward => {
                    let mut grad_owned: Option<Vec<f32>> = None;
                    let grad: Option<&[f32]> = match &bwd_lane {
                        Some(rx) => {
                            let t_wait = Instant::now();
                            let msg = loop {
                                match recv_lane(
                                    rx,
                                    Some(&fwd_lane),
                                    &mut pending,
                                    links.tx_driver.as_ref(),
                                    links.stage,
                                    iter,
                                    hb,
                                    &mut last_beat,
                                )? {
                                    // rx_bwd's only sender is the next
                                    // stage — a close means it died.
                                    None => {
                                        stats.wait_s += t_wait.elapsed().as_secs_f64();
                                        return quiesce(
                                            links, &fwd_lane, backend, stats, hb, iter,
                                            &mut pending, &mut shadow,
                                        );
                                    }
                                    Some(LaneMsg::Wire(Wire::Checkpoint {
                                        iter: ckpt_iter,
                                        base,
                                    })) => {
                                        checkpoint_reply(links, backend, ckpt_iter, base, &mut shadow)
                                    }
                                    Some(m) => break m,
                                }
                            };
                            stats.wait_s += t_wait.elapsed().as_secs_f64();
                            match msg {
                                LaneMsg::Wire(Wire::Packet(buf)) => {
                                    let hdr = decode_payload_into(&buf, &mut grad_buf)?;
                                    if let Some(p) = &links.bwd_return {
                                        p.give(buf);
                                    }
                                    anyhow::ensure!(
                                        hdr.micro_batch as usize == t.micro,
                                        "stage {}: gradient for micro {}, schedule expects {} \
                                         (cross-stage schedule orders disagree)",
                                        links.stage,
                                        hdr.micro_batch,
                                        t.micro
                                    );
                                    Some(&grad_buf[..])
                                }
                                LaneMsg::Act { micro, data } => {
                                    anyhow::ensure!(
                                        micro as usize == t.micro,
                                        "stage {}: gradient for micro {micro}, schedule expects {} \
                                         (cross-stage schedule orders disagree)",
                                        links.stage,
                                        t.micro
                                    );
                                    grad_owned = Some(data);
                                    grad_owned.as_deref()
                                }
                                LaneMsg::Failed(e) => anyhow::bail!(
                                    "stage {}: gradient packet decode failed: {e}",
                                    links.stage
                                ),
                                LaneMsg::Wire(Wire::Stop) => {
                                    return stop(links, backend, stats)
                                }
                                other => anyhow::bail!(
                                    "stage {}: unexpected {other:?} on backward link",
                                    links.stage
                                ),
                            }
                        }
                        None => None,
                    };
                    let t0 = Instant::now();
                    let out = backend.backward(iter, t.micro, grad)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.bwd_s += dt;
                    p_bwd += dt;
                    if let Some(b) = grad_owned.take() {
                        pool_give(&pool, b);
                    }
                    if let Some(dx) = out.dx {
                        if let Some(snd) = &bwd_sender {
                            if !snd.send(iter, t.micro as u32, dx) {
                                // Upstream vanished: park for Stop.
                                return quiesce(
                                    links, &fwd_lane, backend, stats, hb, iter, &mut pending, &mut shadow,
                                );
                            }
                        } else if let (Some(tx), Some(enc)) =
                            (&links.tx_bwd, links.codec.bwd.as_mut())
                        {
                            let (buf, wire) = enc.encode(
                                links.stage,
                                links.stage - 1,
                                OpDataKind::Gradient,
                                iter,
                                t.micro as u32,
                                &dx,
                            );
                            if opts.link_delay_s > 0.0 {
                                std::thread::sleep(Duration::from_secs_f64(opts.link_delay_s));
                            }
                            if tx.send(Wire::Packet(buf)).is_err() {
                                // Upstream vanished: park for Stop.
                                return quiesce(
                                    links, &fwd_lane, backend, stats, hb, iter, &mut pending, &mut shadow,
                                );
                            }
                            stats.bytes_sent += wire;
                            stats.dense_bytes += 4.0 * dx.len() as f64;
                            stats.msgs_sent += 1;
                            p_bytes += wire;
                            p_msgs += 1;
                            pool_give(&pool, dx);
                        } else {
                            pool_give(&pool, dx);
                        }
                    }
                    if let Some(b) = out.free {
                        pool_give(&pool, b);
                    }
                }
                TaskKind::Update => {
                    let t0 = Instant::now();
                    backend.update(iter)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.update_s += dt;
                    p_upd += dt;
                    // Drain the overlapped senders: every packet this
                    // iteration emitted is on the wire *and accounted*
                    // before the profile goes out, so the per-iteration
                    // byte/msg numbers the broker relays are identical to
                    // the blocking mode (the wire counts are integers, so
                    // the f64 sums are exact in any order).
                    let t_flush = Instant::now();
                    for snd in [fwd_sender.as_ref(), bwd_sender.as_ref()].into_iter().flatten()
                    {
                        match snd.flush() {
                            Some((wire, dense, msgs)) => {
                                stats.bytes_sent += wire;
                                stats.dense_bytes += dense;
                                stats.msgs_sent += msgs;
                                p_bytes += wire;
                                p_msgs += msgs;
                            }
                            None => {
                                // A sender thread hit a dead neighbor.
                                return quiesce(
                                    links, &fwd_lane, backend, stats, hb, iter, &mut pending, &mut shadow,
                                );
                            }
                        }
                    }
                    stats.wait_s += t_flush.elapsed().as_secs_f64();
                    links.tx_driver.send(Wire::IterProfile {
                        stage: links.stage,
                        iter,
                        fwd_s: p_fwd,
                        bwd_s: p_bwd,
                        update_s: p_upd,
                        bytes: p_bytes,
                        msgs: p_msgs,
                    })?;
                }
            }
            // Long compute sequences must not starve the liveness plane.
            beat(links.tx_driver.as_ref(), links.stage, iter, hb, &mut last_beat);
        }
    }
    let _ = links.tx_driver.send(Wire::Stats(stats));
    Ok(RunOutcome::Completed)
}

/// Controlled mid-run teardown: emit the migration snapshot (if the
/// backend has one) and the accumulated stats, then exit cleanly.
fn stop<B: StageBackend>(
    links: &StageLinks,
    backend: &B,
    stats: WorkerStats,
) -> anyhow::Result<RunOutcome> {
    if let Some(state) = backend.snapshot() {
        let _ = links.tx_driver.send(Wire::Snapshot { stage: links.stage, state });
    }
    let _ = links.tx_driver.send(Wire::Stats(stats));
    Ok(RunOutcome::Stopped)
}

/// Trivial arithmetic backend for interpreter tests and the dispatch
/// bench: embed maps tokens to f32, body adds 1, the head's loss is the
/// activation sum. Per-micro parameter "gradients" follow the same
/// fixed-accumulation-order contract as the PJRT backend (a single
/// scalar parameter), so GPipe/1F1B equality is checkable without
/// artifacts. Records every executed task for agreement checks.
pub struct NullBackend {
    pub n: usize,
    pub n_micro: usize,
    pub is_head: bool,
    /// Scalar "parameter": updated each iteration from the mean of the
    /// per-micro dp stashes (ascending micro order).
    pub param: f32,
    stash: Vec<Option<Vec<f32>>>,
    dp: Vec<Option<f32>>,
    /// Executed (kind, micro) log, in execution order.
    pub log: Vec<(TaskKind, usize)>,
    pub updates: u32,
    /// When set, `snapshot` exports the scalar parameter as a one-element
    /// `StageState` — the churn/checkpoint tests run killed-and-recovered
    /// pipelines without artifacts and still restore exact state.
    pub stateful: bool,
    /// Artificial seconds slept per forward (`--pace`): gives otherwise
    /// instant Null runs a real duration so multi-process demos and the
    /// CI `kill -9` smoke can hit a *running* job. Never affects math.
    pub pace_s: f64,
    /// Auxiliary deterministic weight block (see `seed_bulk`): snapshots
    /// export it after the scalar param and each optimizer step perturbs
    /// exactly one slot, so checkpoints have a realistic size with a tiny
    /// steady-state delta. Never read by forward/backward/loss math.
    bulk: Vec<f32>,
}

impl NullBackend {
    pub fn new(n: usize, n_micro: usize, is_head: bool) -> NullBackend {
        NullBackend {
            n,
            n_micro,
            is_head,
            param: 0.0,
            stash: (0..n_micro).map(|_| None).collect(),
            dp: vec![None; n_micro],
            log: Vec::new(),
            updates: 0,
            stateful: false,
            pace_s: 0.0,
            bulk: Vec::new(),
        }
    }

    /// A `NullBackend` whose scalar parameter snapshots and restores (the
    /// sim-churn training backend).
    pub fn stateful(n: usize, n_micro: usize, is_head: bool) -> NullBackend {
        NullBackend { stateful: true, ..NullBackend::new(n, n_micro, is_head) }
    }

    /// Attach a deterministic auxiliary weight block of `n` slots, seeded
    /// from `seed` with a fixed LCG. `update` then perturbs exactly one
    /// slot per optimizer step, so consecutive snapshots differ in only a
    /// handful of the `1 + n` exported values — the workload the
    /// incremental-checkpoint gates measure. The block never feeds the
    /// forward/backward math, so loss trajectories are unchanged.
    pub fn seed_bulk(&mut self, seed: u64, n: usize) {
        let mut s = seed | 1;
        self.bulk = (0..n)
            .map(|_| {
                s = s
                    .wrapping_mul(6364136223846793005)
                    .wrapping_add(1442695040888963407);
                ((s >> 40) as u32 as f32) / (1u64 << 24) as f32
            })
            .collect();
    }

    /// Restore a `snapshot` taken from another stateful instance.
    pub fn restore(&mut self, state: &StageState) {
        if let Some(&p) = state.params.first() {
            self.param = p;
        }
        if !self.bulk.is_empty() && state.params.len() == 1 + self.bulk.len() {
            self.bulk.copy_from_slice(&state.params[1..]);
        }
    }
}

impl StageBackend for NullBackend {
    fn act_elems(&self) -> usize {
        self.n
    }

    fn forward(
        &mut self,
        _iter: u32,
        micro: usize,
        input: FwdInput,
        _labels: Option<Vec<i32>>,
    ) -> anyhow::Result<FwdOut> {
        self.log.push((TaskKind::Forward, micro));
        if self.pace_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.pace_s));
        }
        let x: Vec<f32> = match input {
            FwdInput::Tokens(t) => t.iter().map(|&v| v as f32 + self.param).collect(),
            FwdInput::Act(x) => x,
        };
        if self.is_head {
            let loss: f32 = x.iter().sum::<f32>() / x.len().max(1) as f32;
            self.dp[micro] = Some(loss);
            self.stash[micro] = Some(x);
            Ok(FwdOut::Loss { loss, free: None })
        } else {
            let y: Vec<f32> = x.iter().map(|v| v + 1.0 + self.param).collect();
            self.stash[micro] = Some(x);
            Ok(FwdOut::Act(y))
        }
    }

    fn backward(
        &mut self,
        _iter: u32,
        micro: usize,
        grad: Option<&[f32]>,
    ) -> anyhow::Result<BwdOut> {
        self.log.push((TaskKind::Backward, micro));
        let stashed = self.stash[micro]
            .take()
            .ok_or_else(|| anyhow::anyhow!("backward micro {micro} before its forward"))?;
        if self.is_head {
            // Replay the stored activation as dx (PipeDream-flush replay).
            Ok(BwdOut { dx: Some(stashed), free: None })
        } else {
            let g = grad.ok_or_else(|| anyhow::anyhow!("non-head backward without grad"))?;
            self.dp[micro] = Some(g.iter().sum::<f32>() / g.len().max(1) as f32);
            let dx: Vec<f32> = g.iter().map(|v| v * 0.5).collect();
            Ok(BwdOut { dx: Some(dx), free: Some(stashed) })
        }
    }

    fn update(&mut self, _iter: u32) -> anyhow::Result<()> {
        self.log.push((TaskKind::Update, 0));
        // Fixed accumulation order: ascending micro, like the PJRT backend.
        let mut acc = 0.0f32;
        for m in 0..self.n_micro {
            acc += self.dp[m]
                .take()
                .ok_or_else(|| anyhow::anyhow!("update before backward of micro {m}"))?;
        }
        self.param -= 0.01 * acc / self.n_micro as f32;
        if !self.bulk.is_empty() {
            // One touched slot per step keeps consecutive snapshots
            // almost identical — the steady state delta checkpoints
            // compress. Bulk is write-only for the math, so this cannot
            // perturb the loss trajectory.
            let slot = self.updates as usize % self.bulk.len();
            self.bulk[slot] += 0.001;
        }
        self.updates += 1;
        Ok(())
    }

    fn snapshot(&self) -> Option<StageState> {
        if !self.stateful {
            return None;
        }
        let mut params = Vec::with_capacity(1 + self.bulk.len());
        params.push(self.param);
        params.extend_from_slice(&self.bulk);
        Some(StageState {
            params,
            momentum: Vec::new(),
            second: Vec::new(),
        })
    }
}
