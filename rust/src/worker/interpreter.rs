//! The schedule interpreter: one loop that executes *any*
//! `PipelineSchedule` task list (GPipe, 1F1B, or generated variants)
//! against a pluggable `StageBackend`.
//!
//! The interpreter owns everything protocol-shaped — channel receives and
//! sends, wire decode/encode through the per-link codecs, Stop/teardown
//! handling, per-message statistics and the per-iteration `IterProfile`
//! feedback — while the backend owns the math (PJRT execution in
//! production, trivial arithmetic in tests and benches). This is what
//! makes `ScheduleKind::OneFOneB` a real execution mode rather than a
//! sim-only fiction, and what lets the schedule-legality property tests
//! drive the *production* task loop without artifacts.
//!
//! Determinism contract: gradient accumulation order is fixed per micro
//! (backends stash per-micro parameter gradients and sum them in
//! ascending micro order at Update), so GPipe and 1F1B produce bitwise
//! identical loss trajectories.

use super::messages::{decode_payload_into, StageCodec, StageState, Wire, WorkerStats};
use crate::opdag::data::OpDataKind;
use crate::pipeline::{Task, TaskKind};
use crate::transport::{Endpoint, Link, PacketPool, RecvError};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Transport + codec endpoints for one stage: everything the interpreter
/// needs to talk to its pipeline neighbors and the driver. The lanes are
/// trait objects, so the same loop runs over in-process channels
/// (`ChanTransport`) and sockets (`TcpTransport`) unchanged.
pub struct StageLinks {
    pub stage: usize,
    /// CompNode id hosting this stage (for stats attribution).
    pub device: usize,
    /// Per-link wire codecs (compression scratch + staging buffers).
    pub codec: StageCodec,
    /// Forward input (Data from the driver for stage 0, Packets otherwise).
    pub rx_fwd: Box<dyn Endpoint>,
    /// Backward gradient input (None for the head stage).
    pub rx_bwd: Option<Box<dyn Endpoint>>,
    /// Forward output (None for the head stage).
    pub tx_fwd: Option<Box<dyn Link>>,
    /// Backward gradient output (None for the embed stage).
    pub tx_bwd: Option<Box<dyn Link>>,
    /// Head only: label stream from the driver.
    pub rx_labels: Option<Box<dyn Endpoint>>,
    /// Loss + profile + stats reporting to the driver.
    pub tx_driver: Box<dyn Link>,
    /// Free-list of the *previous* stage's fwd `LinkEncoder`: drained
    /// activation packet buffers go back to their sender (None when the
    /// upstream is the driver or out-of-process).
    pub fwd_return: Option<PacketPool>,
    /// Free-list of the *next* stage's bwd `LinkEncoder` (gradients).
    pub bwd_return: Option<PacketPool>,
}

/// Forward input handed to the backend. Stage 0 receives raw tokens from
/// the driver; every other stage receives a decoded dense activation
/// (ownership transfers so the backend can stash it for its backward).
pub enum FwdInput {
    Tokens(Vec<i32>),
    Act(Vec<f32>),
}

/// Forward result. `Act` is sent downstream (the buffer is recycled into
/// the decode pool afterwards); `Loss` goes to the driver. `free` returns
/// a consumed input buffer to the interpreter's decode pool.
pub enum FwdOut {
    Act(Vec<f32>),
    Loss { loss: f32, free: Option<Vec<f32>> },
}

/// Backward result: `dx` travels upstream (if a backward link exists),
/// `free` returns a stashed buffer to the decode pool.
pub struct BwdOut {
    pub dx: Option<Vec<f32>>,
    pub free: Option<Vec<f32>>,
}

/// The compute side of a stage. Implementations own parameters, optimizer
/// state and per-micro stashes; the contract that keeps GPipe and 1F1B
/// bitwise identical is that `update` accumulates the stashed per-micro
/// parameter gradients in ascending micro order regardless of the order
/// the schedule executed them in.
pub trait StageBackend {
    /// Dense element count of one inter-stage activation (decode buffer
    /// size for packets and gradients).
    fn act_elems(&self) -> usize;
    fn forward(
        &mut self,
        iter: u32,
        micro: usize,
        input: FwdInput,
        labels: Option<Vec<i32>>,
    ) -> anyhow::Result<FwdOut>;
    /// `grad` is None only on the head stage (it replays its stored dx).
    fn backward(&mut self, iter: u32, micro: usize, grad: Option<&[f32]>)
        -> anyhow::Result<BwdOut>;
    /// Optimizer step closing the iteration.
    fn update(&mut self, iter: u32) -> anyhow::Result<()>;
    /// Live-migration snapshot, requested on a mid-run Stop. Backends
    /// without portable state (mocks) return None.
    fn snapshot(&self) -> Option<StageState> {
        None
    }
}

/// How a schedule run ended: all iterations done, a driver Stop, or the
/// churn fault injector firing (the worker vanishes without a trace).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RunOutcome {
    Completed,
    Stopped,
    Killed,
}

/// Fault-tolerance knobs for a schedule run. `Default` reproduces the
/// PR 3 behavior exactly: blocking receives, no beacons, no injector.
#[derive(Debug, Clone, Copy, Default)]
pub struct RunOpts {
    /// Send `Wire::Heartbeat` at most once per this interval — while
    /// blocked on a channel and between tasks — so the broker's deadline
    /// monitor can tell a slow stage from a dead one. None = blocking
    /// receives (a vanished neighbor then surfaces as an error, not a
    /// quiesce, exactly as before).
    pub heartbeat: Option<Duration>,
    /// Churn injector: exit silently (no Stats, no Snapshot) at the top
    /// of this global iteration, simulating a device that disappears.
    pub kill_at_iter: Option<u32>,
}

/// Heartbeat if the interval elapsed since the last beacon.
fn beat(
    tx_driver: &dyn Link,
    stage: usize,
    iter: u32,
    hb: Option<Duration>,
    last_beat: &mut Instant,
) {
    if let Some(int) = hb {
        if last_beat.elapsed() >= int {
            let _ = tx_driver.send(Wire::Heartbeat { stage, iter });
            *last_beat = Instant::now();
        }
    }
}

/// Receive the next message from `rx`, heartbeating on every timeout
/// tick. When `fwd_ctl` is given (`rx` is NOT the forward link), the
/// forward link is polled for control messages (Stop / Checkpoint) on
/// each tick — they are returned as if they arrived on `rx`, and any
/// early data messages found on the way are stashed into `pending` for
/// the next forward receive. Returns None when `rx` disconnected.
#[allow(clippy::too_many_arguments)]
fn recv_msg(
    rx: &dyn Endpoint,
    fwd_ctl: Option<&dyn Endpoint>,
    pending: &mut VecDeque<Wire>,
    tx_driver: &dyn Link,
    stage: usize,
    iter: u32,
    hb: Option<Duration>,
    last_beat: &mut Instant,
) -> anyhow::Result<Option<Wire>> {
    let Some(int) = hb else {
        return Ok(rx.recv().ok());
    };
    loop {
        match rx.recv_deadline(int) {
            Ok(m) => return Ok(Some(m)),
            Err(RecvError::Closed) => return Ok(None),
            Err(RecvError::Timeout) => {
                let _ = tx_driver.send(Wire::Heartbeat { stage, iter });
                *last_beat = Instant::now();
                if let Some(f) = fwd_ctl {
                    loop {
                        match f.try_recv() {
                            Ok(m @ (Wire::Stop | Wire::Checkpoint { .. })) => {
                                return Ok(Some(m))
                            }
                            Ok(other) => pending.push_back(other),
                            Err(_) => break,
                        }
                    }
                }
            }
        }
    }
}

/// Answer a broadcast `Wire::Checkpoint` with this stage's state (empty
/// for backends without portable state) and keep running.
fn checkpoint_reply<B: StageBackend>(links: &StageLinks, backend: &B) {
    let state = backend.snapshot().unwrap_or_default();
    let _ = links
        .tx_driver
        .send(Wire::Snapshot { stage: links.stage, state });
}

/// A pipeline neighbor vanished mid-run (send failed or its channel
/// closed). Park: keep heartbeating, answer boundary Checkpoints, drop
/// stale data, and exit cleanly (snapshot + stats) on the driver's Stop.
/// Without heartbeats there is no way to poll, so fail hard as before.
fn quiesce<B: StageBackend>(
    links: &StageLinks,
    backend: &B,
    stats: WorkerStats,
    hb: Option<Duration>,
    iter: u32,
    pending: &mut VecDeque<Wire>,
) -> anyhow::Result<RunOutcome> {
    let Some(int) = hb else {
        anyhow::bail!("stage {}: pipeline neighbor vanished mid-run", links.stage)
    };
    loop {
        let msg = match pending.pop_front() {
            Some(m) => Some(m),
            None => match links.rx_fwd.recv_deadline(int) {
                Ok(m) => Some(m),
                Err(RecvError::Timeout) => None,
                Err(RecvError::Closed) => {
                    anyhow::bail!("stage {}: driver went away during quiesce", links.stage)
                }
            },
        };
        match msg {
            Some(Wire::Stop) => return stop(links, backend, stats),
            Some(Wire::Checkpoint { .. }) => checkpoint_reply(links, backend),
            Some(_) => {} // data for the broken pipeline — drop
            None => {
                let _ = links
                    .tx_driver
                    .send(Wire::Heartbeat { stage: links.stage, iter });
            }
        }
    }
}

/// Execute `iters` iterations of this stage's schedule row starting at
/// global iteration `iter0`. Sends `Wire::IterProfile` after every Update
/// and `Wire::Stats` (plus `Wire::Snapshot` on Stop) before returning.
pub fn run_schedule<B: StageBackend>(
    links: &mut StageLinks,
    backend: &mut B,
    tasks: &[Task],
    iter0: u32,
    iters: usize,
) -> anyhow::Result<RunOutcome> {
    run_schedule_with(links, backend, tasks, iter0, iters, RunOpts::default())
}

/// `run_schedule` with fault-tolerance options (heartbeats + the churn
/// fault injector). The schedule/compute semantics are identical.
pub fn run_schedule_with<B: StageBackend>(
    links: &mut StageLinks,
    backend: &mut B,
    tasks: &[Task],
    iter0: u32,
    iters: usize,
    opts: RunOpts,
) -> anyhow::Result<RunOutcome> {
    let mut stats = WorkerStats {
        stage: links.stage,
        device: links.device,
        ..Default::default()
    };
    let act_n = backend.act_elems();
    // Decode-buffer pool: buffers cycle recv -> backend stash -> backward
    // free -> pool, so the steady state allocates nothing on this side.
    let mut recycle: Vec<Vec<f32>> = Vec::new();
    let mut grad_buf = vec![0.0f32; act_n];
    let hb = opts.heartbeat;
    let mut last_beat = Instant::now();
    // First beacon up front: tells the broker "alive and initialized"
    // (backend construction happens before this function runs).
    if hb.is_some() {
        let _ = links.tx_driver.send(Wire::Heartbeat { stage: links.stage, iter: iter0 });
    }
    // Forward-link messages popped early while scanning for control
    // messages during a blocked backward/label receive.
    let mut pending: VecDeque<Wire> = VecDeque::new();

    for iter in iter0..iter0 + iters as u32 {
        if opts.kill_at_iter == Some(iter) {
            // Churn injector: vanish. No Stats, no Snapshot — the broker
            // must notice via the deadline monitor, like a real death.
            return Ok(RunOutcome::Killed);
        }
        // Per-iteration profile accumulators (reset every iteration).
        let (mut p_fwd, mut p_bwd, mut p_upd) = (0.0f64, 0.0f64, 0.0f64);
        let (mut p_bytes, mut p_msgs) = (0.0f64, 0u64);
        for t in tasks {
            debug_assert_eq!(t.stage, links.stage, "task from another stage's row");
            match t.kind {
                TaskKind::Forward => {
                    // Labels first on the head (the driver sends them
                    // eagerly, in ascending micro order).
                    let labels = match &links.rx_labels {
                        Some(rx) => {
                            let t_wait = Instant::now();
                            let msg = loop {
                                match recv_msg(
                                    rx.as_ref(),
                                    Some(links.rx_fwd.as_ref()),
                                    &mut pending,
                                    links.tx_driver.as_ref(),
                                    links.stage,
                                    iter,
                                    hb,
                                    &mut last_beat,
                                )? {
                                    // The label sender is the driver.
                                    None => anyhow::bail!(
                                        "stage {}: driver went away mid-run",
                                        links.stage
                                    ),
                                    Some(Wire::Checkpoint { .. }) => {
                                        checkpoint_reply(links, backend)
                                    }
                                    Some(m) => break m,
                                }
                            };
                            stats.wait_s += t_wait.elapsed().as_secs_f64();
                            match msg {
                                Wire::Labels { micro, targets, .. } => {
                                    anyhow::ensure!(
                                        micro as usize == t.micro,
                                        "stage {}: labels for micro {micro}, schedule expects {}",
                                        links.stage,
                                        t.micro
                                    );
                                    Some(targets)
                                }
                                Wire::Stop => return stop(links, backend, stats),
                                other => anyhow::bail!(
                                    "stage {}: unexpected {other:?} on label link",
                                    links.stage
                                ),
                            }
                        }
                        None => None,
                    };
                    let t_wait = Instant::now();
                    let input = loop {
                        let msg = match pending.pop_front() {
                            Some(m) => Some(m),
                            None => recv_msg(
                                links.rx_fwd.as_ref(),
                                None,
                                &mut pending,
                                links.tx_driver.as_ref(),
                                links.stage,
                                iter,
                                hb,
                                &mut last_beat,
                            )?,
                        };
                        match msg {
                            // rx_fwd's senders include the driver; a close
                            // means the whole run is gone.
                            None => anyhow::bail!(
                                "stage {}: forward link closed (driver went away)",
                                links.stage
                            ),
                            Some(Wire::Checkpoint { .. }) => checkpoint_reply(links, backend),
                            Some(Wire::Data { micro, tokens, .. }) => {
                                anyhow::ensure!(
                                    micro as usize == t.micro,
                                    "stage {}: data for micro {micro}, schedule expects {}",
                                    links.stage,
                                    t.micro
                                );
                                break FwdInput::Tokens(tokens);
                            }
                            Some(Wire::Packet(buf)) => {
                                let mut x = recycle.pop().unwrap_or_default();
                                x.resize(act_n, 0.0);
                                let hdr = decode_payload_into(&buf, &mut x)?;
                                // Drained packet buffer returns to the
                                // sender's free-list (zero-alloc sends).
                                if let Some(p) = &links.fwd_return {
                                    p.give(buf);
                                }
                                anyhow::ensure!(
                                    hdr.micro_batch as usize == t.micro,
                                    "stage {}: activation for micro {}, schedule expects {} \
                                     (cross-stage schedule orders disagree)",
                                    links.stage,
                                    hdr.micro_batch,
                                    t.micro
                                );
                                break FwdInput::Act(x);
                            }
                            Some(Wire::Stop) => {
                                stats.wait_s += t_wait.elapsed().as_secs_f64();
                                return stop(links, backend, stats);
                            }
                            Some(other) => anyhow::bail!(
                                "stage {}: unexpected {other:?} on forward link",
                                links.stage
                            ),
                        }
                    };
                    stats.wait_s += t_wait.elapsed().as_secs_f64();
                    let t0 = Instant::now();
                    let out = backend.forward(iter, t.micro, input, labels)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.fwd_s += dt;
                    p_fwd += dt;
                    match out {
                        FwdOut::Act(y) => {
                            if let (Some(tx), Some(enc)) =
                                (&links.tx_fwd, links.codec.fwd.as_mut())
                            {
                                let (buf, wire) = enc.encode(
                                    links.stage,
                                    links.stage + 1,
                                    OpDataKind::Activation,
                                    iter,
                                    t.micro as u32,
                                    &y,
                                );
                                if tx.send(Wire::Packet(buf)).is_err() {
                                    // Downstream vanished: park for Stop.
                                    return quiesce(
                                        links, backend, stats, hb, iter, &mut pending,
                                    );
                                }
                                stats.bytes_sent += wire;
                                stats.dense_bytes += 4.0 * y.len() as f64;
                                stats.msgs_sent += 1;
                                p_bytes += wire;
                                p_msgs += 1;
                            }
                            recycle.push(y);
                        }
                        FwdOut::Loss { loss, free } => {
                            if let Some(b) = free {
                                recycle.push(b);
                            }
                            links.tx_driver.send(Wire::Loss {
                                iter,
                                micro: t.micro as u32,
                                loss,
                            })?;
                        }
                    }
                }
                TaskKind::Backward => {
                    let grad: Option<&[f32]> = match &links.rx_bwd {
                        Some(rx) => {
                            let t_wait = Instant::now();
                            let msg = loop {
                                match recv_msg(
                                    rx.as_ref(),
                                    Some(links.rx_fwd.as_ref()),
                                    &mut pending,
                                    links.tx_driver.as_ref(),
                                    links.stage,
                                    iter,
                                    hb,
                                    &mut last_beat,
                                )? {
                                    // rx_bwd's only sender is the next
                                    // stage — a close means it died.
                                    None => {
                                        stats.wait_s += t_wait.elapsed().as_secs_f64();
                                        return quiesce(
                                            links, backend, stats, hb, iter, &mut pending,
                                        );
                                    }
                                    Some(Wire::Checkpoint { .. }) => {
                                        checkpoint_reply(links, backend)
                                    }
                                    Some(m) => break m,
                                }
                            };
                            stats.wait_s += t_wait.elapsed().as_secs_f64();
                            match msg {
                                Wire::Packet(buf) => {
                                    let hdr = decode_payload_into(&buf, &mut grad_buf)?;
                                    if let Some(p) = &links.bwd_return {
                                        p.give(buf);
                                    }
                                    anyhow::ensure!(
                                        hdr.micro_batch as usize == t.micro,
                                        "stage {}: gradient for micro {}, schedule expects {} \
                                         (cross-stage schedule orders disagree)",
                                        links.stage,
                                        hdr.micro_batch,
                                        t.micro
                                    );
                                    Some(&grad_buf[..])
                                }
                                Wire::Stop => return stop(links, backend, stats),
                                other => anyhow::bail!(
                                    "stage {}: unexpected {other:?} on backward link",
                                    links.stage
                                ),
                            }
                        }
                        None => None,
                    };
                    let t0 = Instant::now();
                    let out = backend.backward(iter, t.micro, grad)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.bwd_s += dt;
                    p_bwd += dt;
                    if let Some(dx) = out.dx {
                        if let (Some(tx), Some(enc)) = (&links.tx_bwd, links.codec.bwd.as_mut())
                        {
                            let (buf, wire) = enc.encode(
                                links.stage,
                                links.stage - 1,
                                OpDataKind::Gradient,
                                iter,
                                t.micro as u32,
                                &dx,
                            );
                            if tx.send(Wire::Packet(buf)).is_err() {
                                // Upstream vanished: park for Stop.
                                return quiesce(links, backend, stats, hb, iter, &mut pending);
                            }
                            stats.bytes_sent += wire;
                            stats.dense_bytes += 4.0 * dx.len() as f64;
                            stats.msgs_sent += 1;
                            p_bytes += wire;
                            p_msgs += 1;
                        }
                        recycle.push(dx);
                    }
                    if let Some(b) = out.free {
                        recycle.push(b);
                    }
                }
                TaskKind::Update => {
                    let t0 = Instant::now();
                    backend.update(iter)?;
                    let dt = t0.elapsed().as_secs_f64();
                    stats.update_s += dt;
                    p_upd += dt;
                    links.tx_driver.send(Wire::IterProfile {
                        stage: links.stage,
                        iter,
                        fwd_s: p_fwd,
                        bwd_s: p_bwd,
                        update_s: p_upd,
                        bytes: p_bytes,
                        msgs: p_msgs,
                    })?;
                }
            }
            // Long compute sequences must not starve the liveness plane.
            beat(links.tx_driver.as_ref(), links.stage, iter, hb, &mut last_beat);
        }
    }
    let _ = links.tx_driver.send(Wire::Stats(stats));
    Ok(RunOutcome::Completed)
}

/// Controlled mid-run teardown: emit the migration snapshot (if the
/// backend has one) and the accumulated stats, then exit cleanly.
fn stop<B: StageBackend>(
    links: &StageLinks,
    backend: &B,
    stats: WorkerStats,
) -> anyhow::Result<RunOutcome> {
    if let Some(state) = backend.snapshot() {
        let _ = links.tx_driver.send(Wire::Snapshot { stage: links.stage, state });
    }
    let _ = links.tx_driver.send(Wire::Stats(stats));
    Ok(RunOutcome::Stopped)
}

/// Trivial arithmetic backend for interpreter tests and the dispatch
/// bench: embed maps tokens to f32, body adds 1, the head's loss is the
/// activation sum. Per-micro parameter "gradients" follow the same
/// fixed-accumulation-order contract as the PJRT backend (a single
/// scalar parameter), so GPipe/1F1B equality is checkable without
/// artifacts. Records every executed task for agreement checks.
pub struct NullBackend {
    pub n: usize,
    pub n_micro: usize,
    pub is_head: bool,
    /// Scalar "parameter": updated each iteration from the mean of the
    /// per-micro dp stashes (ascending micro order).
    pub param: f32,
    stash: Vec<Option<Vec<f32>>>,
    dp: Vec<Option<f32>>,
    /// Executed (kind, micro) log, in execution order.
    pub log: Vec<(TaskKind, usize)>,
    pub updates: u32,
    /// When set, `snapshot` exports the scalar parameter as a one-element
    /// `StageState` — the churn/checkpoint tests run killed-and-recovered
    /// pipelines without artifacts and still restore exact state.
    pub stateful: bool,
    /// Artificial seconds slept per forward (`--pace`): gives otherwise
    /// instant Null runs a real duration so multi-process demos and the
    /// CI `kill -9` smoke can hit a *running* job. Never affects math.
    pub pace_s: f64,
}

impl NullBackend {
    pub fn new(n: usize, n_micro: usize, is_head: bool) -> NullBackend {
        NullBackend {
            n,
            n_micro,
            is_head,
            param: 0.0,
            stash: (0..n_micro).map(|_| None).collect(),
            dp: vec![None; n_micro],
            log: Vec::new(),
            updates: 0,
            stateful: false,
            pace_s: 0.0,
        }
    }

    /// A `NullBackend` whose scalar parameter snapshots and restores (the
    /// sim-churn training backend).
    pub fn stateful(n: usize, n_micro: usize, is_head: bool) -> NullBackend {
        NullBackend { stateful: true, ..NullBackend::new(n, n_micro, is_head) }
    }

    /// Restore a `snapshot` taken from another stateful instance.
    pub fn restore(&mut self, state: &StageState) {
        if let Some(&p) = state.params.first() {
            self.param = p;
        }
    }
}

impl StageBackend for NullBackend {
    fn act_elems(&self) -> usize {
        self.n
    }

    fn forward(
        &mut self,
        _iter: u32,
        micro: usize,
        input: FwdInput,
        _labels: Option<Vec<i32>>,
    ) -> anyhow::Result<FwdOut> {
        self.log.push((TaskKind::Forward, micro));
        if self.pace_s > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.pace_s));
        }
        let x: Vec<f32> = match input {
            FwdInput::Tokens(t) => t.iter().map(|&v| v as f32 + self.param).collect(),
            FwdInput::Act(x) => x,
        };
        if self.is_head {
            let loss: f32 = x.iter().sum::<f32>() / x.len().max(1) as f32;
            self.dp[micro] = Some(loss);
            self.stash[micro] = Some(x);
            Ok(FwdOut::Loss { loss, free: None })
        } else {
            let y: Vec<f32> = x.iter().map(|v| v + 1.0 + self.param).collect();
            self.stash[micro] = Some(x);
            Ok(FwdOut::Act(y))
        }
    }

    fn backward(
        &mut self,
        _iter: u32,
        micro: usize,
        grad: Option<&[f32]>,
    ) -> anyhow::Result<BwdOut> {
        self.log.push((TaskKind::Backward, micro));
        let stashed = self.stash[micro]
            .take()
            .ok_or_else(|| anyhow::anyhow!("backward micro {micro} before its forward"))?;
        if self.is_head {
            // Replay the stored activation as dx (PipeDream-flush replay).
            Ok(BwdOut { dx: Some(stashed), free: None })
        } else {
            let g = grad.ok_or_else(|| anyhow::anyhow!("non-head backward without grad"))?;
            self.dp[micro] = Some(g.iter().sum::<f32>() / g.len().max(1) as f32);
            let dx: Vec<f32> = g.iter().map(|v| v * 0.5).collect();
            Ok(BwdOut { dx: Some(dx), free: Some(stashed) })
        }
    }

    fn update(&mut self, _iter: u32) -> anyhow::Result<()> {
        self.log.push((TaskKind::Update, 0));
        // Fixed accumulation order: ascending micro, like the PJRT backend.
        let mut acc = 0.0f32;
        for m in 0..self.n_micro {
            acc += self.dp[m]
                .take()
                .ok_or_else(|| anyhow::anyhow!("update before backward of micro {m}"))?;
        }
        self.param -= 0.01 * acc / self.n_micro as f32;
        self.updates += 1;
        Ok(())
    }

    fn snapshot(&self) -> Option<StageState> {
        if !self.stateful {
            return None;
        }
        Some(StageState {
            params: vec![self.param],
            momentum: Vec::new(),
            second: Vec::new(),
        })
    }
}
