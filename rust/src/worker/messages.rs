//! Wire protocol between the driver and stage workers.

use crate::compress::sparsify::ChunkedTopK;
use crate::compress::{CompressKind, Compressor, Int8Quantizer, NoCompress, RandomK};
use crate::opdag::data::{CompressCfg, OpData, OpDataKind};

/// Channel message. Activations/gradients travel as *encoded* OP-Data
/// byte buffers (the socket wire format), everything else is control.
#[derive(Debug)]
pub enum Wire {
    /// Driver -> embed worker: token microbatch.
    Data { iter: u32, micro: u32, tokens: Vec<i32> },
    /// Driver -> head worker: target microbatch.
    Labels { iter: u32, micro: u32, targets: Vec<i32> },
    /// Stage -> stage: encoded OP-Data (activation or gradient).
    Packet(Vec<u8>),
    /// Head -> driver: per-microbatch loss.
    Loss { iter: u32, micro: u32, loss: f32 },
    /// Worker -> driver on shutdown: accumulated statistics.
    Stats(WorkerStats),
    /// Worker -> driver: unrecoverable error (driver aborts the job).
    Fatal { stage: usize, error: String },
    /// Driver -> workers: clean shutdown.
    Stop,
}

/// Per-worker accumulated counters (profiling plane, §3.5).
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub stage: usize,
    pub device: usize,
    /// Wall seconds in fwd / bwd / update PJRT execution.
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub update_s: f64,
    /// Seconds blocked on channel receives.
    pub wait_s: f64,
    /// Wire bytes sent (post-compression, OP-Data accounting).
    pub bytes_sent: f64,
    /// Messages sent.
    pub msgs_sent: u64,
    /// FLOPs executed (from the cost model) for λ fitting.
    pub flops: f64,
}

/// Build the compressor for one message given plan kind + effective ratio.
/// Top-K variants select per feature row (`chunk` = d_model), per Fig. 6.
pub fn compressor_for(
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    seed: u64,
) -> Box<dyn Compressor> {
    match kind {
        CompressKind::None => Box::new(NoCompress),
        CompressKind::TopK | CompressKind::AdaTopK => {
            Box::new(ChunkedTopK { ratio, chunk: chunk.max(1) })
        }
        CompressKind::RandomK => Box::new(RandomK { ratio, seed }),
        CompressKind::Int8 => Box::new(Int8Quantizer),
    }
}

/// Compress + wrap a dense payload into an encoded OP-Data packet.
#[allow(clippy::too_many_arguments)]
pub fn encode_payload(
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    src_op: usize,
    dst_op: usize,
    data_kind: OpDataKind,
    iter: u32,
    micro: u32,
    dense: &[f32],
) -> (Vec<u8>, f64) {
    let effective_kind = if ratio <= 1.0 { CompressKind::None } else { kind };
    let comp =
        compressor_for(effective_kind, ratio, chunk, (iter as u64) << 32 | micro as u64);
    let c = comp.compress(dense);
    let mut od = OpData::dense(src_op, dst_op, data_kind, iter, micro, Vec::new());
    od.compress = c.cfg.clone();
    od.payload = c.values;
    od.indices = c.indices;
    od.bytes_payload = c.bytes;
    let wire = od.wire_bytes();
    (od.encode(), wire)
}

/// Decode a packet and reconstruct the dense payload of length `n`.
pub fn decode_payload(buf: &[u8], n: usize) -> anyhow::Result<(OpData, Vec<f32>)> {
    let od = OpData::decode(buf)?;
    let mut dense = vec![0.0f32; n];
    match &od.compress {
        CompressCfg::None => {
            anyhow::ensure!(od.payload.len() == n, "dense length mismatch");
            dense.copy_from_slice(&od.payload);
        }
        CompressCfg::TopK { total_len, .. } | CompressCfg::RandomK { total_len, .. } => {
            anyhow::ensure!(*total_len as usize == n, "sparse length mismatch");
            for (&i, &v) in od.indices.iter().zip(&od.payload) {
                anyhow::ensure!((i as usize) < n, "index out of range");
                dense[i as usize] = v;
            }
        }
        CompressCfg::Int8 { scale, total_len } => {
            anyhow::ensure!(*total_len as usize == n, "int8 length mismatch");
            for (d, &b) in dense.iter_mut().zip(&od.bytes_payload) {
                *d = (b as i8) as f32 * scale;
            }
        }
    }
    Ok((od, dense))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn encode_decode_roundtrip_topk() {
        let mut rng = Rng::new(1);
        let dense: Vec<f32> = (0..1000).map(|_| rng.f32() - 0.5).collect();
        let (buf, wire) =
            encode_payload(CompressKind::TopK, 100.0, 1000, 2, 3, OpDataKind::Activation, 5, 1, &dense);
        assert!(wire < 1000.0); // 10 values*4 + 10 idx*8 + header
        let (od, out) = decode_payload(&buf, 1000).unwrap();
        assert_eq!(od.src_op, 2);
        assert_eq!(od.local_iter, 5);
        let nz = out.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nz, 10);
        // Kept values exact.
        for (i, &v) in out.iter().enumerate() {
            if v != 0.0 {
                assert_eq!(v, dense[i]);
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_dense_and_int8() {
        let dense: Vec<f32> = vec![0.5, -1.0, 0.25];
        let (buf, _) =
            encode_payload(CompressKind::None, 1.0, 0, 0, 1, OpDataKind::Gradient, 0, 0, &dense);
        let (_, out) = decode_payload(&buf, 3).unwrap();
        assert_eq!(out, dense);

        let (buf, wire) =
            encode_payload(CompressKind::Int8, 4.0, 0, 0, 1, OpDataKind::Gradient, 0, 0, &dense);
        assert!(wire < 60.0);
        let (_, out) = decode_payload(&buf, 3).unwrap();
        for (a, b) in dense.iter().zip(&out) {
            assert!((a - b).abs() < 1.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn ratio_one_falls_back_to_dense() {
        let dense = vec![1.0f32; 16];
        let (buf, _) =
            encode_payload(CompressKind::AdaTopK, 1.0, 64, 0, 1, OpDataKind::Activation, 0, 0, &dense);
        let (od, out) = decode_payload(&buf, 16).unwrap();
        assert_eq!(od.compress, CompressCfg::None);
        assert_eq!(out, dense);
    }

    #[test]
    fn decode_rejects_wrong_length() {
        let dense = vec![1.0f32; 8];
        let (buf, _) =
            encode_payload(CompressKind::None, 1.0, 0, 0, 1, OpDataKind::Activation, 0, 0, &dense);
        assert!(decode_payload(&buf, 9).is_err());
    }
}
