//! Wire protocol between the driver and stage workers.
//!
//! The steady-state send path is `StageCodec` → `LinkEncoder`: one encoder
//! per outgoing link owns the compression scratch and the compressed
//! staging buffers, so each message costs exactly one allocation — the
//! packet `Vec` that is moved into the channel. The receive path decodes
//! through the zero-copy `OpDataView` straight into a caller-provided
//! dense buffer. `encode_payload`/`decode_payload` remain as allocating
//! wrappers (and differential oracles for the reusing forms).

use crate::compress::sparsify::ChunkedTopK;
use crate::compress::{
    CompressKind, CompressPlan, CompressScratch, Compressed, Compressor, Int8Quantizer,
    NoCompress, Quantized, RandomK, ValueCodec,
};
use crate::opdag::data::{
    encode_parts_into, CompressCfg, OpData, OpDataHeader, OpDataKind, OpDataView,
    WIRE_HEADER_BYTES,
};
use crate::transport::PacketPool;

/// Channel message. Activations/gradients travel as *encoded* OP-Data
/// byte buffers (the socket wire format), everything else is control.
/// Over `TcpTransport` every variant has a binary frame encoding
/// (`transport::codec`); `PartialEq` backs the roundtrip tests.
#[derive(Debug, PartialEq)]
pub enum Wire {
    /// Driver -> embed worker: token microbatch.
    Data { iter: u32, micro: u32, tokens: Vec<i32> },
    /// Driver -> head worker: target microbatch.
    Labels { iter: u32, micro: u32, targets: Vec<i32> },
    /// Stage -> stage: encoded OP-Data (activation or gradient).
    Packet(Vec<u8>),
    /// Head -> driver: per-microbatch loss.
    Loss { iter: u32, micro: u32, loss: f32 },
    /// Worker -> driver after each optimizer step: measured per-iteration
    /// profile (the feedback plane the straggler detector consumes).
    /// `bytes`/`msgs` are the wire traffic this stage emitted during the
    /// iteration, so the driver can report real per-iteration wire bytes.
    IterProfile {
        stage: usize,
        iter: u32,
        fwd_s: f64,
        bwd_s: f64,
        update_s: f64,
        bytes: f64,
        msgs: u64,
    },
    /// Worker -> driver on a mid-run Stop: parameter + optimizer state so
    /// the broker can re-init the stage on a different device (live
    /// migration at an iteration boundary).
    Snapshot { stage: usize, state: StageState },
    /// Worker -> driver checkpoint reply when the broker's acknowledged
    /// base version matches the worker's retained shadow copy: `blob` is
    /// the stage's lossless delta against that base, in the exact
    /// `checkpoint::encode_stage_delta` stage-layer encoding (per tensor:
    /// sparse changed-index/exact-value `OpData`, or a dense replacement).
    /// The broker materializes it with `checkpoint::apply_stage_delta`.
    SnapshotDelta { stage: usize, base_iter: u32, blob: Vec<u8> },
    /// Worker -> driver: liveness beacon, sent at most once per heartbeat
    /// interval (while blocked on a channel or between tasks). The
    /// broker's deadline monitor declares a stage dead when its beacons —
    /// and all other traffic — go stale.
    Heartbeat { stage: usize, iter: u32 },
    /// Driver -> workers (broadcast at an iteration boundary): reply with
    /// the current training state, then keep running. `base` is the last
    /// checkpoint version the broker saved and still holds materialized;
    /// a worker whose retained shadow matches it replies with the cheap
    /// `SnapshotDelta`, anyone else (fresh generation, missed collection)
    /// replies with a full `Snapshot`. The broker persists the collected
    /// states as a versioned checkpoint (base or delta layer on disk).
    Checkpoint { iter: u32, base: Option<u32> },
    /// Worker -> driver on shutdown: accumulated statistics.
    Stats(WorkerStats),
    /// Worker -> driver: unrecoverable error (driver aborts the job, or —
    /// with recovery enabled — treats the stage as dead and re-plans).
    Fatal { stage: usize, error: String },
    /// Driver -> workers: clean shutdown.
    Stop,
}

/// Portable stage training state (flat parameters + optimizer moments),
/// carried across worker generations when the broker re-partitions.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StageState {
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    /// Adam second moment (empty under SGD).
    pub second: Vec<f32>,
}

/// Per-worker accumulated counters (profiling plane, §3.5).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct WorkerStats {
    pub stage: usize,
    pub device: usize,
    /// Wall seconds in fwd / bwd / update PJRT execution.
    pub fwd_s: f64,
    pub bwd_s: f64,
    pub update_s: f64,
    /// Seconds blocked on channel receives.
    pub wait_s: f64,
    /// Wire bytes sent (post-compression, OP-Data accounting).
    pub bytes_sent: f64,
    /// Dense (pre-compression) payload bytes handed to the encoders —
    /// `bytes_sent / dense_bytes` is the achieved wire compression.
    pub dense_bytes: f64,
    /// Messages sent.
    pub msgs_sent: u64,
    /// FLOPs executed (from the cost model) for λ fitting.
    pub flops: f64,
}

/// Per-link steady-state encoder: owns the compression scratch and the
/// compressed staging buffers. Top-K variants select per feature row
/// (`chunk` = d_model), per Fig. 6; ratios <= 1 fall back to dense. The
/// negotiated `ValueCodec` decides how wide each value travels: int8 turns
/// sparse payloads into `QSparseRows` (per-row scales) and dense fallbacks
/// into the 1 B/value `Int8` encoding.
pub struct LinkEncoder {
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    codec: ValueCodec,
    comp: Compressed,
    scratch: CompressScratch,
    /// Free-list the packet `Vec`s are drawn from; receivers return the
    /// drained buffers here (same-process links) or the transport does
    /// right after the socket write, so steady state allocates nothing.
    pool: PacketPool,
}

impl LinkEncoder {
    pub fn new(kind: CompressKind, ratio: f64, chunk: usize) -> LinkEncoder {
        LinkEncoder::with_codec(kind, ratio, chunk, ValueCodec::F32)
    }

    pub fn with_codec(
        kind: CompressKind,
        ratio: f64,
        chunk: usize,
        codec: ValueCodec,
    ) -> LinkEncoder {
        LinkEncoder {
            kind,
            ratio,
            chunk: chunk.max(1),
            codec,
            comp: Compressed::default(),
            scratch: CompressScratch::default(),
            pool: PacketPool::new(),
        }
    }

    pub fn from_spec(spec: LinkSpec, chunk: usize) -> LinkEncoder {
        LinkEncoder::with_codec(spec.kind, spec.ratio, chunk, spec.codec)
    }

    /// Handle to this encoder's packet free-list (hand it to whoever
    /// drains the packets so the buffers come back).
    pub fn pool(&self) -> PacketPool {
        self.pool.clone()
    }

    /// Compress + encode one message. Returns the packet and its wire-byte
    /// accounting (paper Fig. 6, including the fixed header).
    pub fn encode(
        &mut self,
        src_op: usize,
        dst_op: usize,
        data_kind: OpDataKind,
        iter: u32,
        micro: u32,
        dense: &[f32],
    ) -> (Vec<u8>, f64) {
        let effective = if self.ratio <= 1.0 { CompressKind::None } else { self.kind };
        let (comp, scratch) = (&mut self.comp, &mut self.scratch);
        match (effective, self.codec) {
            (CompressKind::None, ValueCodec::F32) => {
                NoCompress.compress_with(dense, comp, scratch)
            }
            // Dense fallback under the int8 codecs: 4 -> ~1 B/value.
            (CompressKind::None, ValueCodec::Int8 | ValueCodec::Int8Delta)
            | (CompressKind::Int8, _) => Int8Quantizer.compress_with(dense, comp, scratch),
            (CompressKind::TopK | CompressKind::AdaTopK, ValueCodec::F32) => {
                ChunkedTopK { ratio: self.ratio, chunk: self.chunk }
                    .compress_with(dense, comp, scratch)
            }
            (
                CompressKind::TopK | CompressKind::AdaTopK,
                ValueCodec::Int8 | ValueCodec::Int8Delta,
            ) => {
                Quantized::per_row(
                    ChunkedTopK { ratio: self.ratio, chunk: self.chunk },
                    self.chunk,
                )
                .compress_with(dense, comp, scratch)
            }
            (CompressKind::RandomK, codec) => {
                let rk = RandomK {
                    ratio: self.ratio,
                    seed: (iter as u64) << 32 | micro as u64,
                };
                match codec {
                    ValueCodec::F32 => rk.compress_with(dense, comp, scratch),
                    // Random-K support is unsorted, so the delta index
                    // packing never applies — both int8 codecs share the
                    // per-message QSparse layout here.
                    ValueCodec::Int8 | ValueCodec::Int8Delta => {
                        Quantized::per_message(rk).compress_with(dense, comp, scratch)
                    }
                }
            }
        }
        // The u24 negotiation: re-tag a row-quantized payload to the
        // delta-index layout when it qualifies (ChunkedTopK emits strictly
        // ascending indices; the length gate covers the u24 range).
        if self.codec == ValueCodec::Int8Delta {
            if let CompressCfg::QSparseRows { ratio, total_len, chunk } = self.comp.cfg {
                if total_len < (1 << 24)
                    && self.comp.indices.windows(2).all(|w| w[0] < w[1])
                {
                    self.comp.cfg =
                        CompressCfg::QSparseRowsDelta { ratio, total_len, chunk };
                }
            }
        }
        let hdr = OpDataHeader {
            src_op,
            dst_op,
            actual_user: dst_op,
            kind: data_kind,
            is_loss: false,
            require_grad: data_kind == OpDataKind::Activation,
            local_iter: iter,
            micro_batch: micro,
        };
        let wire = WIRE_HEADER_BYTES + self.comp.wire_bytes();
        let mut buf = self.pool.take();
        encode_parts_into(
            &hdr,
            &self.comp.cfg,
            &self.comp.values,
            &self.comp.indices,
            &self.comp.bytes,
            &mut buf,
        );
        (buf, wire)
    }
}

/// The negotiated wire configuration of one directed link: compression
/// kind, the Eq. 7 ratio keyed by the receiving device, and the value
/// codec. Serializable (it travels inside the TCP `StageAssign`
/// handshake), so a remote worker builds byte-identical `LinkEncoder`s
/// to the in-process path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpec {
    pub kind: CompressKind,
    pub ratio: f64,
    pub codec: ValueCodec,
}

impl LinkSpec {
    /// The spec `CompressPlan` implies for a message of `data_kind` whose
    /// receiver sits on `dst_device`.
    pub fn from_plan(plan: &CompressPlan, dst_device: usize, data_kind: OpDataKind) -> LinkSpec {
        LinkSpec {
            kind: plan.kind,
            ratio: plan.ratio_for_kind(dst_device, data_kind),
            codec: plan.codec_for_kind(dst_device, data_kind),
        }
    }
}

/// Per-stage codec: one `LinkEncoder` per outgoing link. Ratios are keyed
/// by the *receiving* device (Eq. 7) and gated by the plan's direction
/// knob; built once by the broker (in-process) or from the serialized
/// `LinkSpec` pair in the `StageAssign` handshake (remote workers).
pub struct StageCodec {
    pub fwd: Option<LinkEncoder>,
    pub bwd: Option<LinkEncoder>,
}

impl StageCodec {
    pub fn from_plan(
        plan: &CompressPlan,
        next_device: Option<usize>,
        prev_device: Option<usize>,
        chunk: usize,
    ) -> StageCodec {
        StageCodec::from_specs(
            next_device.map(|d| LinkSpec::from_plan(plan, d, OpDataKind::Activation)),
            prev_device.map(|d| LinkSpec::from_plan(plan, d, OpDataKind::Gradient)),
            chunk,
        )
    }

    pub fn from_specs(
        fwd: Option<LinkSpec>,
        bwd: Option<LinkSpec>,
        chunk: usize,
    ) -> StageCodec {
        StageCodec {
            fwd: fwd.map(|s| LinkEncoder::from_spec(s, chunk)),
            bwd: bwd.map(|s| LinkEncoder::from_spec(s, chunk)),
        }
    }
}

/// Build the compressor for one message given plan kind + effective ratio.
/// Top-K variants select per feature row (`chunk` = d_model), per Fig. 6.
pub fn compressor_for(
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    seed: u64,
) -> Box<dyn Compressor> {
    compressor_for_codec(kind, ratio, chunk, seed, ValueCodec::F32)
}

/// `compressor_for` with an explicit value codec (int8 wraps the sparse
/// selection in `Quantized`, matching what `LinkEncoder` does inline).
pub fn compressor_for_codec(
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    seed: u64,
    codec: ValueCodec,
) -> Box<dyn Compressor> {
    let chunk = chunk.max(1);
    match (kind, codec) {
        (CompressKind::None, ValueCodec::F32) => Box::new(NoCompress),
        (CompressKind::None, ValueCodec::Int8 | ValueCodec::Int8Delta)
        | (CompressKind::Int8, _) => Box::new(Int8Quantizer),
        (CompressKind::TopK | CompressKind::AdaTopK, ValueCodec::F32) => {
            Box::new(ChunkedTopK { ratio, chunk })
        }
        (
            CompressKind::TopK | CompressKind::AdaTopK,
            ValueCodec::Int8 | ValueCodec::Int8Delta,
        ) => Box::new(Quantized::per_row(ChunkedTopK { ratio, chunk }, chunk)),
        (CompressKind::RandomK, ValueCodec::F32) => Box::new(RandomK { ratio, seed }),
        (CompressKind::RandomK, ValueCodec::Int8 | ValueCodec::Int8Delta) => {
            Box::new(Quantized::per_message(RandomK { ratio, seed }))
        }
    }
}

/// Compress + wrap a dense payload into an encoded OP-Data packet
/// (allocating wrapper over `LinkEncoder::encode`).
#[allow(clippy::too_many_arguments)]
pub fn encode_payload(
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    src_op: usize,
    dst_op: usize,
    data_kind: OpDataKind,
    iter: u32,
    micro: u32,
    dense: &[f32],
) -> (Vec<u8>, f64) {
    LinkEncoder::new(kind, ratio, chunk).encode(src_op, dst_op, data_kind, iter, micro, dense)
}

/// `encode_payload` with an explicit value codec (differential oracle for
/// the codec-negotiating `LinkEncoder`).
#[allow(clippy::too_many_arguments)]
pub fn encode_payload_with(
    codec: ValueCodec,
    kind: CompressKind,
    ratio: f64,
    chunk: usize,
    src_op: usize,
    dst_op: usize,
    data_kind: OpDataKind,
    iter: u32,
    micro: u32,
    dense: &[f32],
) -> (Vec<u8>, f64) {
    LinkEncoder::with_codec(kind, ratio, chunk, codec)
        .encode(src_op, dst_op, data_kind, iter, micro, dense)
}

/// Decode a packet into a caller-provided dense buffer (its length is the
/// expected dense element count), scattering straight from the zero-copy
/// view — no intermediate payload/index `Vec`s. Returns the parsed header.
pub fn decode_payload_into(buf: &[u8], dense: &mut [f32]) -> anyhow::Result<OpDataHeader> {
    let v = OpDataView::parse(buf)?;
    scatter_view(&v, dense)?;
    Ok(v.header)
}

/// Scatter a parsed view into the dense buffer per its compression cfg.
/// The width-4 index layouts decode through the dispatched `util::simd`
/// kernels straight from the borrowed little-endian regions; the
/// delta-coded u24 layout keeps the sequential unpack (each index depends
/// on the previous one).
fn scatter_view(v: &OpDataView, dense: &mut [f32]) -> anyhow::Result<()> {
    use crate::util::simd::{self, ScatterError};
    let n = dense.len();
    let scatter_err = |e: ScatterError| match e {
        ScatterError::Index => anyhow::anyhow!("index out of range"),
        ScatterError::Scale => anyhow::anyhow!("row scale out of range"),
    };
    match &v.compress {
        CompressCfg::None => {
            anyhow::ensure!(v.payload_len() == n, "dense length mismatch");
            simd::f32_from_le(v.payload_le_bytes(), dense);
        }
        CompressCfg::TopK { total_len, .. } | CompressCfg::RandomK { total_len, .. } => {
            anyhow::ensure!(*total_len as usize == n, "sparse length mismatch");
            dense.fill(0.0);
            simd::scatter_f32_view(v.indices_le_bytes(), v.payload_le_bytes(), dense)
                .map_err(scatter_err)?;
        }
        CompressCfg::Int8 { scale, total_len } => {
            anyhow::ensure!(*total_len as usize == n, "int8 length mismatch");
            dense.fill(0.0);
            simd::dequant_into(v.bytes_payload(), *scale, dense);
        }
        CompressCfg::QSparse { scale, total_len, .. } => {
            anyhow::ensure!(*total_len as usize == n, "qsparse length mismatch");
            anyhow::ensure!(
                v.indices_len() == v.bytes_payload().len(),
                "qsparse codes/indices mismatch"
            );
            dense.fill(0.0);
            simd::scatter_int8_view(v.indices_le_bytes(), v.bytes_payload(), *scale, dense)
                .map_err(scatter_err)?;
        }
        CompressCfg::QSparseRows { chunk, total_len, .. } => {
            anyhow::ensure!(*total_len as usize == n, "qsparse length mismatch");
            anyhow::ensure!(
                v.indices_len() == v.bytes_payload().len(),
                "qsparse codes/indices mismatch"
            );
            let chunk = (*chunk as usize).max(1);
            dense.fill(0.0);
            simd::scatter_int8_rows_view(
                v.indices_le_bytes(),
                v.bytes_payload(),
                v.payload_le_bytes(),
                chunk,
                dense,
            )
            .map_err(scatter_err)?;
        }
        CompressCfg::QSparseRowsDelta { chunk, total_len, .. } => {
            anyhow::ensure!(*total_len as usize == n, "qsparse length mismatch");
            anyhow::ensure!(
                v.indices_len() == v.bytes_payload().len(),
                "qsparse codes/indices mismatch"
            );
            let chunk = (*chunk as usize).max(1);
            // Row scales are the f32 payload region; read them straight
            // from the borrowed little-endian bytes (alignment-free).
            let scales = v.payload_le_bytes();
            dense.fill(0.0);
            for (i, &b) in v.indices_iter().zip(v.bytes_payload()) {
                anyhow::ensure!((i as usize) < n, "index out of range");
                let off = (i as usize / chunk) * 4;
                let s = scales
                    .get(off..off + 4)
                    .ok_or_else(|| anyhow::anyhow!("row scale out of range"))?;
                dense[i as usize] =
                    (b as i8) as f32 * f32::from_le_bytes(s.try_into().unwrap());
            }
        }
    }
    Ok(())
}

/// Decode a packet and reconstruct the dense payload of length `n`
/// (allocating wrapper; parses the buffer once).
pub fn decode_payload(buf: &[u8], n: usize) -> anyhow::Result<(OpData, Vec<f32>)> {
    let v = OpDataView::parse(buf)?;
    let mut dense = vec![0.0f32; n];
    scatter_view(&v, &mut dense)?;
    Ok((v.to_opdata(), dense))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn encode_decode_roundtrip_topk() {
        let mut rng = Rng::new(1);
        let dense: Vec<f32> = (0..1000).map(|_| rng.f32() - 0.5).collect();
        let (buf, wire) =
            encode_payload(CompressKind::TopK, 100.0, 1000, 2, 3, OpDataKind::Activation, 5, 1, &dense);
        assert!(wire < 1000.0); // 10 values*4 + 10 idx*8 + header
        let (od, out) = decode_payload(&buf, 1000).unwrap();
        assert_eq!(od.src_op, 2);
        assert_eq!(od.local_iter, 5);
        let nz = out.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nz, 10);
        // Kept values exact.
        for (i, &v) in out.iter().enumerate() {
            if v != 0.0 {
                assert_eq!(v, dense[i]);
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_dense_and_int8() {
        let dense: Vec<f32> = vec![0.5, -1.0, 0.25];
        let (buf, _) =
            encode_payload(CompressKind::None, 1.0, 0, 0, 1, OpDataKind::Gradient, 0, 0, &dense);
        let (_, out) = decode_payload(&buf, 3).unwrap();
        assert_eq!(out, dense);

        let (buf, wire) =
            encode_payload(CompressKind::Int8, 4.0, 0, 0, 1, OpDataKind::Gradient, 0, 0, &dense);
        assert!(wire < 60.0);
        let (_, out) = decode_payload(&buf, 3).unwrap();
        for (a, b) in dense.iter().zip(&out) {
            assert!((a - b).abs() < 1.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn ratio_one_falls_back_to_dense() {
        let dense = vec![1.0f32; 16];
        let (buf, _) =
            encode_payload(CompressKind::AdaTopK, 1.0, 64, 0, 1, OpDataKind::Activation, 0, 0, &dense);
        let (od, out) = decode_payload(&buf, 16).unwrap();
        assert_eq!(od.compress, CompressCfg::None);
        assert_eq!(out, dense);
    }

    #[test]
    fn decode_rejects_wrong_length() {
        let dense = vec![1.0f32; 8];
        let (buf, _) =
            encode_payload(CompressKind::None, 1.0, 0, 0, 1, OpDataKind::Activation, 0, 0, &dense);
        assert!(decode_payload(&buf, 9).is_err());
    }

    #[test]
    fn link_encoder_reuse_matches_oneshot() {
        // A reused LinkEncoder must produce byte-identical packets to the
        // allocating wrapper, message after message.
        let mut rng = Rng::new(44);
        let mut enc = LinkEncoder::new(CompressKind::TopK, 20.0, 128);
        for iter in 0..5u32 {
            let dense: Vec<f32> = (0..640).map(|_| rng.f32() - 0.5).collect();
            let (reused, w1) = enc.encode(1, 2, OpDataKind::Gradient, iter, 0, &dense);
            let (oneshot, w2) =
                encode_payload(CompressKind::TopK, 20.0, 128, 1, 2, OpDataKind::Gradient, iter, 0, &dense);
            assert_eq!(reused, oneshot, "iter {iter}");
            assert_eq!(w1, w2);
        }
    }

    #[test]
    fn packet_pool_reuses_the_drained_buffer() {
        // Returning a drained packet to the encoder's free-list makes the
        // next encode reuse the same allocation — and the bytes stay
        // identical to a fresh encode.
        let mut rng = Rng::new(47);
        let dense: Vec<f32> = (0..640).map(|_| rng.f32() - 0.5).collect();
        let mut enc = LinkEncoder::new(CompressKind::TopK, 20.0, 128);
        let pool = enc.pool();
        let (first, _) = enc.encode(1, 2, OpDataKind::Gradient, 0, 0, &dense);
        let want = first.clone();
        let ptr = first.as_ptr();
        pool.give(first);
        assert_eq!(pool.len(), 1);
        let (second, _) = enc.encode(1, 2, OpDataKind::Gradient, 0, 0, &dense);
        assert_eq!(second, want, "pooled buffer must not change the encoding");
        assert_eq!(second.as_ptr(), ptr, "steady state must reuse the allocation");
        assert_eq!(pool.len(), 0);
    }

    #[test]
    fn int8_codec_roundtrip_and_byte_budget() {
        let mut rng = Rng::new(46);
        let chunk = 128usize;
        let n = 64 * chunk;
        let dense: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let (buf_q, wire_q) = encode_payload_with(
            ValueCodec::Int8,
            CompressKind::TopK,
            16.0,
            chunk,
            0,
            1,
            OpDataKind::Activation,
            0,
            0,
            &dense,
        );
        let (buf_f, wire_f) = encode_payload(
            CompressKind::TopK,
            16.0,
            chunk,
            0,
            1,
            OpDataKind::Activation,
            0,
            0,
            &dense,
        );
        // Same support, far fewer bytes on the wire (5ish vs 8 actual).
        assert!(buf_q.len() * 3 < buf_f.len() * 2, "{} vs {}", buf_q.len(), buf_f.len());
        assert!(wire_q < wire_f);
        // Decoded payload within half a row-scale step of the f32 decode.
        let (od, want) = decode_payload(&buf_f, n).unwrap();
        assert!(matches!(od.compress, CompressCfg::TopK { .. }));
        let (od_q, got) = decode_payload(&buf_q, n).unwrap();
        let scales = match od_q.compress {
            CompressCfg::QSparseRows { chunk: c, .. } => {
                assert_eq!(c as usize, chunk);
                od_q.payload.clone()
            }
            other => panic!("expected QSparseRows, got {other:?}"),
        };
        for (i, (&w, &g)) in want.iter().zip(&got).enumerate() {
            let scale = scales[i / chunk];
            assert!((w - g).abs() <= scale * 0.5 + scale * 1e-4, "idx {i}: {w} vs {g}");
            if w == 0.0 {
                assert_eq!(g, 0.0, "support must match at idx {i}");
            }
        }
        // Zero-copy decode agrees with the allocating decode.
        let mut direct = vec![f32::NAN; n];
        decode_payload_into(&buf_q, &mut direct).unwrap();
        assert_eq!(direct, got);
    }

    #[test]
    fn u24_delta_codec_shrinks_indices_and_decodes_identically() {
        let mut rng = Rng::new(48);
        let chunk = 128usize;
        let n = 64 * chunk;
        let dense: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let enc = |codec| {
            encode_payload_with(
                codec,
                CompressKind::TopK,
                16.0,
                chunk,
                0,
                1,
                OpDataKind::Activation,
                0,
                0,
                &dense,
            )
        };
        let (buf_q, wire_q) = enc(ValueCodec::Int8);
        let (buf_d, wire_d) = enc(ValueCodec::Int8Delta);
        let (od_q, want) = decode_payload(&buf_q, n).unwrap();
        let (od_d, got) = decode_payload(&buf_d, n).unwrap();
        // Same codes and support, one byte per index cheaper on the wire.
        assert!(matches!(od_q.compress, CompressCfg::QSparseRows { .. }));
        assert!(matches!(od_d.compress, CompressCfg::QSparseRowsDelta { .. }));
        assert_eq!(od_d.indices, od_q.indices);
        assert_eq!(od_d.bytes_payload, od_q.bytes_payload);
        let k = od_q.indices.len();
        assert_eq!(buf_q.len(), buf_d.len() + k);
        assert!((wire_q - wire_d - k as f64).abs() < 1e-9);
        // Bitwise-identical dense reconstruction.
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Zero-copy decode agrees too.
        let mut direct = vec![f32::NAN; n];
        decode_payload_into(&buf_d, &mut direct).unwrap();
        assert_eq!(direct, got);
    }

    #[test]
    fn u24_delta_codec_dense_fallback_matches_int8() {
        let dense: Vec<f32> = (0..500).map(|i| (i as f32).cos()).collect();
        let enc = |codec| {
            encode_payload_with(
                codec,
                CompressKind::None,
                1.0,
                64,
                0,
                1,
                OpDataKind::Gradient,
                0,
                0,
                &dense,
            )
        };
        let (buf_q, _) = enc(ValueCodec::Int8);
        let (buf_d, _) = enc(ValueCodec::Int8Delta);
        assert_eq!(buf_q, buf_d, "dense fallback is codec-identical");
    }

    #[test]
    fn int8_codec_dense_fallback_is_one_byte_per_value() {
        let dense: Vec<f32> = (0..1000).map(|i| (i as f32).sin()).collect();
        let (buf, wire) = encode_payload_with(
            ValueCodec::Int8,
            CompressKind::AdaTopK,
            1.0, // fast link: AdaTopK says dense — codec still quantizes
            64,
            0,
            1,
            OpDataKind::Gradient,
            0,
            0,
            &dense,
        );
        let (od, out) = decode_payload(&buf, 1000).unwrap();
        assert!(matches!(od.compress, CompressCfg::Int8 { .. }));
        assert!(buf.len() < 1000 + 96, "dense int8 ≈ 1 B/value, got {}", buf.len());
        assert!(wire < 4.0 * 1000.0 / 3.0);
        for (a, b) in dense.iter().zip(&out) {
            assert!((a - b).abs() <= 1.0 / 127.0 + 1e-6);
        }
    }

    #[test]
    fn decode_into_matches_decode_payload() {
        let mut rng = Rng::new(45);
        let dense: Vec<f32> = (0..512).map(|_| rng.f32() - 0.5).collect();
        for kind in [CompressKind::None, CompressKind::TopK, CompressKind::RandomK, CompressKind::Int8] {
            let ratio = if kind == CompressKind::None { 1.0 } else { 8.0 };
            let (buf, _) =
                encode_payload(kind, ratio, 64, 3, 4, OpDataKind::Activation, 7, 2, &dense);
            let (od, want) = decode_payload(&buf, 512).unwrap();
            let mut got = vec![f32::NAN; 512]; // poisoned: decode must overwrite
            let hdr = decode_payload_into(&buf, &mut got).unwrap();
            assert_eq!(got, want, "{kind:?}");
            assert_eq!(hdr.src_op, od.src_op);
            assert_eq!(hdr.local_iter, od.local_iter);
            assert_eq!(hdr.micro_batch, od.micro_batch);
        }
    }
}
