//! The execution plane (§3.2): per-CompNode stage workers.
//!
//! Each worker is an OS thread owning its own PJRT runtime (the client is
//! Rc-based, so handles cannot cross threads), its stage's flat parameters
//! and optimizer state, and channel endpoints to its pipeline neighbors.
//! Messages are OP-Data (§3.4) encoded to flat byte buffers — exactly what
//! would go on a socket — with compression applied per the broker's
//! `CompressPlan` before encoding and reversed after decoding.
//!
//! Execution is schedule-driven: `interpreter::run_schedule` walks the
//! stage's `PipelineSchedule` task row (GPipe or 1F1B) against a
//! `StageBackend` — PJRT in production (`stage::spawn_stage`), trivial
//! arithmetic in tests/benches (`interpreter::NullBackend`).

pub mod interpreter;
pub mod messages;
pub mod remote;
pub mod stage;

pub use interpreter::{
    run_schedule, run_schedule_with, BwdOut, FwdInput, FwdOut, NullBackend, RunOpts, RunOutcome,
    StageBackend, StageLinks,
};
pub use messages::{
    decode_payload, decode_payload_into, LinkEncoder, LinkSpec, StageCodec, StageState, Wire,
    WorkerStats,
};
pub use remote::{run_worker, WorkerOpts};
pub use stage::{run_stage, spawn_stage, BackendKind, StageCtx};
