//! The execution plane (§3.2): per-CompNode stage workers.
//!
//! Each worker is an OS thread owning its own PJRT runtime (the client is
//! Rc-based, so handles cannot cross threads), its stage's flat parameters
//! and optimizer state, and channel endpoints to its pipeline neighbors.
//! Messages are OP-Data (§3.4) encoded to flat byte buffers — exactly what
//! would go on a socket — with compression applied per the broker's
//! `CompressPlan` before encoding and reversed after decoding.

pub mod messages;
pub mod stage;

pub use messages::{
    decode_payload, decode_payload_into, LinkEncoder, StageCodec, Wire, WorkerStats,
};
pub use stage::{spawn_stage, StageCtx};
