//! Broker-side versioned checkpoints (fault tolerance, churn recovery).
//!
//! Every `--checkpoint-every` iterations the broker broadcasts
//! `Wire::Checkpoint` at an iteration boundary, collects one snapshot per
//! stage (full, or a delta against the last saved version), and persists
//! them here: one directory per version (`ckpt-<iter>`), written to a
//! dot-tmp path and atomically renamed into place, carrying a
//! `manifest.json` with FNV-1a-64 checksums over every stage file.
//!
//! Format 2 makes versions incremental: a version is either a **base**
//! layer (self-contained dense tensors, exactly like format 1) or a
//! **delta** layer whose manifest names a `parent` version and whose
//! stage files store, per tensor, either a sparse lossless diff (changed
//! indices + exact new f32 values, scattered onto the parent on load) or
//! a dense replacement when more than half the elements changed. Tensor
//! payloads travel through the same `OpData` codec as the wire hot path —
//! checkpoints exercise the tested encode/decode path instead of
//! inventing a second serializer (`CompressCfg::None` for dense layers,
//! `CompressCfg::TopK` for the sparse diffs).
//!
//! `load_latest` walks versions newest-first, replays each candidate's
//! delta chain down to its base, and falls back past any version whose
//! chain fails integrity (truncated file, flipped byte, bad manifest,
//! missing parent), so a crash mid-write or a corrupt middle layer can
//! never leave the run unrecoverable as long as one older valid chain
//! survives. `prune` reasons about chains, not directories: a base is
//! never deleted while a retained delta still depends on it.

use crate::opdag::data::{
    encode_parts_into, CompressCfg, OpData, OpDataHeader, OpDataKind,
};
use crate::util::json::{arr, n, ni, obj, s, Json};
use crate::worker::StageState;
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Hard bound on delta-chain length walked at load/prune time: guards
/// against manifest cycles or garbage parents in a corrupted directory.
const MAX_CHAIN: usize = 512;

/// Everything needed to resume a run: model state per stage plus the
/// data-loader cursor and the RNG seed that reproduces the stream.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    /// Iteration boundary this state belongs to (first iteration to run
    /// after restoring).
    pub iter: u32,
    /// Microbatches drawn from the synthetic corpus before this boundary
    /// (the data-loader cursor; restore replays the stream up to here).
    pub corpus_batches: u64,
    /// Job seed (RNG provenance — restore must verify it matches).
    pub seed: u64,
    /// Model config name the states belong to.
    pub config: String,
    /// Stage -> device placement when the checkpoint was taken
    /// (informational; recovery re-plans placement anyway).
    pub placement: Vec<usize>,
    /// Per-stage params + optimizer moments, stage order. Always the
    /// fully materialized state — `save` does any delta encoding.
    pub states: Vec<StageState>,
}

/// How one on-disk version stores its stage tensors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    /// Self-contained dense layer.
    Base,
    /// Stores only changes since the `parent` version; loading replays
    /// the chain parent-first.
    Delta { parent: u32 },
}

/// Outcome of one `save`: where the version landed, which layer kind was
/// written, and the byte accounting the broker reports
/// (`TrainReport.checkpoint_bytes_{full,delta}`).
#[derive(Debug, Clone)]
pub struct SaveInfo {
    pub path: PathBuf,
    pub kind: LayerKind,
    /// Stage-file bytes actually written for this version.
    pub bytes_written: u64,
    /// Stage-file bytes a dense full snapshot of the same states would
    /// have occupied (equals `bytes_written` for base layers).
    pub bytes_full: u64,
}

// FNV-1a 64 now lives in `util::fnv` (shared with the frame codec and
// dispatched through `util::simd`); re-exported here so existing callers
// and tests keep working.
pub use crate::util::fnv::fnv1a64;

fn version_dir(dir: &Path, iter: u32) -> PathBuf {
    dir.join(format!("ckpt-{iter:08}"))
}

fn tensor_hdr(stage: usize, iter: u32, idx: u32) -> OpDataHeader {
    OpDataHeader {
        src_op: stage,
        dst_op: stage,
        actual_user: stage,
        kind: OpDataKind::Activation,
        is_loss: false,
        require_grad: false,
        local_iter: iter,
        micro_batch: idx,
    }
}

/// Encode one stage as a self-contained base layer: params / momentum /
/// second as three length-prefixed `OpData` messages (dense f32,
/// micro_batch = tensor index). Encoded from borrowed slices — no tensor
/// copies on the way to disk.
pub fn encode_stage_full(stage: usize, iter: u32, st: &StageState) -> Vec<u8> {
    let mut out = Vec::new();
    let mut blob = Vec::new();
    for (idx, tensor) in [&st.params, &st.momentum, &st.second].into_iter().enumerate() {
        blob.clear();
        encode_parts_into(
            &tensor_hdr(stage, iter, idx as u32),
            &CompressCfg::None,
            tensor,
            &[],
            &[],
            &mut blob,
        );
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
    }
    out
}

/// Exact size `encode_stage_full` produces, without encoding: three
/// 8-byte length prefixes, 48 B of `OpData` header/region framing per
/// tensor, 4 B per element (kept honest by a unit test against the real
/// encoder). This is the "what a full snapshot would have cost" side of
/// the delta accounting.
pub fn full_stage_bytes(st: &StageState) -> u64 {
    let elems = (st.params.len() + st.momentum.len() + st.second.len()) as u64;
    3 * (8 + 48) + 4 * elems
}

/// Encode one stage as a delta layer against `base`. Per tensor: a
/// sparse lossless diff (`CompressCfg::TopK`, changed indices + the exact
/// new f32 bit patterns) when strictly less than half the elements
/// changed, otherwise — or when the tensor was resized — a dense
/// replacement (`CompressCfg::None`). Bitwise-lossless either way, so
/// restore determinism is identical to a full snapshot. Also the wire
/// body of `Wire::SnapshotDelta`: workers diff against their retained
/// shadow with this exact encoding and the broker persists/applies it.
pub fn encode_stage_delta(
    stage: usize,
    iter: u32,
    base: &StageState,
    new: &StageState,
) -> Vec<u8> {
    let mut out = Vec::new();
    let mut blob = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut idx: Vec<u32> = Vec::new();
    let pairs = [
        (&base.params, &new.params),
        (&base.momentum, &new.momentum),
        (&base.second, &new.second),
    ];
    for (ti, (b, t)) in pairs.into_iter().enumerate() {
        let hdr = tensor_hdr(stage, iter, ti as u32);
        blob.clear();
        let mut sparse = b.len() == t.len();
        if sparse {
            vals.clear();
            idx.clear();
            for (i, (bv, nv)) in b.iter().zip(t.iter()).enumerate() {
                if bv.to_bits() != nv.to_bits() {
                    idx.push(i as u32);
                    vals.push(*nv);
                }
            }
            // 8 B per sparse entry (index + value) vs 4 B per dense
            // element: sparse only pays off below half the tensor.
            sparse = idx.len() * 2 < t.len();
        }
        if sparse {
            let cfg = CompressCfg::TopK { ratio: 0.0, total_len: t.len() as u32 };
            encode_parts_into(&hdr, &cfg, &vals, &idx, &[], &mut blob);
        } else {
            encode_parts_into(&hdr, &CompressCfg::None, t, &[], &[], &mut blob);
        }
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
    }
    out
}

/// Split one length-prefixed blob off the front of `buf`.
fn split_blob<'a>(stage: usize, buf: &mut &'a [u8]) -> anyhow::Result<&'a [u8]> {
    anyhow::ensure!(buf.len() >= 8, "stage {stage}: truncated checkpoint blob");
    let len = u64::from_le_bytes(buf[..8].try_into().unwrap()) as usize;
    *buf = &buf[8..];
    anyhow::ensure!(buf.len() >= len, "stage {stage}: truncated checkpoint blob");
    let (head, rest) = buf.split_at(len);
    *buf = rest;
    Ok(head)
}

fn check_ownership(stage: usize, iter: u32, idx: u32, msg: &OpData) -> anyhow::Result<()> {
    anyhow::ensure!(
        msg.src_op == stage && msg.local_iter == iter && msg.micro_batch == idx,
        "stage {stage}: checkpoint blob belongs elsewhere (op {}, iter {}, tensor {})",
        msg.src_op,
        msg.local_iter,
        msg.micro_batch
    );
    Ok(())
}

fn decode_stage(stage: usize, iter: u32, mut buf: &[u8]) -> anyhow::Result<StageState> {
    let mut tensors: Vec<Vec<f32>> = Vec::with_capacity(3);
    for idx in 0..3u32 {
        let msg = OpData::decode(split_blob(stage, &mut buf)?)?;
        check_ownership(stage, iter, idx, &msg)?;
        anyhow::ensure!(
            msg.compress == CompressCfg::None,
            "stage {stage}: base layer tensor is not dense"
        );
        tensors.push(msg.payload);
    }
    anyhow::ensure!(buf.is_empty(), "stage {stage}: trailing checkpoint bytes");
    let mut it = tensors.into_iter();
    Ok(StageState {
        params: it.next().unwrap(),
        momentum: it.next().unwrap(),
        second: it.next().unwrap(),
    })
}

/// Reconstruct one stage from a delta blob and the state it was diffed
/// against: dense tensors replace, sparse tensors scatter the exact new
/// values onto a clone of the base. Validates ownership, index bounds and
/// ascending order, so a mismatched or corrupt layer fails loudly instead
/// of silently blending states.
pub fn apply_stage_delta(
    stage: usize,
    iter: u32,
    base: &StageState,
    mut buf: &[u8],
) -> anyhow::Result<StageState> {
    let base_tensors = [&base.params, &base.momentum, &base.second];
    let mut tensors: Vec<Vec<f32>> = Vec::with_capacity(3);
    for (idx, bt) in base_tensors.into_iter().enumerate() {
        let msg = OpData::decode(split_blob(stage, &mut buf)?)?;
        check_ownership(stage, iter, idx as u32, &msg)?;
        match msg.compress {
            CompressCfg::None => tensors.push(msg.payload),
            CompressCfg::TopK { total_len, .. } => {
                anyhow::ensure!(
                    total_len as usize == bt.len(),
                    "stage {stage}: delta expects base of {total_len} elements, \
                     parent has {}",
                    bt.len()
                );
                anyhow::ensure!(
                    msg.indices.len() == msg.payload.len(),
                    "stage {stage}: delta index/value count mismatch"
                );
                let mut t = bt.clone();
                let mut prev: Option<u32> = None;
                for (&i, &v) in msg.indices.iter().zip(&msg.payload) {
                    anyhow::ensure!(
                        (i as usize) < t.len() && prev.map_or(true, |p| i > p),
                        "stage {stage}: bad delta index {i}"
                    );
                    t[i as usize] = v;
                    prev = Some(i);
                }
                tensors.push(t);
            }
            other => anyhow::bail!("stage {stage}: unexpected delta encoding {other:?}"),
        }
    }
    anyhow::ensure!(buf.is_empty(), "stage {stage}: trailing checkpoint bytes");
    let mut it = tensors.into_iter();
    Ok(StageState {
        params: it.next().unwrap(),
        momentum: it.next().unwrap(),
        second: it.next().unwrap(),
    })
}

/// Persist a checkpoint version. When `parent` names the previously saved
/// version and its materialized states, the version is written as a delta
/// layer storing only what changed since it (falling back to a base when
/// the parent is missing on disk, the stage count changed, or the delta
/// would not actually be smaller). Stage files + manifest are written
/// into a dot-tmp directory first and atomically renamed into
/// `ckpt-<iter>`, then versions beyond the newest `keep` are pruned
/// (chain-aware). The manifest is written last: a version without one is
/// never considered valid.
pub fn save(
    dir: &Path,
    ckpt: &Checkpoint,
    parent: Option<(u32, &[StageState])>,
    keep: usize,
) -> anyhow::Result<SaveInfo> {
    std::fs::create_dir_all(dir)?;
    let bytes_full: u64 = ckpt.states.iter().map(full_stage_bytes).sum();
    let full_blobs = |c: &Checkpoint| -> Vec<Vec<u8>> {
        c.states
            .iter()
            .enumerate()
            .map(|(stage, st)| encode_stage_full(stage, c.iter, st))
            .collect()
    };
    let (kind, blobs) = match parent {
        Some((pit, pstates))
            if pstates.len() == ckpt.states.len()
                && pit < ckpt.iter
                && version_dir(dir, pit).exists() =>
        {
            let blobs: Vec<Vec<u8>> = ckpt
                .states
                .iter()
                .zip(pstates)
                .enumerate()
                .map(|(stage, (st, base))| {
                    encode_stage_delta(stage, ckpt.iter, base, st)
                })
                .collect();
            let delta_bytes: u64 = blobs.iter().map(|b| b.len() as u64).sum();
            if delta_bytes < bytes_full {
                (LayerKind::Delta { parent: pit }, blobs)
            } else {
                (LayerKind::Base, full_blobs(ckpt))
            }
        }
        _ => (LayerKind::Base, full_blobs(ckpt)),
    };

    let tmp = dir.join(format!(".tmp-ckpt-{:08}", ckpt.iter));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp)?;
    }
    std::fs::create_dir_all(&tmp)?;

    let mut bytes_written = 0u64;
    let mut stage_entries: Vec<Json> = Vec::new();
    for (stage, bytes) in blobs.iter().enumerate() {
        let file = format!("stage-{stage}.bin");
        std::fs::write(tmp.join(&file), bytes)?;
        bytes_written += bytes.len() as u64;
        stage_entries.push(obj(vec![
            ("file", s(&file)),
            ("bytes", ni(bytes.len())),
            ("fnv64", s(&format!("{:016x}", fnv1a64(bytes)))),
        ]));
    }
    let mut fields = vec![
        ("format", ni(2)),
        (
            "kind",
            s(match kind {
                LayerKind::Base => "base",
                LayerKind::Delta { .. } => "delta",
            }),
        ),
        ("iter", ni(ckpt.iter as usize)),
        ("corpus_batches", ni(ckpt.corpus_batches as usize)),
        ("seed", s(&format!("{:016x}", ckpt.seed))),
        ("config", s(&ckpt.config)),
        (
            "placement",
            arr(ckpt.placement.iter().map(|&d| ni(d)).collect()),
        ),
        ("stages", arr(stage_entries)),
        ("n_stages", n(ckpt.states.len() as f64)),
    ];
    if let LayerKind::Delta { parent } = kind {
        fields.push(("parent", ni(parent as usize)));
    }
    std::fs::write(tmp.join("manifest.json"), obj(fields).dump_pretty() + "\n")?;

    let fin = version_dir(dir, ckpt.iter);
    if fin.exists() {
        std::fs::remove_dir_all(&fin)?;
    }
    std::fs::rename(&tmp, &fin)?;
    prune(dir, keep)?;
    Ok(SaveInfo { path: fin, kind, bytes_written, bytes_full })
}

/// Version iterations present on disk, oldest first (whether valid or not).
pub fn versions(dir: &Path) -> Vec<u32> {
    let mut v: Vec<u32> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_str()
                    .and_then(|name| name.strip_prefix("ckpt-").map(String::from))
                    .and_then(|it| it.parse::<u32>().ok())
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    v.sort_unstable();
    v
}

/// Parent iteration a version's manifest declares, if it is a delta layer
/// (None for base layers and unreadable manifests).
fn layer_parent(dir: &Path, iter: u32) -> Option<u32> {
    let m = Json::parse_file(&version_dir(dir, iter).join("manifest.json")).ok()?;
    if m.get("kind").as_str() != Some("delta") {
        return None;
    }
    m.get("parent").as_usize().map(|p| p as u32)
}

/// Drop old versions, keeping the newest `keep` (0 = keep everything)
/// **plus every chain ancestor a retained delta layer still depends on**:
/// retention counts versions, reachability decides deletion, so a base is
/// never removed while a kept delta needs it for reconstruction.
pub fn prune(dir: &Path, keep: usize) -> anyhow::Result<()> {
    if keep == 0 {
        return Ok(());
    }
    let vs = versions(dir);
    if vs.len() <= keep {
        return Ok(());
    }
    let mut marked: BTreeSet<u32> = vs.iter().rev().take(keep).copied().collect();
    for &v in vs.iter().rev().take(keep) {
        let mut cur = v;
        for _ in 0..MAX_CHAIN {
            match layer_parent(dir, cur) {
                Some(p) if marked.insert(p) => cur = p,
                _ => break,
            }
        }
    }
    for &iter in &vs {
        if !marked.contains(&iter) {
            let _ = std::fs::remove_dir_all(version_dir(dir, iter));
        }
    }
    Ok(())
}

/// One manifest-validated on-disk layer: metadata plus checksummed stage
/// blobs, not yet decoded.
struct Layer {
    iter: u32,
    kind: LayerKind,
    corpus_batches: u64,
    seed: u64,
    config: String,
    placement: Vec<usize>,
    stage_blobs: Vec<Vec<u8>>,
}

fn read_layer(dir: &Path, iter: u32) -> anyhow::Result<Layer> {
    let vdir = version_dir(dir, iter);
    let m = Json::parse_file(&vdir.join("manifest.json"))?;
    let format = m.req_usize("format")?;
    anyhow::ensure!(
        format == 1 || format == 2,
        "unsupported checkpoint format {format}"
    );
    anyhow::ensure!(m.req_usize("iter")? as u32 == iter, "manifest iter mismatch");
    // Format 1 predates layer kinds: every version was a base.
    let kind = match if format == 1 { "base" } else { m.req_str("kind")? } {
        "base" => LayerKind::Base,
        "delta" => LayerKind::Delta { parent: m.req_usize("parent")? as u32 },
        k => anyhow::bail!("unknown layer kind `{k}`"),
    };
    let corpus_batches = m.req_usize("corpus_batches")? as u64;
    let seed = u64::from_str_radix(m.req_str("seed")?, 16)
        .map_err(|_| anyhow::anyhow!("bad seed field"))?;
    let config = m.req_str("config")?.to_string();
    let placement = m
        .req_arr("placement")?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad placement entry")))
        .collect::<anyhow::Result<Vec<usize>>>()?;
    let mut stage_blobs = Vec::new();
    for (stage, entry) in m.req_arr("stages")?.iter().enumerate() {
        let file = entry.req_str("file")?;
        let want_bytes = entry.req_usize("bytes")?;
        let want_fnv = entry.req_str("fnv64")?;
        let bytes = std::fs::read(vdir.join(file))?;
        anyhow::ensure!(
            bytes.len() == want_bytes,
            "stage {stage}: {} bytes on disk, manifest says {want_bytes}",
            bytes.len()
        );
        let got = format!("{:016x}", fnv1a64(&bytes));
        anyhow::ensure!(
            got == want_fnv,
            "stage {stage}: checksum mismatch ({got} != {want_fnv})"
        );
        stage_blobs.push(bytes);
    }
    anyhow::ensure!(!stage_blobs.is_empty(), "checkpoint has no stages");
    Ok(Layer { iter, kind, corpus_batches, seed, config, placement, stage_blobs })
}

/// Validate + load one version, replaying its delta chain parent-first
/// from the base layer. Any missing or corrupt layer on the chain fails
/// the whole version (the caller falls back to an older one).
fn load_version(dir: &Path, iter: u32) -> anyhow::Result<Checkpoint> {
    // Collect the leaf-to-base chain of validated layers.
    let mut chain: Vec<Layer> = Vec::new();
    let mut cur = iter;
    loop {
        let layer = read_layer(dir, cur)?;
        let kind = layer.kind;
        chain.push(layer);
        match kind {
            LayerKind::Base => break,
            LayerKind::Delta { parent } => {
                anyhow::ensure!(
                    parent < cur,
                    "ckpt-{cur:08}: delta parent {parent} is not older"
                );
                anyhow::ensure!(
                    chain.len() < MAX_CHAIN,
                    "ckpt-{iter:08}: delta chain too long"
                );
                cur = parent;
            }
        }
    }
    let base = chain.pop().unwrap();
    let mut states = base
        .stage_blobs
        .iter()
        .enumerate()
        .map(|(stage, b)| decode_stage(stage, base.iter, b))
        .collect::<anyhow::Result<Vec<StageState>>>()?;
    // Replay deltas oldest-first (chain is leaf..=child-of-base).
    for layer in chain.iter().rev() {
        anyhow::ensure!(
            layer.stage_blobs.len() == states.len(),
            "ckpt-{:08}: stage count changed mid-chain",
            layer.iter
        );
        let mut next = Vec::with_capacity(states.len());
        for (stage, (blob, base_st)) in
            layer.stage_blobs.iter().zip(&states).enumerate()
        {
            next.push(apply_stage_delta(stage, layer.iter, base_st, blob)?);
        }
        states = next;
    }
    // Run metadata (loader cursor, placement) comes from the leaf.
    let leaf = chain.first().unwrap_or(&base);
    Ok(Checkpoint {
        iter,
        corpus_batches: leaf.corpus_batches,
        seed: leaf.seed,
        config: leaf.config.clone(),
        placement: leaf.placement.clone(),
        states,
    })
}

/// Load the newest *valid* checkpoint, walking past corrupt versions
/// (each skip is reported on stderr). Ok(None) when nothing loads.
pub fn load_latest(dir: &Path) -> anyhow::Result<Option<Checkpoint>> {
    load_latest_at_or_before(dir, u32::MAX)
}

/// `load_latest` restricted to versions with `iter <= max_iter`. Recovery
/// uses this so a leftover newer checkpoint (e.g. from a previous
/// completed run sharing the directory) is skipped rather than fatal —
/// for a deterministic (config, seed) pair an older boundary from either
/// run restores the identical state.
pub fn load_latest_at_or_before(
    dir: &Path,
    max_iter: u32,
) -> anyhow::Result<Option<Checkpoint>> {
    for &iter in versions(dir).iter().rev() {
        if iter > max_iter {
            continue;
        }
        match load_version(dir, iter) {
            Ok(c) => return Ok(Some(c)),
            Err(e) => eprintln!(
                "checkpoint: skipping corrupt version ckpt-{iter:08}: {e:#}"
            ),
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fusionllm-ckpt-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn ckpt(iter: u32, scale: f32) -> Checkpoint {
        Checkpoint {
            iter,
            corpus_batches: iter as u64 * 2,
            seed: 0xDEAD_BEEF,
            config: "tiny".into(),
            placement: vec![0, 1, 2, 3],
            states: (0..4)
                .map(|st| StageState {
                    params: (0..128).map(|i| scale * (st as f32 + i as f32)).collect(),
                    momentum: vec![0.5 * scale; 128],
                    second: if st == 0 { Vec::new() } else { vec![scale; 128] },
                })
                .collect(),
        }
    }

    /// `base` advanced to `iter` with `touched` params changed per stage.
    fn bump(base: &Checkpoint, iter: u32, touched: usize) -> Checkpoint {
        let mut c = base.clone();
        c.iter = iter;
        c.corpus_batches = iter as u64 * 2;
        for st in &mut c.states {
            for v in st.params.iter_mut().take(touched) {
                *v += 0.125 * iter as f32;
            }
        }
        c
    }

    fn save_chain(dir: &Path, keep: usize) -> Vec<Checkpoint> {
        // base 2, deltas 4 and 6 each chained on the previous version.
        let c2 = ckpt(2, 1.0);
        let c4 = bump(&c2, 4, 3);
        let c6 = bump(&c4, 6, 3);
        save(dir, &c2, None, keep).unwrap();
        let i4 = save(dir, &c4, Some((2, &c2.states)), keep).unwrap();
        let i6 = save(dir, &c6, Some((4, &c4.states)), keep).unwrap();
        assert_eq!(i4.kind, LayerKind::Delta { parent: 2 });
        assert_eq!(i6.kind, LayerKind::Delta { parent: 4 });
        vec![c2, c4, c6]
    }

    #[test]
    fn fnv_vector() {
        // Known FNV-1a 64 vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn full_stage_bytes_matches_encoder() {
        let c = ckpt(3, 1.0);
        for (stage, st) in c.states.iter().enumerate() {
            assert_eq!(
                encode_stage_full(stage, 3, st).len() as u64,
                full_stage_bytes(st),
                "size formula drifted from the encoder (stage {stage})"
            );
        }
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let dir = tmpdir("roundtrip");
        let c = ckpt(4, 1.25);
        let info = save(&dir, &c, None, 3).unwrap();
        assert!(info.path.ends_with("ckpt-00000004"));
        assert_eq!(info.kind, LayerKind::Base);
        assert_eq!(info.bytes_written, info.bytes_full);
        let back = load_latest(&dir).unwrap().expect("checkpoint present");
        assert_eq!(back.iter, 4);
        assert_eq!(back.corpus_batches, 8);
        assert_eq!(back.seed, 0xDEAD_BEEF);
        assert_eq!(back.config, "tiny");
        assert_eq!(back.placement, vec![0, 1, 2, 3]);
        assert_eq!(back.states.len(), 4);
        for (a, b) in c.states.iter().zip(&back.states) {
            assert_eq!(a.params, b.params);
            assert_eq!(a.momentum, b.momentum);
            assert_eq!(a.second, b.second);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn delta_roundtrip_is_bitwise_and_small() {
        let dir = tmpdir("delta");
        let cs = save_chain(&dir, 0);
        let info = save(&dir, &bump(&cs[2], 8, 3), Some((6, &cs[2].states)), 0).unwrap();
        // Sparse deltas: a 3-of-16-params change costs far less than the
        // dense snapshot (the ≥4× acceptance bar, with margin).
        assert!(
            info.bytes_written * 4 < info.bytes_full,
            "{} written vs {} full",
            info.bytes_written,
            info.bytes_full
        );
        // Chain replay reconstructs the exact bit patterns.
        let want = bump(&cs[2], 8, 3);
        let back = load_latest(&dir).unwrap().unwrap();
        assert_eq!(back.iter, 8);
        assert_eq!(back.corpus_batches, 16);
        for (a, b) in want.states.iter().zip(&back.states) {
            assert_eq!(a, b, "delta restore must be bitwise-equal");
        }
        // Every intermediate version is loadable too.
        assert_eq!(
            load_latest_at_or_before(&dir, 6).unwrap().unwrap().states,
            cs[2].states
        );
        assert_eq!(
            load_latest_at_or_before(&dir, 4).unwrap().unwrap().states,
            cs[1].states
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dense_rewrite_degrades_to_base_layer() {
        let dir = tmpdir("dense");
        let c2 = ckpt(2, 1.0);
        save(&dir, &c2, None, 0).unwrap();
        // Every element changes: a delta would not be smaller, so save
        // writes a self-contained base instead of a pointless chain link.
        let c4 = ckpt(4, 2.0);
        let info = save(&dir, &c4, Some((2, &c2.states)), 0).unwrap();
        assert_eq!(info.kind, LayerKind::Base);
        assert_eq!(load_latest(&dir).unwrap().unwrap().states, c4.states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_parent_on_disk_forces_base() {
        let dir = tmpdir("noparent");
        let c2 = ckpt(2, 1.0);
        // Parent states offered but ckpt-2 was never written.
        let info = save(&dir, &bump(&c2, 4, 2), Some((2, &c2.states)), 0).unwrap();
        assert_eq!(info.kind, LayerKind::Base);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_middle_delta_falls_back_to_chain_prefix() {
        let dir = tmpdir("middelta");
        let cs = save_chain(&dir, 0);
        // Flip a byte in the *middle* delta layer: versions 6 (whose chain
        // crosses it) and 4 (itself) are dead; the base at 2 must load.
        let victim = version_dir(&dir, 4).join("stage-1.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let back = load_latest(&dir).unwrap().expect("base chain prefix survives");
        assert_eq!(back.iter, 2);
        assert_eq!(back.states, cs[0].states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        save(&dir, &ckpt(2, 1.0), None, 3).unwrap();
        save(&dir, &ckpt(4, 2.0), None, 3).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().iter, 4);
        // Flip one byte in the newest version's last stage file.
        let victim = version_dir(&dir, 4).join("stage-3.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let back = load_latest(&dir).unwrap().expect("older version survives");
        assert_eq!(back.iter, 2, "must fall back past the corrupt version");
        assert_eq!(back.states[1].params, ckpt(2, 1.0).states[1].params);
        // A mangled manifest is also just skipped.
        std::fs::write(version_dir(&dir, 2).join("manifest.json"), b"{ nope").unwrap();
        assert!(load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn at_or_before_skips_newer_leftovers() {
        // A stale ckpt-6 from a previous completed run must not shadow
        // the restorable ckpt-2 when the current run is only at iter 3.
        let dir = tmpdir("stale");
        save(&dir, &ckpt(2, 1.0), None, 3).unwrap();
        save(&dir, &ckpt(6, 3.0), None, 3).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().iter, 6);
        let back = load_latest_at_or_before(&dir, 3).unwrap().unwrap();
        assert_eq!(back.iter, 2);
        assert!(load_latest_at_or_before(&dir, 1).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_stage_file_is_rejected() {
        let dir = tmpdir("trunc");
        save(&dir, &ckpt(1, 1.0), None, 3).unwrap();
        let victim = version_dir(&dir, 1).join("stage-0.bin");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keeps_newest_versions() {
        let dir = tmpdir("prune");
        for it in [2u32, 4, 6, 8] {
            save(&dir, &ckpt(it, it as f32), None, 3).unwrap();
        }
        assert_eq!(versions(&dir), vec![4, 6, 8], "keep=3 prunes the oldest");
        save(&dir, &ckpt(10, 1.0), None, 2).unwrap();
        assert_eq!(versions(&dir), vec![8, 10]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_never_drops_a_base_a_kept_delta_needs() {
        let dir = tmpdir("prunechain");
        let cs = save_chain(&dir, 0); // base 2 <- delta 4 <- delta 6
        // keep=1 retains only version 6 by count, but its whole ancestry
        // must survive or 6 is unloadable.
        prune(&dir, 1).unwrap();
        assert_eq!(versions(&dir), vec![2, 4, 6], "chain ancestors are pinned");
        assert_eq!(load_latest(&dir).unwrap().unwrap().states, cs[2].states);
        // A new base at 8 releases the old chain: keep=1 now really
        // drops 2/4/6.
        save(&dir, &ckpt(8, 9.0), None, 0).unwrap();
        prune(&dir, 1).unwrap();
        assert_eq!(versions(&dir), vec![8]);
        assert_eq!(load_latest(&dir).unwrap().unwrap().iter, 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn long_chain_replays_and_rebase_unpins_it() {
        let dir = tmpdir("longchain");
        let mut prev = ckpt(1, 1.0);
        save(&dir, &prev, None, 0).unwrap();
        for it in 2..=7u32 {
            let next = bump(&prev, it, 2);
            let info = save(&dir, &next, Some((prev.iter, &prev.states)), 0).unwrap();
            assert_eq!(info.kind, LayerKind::Delta { parent: prev.iter });
            prev = next;
        }
        let back = load_latest(&dir).unwrap().unwrap();
        assert_eq!(back.iter, 7);
        assert_eq!(back.states, prev.states);
        // A rebase (forced base layer) caps the chain: pruning afterwards
        // keeps only the new self-contained version.
        let rebased = bump(&prev, 8, 2);
        let info = save(&dir, &rebased, None, 0).unwrap();
        assert_eq!(info.kind, LayerKind::Base);
        prune(&dir, 1).unwrap();
        assert_eq!(versions(&dir), vec![8]);
        assert_eq!(load_latest(&dir).unwrap().unwrap().states, rebased.states);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn apply_stage_delta_validates() {
        let a = StageState { params: vec![1.0; 8], momentum: vec![], second: vec![] };
        let mut b = a.clone();
        b.params[3] = 9.0;
        let blob = encode_stage_delta(0, 5, &a, &b);
        assert_eq!(apply_stage_delta(0, 5, &a, &blob).unwrap(), b);
        // Wrong stage, wrong iter, wrong base shape all fail loudly.
        assert!(apply_stage_delta(1, 5, &a, &blob).is_err());
        assert!(apply_stage_delta(0, 6, &a, &blob).is_err());
        let short = StageState { params: vec![1.0; 2], momentum: vec![], second: vec![] };
        assert!(apply_stage_delta(0, 5, &short, &blob).is_err());
        assert!(apply_stage_delta(0, 5, &a, &blob[..blob.len() - 3]).is_err());
    }

    #[test]
    fn load_from_missing_dir_is_none() {
        let dir = tmpdir("missing");
        assert!(load_latest(&dir).unwrap().is_none());
        assert!(versions(&dir).is_empty());
    }
}
