//! Broker-side versioned checkpoints (fault tolerance, churn recovery).
//!
//! Every `--checkpoint-every` iterations the broker broadcasts
//! `Wire::Checkpoint` at an iteration boundary, collects one `StageState`
//! snapshot per stage, and persists them here: one directory per version
//! (`ckpt-<iter>`), written to a dot-tmp path and atomically renamed into
//! place, carrying a `manifest.json` with FNV-1a-64 checksums over every
//! stage file. Tensor payloads travel through the same `OpData` codec as
//! the wire hot path — checkpoints exercise the tested encode/decode path
//! instead of inventing a second serializer.
//!
//! `load_latest` walks versions newest-first and falls back past any
//! version that fails integrity (truncated file, flipped byte, bad
//! manifest), so a crash mid-write can never leave the run unrecoverable
//! as long as one older version survives.

use crate::opdag::data::{
    encode_parts_into, CompressCfg, OpData, OpDataHeader, OpDataKind,
};
use crate::util::json::{arr, n, ni, obj, s, Json};
use crate::worker::StageState;
use std::path::{Path, PathBuf};

/// Everything needed to resume a run: model state per stage plus the
/// data-loader cursor and the RNG seed that reproduces the stream.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    /// Iteration boundary this state belongs to (first iteration to run
    /// after restoring).
    pub iter: u32,
    /// Microbatches drawn from the synthetic corpus before this boundary
    /// (the data-loader cursor; restore replays the stream up to here).
    pub corpus_batches: u64,
    /// Job seed (RNG provenance — restore must verify it matches).
    pub seed: u64,
    /// Model config name the states belong to.
    pub config: String,
    /// Stage -> device placement when the checkpoint was taken
    /// (informational; recovery re-plans placement anyway).
    pub placement: Vec<usize>,
    /// Per-stage params + optimizer moments, stage order.
    pub states: Vec<StageState>,
}

/// FNV-1a 64 over a byte stream (no crypto needed — this guards against
/// torn writes and bit rot, not adversaries).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn version_dir(dir: &Path, iter: u32) -> PathBuf {
    dir.join(format!("ckpt-{iter:08}"))
}

/// Encode one stage: params / momentum / second as three length-prefixed
/// `OpData` messages (dense f32, micro_batch = tensor index). Encoded
/// from borrowed slices — no tensor copies on the way to disk.
fn encode_stage(stage: usize, iter: u32, st: &StageState) -> Vec<u8> {
    let mut out = Vec::new();
    let mut blob = Vec::new();
    for (idx, tensor) in [&st.params, &st.momentum, &st.second].into_iter().enumerate() {
        let hdr = OpDataHeader {
            src_op: stage,
            dst_op: stage,
            actual_user: stage,
            kind: OpDataKind::Activation,
            is_loss: false,
            require_grad: false,
            local_iter: iter,
            micro_batch: idx as u32,
        };
        blob.clear();
        encode_parts_into(&hdr, &CompressCfg::None, tensor, &[], &[], &mut blob);
        out.extend_from_slice(&(blob.len() as u64).to_le_bytes());
        out.extend_from_slice(&blob);
    }
    out
}

fn decode_stage(stage: usize, iter: u32, mut buf: &[u8]) -> anyhow::Result<StageState> {
    let mut tensors: Vec<Vec<f32>> = Vec::with_capacity(3);
    for idx in 0..3u32 {
        anyhow::ensure!(buf.len() >= 8, "stage {stage}: truncated checkpoint blob");
        let len = u64::from_le_bytes(buf[..8].try_into().unwrap()) as usize;
        buf = &buf[8..];
        anyhow::ensure!(buf.len() >= len, "stage {stage}: truncated checkpoint blob");
        let msg = OpData::decode(&buf[..len])?;
        anyhow::ensure!(
            msg.src_op == stage && msg.local_iter == iter && msg.micro_batch == idx,
            "stage {stage}: checkpoint blob belongs elsewhere \
             (op {}, iter {}, tensor {})",
            msg.src_op,
            msg.local_iter,
            msg.micro_batch
        );
        tensors.push(msg.payload);
        buf = &buf[len..];
    }
    anyhow::ensure!(buf.is_empty(), "stage {stage}: trailing checkpoint bytes");
    let mut it = tensors.into_iter();
    Ok(StageState {
        params: it.next().unwrap(),
        momentum: it.next().unwrap(),
        second: it.next().unwrap(),
    })
}

/// Persist a checkpoint version. Stage files + manifest are written into
/// a dot-tmp directory first and atomically renamed into `ckpt-<iter>`,
/// then versions beyond the newest `keep` are pruned. Returns the final
/// version path.
pub fn save(dir: &Path, ckpt: &Checkpoint, keep: usize) -> anyhow::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(".tmp-ckpt-{:08}", ckpt.iter));
    if tmp.exists() {
        std::fs::remove_dir_all(&tmp)?;
    }
    std::fs::create_dir_all(&tmp)?;

    let mut stage_entries: Vec<Json> = Vec::new();
    for (stage, st) in ckpt.states.iter().enumerate() {
        let bytes = encode_stage(stage, ckpt.iter, st);
        let file = format!("stage-{stage}.bin");
        std::fs::write(tmp.join(&file), &bytes)?;
        stage_entries.push(obj(vec![
            ("file", s(&file)),
            ("bytes", ni(bytes.len())),
            ("fnv64", s(&format!("{:016x}", fnv1a64(&bytes)))),
        ]));
    }
    let manifest = obj(vec![
        ("format", ni(1)),
        ("iter", ni(ckpt.iter as usize)),
        ("corpus_batches", ni(ckpt.corpus_batches as usize)),
        ("seed", s(&format!("{:016x}", ckpt.seed))),
        ("config", s(&ckpt.config)),
        (
            "placement",
            arr(ckpt.placement.iter().map(|&d| ni(d)).collect()),
        ),
        ("stages", arr(stage_entries)),
        ("n_stages", n(ckpt.states.len() as f64)),
    ]);
    // Manifest last: a version without one is never considered valid.
    std::fs::write(tmp.join("manifest.json"), manifest.dump_pretty() + "\n")?;

    let fin = version_dir(dir, ckpt.iter);
    if fin.exists() {
        std::fs::remove_dir_all(&fin)?;
    }
    std::fs::rename(&tmp, &fin)?;
    prune(dir, keep)?;
    Ok(fin)
}

/// Version iterations present on disk, oldest first (whether valid or not).
pub fn versions(dir: &Path) -> Vec<u32> {
    let mut v: Vec<u32> = match std::fs::read_dir(dir) {
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_str()
                    .and_then(|name| name.strip_prefix("ckpt-").map(String::from))
                    .and_then(|it| it.parse::<u32>().ok())
            })
            .collect(),
        Err(_) => Vec::new(),
    };
    v.sort_unstable();
    v
}

/// Drop all but the newest `keep` versions (0 = keep everything).
pub fn prune(dir: &Path, keep: usize) -> anyhow::Result<()> {
    if keep == 0 {
        return Ok(());
    }
    let vs = versions(dir);
    for &iter in vs.iter().rev().skip(keep) {
        let _ = std::fs::remove_dir_all(version_dir(dir, iter));
    }
    Ok(())
}

/// Validate + load one version directory.
fn load_version(dir: &Path, iter: u32) -> anyhow::Result<Checkpoint> {
    let vdir = version_dir(dir, iter);
    let m = Json::parse_file(&vdir.join("manifest.json"))?;
    anyhow::ensure!(m.req_usize("format")? == 1, "unsupported checkpoint format");
    anyhow::ensure!(m.req_usize("iter")? as u32 == iter, "manifest iter mismatch");
    let corpus_batches = m.req_usize("corpus_batches")? as u64;
    let seed = u64::from_str_radix(m.req_str("seed")?, 16)
        .map_err(|_| anyhow::anyhow!("bad seed field"))?;
    let config = m.req_str("config")?.to_string();
    let placement = m
        .req_arr("placement")?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad placement entry")))
        .collect::<anyhow::Result<Vec<usize>>>()?;
    let mut states = Vec::new();
    for (stage, entry) in m.req_arr("stages")?.iter().enumerate() {
        let file = entry.req_str("file")?;
        let want_bytes = entry.req_usize("bytes")?;
        let want_fnv = entry.req_str("fnv64")?;
        let bytes = std::fs::read(vdir.join(file))?;
        anyhow::ensure!(
            bytes.len() == want_bytes,
            "stage {stage}: {} bytes on disk, manifest says {want_bytes}",
            bytes.len()
        );
        let got = format!("{:016x}", fnv1a64(&bytes));
        anyhow::ensure!(
            got == want_fnv,
            "stage {stage}: checksum mismatch ({got} != {want_fnv})"
        );
        states.push(decode_stage(stage, iter, &bytes)?);
    }
    anyhow::ensure!(!states.is_empty(), "checkpoint has no stages");
    Ok(Checkpoint { iter, corpus_batches, seed, config, placement, states })
}

/// Load the newest *valid* checkpoint, walking past corrupt versions
/// (each skip is reported on stderr). Ok(None) when nothing loads.
pub fn load_latest(dir: &Path) -> anyhow::Result<Option<Checkpoint>> {
    load_latest_at_or_before(dir, u32::MAX)
}

/// `load_latest` restricted to versions with `iter <= max_iter`. Recovery
/// uses this so a leftover newer checkpoint (e.g. from a previous
/// completed run sharing the directory) is skipped rather than fatal —
/// for a deterministic (config, seed) pair an older boundary from either
/// run restores the identical state.
pub fn load_latest_at_or_before(
    dir: &Path,
    max_iter: u32,
) -> anyhow::Result<Option<Checkpoint>> {
    for &iter in versions(dir).iter().rev() {
        if iter > max_iter {
            continue;
        }
        match load_version(dir, iter) {
            Ok(c) => return Ok(Some(c)),
            Err(e) => eprintln!(
                "checkpoint: skipping corrupt version ckpt-{iter:08}: {e:#}"
            ),
        }
    }
    Ok(None)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "fusionllm-ckpt-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn ckpt(iter: u32, scale: f32) -> Checkpoint {
        Checkpoint {
            iter,
            corpus_batches: iter as u64 * 2,
            seed: 0xDEAD_BEEF,
            config: "tiny".into(),
            placement: vec![0, 1, 2, 3],
            states: (0..4)
                .map(|st| StageState {
                    params: (0..16).map(|i| scale * (st as f32 + i as f32)).collect(),
                    momentum: vec![0.5 * scale; 16],
                    second: if st == 0 { Vec::new() } else { vec![scale; 16] },
                })
                .collect(),
        }
    }

    #[test]
    fn fnv_vector() {
        // Known FNV-1a 64 vectors.
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
    }

    #[test]
    fn save_load_roundtrip_exact() {
        let dir = tmpdir("roundtrip");
        let c = ckpt(4, 1.25);
        let path = save(&dir, &c, 3).unwrap();
        assert!(path.ends_with("ckpt-00000004"));
        let back = load_latest(&dir).unwrap().expect("checkpoint present");
        assert_eq!(back.iter, 4);
        assert_eq!(back.corpus_batches, 8);
        assert_eq!(back.seed, 0xDEAD_BEEF);
        assert_eq!(back.config, "tiny");
        assert_eq!(back.placement, vec![0, 1, 2, 3]);
        assert_eq!(back.states.len(), 4);
        for (a, b) in c.states.iter().zip(&back.states) {
            assert_eq!(a.params, b.params);
            assert_eq!(a.momentum, b.momentum);
            assert_eq!(a.second, b.second);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_latest_falls_back_to_previous() {
        let dir = tmpdir("fallback");
        save(&dir, &ckpt(2, 1.0), 3).unwrap();
        save(&dir, &ckpt(4, 2.0), 3).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().iter, 4);
        // Flip one byte in the newest version's last stage file.
        let victim = version_dir(&dir, 4).join("stage-3.bin");
        let mut bytes = std::fs::read(&victim).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&victim, &bytes).unwrap();
        let back = load_latest(&dir).unwrap().expect("older version survives");
        assert_eq!(back.iter, 2, "must fall back past the corrupt version");
        assert_eq!(back.states[1].params, ckpt(2, 1.0).states[1].params);
        // A mangled manifest is also just skipped.
        std::fs::write(version_dir(&dir, 2).join("manifest.json"), b"{ nope").unwrap();
        assert!(load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn at_or_before_skips_newer_leftovers() {
        // A stale ckpt-6 from a previous completed run must not shadow
        // the restorable ckpt-2 when the current run is only at iter 3.
        let dir = tmpdir("stale");
        save(&dir, &ckpt(2, 1.0), 3).unwrap();
        save(&dir, &ckpt(6, 3.0), 3).unwrap();
        assert_eq!(load_latest(&dir).unwrap().unwrap().iter, 6);
        let back = load_latest_at_or_before(&dir, 3).unwrap().unwrap();
        assert_eq!(back.iter, 2);
        assert!(load_latest_at_or_before(&dir, 1).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_stage_file_is_rejected() {
        let dir = tmpdir("trunc");
        save(&dir, &ckpt(1, 1.0), 3).unwrap();
        let victim = version_dir(&dir, 1).join("stage-0.bin");
        let bytes = std::fs::read(&victim).unwrap();
        std::fs::write(&victim, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load_latest(&dir).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prune_keeps_newest_versions() {
        let dir = tmpdir("prune");
        for it in [2u32, 4, 6, 8] {
            save(&dir, &ckpt(it, it as f32), 3).unwrap();
        }
        assert_eq!(versions(&dir), vec![4, 6, 8], "keep=3 prunes the oldest");
        save(&dir, &ckpt(10, 1.0), 2).unwrap();
        assert_eq!(versions(&dir), vec![8, 10]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_from_missing_dir_is_none() {
        let dir = tmpdir("missing");
        assert!(load_latest(&dir).unwrap().is_none());
        assert!(versions(&dir).is_empty());
    }
}
