//! CLI subcommand implementations (thin wrappers over the library API).

use crate::broker::{self, Job};
use crate::cluster::compnode::{gpu_days_for_gpt3, gpus_to_load_gpt3, GpuModel};
use crate::cluster::{louvain::louvain, testbed};
use crate::compress::{CompressKind, CompressPlan};
use crate::cost::throughput::{dense_bytes, evaluate, PipelineParams};
use crate::opdag::builders::{transformer_chain, TransformerSpec};
use crate::pipeline::{PipelineSchedule, ScheduleKind};
use crate::simnet::{simulate_iteration, StagePlan};
use crate::util::cli::Args;
use crate::util::math::{fmt_bytes, fmt_secs};
use crate::util::table::Table;
use anyhow::Result;

/// `fusionllm testbed --testbed N [--seed S]` — Fig. 9.
pub fn testbed(args: &Args) -> Result<()> {
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    println!("{}\n", tb.summary());

    let mut t = Table::new(vec!["node", "gpu", "λ", "S(p) TFLOPS", "cluster/machine"]);
    for n in &tb.nodes {
        t.row(vec![
            n.id.to_string(),
            n.gpu.name().to_string(),
            format!("{:.3}", n.lambda),
            format!("{:.1}", n.speed_flops() / 1e12),
            format!("{}/{}", n.cluster, n.machine),
        ]);
    }
    t.print();

    // Link-class statistics (the Fig. 9 heatmap, summarized).
    println!("\nlink classes (α latency / bandwidth):");
    let mut classes: std::collections::BTreeMap<&str, Vec<(f64, f64)>> = Default::default();
    for i in 0..tb.nodes.len() {
        for j in (i + 1)..tb.nodes.len() {
            let (a, b) = (&tb.nodes[i], &tb.nodes[j]);
            let class = if a.cluster == b.cluster && a.machine == b.machine {
                "intra-machine"
            } else if a.cluster == b.cluster {
                "intra-cluster"
            } else {
                "cross-cluster"
            };
            classes
                .entry(class)
                .or_default()
                .push((tb.net.alpha(i, j), tb.net.bandwidth_bps(i, j)));
        }
    }
    let mut t = Table::new(vec!["class", "links", "α min–max", "bw min–max"]);
    for (class, links) in classes {
        let amin = links.iter().map(|l| l.0).fold(f64::MAX, f64::min);
        let amax = links.iter().map(|l| l.0).fold(0.0, f64::max);
        let bmin = links.iter().map(|l| l.1).fold(f64::MAX, f64::min);
        let bmax = links.iter().map(|l| l.1).fold(0.0, f64::max);
        t.row(vec![
            class.to_string(),
            links.len().to_string(),
            format!("{} – {}", fmt_secs(amin), fmt_secs(amax)),
            format!("{:.0} Mbps – {:.1} Gbps", bmin / 1e6, bmax / 1e9),
        ]);
    }
    t.print();

    let comm = louvain(&tb.net);
    let k = comm.iter().max().map(|&c| c + 1).unwrap_or(0);
    println!("\nLouvain discovers {k} high-bandwidth communities");
    Ok(())
}

/// `fusionllm schedule --testbed N --scheduler S` — partition + Eq. 2/3.
pub fn schedule(args: &Args) -> Result<()> {
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let name = args.str("scheduler", "opfence");
    let sched = crate::scheduler::by_name(&name)?;
    let part = sched.schedule(&dag, &tb)?;
    part.validate(&dag)?;
    let params = PipelineParams {
        n_micro: args.usize("micro", 2),
        micro_size: 3,
        include_bwd: true,
    };
    let est = evaluate(&dag, &part, &tb, params, &dense_bytes);

    println!("scheduler={name} workload=GPT2-XL testbed={}", tb.name);
    let mut t = Table::new(vec!["node", "gpu", "ops", "C_p", "R_p"]);
    for c in &est.per_node {
        let ops = dag
            .ops
            .iter()
            .filter(|o| part.node_of(o.id) == c.node)
            .count();
        t.row(vec![
            c.node.to_string(),
            tb.nodes[c.node].gpu.name().to_string(),
            ops.to_string(),
            fmt_secs(c.comp_s),
            fmt_secs(c.comm_s),
        ]);
    }
    t.print();
    println!(
        "T_lat={} T_pipe={} bottleneck={} @node{} cut-edges={}",
        fmt_secs(est.t_lat),
        fmt_secs(est.t_pipe),
        fmt_secs(est.bottleneck_s),
        est.bottleneck_node,
        part.cut_edges(&dag),
    );
    Ok(())
}

/// `fusionllm simulate --testbed N --scheduler S --compress C --ratio R`.
pub fn simulate(args: &Args) -> Result<()> {
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let sched_name = args.str("scheduler", "opfence");
    let part = crate::scheduler::by_name(&sched_name)?.schedule(&dag, &tb)?;
    let n_micro = args.usize("micro", 2);
    let kind = CompressKind::parse(&args.str("compress", "none"))?;
    let ratio = args.f64("ratio", 100.0);
    let params = PipelineParams { n_micro, micro_size: 3, include_bwd: true };
    let plan = match kind {
        CompressKind::None => CompressPlan::dense(tb.nodes.len()),
        CompressKind::AdaTopK => CompressPlan::adatopk(&dag, &part, &tb, params, ratio),
        k => CompressPlan::uniform(k, ratio, tb.nodes.len()),
    };
    let stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    let pipe_kind = ScheduleKind::parse(&args.str("pipeline", "gpipe"))?;
    let sched = PipelineSchedule::new(pipe_kind, stage_plan.n_stages(), n_micro);
    let sim = simulate_iteration(&stage_plan, &tb, &sched, &plan);
    println!(
        "testbed={} scheduler={sched_name} compress={} ratio={ratio} n_micro={n_micro}",
        tb.name,
        kind.name()
    );
    println!(
        "iteration latency = {}   wire = {}   bubble = {:.1}%",
        fmt_secs(sim.iter_s),
        fmt_bytes(sim.wire_bytes),
        100.0 * sim.bubble_frac
    );
    Ok(())
}

/// `fusionllm train --config C --steps N ...` — real PJRT training.
pub fn train(args: &Args) -> Result<()> {
    let job = Job::from_args(args)?;
    println!(
        "training config={} scheduler={} compress={} ratio={} steps={}",
        job.config,
        job.scheduler,
        job.compress.name(),
        job.ratio,
        job.iters
    );
    let report = broker::run(&job)?;
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!(
                "step {i:4}  loss {loss:.4}  wall {}  sim-geo {}",
                fmt_secs(report.wall_s[i]),
                fmt_secs(report.sim_s[i]),
            );
        }
    }
    println!(
        "final loss {:.4}; mean simulated geo-iteration {}",
        report.final_loss(),
        fmt_secs(report.mean_sim_latency())
    );
    if let Some(path) = args.opt_str("out") {
        std::fs::write(path, report.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `fusionllm economics` — Table 1.
pub fn economics(_args: &Args) -> Result<()> {
    println!("Table 1: pre-training GPT-3 (175B, 3.14e23 FLOPs) on one GPU\n");
    let mut t = Table::new(vec![
        "GPU",
        "Price",
        "TFLOPS",
        "GPU days",
        "Memory",
        "# GPUs to load GPT-3",
        "days·$ (M)",
    ]);
    for gpu in [
        GpuModel::H100,
        GpuModel::A100,
        GpuModel::Rtx4090,
        GpuModel::Rtx4080,
        GpuModel::Rtx3080,
    ] {
        let days = gpu_days_for_gpt3(gpu);
        t.row(vec![
            gpu.name().to_string(),
            format!("${:.0}", gpu.price_usd()),
            format!("{:.2}", gpu.peak_tflops()),
            format!("{:.0}", days),
            format!("{} GB", gpu.memory_bytes() >> 30),
            gpus_to_load_gpt3(gpu).to_string(),
            format!("{:.1}", days * gpu.price_usd() / 1e6),
        ]);
    }
    t.print();
    println!("\nConsumer GPUs have the better GPU-days/price ratio (§2.3) —");
    println!("the motivation for aggregating geo-distributed consumer GPUs.");
    Ok(())
}
