//! CLI subcommand implementations (thin wrappers over the library API).

use crate::broker::{self, Job};
use crate::cluster::compnode::{gpu_days_for_gpt3, gpus_to_load_gpt3, GpuModel};
use crate::cluster::{louvain::louvain, testbed};
use crate::compress::{CompressKind, CompressPlan, ValueCodec};
use crate::cost::throughput::{dense_bytes, evaluate, PipelineParams};
use crate::cost::ProfileStore;
use crate::opdag::builders::{transformer_chain, TransformerSpec};
use crate::pipeline::{PipelineSchedule, ScheduleKind};
use crate::scheduler::replan::{ReplanInput, ReplanMode, Replanner};
use crate::simnet::{simulate_iteration, simulate_iteration_with, SimOpts, StagePlan};
use crate::trainer::TrainReport;
use crate::transport::{DataPlane, TransportKind};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::math::{fmt_bytes, fmt_secs};
use crate::util::table::Table;
use crate::worker::BackendKind;
use anyhow::Result;

/// `fusionllm testbed --testbed N [--seed S]` — Fig. 9.
pub fn testbed(args: &Args) -> Result<()> {
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    println!("{}\n", tb.summary());

    let mut t = Table::new(vec!["node", "gpu", "λ", "S(p) TFLOPS", "cluster/machine"]);
    for n in &tb.nodes {
        t.row(vec![
            n.id.to_string(),
            n.gpu.name().to_string(),
            format!("{:.3}", n.lambda),
            format!("{:.1}", n.speed_flops() / 1e12),
            format!("{}/{}", n.cluster, n.machine),
        ]);
    }
    t.print();

    // Link-class statistics (the Fig. 9 heatmap, summarized).
    println!("\nlink classes (α latency / bandwidth):");
    let mut classes: std::collections::BTreeMap<&str, Vec<(f64, f64)>> = Default::default();
    for i in 0..tb.nodes.len() {
        for j in (i + 1)..tb.nodes.len() {
            let (a, b) = (&tb.nodes[i], &tb.nodes[j]);
            let class = if a.cluster == b.cluster && a.machine == b.machine {
                "intra-machine"
            } else if a.cluster == b.cluster {
                "intra-cluster"
            } else {
                "cross-cluster"
            };
            classes
                .entry(class)
                .or_default()
                .push((tb.net.alpha(i, j), tb.net.bandwidth_bps(i, j)));
        }
    }
    let mut t = Table::new(vec!["class", "links", "α min–max", "bw min–max"]);
    for (class, links) in classes {
        let amin = links.iter().map(|l| l.0).fold(f64::MAX, f64::min);
        let amax = links.iter().map(|l| l.0).fold(0.0, f64::max);
        let bmin = links.iter().map(|l| l.1).fold(f64::MAX, f64::min);
        let bmax = links.iter().map(|l| l.1).fold(0.0, f64::max);
        t.row(vec![
            class.to_string(),
            links.len().to_string(),
            format!("{} – {}", fmt_secs(amin), fmt_secs(amax)),
            format!("{:.0} Mbps – {:.1} Gbps", bmin / 1e6, bmax / 1e9),
        ]);
    }
    t.print();

    let comm = louvain(&tb.net);
    let k = comm.iter().max().map(|&c| c + 1).unwrap_or(0);
    println!("\nLouvain discovers {k} high-bandwidth communities");
    Ok(())
}

/// `fusionllm schedule --testbed N --scheduler S` — partition + Eq. 2/3.
pub fn schedule(args: &Args) -> Result<()> {
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let name = args.str("scheduler", "opfence");
    let sched = crate::scheduler::by_name(&name)?;
    let part = sched.schedule(&dag, &tb)?;
    part.validate(&dag)?;
    let params = PipelineParams {
        n_micro: args.usize("micro", 2),
        micro_size: 3,
        include_bwd: true,
    };
    let est = evaluate(&dag, &part, &tb, params, &dense_bytes);

    println!("scheduler={name} workload=GPT2-XL testbed={}", tb.name);
    let mut t = Table::new(vec!["node", "gpu", "ops", "C_p", "R_p"]);
    for c in &est.per_node {
        let ops = dag
            .ops
            .iter()
            .filter(|o| part.node_of(o.id) == c.node)
            .count();
        t.row(vec![
            c.node.to_string(),
            tb.nodes[c.node].gpu.name().to_string(),
            ops.to_string(),
            fmt_secs(c.comp_s),
            fmt_secs(c.comm_s),
        ]);
    }
    t.print();
    println!(
        "T_lat={} T_pipe={} bottleneck={} @node{} cut-edges={}",
        fmt_secs(est.t_lat),
        fmt_secs(est.t_pipe),
        fmt_secs(est.bottleneck_s),
        est.bottleneck_node,
        part.cut_edges(&dag),
    );
    Ok(())
}

/// `fusionllm simulate --testbed N --scheduler S --compress C --ratio R
///  [--pipeline gpipe|1f1b] [--slow-node I --slow-factor F
///   --replan off|advise|auto [--min-recovery X]]`.
///
/// With `--slow-node`, one device's compute runs F× slower than the
/// scheduler believes (a straggler). `--replan` feeds the slowed times
/// through the measured-profile re-planner and reports the recovered
/// throughput; `--min-recovery` turns that into a CI gate (nonzero exit
/// when static/replanned < X).
pub fn simulate(args: &Args) -> Result<()> {
    // Churn modes run a *real* (Null-backend) training pipeline through
    // the broker — heartbeats, checkpoints, death detection, failover
    // re-plan, checkpoint restore, elastic membership — and gate the
    // result. `--churn-trace` drives a full membership script
    // (kill/join/rejoin); `--kill-node` is the legacy single-kill form.
    if args.opt_str("churn-trace").is_some() {
        return simulate_churn_trace(args);
    }
    if args.opt_str("kill-node").is_some() {
        return simulate_churn(args);
    }
    let tb = testbed::by_id(args.usize("testbed", 1), args.u64("seed", 1));
    let dag = transformer_chain(&TransformerSpec::gpt2_xl());
    let sched_name = args.str("scheduler", "opfence");
    let part = crate::scheduler::by_name(&sched_name)?.schedule(&dag, &tb)?;
    let n_micro = args.usize("micro", 2);
    let kind = CompressKind::parse(&args.str("compress", "none"))?;
    let ratio = args.f64("ratio", 100.0);
    let codec = ValueCodec::parse(&args.str("wire-codec", "f32"))?;
    let params = PipelineParams { n_micro, micro_size: 3, include_bwd: true };
    let plan_for = |p: &crate::opdag::Partition, t: &crate::cluster::Testbed| match kind {
        CompressKind::None => CompressPlan::dense(t.nodes.len()).with_value_codec(codec),
        CompressKind::AdaTopK => {
            CompressPlan::adatopk_with_codec(&dag, p, t, params, ratio, codec)
        }
        k => CompressPlan::uniform(k, ratio, t.nodes.len()).with_value_codec(codec),
    };
    let plan = plan_for(&part, &tb);
    let stage_plan = StagePlan::from_partition(&dag, &part, &tb);
    let pipe_kind = ScheduleKind::parse(&args.str("pipeline", "gpipe"))?;
    let sched = PipelineSchedule::new(pipe_kind, stage_plan.n_stages(), n_micro);
    let overlap = match args.str("overlap", "on").as_str() {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("--overlap expects on|off, got `{other}`"),
    };
    let opts = if overlap { SimOpts::overlapped() } else { SimOpts::blocking() };
    let sim = simulate_iteration_with(&stage_plan, &tb, &sched, &plan, opts);
    println!(
        "testbed={} scheduler={sched_name} compress={} ratio={ratio} wire-codec={} \
         pipeline={} n_micro={n_micro} overlap={}",
        tb.name,
        kind.name(),
        codec.name(),
        pipe_kind.name(),
        if overlap { "on" } else { "off" }
    );
    println!(
        "iteration latency = {}   wire = {}   bubble = {:.1}%",
        fmt_secs(sim.iter_s),
        fmt_bytes(sim.wire_bytes),
        100.0 * sim.bubble_frac
    );
    // Predicted win from the overlapped wire pipeline on this plan.
    let blocking =
        simulate_iteration_with(&stage_plan, &tb, &sched, &plan, SimOpts::blocking());
    let overlapped =
        simulate_iteration_with(&stage_plan, &tb, &sched, &plan, SimOpts::overlapped());
    println!(
        "overlap model: blocking = {}   overlapped = {}   predicted speedup = {:.2}x",
        fmt_secs(blocking.iter_s),
        fmt_secs(overlapped.iter_s),
        blocking.iter_s / overlapped.iter_s.max(1e-12)
    );

    // ---- straggler scenario + re-planning smoke -----------------------
    let slow_node = match args.opt_str("slow-node") {
        Some(s) => s
            .parse::<usize>()
            .map_err(|_| anyhow::anyhow!("--slow-node expects a device id"))?,
        None => return Ok(()),
    };
    anyhow::ensure!(slow_node < tb.nodes.len(), "--slow-node {slow_node} out of range");
    let factor = args.f64("slow-factor", 4.0).max(1.0);

    // Ground truth: the node is `factor`× slower than believed. The
    // "measured" plan is what the profile plane would observe.
    let mut measured = stage_plan.clone();
    let mut hosts_stage = false;
    for s in 0..measured.n_stages() {
        if measured.devices[s] == slow_node {
            measured.fwd_s[s] *= factor;
            measured.bwd_s[s] *= factor;
            measured.update_s[s] *= factor;
            hosts_stage = true;
        }
    }
    anyhow::ensure!(
        hosts_stage,
        "--slow-node {slow_node} hosts no stage under scheduler `{sched_name}`"
    );
    let static_sim = simulate_iteration(&measured, &tb, &sched, &plan);
    println!(
        "straggler: node {slow_node} runs {factor}x slower -> static plan iteration = {}  \
         (was {})",
        fmt_secs(static_sim.iter_s),
        fmt_secs(sim.iter_s)
    );

    let mode = ReplanMode::parse(&args.str("replan", "off"))?;
    if mode == ReplanMode::Off {
        return Ok(());
    }
    let mut store = ProfileStore::new(measured.n_stages(), n_micro, 1.0);
    store.seed_from_plan(&measured);
    let replanner = Replanner {
        scheduler: sched_name.clone(),
        threshold: args.f64("straggler-threshold", 2.0),
        hysteresis: args.f64("replan-hysteresis", 0.10),
        min_samples: 1,
        // Simulation has no live worker chain to preserve.
        keep_stage_count: false,
    };
    let inp = ReplanInput {
        dag: &dag,
        testbed: &tb,
        part: &part,
        modeled: &stage_plan,
        store: &store,
        schedule: pipe_kind,
        n_micro,
        current_compress: &plan,
    };
    let decision = replanner.consider(&inp, &|p, t| plan_for(p, t))?;
    let d = match decision {
        None => {
            println!("re-planner: no straggler flagged / no better partition found");
            anyhow::ensure!(
                args.opt_str("min-recovery").is_none(),
                "--min-recovery set but the re-planner produced no plan"
            );
            return Ok(());
        }
        Some(d) => d,
    };
    println!(
        "re-planner [{}]: flagged stages {:?}; simulated {} -> {} (predicted), \
         migration ~{}",
        d.candidate.origin,
        d.flagged,
        fmt_secs(d.current_sim_s),
        fmt_secs(d.candidate_sim_s),
        fmt_secs(d.migration_s)
    );

    // Ground-truth evaluation of the candidate: re-derive its stage times
    // on a testbed where the slow node *really* is `factor`× slower.
    let mut tb_truth = tb.clone();
    tb_truth.nodes[slow_node].lambda =
        (tb_truth.nodes[slow_node].lambda / factor).max(1e-6);
    let cand_truth = StagePlan::from_partition(&dag, &d.candidate.partition, &tb_truth);
    let cand_sched = PipelineSchedule::new(pipe_kind, cand_truth.n_stages(), n_micro);
    let cand_plan = plan_for(&d.candidate.partition, &tb);
    let replanned = simulate_iteration(&cand_truth, &tb_truth, &cand_sched, &cand_plan);
    let recovery = static_sim.iter_s / replanned.iter_s;
    println!(
        "re-planned iteration = {}   recovery = {recovery:.2}x   (adopt: {})",
        fmt_secs(replanned.iter_s),
        if mode == ReplanMode::Auto && d.adopt { "yes" } else { "advise-only" }
    );
    if let Some(min) = args.opt_str("min-recovery") {
        let min: f64 = min
            .parse()
            .map_err(|_| anyhow::anyhow!("--min-recovery expects a number"))?;
        anyhow::ensure!(
            recovery >= min,
            "straggler recovery gate: {recovery:.2}x < required {min}x"
        );
        println!("recovery gate OK ({recovery:.2}x >= {min}x)");
    }
    Ok(())
}

/// `fusionllm simulate --kill-node N [--kill-at-iter K] [--steps I]
///  [--replan auto] [--checkpoint-every E] [--checkpoint-rebase-every R]
///  [--min-ckpt-shrink X] [--loss-tol T]` — the churn smoke / CI gate.
///
/// Runs two artifact-free (Null-backend) training jobs through the real
/// broker: an uninterrupted reference, and one where device N's worker
/// vanishes at the top of iteration K. The churn run must (a) finish all
/// requested iterations, (b) record exactly one recovery, and (c) end
/// with a loss trajectory within `--loss-tol` of the reference — the
/// checkpoint restore + data-loader rewind make the re-run deterministic.
/// With `--min-ckpt-shrink X` the run additionally gates the incremental
/// checkpoint pipeline: at least one delta layer must have been persisted
/// and the cumulative counterfactual full-snapshot bytes must be ≥ X× the
/// delta bytes actually written (read from
/// `TrainReport.checkpoint_bytes_{full,delta}`). Nonzero exit on any
/// violation.
fn simulate_churn(args: &Args) -> Result<()> {
    let kill_dev: usize = args
        .opt_str("kill-node")
        .unwrap()
        .parse()
        .map_err(|_| anyhow::anyhow!("--kill-node expects a device id"))?;
    let kill_at = args.u64("kill-at-iter", 3) as u32;
    let iters = args.usize("steps", 8);
    let replan = ReplanMode::parse(&args.str("replan", "auto"))?;
    let loss_tol = args.f64("loss-tol", 1e-5);
    anyhow::ensure!(
        (kill_at as usize) < iters,
        "--kill-at-iter {kill_at} must be < --steps {iters}"
    );

    // The Null config has 4 stages; pin them to devices 0..4 by default so
    // --kill-node maps onto a stage deterministically.
    let placement: Vec<usize> = match args.opt_str("placement") {
        Some(s) => s
            .split(',')
            .map(|v| v.parse().map_err(|_| anyhow::anyhow!("bad --placement entry `{v}`")))
            .collect::<Result<_>>()?,
        None => (0..4).collect(),
    };
    anyhow::ensure!(
        placement.contains(&kill_dev),
        "--kill-node {kill_dev} hosts no stage under placement {placement:?}"
    );

    let ckpt_dir = std::env::temp_dir().join(format!(
        "fusionllm-churn-{}-{kill_dev}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    // Transport knobs pass through: with `--transport tcp` the *churn*
    // run executes over real sockets and worker processes while the
    // clean reference stays in-process — the loss gate below then proves
    // chan ≡ tcp bitwise on top of the recovery gate.
    let parsed = Job::from_args(args)?;
    let base = Job {
        config: "sim-churn".into(),
        backend: BackendKind::Null,
        testbed: args.usize("testbed", 1),
        seed: args.u64("seed", 42),
        iters,
        n_micro: args.usize("micro", 2),
        placement: Some(placement),
        replan,
        // Crash recovery only — the Null backend's microsecond compute
        // times are too noisy for meaningful straggler detection.
        straggler_threshold: args.f64("straggler-threshold", 1e9),
        // 1 s death deadline: fast enough for a smoke, wide enough that
        // a descheduled-but-alive worker thread on a loaded CI machine is
        // not misdeclared dead.
        heartbeat_s: args.f64("heartbeat-interval", 0.025),
        heartbeat_timeout: args.u64("heartbeat-timeout", 40) as u32,
        heartbeat_grace: parsed.heartbeat_grace,
        transport: parsed.transport,
        listen: parsed.listen,
        token: parsed.token,
        workers: parsed.workers,
        pace_s: parsed.pace_s,
        data_plane: parsed.data_plane,
        checkpoint_every: args.usize("checkpoint-every", 2),
        checkpoint_rebase_every: parsed.checkpoint_rebase_every,
        checkpoint_dir: ckpt_dir.clone(),
        ..Job::default()
    };
    println!(
        "churn smoke: kill device {kill_dev} at iteration {kill_at} of {iters} \
         (checkpoint every {}, replan {}, transport {}, data plane {})",
        base.checkpoint_every,
        replan.name(),
        base.transport.name(),
        base.data_plane.name()
    );

    // The reference run is always in-process (chan): over tcp the same
    // worker pool cannot serve two broker lifetimes back-to-back.
    let clean = broker::run(&Job {
        replan: ReplanMode::Off,
        checkpoint_every: 0,
        transport: TransportKind::Chan,
        data_plane: DataPlane::Relay,
        ..base.clone()
    })?;
    let churn_result = broker::run(&Job {
        kill_device: Some(kill_dev),
        kill_at_iter: kill_at,
        ..base.clone()
    });
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let churn = churn_result?;

    print_data_plane(&churn, &base);
    print_recoveries(&churn);
    anyhow::ensure!(
        churn.losses.len() == iters,
        "churn gate: {} of {iters} iterations completed",
        churn.losses.len()
    );
    anyhow::ensure!(
        churn.recoveries.len() == 1,
        "churn gate: expected exactly one recovery, got {}",
        churn.recoveries.len()
    );
    let max_diff = clean
        .losses
        .iter()
        .zip(&churn.losses)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    println!(
        "final loss: uninterrupted {:.6} vs recovered {:.6} (max per-iter |Δ| = {max_diff:.2e})",
        clean.final_loss(),
        churn.final_loss()
    );
    anyhow::ensure!(
        max_diff <= loss_tol,
        "churn gate: recovered loss diverged by {max_diff:.2e} > tolerance {loss_tol:.2e}"
    );
    let r = &churn.recoveries[0];
    println!(
        "churn gate OK: survived the death of device {} (stage {}), lost {} iteration(s), \
         replan {} + restore {}",
        r.device,
        r.stage,
        r.iters_lost,
        fmt_secs(r.replan_s),
        fmt_secs(r.restore_s)
    );
    if churn.checkpoint_bytes_delta > 0.0 {
        println!(
            "incremental checkpoints: {} delta bytes vs {} counterfactual full bytes \
             ({:.1}x shrink)",
            fmt_bytes(churn.checkpoint_bytes_delta),
            fmt_bytes(churn.checkpoint_bytes_full),
            churn.checkpoint_bytes_full / churn.checkpoint_bytes_delta
        );
    }
    // Incremental-checkpoint gate: the report counters accumulate only
    // over versions persisted as delta layers, so any nonzero delta count
    // proves the wire/disk delta path actually ran. The checkpoint dir is
    // already deleted above — gate on the report, never the filesystem.
    let min_shrink = args.f64("min-ckpt-shrink", 0.0);
    if min_shrink > 0.0 {
        anyhow::ensure!(
            churn.checkpoint_bytes_delta > 0.0,
            "checkpoint gate: no delta layers were persisted \
             (checkpoint_bytes_delta = 0)"
        );
        anyhow::ensure!(
            churn.checkpoint_bytes_delta < churn.checkpoint_bytes_full,
            "checkpoint gate: delta bytes {:.0} not smaller than full bytes {:.0}",
            churn.checkpoint_bytes_delta,
            churn.checkpoint_bytes_full
        );
        let shrink = churn.checkpoint_bytes_full / churn.checkpoint_bytes_delta;
        anyhow::ensure!(
            shrink >= min_shrink,
            "checkpoint gate: delta shrink {shrink:.2}x < required {min_shrink}x"
        );
        println!("checkpoint gate OK ({shrink:.2}x >= {min_shrink}x)");
    }
    Ok(())
}

/// `fusionllm simulate --churn-trace FILE [--steps I] [--replan auto]
///  [--loss-tol T]` — the scripted elastic-membership smoke / CI gate.
///
/// Runs the ordered membership script (kill / join / rejoin events, see
/// `broker::churn`) against a real Null-backend broker run and gates the
/// outcome against an uninterrupted in-process reference: (a) every
/// requested iteration completes, (b) exactly one recovery per scripted
/// kill, (c) the membership events in `TrainReport.joins` match the
/// scripted admissions one-for-one, and (d) the loss trajectory is
/// bitwise-identical (default `--loss-tol 0`) — any trace whose
/// survivors can host the pipeline must not change the math. Transport
/// knobs pass through, so the same gate runs over real TCP workers in
/// CI. Nonzero exit on any violation.
fn simulate_churn_trace(args: &Args) -> Result<()> {
    let iters = args.usize("steps", 8);
    let replan = ReplanMode::parse(&args.str("replan", "auto"))?;
    let loss_tol = args.f64("loss-tol", 0.0);

    // The Null config has 4 stages; pin them to devices 0..4 by default
    // so trace events map onto stages deterministically.
    let placement: Vec<usize> = match args.opt_str("placement") {
        Some(s) => s
            .split(',')
            .map(|v| v.parse().map_err(|_| anyhow::anyhow!("bad --placement entry `{v}`")))
            .collect::<Result<_>>()?,
        None => (0..4).collect(),
    };

    let parsed = Job::from_args(args)?;
    let trace = parsed
        .effective_churn()?
        .ok_or_else(|| anyhow::anyhow!("--churn-trace file holds no events"))?;
    trace.validate(&placement)?;
    for ev in &trace.events {
        anyhow::ensure!(
            (ev.at_iter as usize) < iters,
            "churn trace: {} {} @{} is at/after the last iteration (--steps {iters})",
            ev.action.name(),
            ev.device,
            ev.at_iter
        );
    }
    let n_kills = trace.kills().count();
    let admissions: Vec<crate::broker::ChurnEvent> = trace.admissions().copied().collect();

    let ckpt_dir = std::env::temp_dir()
        .join(format!("fusionllm-trace-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let base = Job {
        config: "sim-churn".into(),
        backend: BackendKind::Null,
        testbed: args.usize("testbed", 1),
        seed: args.u64("seed", 42),
        iters,
        n_micro: args.usize("micro", 2),
        placement: Some(placement),
        replan,
        // Membership churn only — the Null backend's microsecond compute
        // times are too noisy for meaningful straggler detection.
        straggler_threshold: args.f64("straggler-threshold", 1e9),
        heartbeat_s: args.f64("heartbeat-interval", 0.025),
        heartbeat_timeout: args.u64("heartbeat-timeout", 40) as u32,
        heartbeat_grace: parsed.heartbeat_grace,
        transport: parsed.transport,
        listen: parsed.listen,
        token: parsed.token,
        workers: parsed.workers,
        pace_s: parsed.pace_s,
        data_plane: parsed.data_plane,
        checkpoint_every: args.usize("checkpoint-every", 2),
        checkpoint_rebase_every: parsed.checkpoint_rebase_every,
        checkpoint_dir: ckpt_dir.clone(),
        ..Job::default()
    };
    println!(
        "churn trace: {} event(s) ({} kill(s), {} admission(s)) over {iters} iterations \
         (checkpoint every {}, replan {}, transport {}, data plane {})",
        trace.events.len(),
        n_kills,
        admissions.len(),
        base.checkpoint_every,
        replan.name(),
        base.transport.name(),
        base.data_plane.name()
    );
    for ev in &trace.events {
        println!("  {} {} @{}", ev.action.name(), ev.device, ev.at_iter);
    }

    // The reference run is always in-process (chan), uninterrupted, and
    // replan-free: the determinism gate below says churn must not move
    // the losses at all.
    let clean = broker::run(&Job {
        replan: ReplanMode::Off,
        checkpoint_every: 0,
        transport: TransportKind::Chan,
        data_plane: DataPlane::Relay,
        ..base.clone()
    })?;
    let churn_result = broker::run(&Job { churn: Some(trace.clone()), ..base.clone() });
    let _ = std::fs::remove_dir_all(&ckpt_dir);
    let churn = churn_result?;

    print_data_plane(&churn, &base);
    print_recoveries(&churn);
    print_joins(&churn);
    anyhow::ensure!(
        churn.losses.len() == iters,
        "churn gate: {} of {iters} iterations completed",
        churn.losses.len()
    );
    anyhow::ensure!(
        churn.recoveries.len() == n_kills,
        "churn gate: expected {n_kills} recovery(ies) for {n_kills} scripted kill(s), got {}",
        churn.recoveries.len()
    );
    anyhow::ensure!(
        churn.joins.len() == admissions.len(),
        "churn gate: expected {} membership event(s), got {}",
        admissions.len(),
        churn.joins.len()
    );
    for (got, want) in churn.joins.iter().zip(&admissions) {
        anyhow::ensure!(
            got.device == want.device && got.kind == want.action.name(),
            "churn gate: membership mismatch: report says {} of device {}, \
             script says {} {} @{}",
            got.kind,
            got.device,
            want.action.name(),
            want.device,
            want.at_iter
        );
    }
    let max_diff = clean
        .losses
        .iter()
        .zip(&churn.losses)
        .map(|(a, b)| (a - b).abs() as f64)
        .fold(0.0f64, f64::max);
    println!(
        "final loss: uninterrupted {:.6} vs churned {:.6} (max per-iter |Δ| = {max_diff:.2e})",
        clean.final_loss(),
        churn.final_loss()
    );
    anyhow::ensure!(
        max_diff <= loss_tol,
        "churn gate: churned loss diverged by {max_diff:.2e} > tolerance {loss_tol:.2e}"
    );
    println!(
        "churn gate OK: survived {} kill(s) and {} admission(s) with an identical \
         loss trajectory",
        n_kills,
        admissions.len()
    );
    Ok(())
}

/// Print the data-plane byte accounting of a tcp run (shared by train and
/// the churn smokes). The CI mesh gates grep this line to assert the
/// broker relayed ~no packet bytes while peer links carried the traffic.
fn print_data_plane(report: &TrainReport, job: &Job) {
    if job.transport != TransportKind::Tcp {
        return;
    }
    println!(
        "data plane {}: broker-relayed packet bytes {:.0}; peer-direct packet bytes {:.0}",
        job.data_plane.name(),
        report.relayed_packet_bytes,
        report.peer_packet_bytes
    );
}

/// Print `TrainReport.recoveries` (shared by train and the churn smoke).
fn print_recoveries(report: &TrainReport) {
    for r in &report.recoveries {
        println!(
            "recovery [{}] @iter {}: stage {} on device {} died ({}); resumed from \
             checkpoint iter {} ({} iteration(s) lost); placement {:?} -> {:?}; \
             replan {} restore {}",
            r.origin,
            r.died_iter,
            r.stage,
            r.device,
            r.cause,
            r.resume_iter,
            r.iters_lost,
            r.from,
            r.to,
            fmt_secs(r.replan_s),
            fmt_secs(r.restore_s),
        );
    }
}

/// Print `TrainReport.joins` (shared by train and the churn-trace smoke).
fn print_joins(report: &TrainReport) {
    for j in &report.joins {
        println!(
            "join [{}] @iter {}: device {} admitted, {}; placement {:?} -> {:?}; \
             simulated {} -> {}",
            j.kind,
            j.iter,
            j.device,
            if j.adopted { "folded into the pipeline" } else { "parked as a spare" },
            j.from,
            j.to,
            fmt_secs(j.sim_before_s),
            fmt_secs(j.sim_after_s),
        );
    }
}

/// `fusionllm worker --connect HOST:PORT [--token T] [--device D]
///  [--artifacts ROOT] [--retry-secs S] [--peer-listen HOST:PORT]` — a
/// remote stage executor: one OS process hosting one pipeline stage per
/// generation, assigned by the broker over the TCP transport. With
/// `--peer-listen` the worker also binds a mesh endpoint (port 0 = any
/// free port) so `--data-plane mesh` brokers can route packet lanes
/// directly between neighboring workers.
pub fn worker(args: &Args) -> Result<()> {
    let usage = "usage: fusionllm worker --connect HOST:PORT [--token T] [--device D] \
                 [--artifacts ROOT] [--retry-secs S] [--peer-listen HOST:PORT]";
    let connect = args
        .opt_str("connect")
        .ok_or_else(|| anyhow::anyhow!(usage))?
        .to_string();
    let opts = crate::worker::WorkerOpts {
        connect,
        token: args.str("token", "fusionllm"),
        device: args
            .opt_str("device")
            .map(|s| s.parse().expect("--device expects a device id")),
        artifacts: args
            .opt_str("artifacts")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(crate::broker::job::default_artifacts_root),
        retry: std::time::Duration::from_secs_f64(args.f64("retry-secs", 10.0).max(0.0)),
        peer_listen: args.opt_str("peer-listen").map(String::from),
    };
    crate::worker::run_worker(&opts)
}

/// `fusionllm train --config C --steps N ...` — real PJRT training.
pub fn train(args: &Args) -> Result<()> {
    let job = Job::from_args(args)?;
    println!(
        "training config={} scheduler={} compress={} ratio={} pipeline={} replan={} \
         transport={} steps={}",
        job.config,
        job.scheduler,
        job.compress.name(),
        job.ratio,
        job.pipeline.name(),
        job.replan.name(),
        job.transport.name(),
        job.iters
    );
    let report = broker::run(&job)?;
    print_data_plane(&report, &job);
    for (i, loss) in report.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == report.losses.len() {
            println!(
                "step {i:4}  loss {loss:.4}  wall {}  sim-geo {}",
                fmt_secs(report.wall_s[i]),
                fmt_secs(report.sim_s[i]),
            );
        }
    }
    for ev in &report.replans {
        println!(
            "replan [{}{}] @iter {}: stages {:?} flagged; placement {:?} -> {:?}; \
             simulated {} -> {}; migration {}",
            ev.origin,
            if ev.applied { "" } else { ", advised" },
            ev.iter,
            ev.flagged,
            ev.from,
            ev.to,
            fmt_secs(ev.sim_before_s),
            fmt_secs(ev.sim_after_s),
            fmt_secs(ev.migration_s),
        );
    }
    print_recoveries(&report);
    print_joins(&report);
    println!(
        "final loss {:.4}; mean simulated geo-iteration {}; wire shrink {:.1}x; \
         replans {}; recoveries {}; joins {}",
        report.final_loss(),
        fmt_secs(report.mean_sim_latency()),
        report.wire_shrink,
        report.replans.len(),
        report.recoveries.len(),
        report.joins.len(),
    );
    if let Some(path) = args.opt_str("out") {
        std::fs::write(path, report.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Per-op delta between two `BENCH_micro_hotpath.json` files.
#[derive(Debug, Clone)]
pub struct BenchDelta {
    pub op: String,
    pub old_s: f64,
    pub new_s: f64,
    /// Median-time regression in percent (negative = got faster).
    pub regress_pct: f64,
}

/// Result of comparing a fresh bench run against the committed baseline.
#[derive(Debug, Clone, Default)]
pub struct BenchDiff {
    pub rows: Vec<BenchDelta>,
    /// Ops in the baseline missing from the new run (stale baseline —
    /// refresh it deliberately instead of losing the trajectory).
    pub missing: Vec<String>,
    /// Ops only in the new run (no baseline yet; informational).
    pub added: Vec<String>,
}

impl BenchDiff {
    /// Gate violations at the given regression budget.
    pub fn violations(&self, max_regress_pct: f64) -> Vec<String> {
        let mut v: Vec<String> = self
            .rows
            .iter()
            .filter(|r| r.regress_pct > max_regress_pct)
            .map(|r| {
                format!(
                    "`{}` regressed {:.1}% ({} -> {})",
                    r.op,
                    r.regress_pct,
                    fmt_secs(r.old_s),
                    fmt_secs(r.new_s)
                )
            })
            .collect();
        v.extend(self.missing.iter().map(|op| format!("`{op}` missing from new run")));
        v
    }
}

/// Compare two bench JSON documents (op -> {median_s, ...}). Keys starting
/// with `_` are metadata (e.g. `_threads`) and are skipped.
pub fn diff_benches(old: &Json, new: &Json) -> Result<BenchDiff> {
    let old_obj = old
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("baseline is not a JSON object"))?;
    let new_obj = new
        .as_obj()
        .ok_or_else(|| anyhow::anyhow!("new run is not a JSON object"))?;
    let mut diff = BenchDiff::default();
    for (op, entry) in old_obj {
        if op.starts_with('_') {
            continue;
        }
        let old_s = entry
            .get("median_s")
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("baseline op `{op}` has no median_s"))?;
        match new_obj.get(op) {
            None => diff.missing.push(op.clone()),
            Some(e) => {
                let new_s = e
                    .get("median_s")
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("new op `{op}` has no median_s"))?;
                let regress_pct = if old_s > 0.0 {
                    (new_s / old_s - 1.0) * 100.0
                } else if new_s > 0.0 {
                    f64::INFINITY
                } else {
                    0.0
                };
                diff.rows.push(BenchDelta { op: op.clone(), old_s, new_s, regress_pct });
            }
        }
    }
    for op in new_obj.keys() {
        if !op.starts_with('_') && !old_obj.contains_key(op) {
            diff.added.push(op.clone());
        }
    }
    Ok(diff)
}

/// `fusionllm bench-diff OLD.json NEW.json [--max-regress PCT]` — the CI
/// perf gate: nonzero exit when any op's median time regressed by more
/// than the budget (default 20%) against the committed baseline.
pub fn bench_diff(args: &Args) -> Result<()> {
    let usage = "usage: fusionllm bench-diff OLD.json NEW.json [--max-regress 20]";
    let old_path = args.positional.get(1).ok_or_else(|| anyhow::anyhow!(usage))?;
    let new_path = args.positional.get(2).ok_or_else(|| anyhow::anyhow!(usage))?;
    let max = args.f64("max-regress", 20.0);
    let old = Json::parse_file(std::path::Path::new(old_path.as_str()))?;
    let new = Json::parse_file(std::path::Path::new(new_path.as_str()))?;
    let diff = diff_benches(&old, &new)?;

    let mut t = Table::new(vec!["op", "baseline", "new", "Δ%", "gate"]);
    for r in &diff.rows {
        t.row(vec![
            r.op.clone(),
            fmt_secs(r.old_s),
            fmt_secs(r.new_s),
            format!("{:+.1}", r.regress_pct),
            if r.regress_pct > max { "FAIL".into() } else { "ok".into() },
        ]);
    }
    t.print();
    for op in &diff.added {
        println!("new op (no baseline yet): {op}");
    }
    let violations = diff.violations(max);
    if violations.is_empty() {
        println!("bench-diff OK: {} op(s) within {max}% of baseline", diff.rows.len());
        Ok(())
    } else {
        anyhow::bail!(
            "bench regression gate (max {max}%):\n  {}",
            violations.join("\n  ")
        )
    }
}

/// `fusionllm economics` — Table 1.
pub fn economics(_args: &Args) -> Result<()> {
    println!("Table 1: pre-training GPT-3 (175B, 3.14e23 FLOPs) on one GPU\n");
    let mut t = Table::new(vec![
        "GPU",
        "Price",
        "TFLOPS",
        "GPU days",
        "Memory",
        "# GPUs to load GPT-3",
        "days·$ (M)",
    ]);
    for gpu in [
        GpuModel::H100,
        GpuModel::A100,
        GpuModel::Rtx4090,
        GpuModel::Rtx4080,
        GpuModel::Rtx3080,
    ] {
        let days = gpu_days_for_gpt3(gpu);
        t.row(vec![
            gpu.name().to_string(),
            format!("${:.0}", gpu.price_usd()),
            format!("{:.2}", gpu.peak_tflops()),
            format!("{:.0}", days),
            format!("{} GB", gpu.memory_bytes() >> 30),
            gpus_to_load_gpt3(gpu).to_string(),
            format!("{:.1}", days * gpu.price_usd() / 1e6),
        ]);
    }
    t.print();
    println!("\nConsumer GPUs have the better GPU-days/price ratio (§2.3) —");
    println!("the motivation for aggregating geo-distributed consumer GPUs.");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::benchkit::bench;
    use crate::util::json::{n, obj};

    fn doc(entries: &[(&str, f64)]) -> Json {
        let mut fields: Vec<(&str, Json)> = entries
            .iter()
            .map(|&(op, m)| (op, obj(vec![("median_s", n(m)), ("iters", n(10.0))])))
            .collect();
        fields.push(("_threads", n(8.0)));
        obj(fields)
    }

    #[test]
    fn diff_flags_only_over_budget_ops() {
        let old = doc(&[("compress", 1.0), ("encode", 0.010), ("decode", 0.020)]);
        let new = doc(&[("compress", 1.15), ("encode", 0.013), ("decode", 0.019)]);
        let d = diff_benches(&old, &new).unwrap();
        assert_eq!(d.rows.len(), 3);
        assert!(d.missing.is_empty() && d.added.is_empty());
        // encode regressed 30% — only violation at a 20% budget.
        let v = d.violations(20.0);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].contains("encode"));
        // ...and none at a 40% budget.
        assert!(d.violations(40.0).is_empty());
    }

    #[test]
    fn diff_tracks_missing_and_added_ops() {
        let old = doc(&[("gone", 1.0), ("kept", 1.0)]);
        let new = doc(&[("kept", 1.0), ("fresh", 1.0)]);
        let d = diff_benches(&old, &new).unwrap();
        assert_eq!(d.missing, vec!["gone".to_string()]);
        assert_eq!(d.added, vec!["fresh".to_string()]);
        // A stale baseline is itself a gate violation.
        assert_eq!(d.violations(1000.0).len(), 1);
    }

    #[test]
    fn identical_runs_pass_the_gate() {
        let old = doc(&[("a", 0.5), ("b", 1e-9)]);
        let d = diff_benches(&old, &old.clone()).unwrap();
        assert!(d.violations(0.0).is_empty());
    }

    /// The gate must trip on a real injected `std::thread::sleep` in a
    /// benched op (the satellite's acceptance proof): the baseline is the
    /// clean closure, the "regressed" run has a 2 ms sleep injected.
    #[test]
    fn gate_trips_on_injected_sleep() {
        let work = || std::hint::black_box((0..500u64).map(|i| i * i).sum::<u64>());
        let clean = bench("hot op", 1, 5, work);
        let slowed = bench("hot op", 1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
            work()
        });
        let old = doc(&[("hot op", clean.median_s)]);
        let new = doc(&[("hot op", slowed.median_s)]);
        let d = diff_benches(&old, &new).unwrap();
        assert_eq!(
            d.violations(20.0).len(),
            1,
            "2ms sleep on a microsecond op must blow a 20% budget: {:?}",
            d.rows
        );
        // Comparing the clean run against itself stays green.
        let same = diff_benches(&old, &old.clone()).unwrap();
        assert!(same.violations(20.0).is_empty());
    }

    #[test]
    fn malformed_docs_are_rejected() {
        assert!(diff_benches(&Json::Num(3.0), &doc(&[])).is_err());
        let bad = obj(vec![("op", obj(vec![("min_s", n(1.0))]))]); // no median_s
        assert!(diff_benches(&bad, &bad.clone()).is_err());
    }
}
