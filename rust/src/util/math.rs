//! Numeric helpers shared across the coordinator: radix/quick-select for
//! Top-K thresholds, stable statistics, and unit formatting.

use crate::util::simd;
use std::sync::OnceLock;

/// IEEE-754 f32 magnitude mask: |x| is monotone in `bits & ABS_MASK`.
const ABS_MASK: u32 = 0x7FFF_FFFF;

/// Below this many elements the parallel paths (select AND gather — shared
/// so the cutover is consistent) fall back to sequential scans: thread
/// spawn/join overhead would dominate.
pub(crate) const PAR_MIN: usize = 1 << 15;

/// Worker-thread count for the wire hot path (compress + select). Reads
/// `FUSIONLLM_COMPRESS_THREADS` once, else `available_parallelism` capped
/// at 8 (stage workers already run one thread per pipeline stage, so the
/// per-message fan-out stays bounded).
pub fn compress_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("FUSIONLLM_COMPRESS_THREADS") {
            if let Ok(n) = v.trim().parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    })
}

/// Reusable buffers for `kth_largest_abs_with`: holding these per link makes
/// the steady-state threshold computation allocation-free.
#[derive(Debug, Default)]
pub struct SelectScratch {
    /// Magnitude bit patterns surviving the current radix prefix.
    cand: Vec<u32>,
    /// Spare buffer ping-ponged with `cand` during narrowing passes.
    spare: Vec<u32>,
    /// Per-thread stitch partitions for the parallel filter passes.
    parts: Vec<Vec<u32>>,
    /// Per-thread histograms for the parallel counting passes.
    hists: Vec<[usize; 256]>,
}

/// k-th largest absolute value of `xs` (1-based k) — the wire-compression
/// hot path (a threshold is computed for every cross-node message).
///
/// Radix select over the f32 bit patterns: for non-negative floats the IEEE
/// bit pattern is monotone in value, so |x| reduces to `bits & 0x7FFF_FFFF`
/// and selection proceeds byte-by-byte over histograms — streaming passes
/// and a small tail sort, no swaps. Histogram and filter passes run on
/// `compress_threads()` worker threads; see `kth_largest_abs_threads` for
/// the determinism contract.
pub fn kth_largest_abs(xs: &[f32], k: usize) -> f32 {
    let mut scratch = SelectScratch::default();
    kth_largest_abs_with(xs, k, compress_threads(), &mut scratch)
}

/// `kth_largest_abs` with an explicit thread count. The result is
/// bit-identical for every thread count: per-chunk histograms merge by
/// exact integer addition and per-thread filter partitions are stitched in
/// chunk (= index) order, so the candidate multiset never depends on the
/// chunking.
pub fn kth_largest_abs_threads(xs: &[f32], k: usize, threads: usize) -> f32 {
    let mut scratch = SelectScratch::default();
    kth_largest_abs_with(xs, k, threads, &mut scratch)
}

/// `kth_largest_abs_threads` with caller-owned scratch (allocation-free in
/// steady state once the scratch has warmed up).
pub fn kth_largest_abs_with(
    xs: &[f32],
    k: usize,
    threads: usize,
    scratch: &mut SelectScratch,
) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} len={}", xs.len());
    // Small inputs: sorting is simpler and faster.
    if xs.len() <= 512 {
        let v = &mut scratch.cand;
        v.clear();
        v.resize(xs.len(), 0);
        simd::abs_bits(xs, v);
        v.sort_unstable();
        return f32::from_bits(v[v.len() - k]);
    }
    let threads = threads.max(1).min(xs.len() / PAR_MIN + 1);

    // Multi-level radix select over the 31-bit magnitude patterns: refine
    // one byte per level, narrowing the candidate set each time. Floats
    // cluster by exponent, so a single level can leave most of the data in
    // one bucket — the later levels handle any distribution in O(n) total.
    let mut remaining = k;
    let hist = hist_f32(xs, 24, threads, &mut scratch.hists);
    let bucket = take_bucket(&hist, &mut remaining);
    let mut prefix: u32 = (bucket as u32) << 24;
    let mut prefix_mask: u32 = 0xFF << 24;
    filter_f32(xs, prefix, prefix_mask, threads, &mut scratch.parts, &mut scratch.cand);

    for shift in [16u32, 8, 0] {
        if scratch.cand.len() <= 2048 {
            // Small tail: sort and index directly.
            scratch.cand.sort_unstable();
            return f32::from_bits(scratch.cand[scratch.cand.len() - remaining]);
        }
        let hist = hist_u32(&scratch.cand, shift, threads, &mut scratch.hists);
        let bucket = take_bucket(&hist, &mut remaining);
        prefix |= (bucket as u32) << shift;
        prefix_mask |= 0xFFu32 << shift;
        if shift == 0 {
            break; // all 32 bits determined
        }
        filter_u32(
            &scratch.cand,
            prefix,
            prefix_mask,
            threads,
            &mut scratch.parts,
            &mut scratch.spare,
        );
        std::mem::swap(&mut scratch.cand, &mut scratch.spare);
    }
    f32::from_bits(prefix)
}

/// Walk buckets from the top to locate the one holding the k-th largest,
/// consuming `remaining` along the way.
fn take_bucket(hist: &[usize; 256], remaining: &mut usize) -> usize {
    let mut bucket = 255usize;
    loop {
        if hist[bucket] >= *remaining {
            return bucket;
        }
        *remaining -= hist[bucket];
        if bucket == 0 {
            return 0;
        }
        bucket -= 1;
    }
}

/// One thread's f32 histogram pass: the magnitude-bit extraction runs
/// through the SIMD `abs_bits` kernel 512 elements at a time (stack
/// buffer), the bucket counting stays scalar (data-dependent stores).
fn hist_slice_f32(xs: &[f32], shift: u32, hist: &mut [usize; 256]) {
    let mut bits = [0u32; 512];
    for c in xs.chunks(512) {
        let b = &mut bits[..c.len()];
        simd::abs_bits(c, b);
        for &v in b.iter() {
            hist[((v >> shift) & 0xFF) as usize] += 1;
        }
    }
}

fn hist_f32(xs: &[f32], shift: u32, threads: usize, hists: &mut Vec<[usize; 256]>) -> [usize; 256] {
    let mut hist = [0usize; 256];
    if threads <= 1 || xs.len() < PAR_MIN {
        hist_slice_f32(xs, shift, &mut hist);
        return hist;
    }
    let chunk = (xs.len() + threads - 1) / threads;
    let n_parts = xs.chunks(chunk).len();
    if hists.len() < n_parts {
        hists.resize(n_parts, [0usize; 256]);
    }
    std::thread::scope(|s| {
        for (slice, h) in xs.chunks(chunk).zip(hists.iter_mut()) {
            s.spawn(move || {
                h.fill(0);
                hist_slice_f32(slice, shift, h);
            });
        }
    });
    for h in hists.iter().take(n_parts) {
        for (a, b) in hist.iter_mut().zip(h.iter()) {
            *a += *b;
        }
    }
    hist
}

fn hist_u32(bits: &[u32], shift: u32, threads: usize, hists: &mut Vec<[usize; 256]>) -> [usize; 256] {
    let mut hist = [0usize; 256];
    if threads <= 1 || bits.len() < PAR_MIN {
        for &b in bits {
            hist[((b >> shift) & 0xFF) as usize] += 1;
        }
        return hist;
    }
    let chunk = (bits.len() + threads - 1) / threads;
    let n_parts = bits.chunks(chunk).len();
    if hists.len() < n_parts {
        hists.resize(n_parts, [0usize; 256]);
    }
    std::thread::scope(|s| {
        for (slice, h) in bits.chunks(chunk).zip(hists.iter_mut()) {
            s.spawn(move || {
                h.fill(0);
                for &b in slice {
                    h[((b >> shift) & 0xFF) as usize] += 1;
                }
            });
        }
    });
    for h in hists.iter().take(n_parts) {
        for (a, b) in hist.iter_mut().zip(h.iter()) {
            *a += *b;
        }
    }
    hist
}

/// Filter the magnitude patterns of `xs` matching `prefix` under `mask`
/// into `out`: per-thread partitions stitched in chunk order, so the output
/// order equals the sequential scan's for every thread count.
fn filter_f32(
    xs: &[f32],
    prefix: u32,
    mask: u32,
    threads: usize,
    parts: &mut Vec<Vec<u32>>,
    out: &mut Vec<u32>,
) {
    out.clear();
    if threads <= 1 || xs.len() < PAR_MIN {
        out.extend(xs.iter().map(|x| x.to_bits() & ABS_MASK).filter(|b| b & mask == prefix));
        return;
    }
    let chunk = (xs.len() + threads - 1) / threads;
    let n_parts = xs.chunks(chunk).len();
    if parts.len() < n_parts {
        parts.resize_with(n_parts, Vec::new);
    }
    std::thread::scope(|s| {
        for (slice, part) in xs.chunks(chunk).zip(parts.iter_mut()) {
            s.spawn(move || {
                part.clear();
                part.extend(
                    slice.iter().map(|x| x.to_bits() & ABS_MASK).filter(|b| b & mask == prefix),
                );
            });
        }
    });
    for part in parts.iter().take(n_parts) {
        out.extend_from_slice(part);
    }
}

/// `filter_f32` for an already-masked u32 candidate set.
fn filter_u32(
    bits: &[u32],
    prefix: u32,
    mask: u32,
    threads: usize,
    parts: &mut Vec<Vec<u32>>,
    out: &mut Vec<u32>,
) {
    out.clear();
    if threads <= 1 || bits.len() < PAR_MIN {
        out.extend(bits.iter().copied().filter(|b| b & mask == prefix));
        return;
    }
    let chunk = (bits.len() + threads - 1) / threads;
    let n_parts = bits.chunks(chunk).len();
    if parts.len() < n_parts {
        parts.resize_with(n_parts, Vec::new);
    }
    std::thread::scope(|s| {
        for (slice, part) in bits.chunks(chunk).zip(parts.iter_mut()) {
            s.spawn(move || {
                part.clear();
                part.extend(slice.iter().copied().filter(|b| b & mask == prefix));
            });
        }
    });
    for part in parts.iter().take(n_parts) {
        out.extend_from_slice(part);
    }
}

/// Quickselect variant kept for the §Perf ablation and as a cross-check
/// oracle in tests.
pub fn kth_largest_abs_quickselect(xs: &[f32], k: usize) -> f32 {
    assert!(k >= 1 && k <= xs.len(), "k={k} len={}", xs.len());
    let mut buf: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
    // k-th largest == (len-k)-th smallest (0-based).
    let target = buf.len() - k;
    let (mut lo, mut hi) = (0usize, buf.len() - 1);
    // Deterministic median-of-three pivoting.
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        // median of buf[lo], buf[mid], buf[hi]
        let (a, b, c) = (buf[lo], buf[mid], buf[hi]);
        let pivot = if (a <= b) == (b <= c) {
            b
        } else if (b <= a) == (a <= c) {
            a
        } else {
            c
        };
        // 3-way partition (Dutch national flag) to handle duplicates fast.
        let (mut i, mut j, mut p) = (lo, lo, hi);
        while j <= p {
            if buf[j] < pivot {
                buf.swap(i, j);
                i += 1;
                j += 1;
            } else if buf[j] > pivot {
                buf.swap(j, p);
                if p == 0 {
                    break;
                }
                p -= 1;
            } else {
                j += 1;
            }
        }
        if target < i {
            if i == 0 {
                break;
            }
            hi = i - 1;
        } else if target > p {
            lo = p + 1;
        } else {
            return pivot;
        }
    }
    buf[target.min(buf.len() - 1)]
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (copies + sorts; for reporting only).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Simple least-squares fit y = a + b·x, returns (a, b).
/// Used to fit the λ scaling factor and alpha-beta link models from
/// warm-up profiling measurements (§3.5 of the paper).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Human-readable byte count.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    format!("{v:.2} {}", UNITS[u])
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{s:.2} s")
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn kth_ref(xs: &[f32], k: usize) -> f32 {
        let mut v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        v.sort_by(|a, b| b.partial_cmp(a).unwrap());
        v[k - 1]
    }

    #[test]
    fn kth_largest_matches_sort_reference() {
        let mut rng = Rng::new(123);
        for trial in 0..50 {
            let n = 1 + rng.below(200) as usize;
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 10.0).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let got = kth_largest_abs(&xs, k);
            let want = kth_ref(&xs, k);
            assert_eq!(got, want, "trial {trial} n={n} k={k}");
            assert_eq!(kth_largest_abs_quickselect(&xs, k), want);
        }
    }

    #[test]
    fn kth_largest_radix_path_matches_reference() {
        // Force the >512 radix path with varied distributions.
        let mut rng = Rng::new(321);
        for trial in 0..20 {
            let n = 600 + rng.below(5000) as usize;
            let scale = 10f32.powi(rng.range(-6, 6) as i32);
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * scale).collect();
            for k in [1, 7, n / 100 + 1, n / 2, n] {
                let got = kth_largest_abs(&xs, k);
                let want = kth_ref(&xs, k);
                assert_eq!(got, want, "trial {trial} n={n} k={k}");
            }
        }
    }

    #[test]
    fn kth_largest_radix_with_zeros_and_duplicates() {
        let mut xs = vec![0.0f32; 1000];
        xs[10] = 3.0;
        xs[900] = -5.0;
        assert_eq!(kth_largest_abs(&xs, 1), 5.0);
        assert_eq!(kth_largest_abs(&xs, 2), 3.0);
        assert_eq!(kth_largest_abs(&xs, 3), 0.0);
        assert_eq!(kth_largest_abs(&xs, 1000), 0.0);
        let xs = vec![2.5f32; 4096];
        assert_eq!(kth_largest_abs(&xs, 1), 2.5);
        assert_eq!(kth_largest_abs(&xs, 4096), 2.5);
    }

    #[test]
    fn kth_with_duplicates() {
        let xs = vec![1.0f32; 64];
        assert_eq!(kth_largest_abs(&xs, 1), 1.0);
        assert_eq!(kth_largest_abs(&xs, 64), 1.0);
        let xs = vec![2.0, -2.0, 2.0, 1.0, -1.0];
        assert_eq!(kth_largest_abs(&xs, 3), 2.0);
        assert_eq!(kth_largest_abs(&xs, 4), 1.0);
    }

    #[test]
    fn kth_largest_parallel_is_deterministic_across_thread_counts() {
        // The parallel radix select must return bit-identical thresholds
        // for every worker count (chunked histograms merge exactly and
        // filter partitions stitch in index order).
        let mut rng = Rng::new(0x7EAD);
        for &n in &[600usize, 4096, 100_000] {
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 3.0).collect();
            for k in [1, 2, n / 100 + 1, n / 2, n] {
                let t1 = kth_largest_abs_threads(&xs, k, 1);
                let t2 = kth_largest_abs_threads(&xs, k, 2);
                let t8 = kth_largest_abs_threads(&xs, k, 8);
                assert_eq!(t1.to_bits(), t2.to_bits(), "n={n} k={k}");
                assert_eq!(t1.to_bits(), t8.to_bits(), "n={n} k={k}");
                assert_eq!(t1, kth_ref(&xs, k), "n={n} k={k}");
            }
        }
    }

    #[test]
    fn kth_largest_scratch_reuse_matches_fresh() {
        let mut rng = Rng::new(0x5C8A);
        let mut scratch = SelectScratch::default();
        for trial in 0..20 {
            let n = 600 + rng.below(4000) as usize;
            let xs: Vec<f32> = (0..n).map(|_| (rng.f32() - 0.5) * 7.0).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let with = kth_largest_abs_with(&xs, k, 4, &mut scratch);
            assert_eq!(with, kth_largest_abs(&xs, k), "trial {trial}");
        }
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn stats_sane() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!(std_dev(&xs) > 1.0 && std_dev(&xs) < 1.2);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_bytes(2048.0), "2.00 KiB");
        assert!(fmt_secs(0.5).contains("ms"));
        assert!(fmt_secs(5.0).contains("s"));
    }
}

#[cfg(test)]
mod perf_probe {
    use super::*;
    use crate::util::rng::Rng;
    #[test]
    #[ignore]
    fn breakdown() {
        let mut rng = Rng::new(7);
        let n = 3 * 1024 * 1600;
        let xs: Vec<f32> = (0..n).map(|_| rng.f32() - 0.5).collect();
        let k = n / 100;
        let t0 = std::time::Instant::now();
        for _ in 0..5 { std::hint::black_box(kth_largest_abs(&xs, k)); }
        println!("kth_largest_abs: {:?}/iter", t0.elapsed() / 5);
        let t0 = std::time::Instant::now();
        for _ in 0..5 {
            let v: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
            std::hint::black_box(v);
        }
        println!("abs copy: {:?}/iter", t0.elapsed() / 5);
    }
}
